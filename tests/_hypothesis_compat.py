"""hypothesis if installed, else a tiny deterministic fallback.

The seed environment does not ship ``hypothesis``; rather than losing the
property tests entirely, this shim implements exactly the strategy
surface the suite uses (``integers``, ``sampled_from``, ``none``,
``one_of``) and runs each ``@given`` test as a deterministic sweep of
pseudo-random draws (seeded, capped at 25 examples).  With hypothesis
installed the real library is re-exported unchanged.
"""

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import functools
    import random as _random

    HAVE_HYPOTHESIS = False
    _FALLBACK_CAP = 25

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(lambda rng: rng.choice(options))

        @staticmethod
        def none():
            return _Strategy(lambda rng: None)

        @staticmethod
        def one_of(*strats):
            return _Strategy(lambda rng: rng.choice(strats).draw(rng))

    st = _Strategies()

    def settings(max_examples=_FALLBACK_CAP, **_kwargs):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = min(
                    getattr(wrapper, "_max_examples", _FALLBACK_CAP),
                    _FALLBACK_CAP,
                )
                rng = _random.Random(0)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strats.items()}
                    fn(*args, **drawn, **kwargs)

            # pytest must not see the original parameters as fixtures
            del wrapper.__wrapped__
            return wrapper

        return deco
