"""Ragged-stripe + pipelined MLA: geometry, accounting, model, replay.

Covers the tentpole of the pipelined MLA engine at the host level (no
jax): the uneven-block stripe geometry and its NumPy oracle, the
zero-padded-bytes accounting claim, the chunked schedule's structure and
dependencies, the pipelined cost model, the simulator's overlap win, and
the op-safe three-contender dispatch decision.
"""

import math

import numpy as np
import pytest

from repro.core import napalg, perf_model as pm, simulator as sim

TPU = pm.TPU_V5E_POD


# ---------------------------------------------------------------------------
# ragged split geometry
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("total", [0, 1, 4, 37, 101, 1 << 14])
@pytest.mark.parametrize("k", [1, 2, 3, 5, 16])
def test_ragged_splits_partition(total, k):
    parts = napalg.ragged_splits(total, k)
    assert len(parts) == k
    assert sum(parts) == total
    assert max(parts) - min(parts) <= 1
    assert list(parts) == sorted(parts, reverse=True)  # larger first


@pytest.mark.parametrize("n_nodes,ppn,elems", [(5, 3, 37), (3, 5, 41), (14, 4, 999)])
def test_stripe_geometry_partitions_exactly(n_nodes, ppn, elems):
    stripes, blocks = napalg.mla_stripe_geometry(n_nodes, ppn, elems)
    assert sum(stripes) == elems
    for sr, bl in zip(stripes, blocks):
        assert sum(bl) == sr
        assert len(bl) == n_nodes


# ---------------------------------------------------------------------------
# NumPy oracle: ragged (and chunked) MLA stripes reduce exactly
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("op", ["sum", "max", "min"])
@pytest.mark.parametrize(
    "n_nodes,ppn",
    [(1, 4), (3, 1), (3, 3), (5, 3), (6, 1), (6, 4), (4, 4), (14, 4)],
)
@pytest.mark.parametrize("elems", [1, 5, 37, 101])
def test_mla_oracle_matches_reduction(n_nodes, ppn, elems, op):
    rng = np.random.default_rng(n_nodes * 1000 + ppn * 10 + elems)
    values = rng.normal(size=(n_nodes * ppn, elems))
    for chunks in [1, 2, 3]:
        got = napalg.simulate_mla_allreduce(
            n_nodes, ppn, values, op=op, chunks=chunks
        )
        ref = {"sum": np.sum, "max": np.max, "min": np.min}[op](
            values, axis=0
        )
        np.testing.assert_allclose(
            got, np.broadcast_to(ref, values.shape), rtol=1e-12, atol=1e-12
        )


# ---------------------------------------------------------------------------
# the tentpole byte claim: zero padded bytes cross the slow domain
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "n_nodes,ppn,elems",
    [(5, 3, 37), (3, 5, 41), (6, 4, 101), (14, 4, 1000), (3, 3, 7), (7, 2, 13)],
)
def test_ragged_accounting_equals_uneven_lower_bound(n_nodes, ppn, elems):
    itemsize = 4.0
    s = elems * itemsize
    sched = napalg.build_mla_schedule(n_nodes, ppn, elems)
    got = sched.max_internode_bytes_per_chip(s)
    want = napalg.mla_internode_lower_bound(n_nodes, ppn, elems) * itemsize
    assert got == pytest.approx(want)
    # strictly below what pad-to-divisible striping would ship: the padded
    # stripe is ceil(elems/ppn) elements and its padded inter blocks are
    # ceil(stripe/n) each, all of which cross the slow domain
    padded_stripe = math.ceil(elems / ppn)
    padded = 2.0 * math.ceil(padded_stripe / n_nodes) * (n_nodes - 1) * itemsize
    assert got <= padded + 1e-9


def test_ragged_accounting_matches_even_ideal_when_divisible():
    # divisible payloads: ragged == even == 2*(s/ppn)*(n-1)/n exactly
    n_nodes, ppn, elems = 4, 4, 1 << 10
    s = float(elems * 4)
    ragged = napalg.build_mla_schedule(n_nodes, ppn, elems)
    even = napalg.build_mla_schedule(n_nodes, ppn)
    want = 2.0 * (s / ppn) * (n_nodes - 1) / n_nodes
    assert ragged.max_internode_bytes_per_chip(s) == pytest.approx(want)
    assert even.max_internode_bytes_per_chip(s) == pytest.approx(want)


# ---------------------------------------------------------------------------
# pipelined schedule structure: chunks, deps, byte conservation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_nodes,ppn", [(4, 4), (16, 16), (5, 3)])
@pytest.mark.parametrize("chunks", [1, 2, 4])
def test_pipelined_schedule_structure(n_nodes, ppn, chunks):
    sched = napalg.build_mla_pipelined_schedule(n_nodes, ppn, chunks)
    assert sched.kind == "mla_pipelined"
    assert sched.chunks == chunks
    seen_chunks = {st.chunk for st in sched.steps}
    assert seen_chunks == set(range(chunks))
    # dep chains: each step's dependency is an earlier step of the SAME
    # chunk (cross-chunk order is left to port contention — the overlap)
    last = {}
    for i, st in enumerate(sched.steps):
        assert st.dep < i
        assert st.dep == last.get(st.chunk, -1)
        if st.dep >= 0:
            assert sched.steps[st.dep].chunk == st.chunk
        last[st.chunk] = i
    # per-chunk step count matches the unpipelined schedule
    base = napalg.build_mla_schedule(n_nodes, ppn)
    for c in range(chunks):
        assert sum(1 for st in sched.steps if st.chunk == c) == len(base.steps)


@pytest.mark.parametrize("n_nodes,ppn", [(4, 4), (16, 16), (8, 16)])
def test_pipelining_conserves_bytes(n_nodes, ppn):
    """Chunking must not change the total inter-node bytes (even split)."""
    s = float(1 << 20)
    base = napalg.build_mla_schedule(n_nodes, ppn).max_internode_bytes_per_chip(s)
    for chunks in [2, 3, 8]:
        pip = napalg.build_mla_pipelined_schedule(n_nodes, ppn, chunks)
        assert pip.max_internode_bytes_per_chip(s) == pytest.approx(base)


def test_pipelined_ragged_bytes_are_sum_of_chunk_bounds():
    """Ragged chunking re-derives uneven blocks per chunk; the per-chip
    total is exactly the sum of the per-chunk uneven-block bounds."""
    n_nodes, ppn, elems, chunks = 5, 3, 37, 3
    itemsize = 4.0
    sched = napalg.build_mla_pipelined_schedule(n_nodes, ppn, chunks, elems)
    sends = np.zeros(n_nodes * ppn)
    for ce in napalg.ragged_splits(elems, chunks):
        stripes, blocks = napalg.mla_stripe_geometry(n_nodes, ppn, ce)
        for j in range(n_nodes):
            for r in range(ppn):
                sends[j * ppn + r] += 2 * (stripes[r] - blocks[r][j])
    got = sched.max_internode_bytes_per_chip(elems * itemsize)
    assert got == pytest.approx(sends.max() * itemsize)


# ---------------------------------------------------------------------------
# pipelined cost model
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("params", [pm.BLUE_WATERS, pm.TPU_V5E_POD])
@pytest.mark.parametrize("n_nodes,ppn", [(16, 16), (8, 16), (5, 3)])
def test_cost_mla_pipelined_chunk1_is_cost_mla(params, n_nodes, ppn):
    for s in [8.0, float(1 << 20), float(16 << 20)]:
        assert pm.cost_mla_pipelined(
            s, n_nodes, ppn, params, chunks=1
        ) == pytest.approx(pm.cost_mla(s, n_nodes, ppn, params))


def test_optimal_chunks_scale_with_payload():
    """Small payloads must not pipeline (alpha bill); huge ones must."""
    assert pm.optimal_pipeline_chunks(8.0, 16, 16, TPU) == 1
    assert pm.optimal_pipeline_chunks(float(1 << 12), 16, 16, TPU) == 1
    big = pm.optimal_pipeline_chunks(float(64 << 20), 16, 16, TPU)
    assert big > 1
    # degenerate grids never pipeline (no second domain to overlap)
    assert pm.optimal_pipeline_chunks(float(64 << 20), 1, 16, TPU) == 1
    assert pm.optimal_pipeline_chunks(float(64 << 20), 16, 1, TPU) == 1


def test_pipelined_cost_never_worse_than_mla():
    for n_nodes, ppn in [(16, 16), (64, 16), (4, 4)]:
        for s in [8.0, float(1 << 20), float(16 << 20), float(256 << 20)]:
            assert pm.cost_mla_pipelined(s, n_nodes, ppn, TPU) <= (
                pm.cost_mla(s, n_nodes, ppn, TPU) * (1 + 1e-12)
            )


def test_crossover_three_contenders_ordered():
    """The pipelined contender can only move the NAP↔large crossover
    down (it lower-bounds plain MLA), so the three-regime dispatch is
    consistent: nap below, mla just above, pipelined for huge payloads."""
    for n_nodes, ppn in [(16, 16), (8, 16)]:
        xo_mla = pm.crossover_bytes(n_nodes, ppn, TPU, large="mla")
        xo_pip = pm.crossover_bytes(n_nodes, ppn, TPU, large="mla_pipelined")
        assert xo_pip <= xo_mla * 1.01


# ---------------------------------------------------------------------------
# simulator: the overlap win (acceptance criterion)
# ---------------------------------------------------------------------------


def test_simulated_pipelined_never_slower_from_1mib_16x16():
    """Acceptance: pipelined MLA <= non-pipelined MLA wall-time for
    payloads >= 1 MiB on a 16x16 grid (model-chosen depth)."""
    for s in [1 << 20, 2 << 20, 4 << 20, 16 << 20, 64 << 20]:
        t_mla = sim.simulate_algorithm("mla", 16, 16, float(s), TPU)
        t_pip = sim.simulate_algorithm("mla_pipelined", 16, 16, float(s), TPU)
        assert t_pip <= t_mla * (1 + 1e-9), (s, t_pip, t_mla)


def test_simulated_overlap_win_is_real():
    """For payloads past the chunking threshold the replayed clock skew
    must show a strict win, and deeper-than-model pipelining must not
    mysteriously beat the model's pick by much (sanity of the model)."""
    s = float(16 << 20)
    c_star = pm.optimal_pipeline_chunks(s, 16, 16, TPU)
    assert c_star > 1
    t1 = sim.simulate_algorithm("mla_pipelined", 16, 16, s, TPU, chunks=1)
    t_star = sim.simulate_algorithm(
        "mla_pipelined", 16, 16, s, TPU, chunks=c_star
    )
    assert t_star < t1 * 0.95  # >= 5% simulated overlap win at 16 MiB
    # model and replay agree on the same order of magnitude
    t_model = pm.cost_mla_pipelined(s, 16, 16, TPU, chunks=c_star)
    assert 0.2 < t_star / t_model < 5.0


def test_simulated_chunk1_replay_matches_unchunked():
    """The chunked replayer with C=1 must agree with the plain P2P replay
    (same costs, data deps serialize identically)."""
    for s in [8.0, float(1 << 16), float(1 << 22)]:
        a = sim.simulate_algorithm("mla", 16, 16, s, TPU)
        b = sim.simulate_algorithm("mla_pipelined", 16, 16, s, TPU, chunks=1)
        assert b == pytest.approx(a, rel=1e-9)


def test_ragged_bytes_via_simulator_api():
    got = sim.internode_bytes_per_chip("mla", 5, 3, 37 * 4.0, elems=37)
    want = napalg.mla_internode_lower_bound(5, 3, 37) * 4.0
    assert got == pytest.approx(want)


# ---------------------------------------------------------------------------
# op-safe three-contender dispatch (host-side decision logic)
# ---------------------------------------------------------------------------


def test_select_algorithm_three_contenders():
    from repro.core import collectives

    n_nodes, ppn = 16, 16
    xo = collectives.auto_crossover_bytes(n_nodes, ppn)
    assert collectives.select_algorithm(int(xo) - 8, n_nodes, ppn) == "nap"
    assert collectives.select_algorithm(int(xo) + 8, n_nodes, ppn) == "mla"
    assert (
        collectives.select_algorithm(64 << 20, n_nodes, ppn)
        == "mla_pipelined"
    )


@pytest.mark.parametrize("op", ["sum", "max", "min"])
def test_select_algorithm_op_aware(op):
    """Every registered op must dispatch to an engine that supports it on
    every regime — the max/min-above-crossover crash regression."""
    from repro.core import collectives

    for n_nodes, ppn in [(4, 4), (5, 3), (16, 16)]:
        for nbytes in [8, 1 << 16, 64 << 20]:
            algo = collectives.select_algorithm(
                nbytes, n_nodes, ppn, op=op
            )
            assert algo in ("nap", "mla", "mla_pipelined")
            if algo in ("mla", "mla_pipelined"):
                assert op in collectives._MLA_OPS


def test_select_algorithm_degenerate_grids_both_threshold_modes():
    """psum for n<=1 and RS+AG (mla) for ppn==1 — identically under the
    modeled crossover and a fixed threshold (the ppn==1 ValueError
    regression)."""
    from repro.core import collectives

    for thresh in [None, 2048]:
        kw = {"small_threshold_bytes": thresh}
        assert collectives.select_algorithm(8, 1, 16, **kw) == "psum"
        assert collectives.select_algorithm(1 << 30, 1, 16, **kw) == "psum"
        for nbytes in [8, 1 << 20]:
            algo = collectives.select_algorithm(nbytes, 6, 1, **kw)
            assert algo in ("mla", "mla_pipelined")  # never NAP: ppn == 1
    # fixed threshold still honours the NAP/MLA split on healthy grids
    assert (
        collectives.select_algorithm(8, 4, 4, small_threshold_bytes=2048)
        == "nap"
    )
    assert (
        collectives.select_algorithm(4096, 4, 4, small_threshold_bytes=2048)
        == "mla"
    )
