"""SPMD jaxpr lint coverage: real lowerings pass, mutants fail.

Mirrors ``test_schedule_verifier`` one layer down the proof chain:

* **sweep** — every registered engine's *executed lowering* lints clean
  via :func:`repro.core.comm.lint_lowering` (which also closes the
  byte-accounting loop against the schedule-declared bound);
* **mutation** — each rule family fires on a deliberately broken
  program (collective under a rank-varying predicate, asymmetric cond
  branches, sub-f32 cross-node accumulation, widened wire words,
  inflated byte bound, donated-buffer reuse): no vacuous passes, each
  paired with a clean twin;
* **property** — randomly generated *uniform* control-flow programs
  never produce a false positive;
* **integration** — the lint-on-register gate rejects a broken engine
  registered with ``verify=False`` and rolls the registry back.
"""

import jax
import jax.numpy as jnp
import pytest
from jax import lax
from _hypothesis_compat import given, settings, st

from repro.analysis import spmd_lint
from repro.core import comm
from repro.kernels import transport

AXIS_ENV = [("pod", 2), ("data", 2)]
TOPO_KW = dict(
    axis_env=AXIS_ENV, inter_axes=("pod",), intra_axes=("data",)
)


def _lint(fn, *args, **kw):
    merged = {**TOPO_KW, **kw}
    return spmd_lint.lint_traced(fn, *args, **merged)


def _rules(report):
    return {v.rule for v in report.violations}


# ---------------------------------------------------------------------------
# sweep: every registered engine's lowering lints clean (bytes included)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("key", sorted(comm.registered_engines()))
def test_engine_lowering_lints_clean(key):
    _collective, name = key.split(":", 1)
    spec = comm.find_engine(name)
    n = max(2, spec.min_nodes)
    p = max(2, spec.min_ppn)
    report = comm.lint_lowering(
        name, n_nodes=n, ppn=p, raise_on_violation=True
    )
    assert report.ok
    assert report.collectives > 0


@pytest.mark.parametrize("name", ["nap", "mla", "rabenseifner", "psum"])
def test_engine_lowering_lints_clean_bf16(name):
    report = comm.lint_lowering(
        name, n_nodes=3, ppn=2, dtype="bfloat16", raise_on_violation=True
    )
    assert report.ok


def test_scheduled_engine_bytes_match_declared():
    """The byte-accounting loop actually closes: the report carries both
    the jaxpr-recomputed and the schedule-declared figures."""
    report = comm.lint_lowering("nap", n_nodes=3, ppn=2)
    assert report.declared_bytes is not None
    lo, hi = report.declared_bytes
    assert lo <= report.internode_bytes_per_chip <= hi


# ---------------------------------------------------------------------------
# mutation: collective-uniformity (the static hang detector)
# ---------------------------------------------------------------------------


def test_collective_under_rank_varying_cond_fires():
    def bad(x):
        pred = lax.axis_index("pod") == 0
        return lax.cond(
            pred,
            lambda v: lax.psum(v, ("pod", "data")),
            lambda v: lax.psum(v, ("pod", "data")) * 0.0,
            x,
        )

    report = _lint(bad, jnp.zeros((8,), jnp.float32))
    assert "collective-uniformity" in _rules(report)


def test_collective_under_rank_varying_while_fires():
    def bad(x):
        def cond(c):
            return lax.axis_index("pod") < 1

        def body(c):
            return lax.psum(c, "pod")

        return lax.while_loop(cond, body, x)

    report = _lint(bad, jnp.zeros((8,), jnp.float32))
    assert "collective-uniformity" in _rules(report)


def test_collective_under_uniform_cond_is_clean():
    def good(x):
        # pred derives from a whole-group reduction: provably uniform
        agreed = lax.psum(x, ("pod", "data"))
        pred = jnp.sum(agreed) > 0.0
        return lax.cond(
            pred,
            lambda v: lax.psum(v, "pod") + 1.0,
            lambda v: lax.psum(v, "pod") - 1.0,
            agreed,
        )

    report = _lint(good, jnp.zeros((8,), jnp.float32))
    assert report.ok, report.violations


# ---------------------------------------------------------------------------
# mutation: axis discipline
# ---------------------------------------------------------------------------


def test_asymmetric_cond_branches_fire():
    def bad(x):
        agreed = lax.psum(x, ("pod", "data"))  # pred itself is uniform
        pred = jnp.sum(agreed) > 0.0
        return lax.cond(
            pred,
            lambda v: lax.psum(v, "pod"),  # collective in one branch
            lambda v: v * 2.0,  # ... and not the other
            agreed,
        )

    report = _lint(bad, jnp.zeros((8,), jnp.float32))
    assert "axis-discipline" in _rules(report)


def test_unbound_axis_fires():
    """A collective over an axis the declared topology doesn't know —
    jax needs it in the trace env, the lint holds it against the
    *topology* under analysis."""

    def bad(x):
        return lax.psum(x, "model")

    closed = jax.make_jaxpr(
        bad, axis_env=AXIS_ENV + [("model", 2)]
    )(jnp.zeros((8,), jnp.float32))
    report = spmd_lint.lint_jaxpr(
        closed,
        axis_sizes=dict(AXIS_ENV),
        inter_axes=("pod",),
        intra_axes=("data",),
    )
    assert "axis-discipline" in _rules(report)


def test_shard_map_shadowing_fires():
    """A shard_map over axis names already bound by the trace-time axis
    env is shadowing; the same program linted as a mesh-level trace
    (``axes_bound_at_root=False``) is the legitimate first binding."""
    from jax.sharding import AbstractMesh

    from repro import compat

    # AbstractMesh traces on any device count — the lint only ever sees
    # the jaxpr, never a device
    mesh = AbstractMesh((("pod", 2), ("data", 4)))
    inner = compat.shard_map(
        lambda v: lax.psum(v, ("pod", "data")),
        mesh=mesh,
        in_specs=jax.sharding.PartitionSpec("data"),
        out_specs=jax.sharding.PartitionSpec(),
        check_vma=False,
    )
    x = jnp.zeros((8,), jnp.float32)

    closed = jax.make_jaxpr(inner)(x)
    shadowed = spmd_lint.lint_jaxpr(
        closed,
        axis_sizes={"pod": 2, "data": 4},
        inter_axes=("pod",),
        intra_axes=("data",),
    )
    assert "axis-discipline" in _rules(shadowed)

    mesh_level = spmd_lint.lint_jaxpr(
        closed,
        axis_sizes={"pod": 2, "data": 4},
        inter_axes=("pod",),
        intra_axes=("data",),
        axes_bound_at_root=False,
    )
    assert mesh_level.ok, mesh_level.violations


# ---------------------------------------------------------------------------
# mutation: numerics flow
# ---------------------------------------------------------------------------


def test_bf16_psum_over_inter_fires():
    def bad(x):
        return lax.psum(x, "pod")

    report = _lint(bad, jnp.zeros((8,), jnp.bfloat16))
    assert "numerics-flow" in _rules(report)


def test_bf16_psum_upcast_is_clean():
    def good(x):
        return lax.psum(x.astype(jnp.float32), "pod").astype(jnp.bfloat16)

    report = _lint(good, jnp.zeros((8,), jnp.bfloat16))
    assert report.ok, report.violations


def test_bf16_fold_of_received_value_fires():
    def bad(x):
        recv = lax.ppermute(x, "pod", [(0, 1), (1, 0)])
        return x + recv  # bf16 accumulation of a cross-node value

    report = _lint(bad, jnp.zeros((8,), jnp.bfloat16))
    assert "numerics-flow" in _rules(report)


def test_f32_fold_of_received_value_is_clean():
    def good(x):
        recv = lax.ppermute(x, "pod", [(0, 1), (1, 0)])
        acc = x.astype(jnp.float32) + recv.astype(jnp.float32)
        return acc.astype(jnp.bfloat16)

    report = _lint(good, jnp.zeros((8,), jnp.bfloat16))
    assert report.ok, report.violations


def test_widened_wire_words_fire():
    """Packed wire words cast up to s32 before the collective: the wire
    moves 4x the declared width."""

    def bad(x):
        scales = jnp.max(jnp.abs(x)).reshape(1) / 127.0
        wire = transport.quantize_pack(
            x, scales, offsets=(0,), bits=8
        )
        wide = wire.astype(jnp.int32)
        return lax.ppermute(wide, "pod", [(0, 1), (1, 0)])

    report = _lint(bad, jnp.zeros((1, 256), jnp.float32))
    assert "numerics-flow" in _rules(report)


def test_packed_wire_words_are_clean():
    def good(x):
        scales = jnp.max(jnp.abs(x)).reshape(1) / 127.0
        wire = transport.quantize_pack(x, scales, offsets=(0,), bits=8)
        return lax.ppermute(wire, "pod", [(0, 1), (1, 0)])

    report = _lint(good, jnp.zeros((1, 256), jnp.float32))
    assert report.ok, report.violations


def test_undominated_scale_fires():
    def bad(x):
        scales = x[0, :1] + 1.0  # no max-abs ancestry
        return transport.quantize_pack(x, scales, offsets=(0,), bits=8)

    report = _lint(bad, jnp.zeros((1, 256), jnp.float32))
    assert "numerics-flow" in _rules(report)


# ---------------------------------------------------------------------------
# mutation: byte accounting
# ---------------------------------------------------------------------------


def _psum_pod(x):
    return lax.psum(x, "pod")


def test_byte_accounting_equality_holds():
    # psum of 8 f32 over 'pod' (2 nodes, 2 chips/node): every chip
    # exchanges 2 * (32 bytes / group of 2) with its 1 cross-node peer
    report = _lint(
        _psum_pod,
        jnp.zeros((8,), jnp.float32),
        declared_internode_bytes=32.0,
    )
    assert report.ok, report.violations
    assert report.internode_bytes_per_chip == 32.0


def test_inflated_declared_bound_fires():
    report = _lint(
        _psum_pod,
        jnp.zeros((8,), jnp.float32),
        declared_internode_bytes=1.0,
    )
    assert "byte-accounting" in _rules(report)


# ---------------------------------------------------------------------------
# mutation: alias-donation
# ---------------------------------------------------------------------------


def test_donated_buffer_reuse_fires():
    def bad(x):
        scales = jnp.max(jnp.abs(x)).reshape(1) / 127.0
        wire = transport.quantize_pack(
            x, scales, offsets=(0,), bits=8, donate_input=True
        )
        # the donated payload is read again after the call
        return jnp.sum(wire.astype(jnp.float32)) + jnp.sum(x)

    report = _lint(bad, jnp.zeros((1, 256), jnp.float32))
    assert "alias-donation" in _rules(report)


def test_donated_buffer_returned_fires():
    def bad(x):
        scales = jnp.max(jnp.abs(x)).reshape(1) / 127.0
        wire = transport.quantize_pack(
            x, scales, offsets=(0,), bits=8, donate_input=True
        )
        return wire, x  # donated payload escapes as an output

    report = _lint(bad, jnp.zeros((1, 256), jnp.float32))
    assert "alias-donation" in _rules(report)


def test_donation_of_dead_buffer_is_clean():
    def good(x):
        scales = jnp.max(jnp.abs(x)).reshape(1) / 127.0
        return transport.quantize_pack(
            x, scales, offsets=(0,), bits=8, donate_input=True
        )

    report = _lint(good, jnp.zeros((1, 256), jnp.float32))
    assert report.ok, report.violations


# ---------------------------------------------------------------------------
# property: uniform control flow never false-positives
# ---------------------------------------------------------------------------


@settings(max_examples=20)
@given(
    depth=st.integers(1, 3),
    coll=st.sampled_from(["psum", "pmax", "pmin"]),
    wrap=st.sampled_from(["plain", "cond", "scan"]),
    axes=st.sampled_from([("pod",), ("pod", "data")]),
)
def test_uniform_programs_lint_clean(depth, coll, wrap, axes):
    reduce_ = getattr(lax, coll)

    def step(v):
        return reduce_(v, axes)

    def prog(x):
        y = lax.psum(x, ("pod", "data"))  # uniformize once up front
        for _ in range(depth):
            if wrap == "cond":
                pred = jnp.sum(y) > 0.0
                y = lax.cond(
                    pred,
                    lambda v: step(v) + 1.0,
                    lambda v: step(v) - 1.0,
                    y,
                )
            elif wrap == "scan":
                y, _ = lax.scan(
                    lambda c, _x: (step(c), None), y, None, length=2
                )
            else:
                y = step(y)
        return y

    report = _lint(prog, jnp.zeros((8,), jnp.float32))
    assert report.ok, (depth, coll, wrap, axes, report.violations)


# ---------------------------------------------------------------------------
# integration: the lint-on-register gate
# ---------------------------------------------------------------------------


def test_register_gate_rejects_unlintable_engine():
    """An engine whose lowering hides a collective under a rank-varying
    predicate is rejected at registration even with ``verify=False``
    (it has no schedule to verify — but it has a lowering to prove),
    and the registry is rolled back."""
    name = "bad_spmd_lint_engine"

    def bad_execute(x, *, topology, op="sum", pipeline_chunks=1):
        pred = lax.axis_index(topology.inter_axes[0]) == 0
        return lax.cond(
            pred,
            lambda v: lax.psum(v, topology.axes),
            lambda v: lax.psum(v, topology.axes) * 0.0,
            x,
        )

    with pytest.raises(ValueError, match="collective-uniformity"):
        comm.register_engine(
            name, execute=bad_execute, verify=False, override=True
        )
    assert name not in comm.registered_engines("allreduce")
