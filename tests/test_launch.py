"""Launch-layer tests: step builders, input specs, and a dry-run cell.

The full 66-cell sweep runs via ``python -m repro.launch.dryrun --all``
(artifacts in reports/dryrun); here we regression-test the machinery
itself with the cheapest real cell in a subprocess (512 virtual devices).
"""

import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_config
from repro.configs.base import OptimizerConfig
from repro.launch import steps as steps_mod
from repro.launch.dryrun import LONG_OK, cells

_SRC = str(Path(__file__).parent.parent / "src")


def test_cell_enumeration_covers_assignment():
    cs = list(cells())
    # 10 archs x 4 shapes - 7 long_500k skips (DESIGN.md §4)
    assert len(cs) == 10 * 4 - 7
    for arch, shape in cs:
        assert arch in ARCHS and shape in SHAPES
    longs = [a for a, s in cs if s == "long_500k"]
    assert sorted(longs) == sorted(LONG_OK)


def test_input_specs_abstract_no_allocation():
    batch = steps_mod.input_specs("qwen2-72b", "train_4k", None)
    assert set(batch) == {"tokens", "labels", "loss_mask"}
    assert all(isinstance(v, jax.ShapeDtypeStruct) for v in batch.values())
    assert batch["tokens"].shape == (256, 4096)
    dec = steps_mod.input_specs("whisper-tiny", "decode_32k", None)
    assert "frames" in dec and dec["tokens"].shape == (128, 1)
    vlm = steps_mod.input_specs("qwen2-vl-2b", "prefill_32k", None)
    assert vlm["embeds"].shape == (32, 32768, 1536)
    assert vlm["positions"].shape == (3, 32, 32768)


def test_state_specs_abstract_for_72b():
    """Building 72B abstract state must not allocate memory."""
    model, policy, state, opt_cfg = steps_mod.state_specs(
        "qwen2-72b", "train_4k", None
    )
    total = sum(
        int(np.prod(l.shape)) for l in jax.tree.leaves(state["params"])
    )
    assert total > 70e9  # it really is the 72B config
    assert all(
        isinstance(l, jax.ShapeDtypeStruct)
        for l in jax.tree.leaves(state)
    )


def test_microbatch_split_rules():
    from repro.launch.mesh import make_mesh  # noqa: F401 (doc only)

    cfg = get_config("qwen2-72b")
    n = steps_mod.microbatch_split(cfg, SHAPES["train_4k"], None)
    assert n >= 1
    assert SHAPES["train_4k"].global_batch % n == 0
    # decode/prefill never microbatch
    assert steps_mod.microbatch_split(cfg, SHAPES["decode_32k"], None) == 1


@pytest.mark.parametrize("arch,shape", [("whisper-tiny", "train_4k")])
def test_dryrun_cell_subprocess(arch, shape, tmp_path):
    """One real dry-run cell end to end (512 virtual devices)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", arch, "--shape", shape, "--tag", "testcell",
            "--force",
        ],
        capture_output=True, text=True, env=env, timeout=900,
        cwd=str(Path(__file__).parent.parent),
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    rec = json.loads(proc.stdout[proc.stdout.index("{"):])
    assert rec["ok"], rec.get("error")
    rl = rec["roofline"]
    assert rl["flops_per_chip"] > 0
    assert rl["collective_bytes_per_chip"] > 0
    assert rl["dominant"] in ("compute", "memory", "collective")
    # trip-count-aware flops must be >= the (undercounting) cost_analysis
    assert rl["flops_per_chip"] >= rec["cost"].get("flops", 0) * 0.99
