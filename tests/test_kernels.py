"""Pallas kernel validation: interpret-mode vs pure-jnp oracles.

Per instructions: sweep shapes/dtypes for every kernel and
assert_allclose against the ref.py oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.mamba_scan import mamba_scan_pallas
from repro.kernels.rwkv6_scan import rwkv6_scan_pallas

TOL = {"float32": 2e-5, "bfloat16": 2e-2}


def _rand(key, shape, dtype):
    return (jax.random.normal(key, shape) * 0.5).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize(
    "bh,s,hd,bq,bk",
    [
        (2, 128, 64, 64, 64),
        (1, 256, 64, 128, 128),
        (3, 192, 32, 64, 64),   # padded seq (192 % 64 == 0, non-pow2 grid)
        (2, 100, 64, 64, 64),   # ragged -> padding path
        (1, 128, 128, 128, 64),
    ],
)
@pytest.mark.parametrize("causal,window,softcap", [
    (True, None, None),
    (True, 32, None),
    (True, None, 30.0),
    (False, None, None),
])
def test_flash_attention_matches_ref(bh, s, hd, bq, bk, causal, window, softcap, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(bh * s + hd), 3)
    q = _rand(k1, (bh, s, hd), dtype)
    k = _rand(k2, (bh, s, hd), dtype)
    v = _rand(k3, (bh, s, hd), dtype)
    got = flash_attention_pallas(
        q, k, v, causal=causal, window=window, softcap=softcap,
        block_q=bq, block_k=bk, interpret=True,
    )
    want = ref.flash_attention_ref(
        q, k, v, causal=causal, window=window, softcap=softcap
    )
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(want, np.float32),
        rtol=TOL[dtype], atol=TOL[dtype],
    )


@settings(max_examples=10, deadline=None)
@given(
    s=st.integers(min_value=16, max_value=200),
    hd=st.sampled_from([32, 64]),
    window=st.one_of(st.none(), st.integers(min_value=4, max_value=64)),
)
def test_flash_attention_property(s, hd, window):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(s * hd), 3)
    q = _rand(k1, (1, s, hd), "float32")
    k = _rand(k2, (1, s, hd), "float32")
    v = _rand(k3, (1, s, hd), "float32")
    got = flash_attention_pallas(
        q, k, v, causal=True, window=window, block_q=64, block_k=64,
        interpret=True,
    )
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5
    )


def test_flash_attention_gqa_wrapper():
    B, S, H, KV, hd = 2, 64, 8, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (B, S, H, hd), "float32")
    k = _rand(ks[1], (B, S, KV, hd), "float32")
    v = _rand(ks[2], (B, S, KV, hd), "float32")
    got = ops.flash_attention(q, k, v, impl="pallas", block_q=32, block_k=32)
    want = ops.flash_attention(q, k, v, impl="xla")
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5
    )


# ---------------------------------------------------------------------------
# rwkv6 scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize(
    "bh,s,hd,chunk", [(2, 64, 32, 16), (1, 128, 64, 64), (3, 50, 32, 16)]
)
def test_rwkv6_scan_matches_ref(bh, s, hd, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(bh + s), 5)
    r = _rand(ks[0], (bh, s, hd), dtype)
    k = _rand(ks[1], (bh, s, hd), dtype)
    v = _rand(ks[2], (bh, s, hd), dtype)
    w = jax.nn.sigmoid(
        jax.random.normal(ks[3], (bh, s, hd))
    ).astype(dtype)  # decay in (0, 1)
    u = (jax.random.normal(ks[4], (bh, hd)) * 0.1).astype(jnp.float32)
    got = rwkv6_scan_pallas(r, k, v, w, u, chunk=chunk, interpret=True)
    want = ref.rwkv6_scan_ref(r, k, v, w, u)
    tol = 5e-5 if dtype == "float32" else 5e-2
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=tol, atol=tol
    )


# ---------------------------------------------------------------------------
# mamba scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize(
    "b,s,d,n,chunk,blk",
    [(2, 64, 128, 8, 16, 64), (1, 96, 64, 16, 32, 64), (2, 50, 96, 4, 16, 32)],
)
def test_mamba_scan_matches_ref(b, s, d, n, chunk, blk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(b * s + d), 5)
    x = _rand(ks[0], (b, s, d), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, d))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (d, n)) * 0.5).astype(jnp.float32)
    B = _rand(ks[3], (b, s, n), dtype)
    C = _rand(ks[4], (b, s, n), dtype)
    got = mamba_scan_pallas(
        x, dt, A, B, C, chunk=chunk, block_d=blk, interpret=True
    )
    want = ref.mamba_scan_ref(x, dt, A, B, C)
    tol = 5e-5 if dtype == "float32" else 5e-2
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=tol, atol=tol
    )


def test_mamba_scan_matches_model_oracle():
    """The kernel oracle must agree with the model's mamba_full internals
    (same recurrence) on a tiny case."""
    import dataclasses
    from repro.configs import ARCHS, reduced
    from repro.models import build_model

    cfg = dataclasses.replace(reduced(ARCHS["jamba-1.5-large-398b"]), dtype="float32")
    # direct equivalence of the scan core:
    b, s, d, n = 1, 8, 16, 4
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    x = jax.random.normal(ks[0], (b, s, d))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, d)))
    A = -jnp.exp(jax.random.normal(ks[2], (d, n)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    got = ops.mamba_scan(x, dt, A, B, C, impl="pallas", chunk=4, block_d=8)
    want = ref.mamba_scan_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
