"""Data pipeline, optimizer, checkpoint and fault-runtime tests."""

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs.base import OptimizerConfig
from repro.data import Prefetcher, SyntheticLM
from repro.optim import adamw_init, adamw_update, global_norm, make_schedule
from repro.runtime import ResumableLoop, StragglerMonitor


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_data_deterministic_and_restartable():
    src = SyntheticLM(vocab_size=100, seq_len=32, global_batch=4, seed=7)
    b1 = src.batch(5)
    b2 = SyntheticLM(vocab_size=100, seq_len=32, global_batch=4, seed=7).batch(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    b3 = src.batch(6)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))
    # labels shifted
    np.testing.assert_array_equal(
        np.asarray(b1["labels"])[:, :-1], np.asarray(b1["tokens"])[:, 1:]
    )


def test_prefetcher_orders_batches():
    src = SyntheticLM(vocab_size=50, seq_len=8, global_batch=2, seed=1)
    pf = Prefetcher(src, start_step=3, depth=2)
    try:
        steps = [pf.next()[0] for _ in range(4)]
        assert steps == [3, 4, 5, 6]
    finally:
        pf.close()


# ---------------------------------------------------------------------------
# optimizer / schedules
# ---------------------------------------------------------------------------


def test_adamw_reduces_quadratic_loss():
    params = {"w": jnp.array([3.0, -2.0]), "b": jnp.array([[1.0, 1.0]])}
    state = adamw_init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    l0 = float(loss(params))
    for _ in range(200):
        grads = jax.grad(loss)(params)
        params, state, m = adamw_update(
            grads, state, params, lr=0.05, weight_decay=0.0
        )
    assert float(loss(params)) < l0 * 0.01
    assert np.isfinite(float(m["grad_norm"]))


def test_grad_clip_bounds_update_norm():
    params = {"w": jnp.ones((4,))}
    state = adamw_init(params)
    grads = {"w": jnp.full((4,), 1e6)}
    _, _, m = adamw_update(
        grads, state, params, lr=1e-3, grad_clip=1.0, weight_decay=0.0
    )
    assert float(m["grad_norm"]) > 1e5  # reported pre-clip


def test_wsd_schedule_shape():
    cfg = OptimizerConfig(
        schedule="wsd", lr=1.0, warmup_steps=10, stable_steps=100,
        decay_steps=50,
    )
    sched = make_schedule(cfg)
    assert float(sched(0)) == 0.0
    assert float(sched(10)) == pytest.approx(1.0)
    assert float(sched(60)) == pytest.approx(1.0)  # stable plateau
    assert float(sched(110)) == pytest.approx(1.0)
    assert float(sched(160)) < 0.01                # decayed tail
    cos = make_schedule(OptimizerConfig(schedule="cosine", lr=1.0,
                                        warmup_steps=10, decay_steps=100))
    assert float(cos(10)) == pytest.approx(1.0)
    assert float(cos(110)) == pytest.approx(0.1, rel=1e-2)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def _tiny_state():
    return {
        "params": {"w": jnp.arange(6.0).reshape(2, 3)},
        "opt": adamw_init({"w": jnp.zeros((2, 3))}),
        "step": jnp.array(0, jnp.int32),
    }


def test_checkpoint_roundtrip_and_keep_k(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    state = _tiny_state()
    for s in [10, 20, 30]:
        state["params"]["w"] = state["params"]["w"] + s
        mgr.save(s, state, block=True)
    assert mgr.all_steps() == [20, 30]  # keep-2 GC
    restored, meta = mgr.restore_latest(_tiny_state())
    assert meta["step"] == 30
    np.testing.assert_allclose(
        np.asarray(restored["params"]["w"]), np.asarray(state["params"]["w"])
    )


def test_checkpoint_async_and_atomic(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3, async_save=True)
    state = _tiny_state()
    mgr.save(1, state)
    mgr.wait()
    assert mgr.latest_step() == 1
    # a stale .tmp dir must never be listed as a checkpoint
    (tmp_path / "step_00000099.tmp").mkdir()
    assert mgr.latest_step() == 1


# ---------------------------------------------------------------------------
# fault runtime
# ---------------------------------------------------------------------------


def test_resumable_loop_survives_crash(tmp_path):
    calls = {"n": 0}

    def step_fn(state, step):
        calls["n"] += 1
        if step == 7 and calls["n"] <= 8:  # crash once at step 7
            raise RuntimeError("injected failure")
        return {"x": state["x"] + 1}, {"loss": float(step)}

    mgr = CheckpointManager(tmp_path, keep=3, async_save=False)
    loop = ResumableLoop(
        step_fn=step_fn,
        make_state=lambda: {"x": jnp.zeros(())},
        ckpt=mgr,
        checkpoint_every=5,
        max_retries=2,
    )
    final = loop.run(10)
    # crash at 7 -> resume from ckpt@4 (x=5) -> replay 5..9 => x = 10
    assert float(final["x"]) == 10.0

    # a fresh loop resumes from the newest checkpoint, not from zero
    loop2 = ResumableLoop(
        step_fn=step_fn,
        make_state=lambda: {"x": jnp.zeros(())},
        ckpt=mgr,
        checkpoint_every=5,
    )
    assert loop2.start_step == 10


def test_straggler_monitor_detects_slow_step():
    mon = StragglerMonitor(threshold=2.0, warmup=2)
    for s in range(6):
        mon.record(s, 0.1)
    ev = mon.record(6, 0.5)
    assert ev is not None and ev.ratio > 2.0
    assert len(mon.events) == 1
    # EWMA not poisoned by the outlier
    assert mon.ewma == pytest.approx(0.1, rel=1e-6)
