"""Multi-device collective checks, run in a subprocess by test_collectives.py.

Must be executed as a script: sets XLA_FLAGS before importing jax, runs a
battery of checks on a virtual 16-device CPU mesh, prints one JSON blob.
"""

import os
import sys

N_DEV = int(os.environ.get("REPRO_CHECK_DEVICES", "16"))
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={N_DEV} "
    + os.environ.get("XLA_FLAGS", "")
)

import json  # noqa: E402
from functools import partial  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core import collectives  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402

RESULTS: dict[str, dict] = {}


def record(name, ok, **info):
    RESULTS[name] = {"ok": bool(ok), **{k: str(v) for k, v in info.items()}}


def count_hlo(compiled, needle):
    return compiled.as_text().count(needle)


def check_allreduce_correctness():
    mesh = make_mesh((4, 4), ("pod", "data"))
    rng = np.random.default_rng(0)
    xs = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32))
    want = np.asarray(xs).sum(axis=0)

    for algo in ["nap", "rd", "smp", "psum"]:
        fn = jax.jit(
            jax.shard_map(
                partial(
                    collectives.ALGORITHMS[algo],
                    inter_axes="pod",
                    intra_axes="data",
                ),
                mesh=mesh,
                in_specs=P(("pod", "data")),
                out_specs=P(("pod", "data")),
            )
        )
        got = np.asarray(fn(xs))
        ok = np.allclose(got, np.tile(want, (16, 1)), rtol=1e-5, atol=1e-5)
        record(f"correct_{algo}", ok, max_err=np.abs(got - want).max())

    for algo in ["ring", "rabenseifner"]:
        fn = jax.jit(
            jax.shard_map(
                partial(
                    collectives.hierarchical_allreduce,
                    inter_axes="pod",
                    intra_axes="data",
                    algorithm=algo,
                ),
                mesh=mesh,
                in_specs=P(("pod", "data")),
                out_specs=P(("pod", "data")),
            )
        )
        got = np.asarray(fn(xs))
        ok = np.allclose(got, np.tile(want, (16, 1)), rtol=1e-5, atol=1e-5)
        record(f"correct_{algo}", ok, max_err=np.abs(got - want).max())

    # max / min ops through the NAP path
    for op in ["max", "min"]:
        fn = jax.jit(
            jax.shard_map(
                partial(
                    collectives.nap_allreduce,
                    inter_axes="pod",
                    intra_axes="data",
                    op=op,
                ),
                mesh=mesh,
                in_specs=P(("pod", "data")),
                out_specs=P(("pod", "data")),
            )
        )
        got = np.asarray(fn(xs))
        ref = getattr(np, op)(np.asarray(xs), axis=0)
        record(f"correct_nap_{op}", np.allclose(got, np.tile(ref, (16, 1))))


def check_internode_message_reduction():
    """The paper's headline, at the HLO level: NAP lowers to log_ppn(n)
    collective-permutes vs log2(p) for recursive doubling."""
    mesh = make_mesh((4, 4), ("pod", "data"))
    x = jnp.zeros((16, 4), jnp.float32)

    def lower(algo):
        fn = jax.jit(
            jax.shard_map(
                partial(
                    collectives.ALGORITHMS[algo],
                    inter_axes="pod",
                    intra_axes="data",
                ),
                mesh=mesh,
                in_specs=P(("pod", "data")),
                out_specs=P(("pod", "data")),
            )
        )
        return fn.lower(x).compile()

    nap_cp = count_hlo(lower("nap"), "collective-permute(")
    rd_cp = count_hlo(lower("rd"), "collective-permute(")
    smp_cp = count_hlo(lower("smp"), "collective-permute(")
    # 4 pods x 4 chips: NAP = log_4(4) = 1 permute; RD = log2(16) = 4;
    # SMP = 2 local tree + log2(4)=2 RD + 2 bcast = 6 permute steps.
    record(
        "hlo_permute_counts",
        nap_cp == 1 and rd_cp == 4 and smp_cp == 6,
        nap=nap_cp,
        rd=rd_cp,
        smp=smp_cp,
    )


def check_nonpower_mesh():
    """Ragged node count through the joint-axis grid: 8 devs = 2x4? use
    (8 pods x 2 chips) grid with NAP — non-power-of-ppn pod count."""
    if N_DEV < 16:
        return
    mesh = make_mesh((8, 2), ("pod", "data"))
    rng = np.random.default_rng(1)
    xs = jnp.asarray(rng.normal(size=(16, 3)).astype(np.float32))
    fn = jax.jit(
        jax.shard_map(
            partial(
                collectives.nap_allreduce, inter_axes="pod", intra_axes="data"
            ),
            mesh=mesh,
            in_specs=P(("pod", "data")),
            out_specs=P(("pod", "data")),
        )
    )
    got = np.asarray(fn(xs))
    want = np.asarray(xs).sum(axis=0)
    record(
        "correct_nap_nonpower_8x2",
        np.allclose(got, np.tile(want, (16, 1)), rtol=1e-5, atol=1e-5),
    )


def check_multiaxis_hierarchy():
    """NAP over a 3-axis mesh: inter=('pod',), intra=('data','model')."""
    mesh = make_mesh((2, 2, 4), ("pod", "data", "model"))
    rng = np.random.default_rng(2)
    xs = jnp.asarray(rng.normal(size=(16, 5)).astype(np.float32))
    fn = jax.jit(
        jax.shard_map(
            partial(
                collectives.nap_allreduce,
                inter_axes="pod",
                intra_axes=("data", "model"),
            ),
            mesh=mesh,
            in_specs=P(("pod", "data", "model")),
            out_specs=P(("pod", "data", "model")),
        )
    )
    got = np.asarray(fn(xs))
    want = np.asarray(xs).sum(axis=0)
    record(
        "correct_nap_multiaxis",
        np.allclose(got, np.tile(want, (16, 1)), rtol=1e-5, atol=1e-5),
    )


def check_grad_sync():
    from repro.core import grad_sync

    mesh = make_mesh((4, 4), ("pod", "data"))
    rng = np.random.default_rng(3)
    grads = {
        "w": jnp.asarray(rng.normal(size=(16, 4, 2)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(16, 2)).astype(np.float32)),
    }
    specs = {"w": P(("pod", "data")), "b": P(("pod", "data"))}
    cfg = grad_sync.GradSyncConfig(algorithm="nap", mean=True)
    sync = grad_sync.make_grad_sync(
        cfg, mesh, data_axes=("pod", "data"), grad_specs=specs
    )
    out = jax.jit(sync)(grads)
    ok = True
    for k in grads:
        want = np.asarray(grads[k]).mean(axis=0)
        got = np.asarray(out[k])
        ok &= np.allclose(got, np.tile(want, (16,) + (1,) * want.ndim))
    record("grad_sync_nap_mean", ok)

    # compressed path: int8 quantised allreduce stays within quant error
    cfg = grad_sync.GradSyncConfig(algorithm="nap", mean=False, compress_bits=8)
    sync = grad_sync.make_grad_sync(
        cfg, mesh, data_axes=("pod", "data"), grad_specs=specs
    )
    out = jax.jit(sync)(grads)
    ok = True
    for k in grads:
        want = np.asarray(grads[k]).sum(axis=0)
        got = np.asarray(out[k])
        scale = np.abs(np.asarray(grads[k])).max() * 16
        ok &= np.abs(got - want).max() < scale * (2.0 / 127)
    record("grad_sync_compressed", ok)


def check_dp_training_nap_equals_psum():
    """End-to-end: a few training steps with NAP gradient sync must match
    the psum baseline bit-for-bit-ish (same reduction, different schedule)
    and the loss must decrease."""
    import dataclasses

    from repro.configs import ARCHS, reduced
    from repro.configs.base import OptimizerConfig
    from repro.core.grad_sync import GradSyncConfig
    from repro.launch.steps import make_dp_train_step
    from repro.models import build_model
    from repro.optim import adamw_init
    from repro.data import SyntheticLM

    mesh = make_mesh((4, 4), ("pod", "data"))
    cfg = dataclasses.replace(reduced(ARCHS["minicpm-2b"]), dtype="float32")
    opt_cfg = OptimizerConfig(lr=1e-3, schedule="constant", warmup_steps=1)
    model = build_model(cfg)
    params0 = jax.jit(model.init)(jax.random.PRNGKey(0))
    data = SyntheticLM(
        vocab_size=cfg.vocab_size, seq_len=32, global_batch=16, seed=3,
        mesh=mesh, batch_axes=("pod", "data"),
    )

    losses = {}
    for algo in ["psum", "nap"]:
        step = jax.jit(
            make_dp_train_step(
                cfg, opt_cfg, mesh,
                GradSyncConfig(algorithm=algo, mean=True),
            )
        )
        state = {"params": params0, "opt": adamw_init(params0)}
        ls = []
        for s in range(4):
            state, m = step(state, data.batch(s))
            ls.append(float(m["loss"]))
        losses[algo] = ls
    close = np.allclose(losses["psum"], losses["nap"], rtol=1e-4, atol=1e-5)
    finite = all(np.isfinite(losses["nap"]))
    record(
        "dp_train_nap_equals_psum", close and finite,
        psum=losses["psum"], nap=losses["nap"],
    )


def check_nap_extensions():
    from repro.core import extensions

    mesh = make_mesh((4, 4), ("pod", "data"))
    rng = np.random.default_rng(9)
    xs = jnp.asarray(rng.normal(size=(16, 4)).astype(np.float32))

    fn = jax.jit(
        jax.shard_map(
            partial(
                extensions.nap_allgather, inter_axes="pod", intra_axes="data"
            ),
            mesh=mesh,
            in_specs=P(("pod", "data")),
            out_specs=P(("pod", "data")),
        )
    )
    got = np.asarray(fn(xs))  # every chip holds all 16 rows
    want = np.tile(np.asarray(xs).reshape(-1), (16, 1)).reshape(16, 16, 4)
    ok = np.allclose(got.reshape(16, 16, 4), want)
    record("nap_allgather", ok)

    def rs_local(x):  # x local: (1, 16, c) -> drop the sharded lead dim
        return extensions.nap_reduce_scatter(
            x[0], inter_axes="pod", intra_axes="data"
        )

    fn = jax.jit(
        jax.shard_map(
            rs_local,
            mesh=mesh,
            in_specs=P(("pod", "data"), None, None),
            out_specs=P(("pod", "data"), None),
        )
    )
    # chip i contributes its own (16, c) matrix; chip q must end up with
    # row q of the cross-chip sum.
    xs2 = jnp.asarray(rng.normal(size=(16, 16, 5)).astype(np.float32))
    got = np.asarray(fn(xs2))  # (16, 5): row q from chip q
    want = np.asarray(xs2).sum(axis=0)
    ok = np.allclose(got, want, rtol=1e-4, atol=1e-4)
    record("nap_reduce_scatter", ok)

    # large-message node-aware allreduce (§VI future work): RS + AG
    def large_local(x):
        return extensions.nap_allreduce_large(
            x[0], inter_axes="pod", intra_axes="data"
        )

    fn = jax.jit(
        jax.shard_map(
            large_local,
            mesh=mesh,
            in_specs=P(("pod", "data"), None),
            out_specs=P(("pod", "data")),
        )
    )
    xs3 = jnp.asarray(rng.normal(size=(16, 100)).astype(np.float32))
    got = np.asarray(fn(xs3))  # (16*100,) hmm: local (100,) replicated
    want = np.asarray(xs3).sum(axis=0)
    ok = np.allclose(got.reshape(16, 100), np.tile(want, (16, 1)),
                     rtol=1e-4, atol=1e-4)
    record("nap_allreduce_large", ok)


def main():
    assert jax.device_count() == N_DEV, jax.device_count()
    check_allreduce_correctness()
    check_internode_message_reduction()
    check_nonpower_mesh()
    check_multiaxis_hierarchy()
    check_grad_sync()
    check_dp_training_nap_equals_psum()
    check_nap_extensions()
    print("RESULTS_JSON:" + json.dumps(RESULTS))


if __name__ == "__main__":
    main()
