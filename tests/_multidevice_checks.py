"""Multi-device collective checks, run in a subprocess by test_collectives.py.

Must be executed as a script: sets XLA_FLAGS before importing jax, runs a
battery of checks on a virtual 16-device CPU mesh, prints one JSON blob.
"""

import os
import sys

N_DEV = int(os.environ.get("REPRO_CHECK_DEVICES", "16"))
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={N_DEV} "
    + os.environ.get("XLA_FLAGS", "")
)

import json  # noqa: E402
from functools import partial  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.core import collectives  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402

RESULTS: dict[str, dict] = {}


def record(name, ok, **info):
    RESULTS[name] = {"ok": bool(ok), **{k: str(v) for k, v in info.items()}}


def count_hlo(compiled, needle):
    return compiled.as_text().count(needle)


def check_allreduce_correctness():
    mesh = make_mesh((4, 4), ("pod", "data"))
    rng = np.random.default_rng(0)
    xs = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32))
    want = np.asarray(xs).sum(axis=0)

    for algo in ["nap", "rd", "smp", "psum"]:
        fn = jax.jit(
            compat.shard_map(
                partial(
                    collectives.ALGORITHMS[algo],
                    inter_axes="pod",
                    intra_axes="data",
                ),
                mesh=mesh,
                in_specs=P(("pod", "data")),
                out_specs=P(("pod", "data")),
            )
        )
        got = np.asarray(fn(xs))
        ok = np.allclose(got, np.tile(want, (16, 1)), rtol=1e-5, atol=1e-5)
        record(f"correct_{algo}", ok, max_err=np.abs(got - want).max())

    for algo in ["ring", "rabenseifner"]:
        fn = jax.jit(
            compat.shard_map(
                partial(
                    collectives.hierarchical_allreduce,
                    inter_axes="pod",
                    intra_axes="data",
                    algorithm=algo,
                ),
                mesh=mesh,
                in_specs=P(("pod", "data")),
                out_specs=P(("pod", "data")),
            )
        )
        got = np.asarray(fn(xs))
        ok = np.allclose(got, np.tile(want, (16, 1)), rtol=1e-5, atol=1e-5)
        record(f"correct_{algo}", ok, max_err=np.abs(got - want).max())

    # max / min ops through the NAP path
    for op in ["max", "min"]:
        fn = jax.jit(
            compat.shard_map(
                partial(
                    collectives.nap_allreduce,
                    inter_axes="pod",
                    intra_axes="data",
                    op=op,
                ),
                mesh=mesh,
                in_specs=P(("pod", "data")),
                out_specs=P(("pod", "data")),
            )
        )
        got = np.asarray(fn(xs))
        ref = getattr(np, op)(np.asarray(xs), axis=0)
        record(f"correct_nap_{op}", np.allclose(got, np.tile(ref, (16, 1))))


def check_mla_allreduce():
    """MLA striped bandwidth path: exact vs np.sum oracle, power-of-two
    and ragged payload sizes, plus a multi-axis intra hierarchy."""
    rng = np.random.default_rng(11)

    def run(mesh, spec, size, algo="mla"):
        xs = jnp.asarray(
            rng.normal(size=(16, size)).astype(np.float32)
        )
        fn = jax.jit(
            compat.shard_map(
                partial(
                    collectives.ALGORITHMS[algo]
                    if algo in collectives.ALGORITHMS
                    else collectives.hierarchical_allreduce,
                    inter_axes=spec[0],
                    intra_axes=spec[1],
                ),
                mesh=mesh,
                in_specs=P(tuple(mesh.axis_names)),
                out_specs=P(tuple(mesh.axis_names)),
            )
        )
        got = np.asarray(fn(xs))
        want = np.asarray(xs).sum(axis=0)
        return np.allclose(got, np.tile(want, (16, 1)), rtol=1e-5, atol=1e-5)

    mesh = make_mesh((4, 4), ("pod", "data"))
    record("correct_mla_pow2", run(mesh, ("pod", "data"), 64))
    # ragged payload: 37 % ppn != 0 and the stripe 10 % n != 0 (padding)
    record("correct_mla_ragged", run(mesh, ("pod", "data"), 37))
    # ragged payload smaller than the chip count
    record("correct_mla_tiny", run(mesh, ("pod", "data"), 3))
    mesh3 = make_mesh((2, 2, 4), ("pod", "data", "model"))
    record(
        "correct_mla_multiaxis",
        run(mesh3, ("pod", ("data", "model")), 21),
    )


def check_ragged_roundtrips():
    """ring / rabenseifner / mla round-trip non-divisible payloads."""
    mesh = make_mesh((4, 4), ("pod", "data"))
    rng = np.random.default_rng(13)
    for algo in ["ring", "rabenseifner", "mla"]:
        ok = True
        for size in [1, 5, 13, 47]:  # all ragged vs p=16 / ppn=4
            xs = jnp.asarray(
                rng.normal(size=(16, size)).astype(np.float32)
            )
            fn = jax.jit(
                compat.shard_map(
                    partial(
                        collectives.hierarchical_allreduce,
                        inter_axes="pod",
                        intra_axes="data",
                        algorithm=algo,
                    ),
                    mesh=mesh,
                    in_specs=P(("pod", "data")),
                    out_specs=P(("pod", "data")),
                )
            )
            got = np.asarray(fn(xs))
            want = np.asarray(xs).sum(axis=0)
            ok &= np.allclose(
                got, np.tile(want, (16, 1)), rtol=1e-5, atol=1e-5
            )
        record(f"ragged_roundtrip_{algo}", ok)


def check_auto_dispatch():
    """'auto' must pick NAP vs MLA from the modeled crossover, visible in
    the lowered HLO (permutes for NAP; no permutes, RS/AG for MLA)."""
    from repro.core import perf_model as pm

    mesh = make_mesh((4, 4), ("pod", "data"))
    xo = collectives.auto_crossover_bytes(4, 4)
    # decision agrees with perf_model, not a hardcoded constant
    ok_sel = (
        collectives.select_algorithm(int(xo) - 8, 4, 4) == "nap"
        and collectives.select_algorithm(int(xo) + 8, 4, 4) == "mla"
        and xo == pm.crossover_bytes(4, 4, pm.TPU_V5E_POD, large="mla")
        and collectives.select_algorithm(1 << 30, 1, 16) == "psum"
    )

    def lower_auto(n_elems):
        fn = jax.jit(
            compat.shard_map(
                partial(
                    collectives.hierarchical_allreduce,
                    inter_axes="pod",
                    intra_axes="data",
                ),
                mesh=mesh,
                in_specs=P(("pod", "data")),
                out_specs=P(("pod", "data")),
            )
        )
        return fn.lower(
            jnp.zeros((16, n_elems), jnp.float32)
        ).compile().as_text()

    small_hlo = lower_auto(2)  # 8 B/chip << crossover -> NAP
    large_elems = int(xo) // 4 * 2  # ~2x crossover in f32 -> MLA
    large_hlo = lower_auto(large_elems)
    ok_hlo = (
        small_hlo.count("collective-permute(") >= 1
        and large_hlo.count("collective-permute(") == 0
    )
    record(
        "auto_dispatch_model_driven",
        ok_sel and ok_hlo,
        crossover_bytes=xo,
        small_cp=small_hlo.count("collective-permute("),
        large_cp=large_hlo.count("collective-permute("),
    )


def check_schedule_cache():
    """Repeated traces at the same (n, ppn) must hit the lru_cache."""
    from repro.core import napalg

    napalg.build_nap_schedule.cache_clear()
    mesh = make_mesh((4, 4), ("pod", "data"))
    for size in [4, 8]:  # two traces, same grid
        fn = jax.jit(
            compat.shard_map(
                partial(
                    collectives.nap_allreduce,
                    inter_axes="pod",
                    intra_axes="data",
                ),
                mesh=mesh,
                in_specs=P(("pod", "data")),
                out_specs=P(("pod", "data")),
            )
        )
        fn(jnp.zeros((16, size), jnp.float32))
    info = napalg.build_nap_schedule.cache_info()
    record(
        "schedule_cache_hits",
        info.hits > 0,
        hits=info.hits,
        misses=info.misses,
    )


def check_internode_message_reduction():
    """The paper's headline, at the HLO level: NAP lowers to log_ppn(n)
    collective-permutes vs log2(p) for recursive doubling."""
    mesh = make_mesh((4, 4), ("pod", "data"))
    x = jnp.zeros((16, 4), jnp.float32)

    def lower(algo):
        fn = jax.jit(
            compat.shard_map(
                partial(
                    collectives.ALGORITHMS[algo],
                    inter_axes="pod",
                    intra_axes="data",
                ),
                mesh=mesh,
                in_specs=P(("pod", "data")),
                out_specs=P(("pod", "data")),
            )
        )
        return fn.lower(x).compile()

    nap_cp = count_hlo(lower("nap"), "collective-permute(")
    rd_cp = count_hlo(lower("rd"), "collective-permute(")
    smp_cp = count_hlo(lower("smp"), "collective-permute(")
    # 4 pods x 4 chips: NAP = log_4(4) = 1 permute; RD = log2(16) = 4;
    # SMP = 2 local tree + log2(4)=2 RD + 2 bcast = 6 permute steps.
    record(
        "hlo_permute_counts",
        nap_cp == 1 and rd_cp == 4 and smp_cp == 6,
        nap=nap_cp,
        rd=rd_cp,
        smp=smp_cp,
    )


def check_nonpower_mesh():
    """Ragged node count through the joint-axis grid: 8 devs = 2x4? use
    (8 pods x 2 chips) grid with NAP — non-power-of-ppn pod count."""
    if N_DEV < 16:
        return
    mesh = make_mesh((8, 2), ("pod", "data"))
    rng = np.random.default_rng(1)
    xs = jnp.asarray(rng.normal(size=(16, 3)).astype(np.float32))
    fn = jax.jit(
        compat.shard_map(
            partial(
                collectives.nap_allreduce, inter_axes="pod", intra_axes="data"
            ),
            mesh=mesh,
            in_specs=P(("pod", "data")),
            out_specs=P(("pod", "data")),
        )
    )
    got = np.asarray(fn(xs))
    want = np.asarray(xs).sum(axis=0)
    record(
        "correct_nap_nonpower_8x2",
        np.allclose(got, np.tile(want, (16, 1)), rtol=1e-5, atol=1e-5),
    )


def check_multiaxis_hierarchy():
    """NAP over a 3-axis mesh: inter=('pod',), intra=('data','model')."""
    mesh = make_mesh((2, 2, 4), ("pod", "data", "model"))
    rng = np.random.default_rng(2)
    xs = jnp.asarray(rng.normal(size=(16, 5)).astype(np.float32))
    fn = jax.jit(
        compat.shard_map(
            partial(
                collectives.nap_allreduce,
                inter_axes="pod",
                intra_axes=("data", "model"),
            ),
            mesh=mesh,
            in_specs=P(("pod", "data", "model")),
            out_specs=P(("pod", "data", "model")),
        )
    )
    got = np.asarray(fn(xs))
    want = np.asarray(xs).sum(axis=0)
    record(
        "correct_nap_multiaxis",
        np.allclose(got, np.tile(want, (16, 1)), rtol=1e-5, atol=1e-5),
    )


def check_op_dtype_matrix():
    """Acceptance sweep: op x dtype x grid through ``algorithm="auto"``.

    sum/max/min x f32/bf16/int32 on square, ragged (5x3) and ppn==1
    grids, under both the modeled crossover and a tiny fixed threshold
    (which forces every payload onto the bandwidth-regime engines — the
    regression surface: MLA used to raise for max/min, promote integer
    payloads, and NAP used to crash on ppn==1 fixed-threshold grids).
    Values are all-negative for max and all-positive for min so a wrong
    (zero) pad identity is caught, not masked.
    """
    rng = np.random.default_rng(23)
    ops = ["sum", "max", "min"]
    dtypes = [jnp.float32, jnp.bfloat16, jnp.int32]
    elems = 40  # ragged vs every tested ppn and node count
    for shape, gname in [((4, 4), "g4x4"), ((5, 3), "g5x3"), ((6, 1), "g6x1")]:
        n, ppn = shape
        chips = n * ppn
        mesh = make_mesh(shape, ("pod", "data"))
        inputs, refs = {}, {}
        for op in ops:
            for dt in dtypes:
                key = f"{op}_{jnp.dtype(dt).name}"
                if jnp.issubdtype(dt, jnp.integer):
                    base = rng.integers(5, 90, size=(chips, elems))
                    vals = -base if op == "max" else base
                    arr = jnp.asarray(vals.astype(np.int32))
                else:
                    base = np.abs(rng.normal(size=(chips, elems))) + 0.5
                    vals = -base if op == "max" else base
                    arr = jnp.asarray(vals.astype(np.float32)).astype(dt)
                inputs[key] = arr
                ref_vals = np.asarray(arr.astype(jnp.float32))
                refs[key] = {"sum": np.sum, "max": np.max, "min": np.min}[
                    op
                ](ref_vals, axis=0)
        for mode, kw in [
            ("fixed", {"small_threshold_bytes": 64}),
            ("auto", {}),
        ]:

            def local(tree, kw=kw):
                return {
                    k: collectives.hierarchical_allreduce(
                        v,
                        inter_axes="pod",
                        intra_axes="data",
                        algorithm="auto",
                        op=k.split("_")[0],
                        **kw,
                    )
                    for k, v in tree.items()
                }

            spec = {k: P(("pod", "data")) for k in inputs}
            fn = jax.jit(
                compat.shard_map(
                    local, mesh=mesh, in_specs=(spec,), out_specs=spec
                )
            )
            out = fn(inputs)
            ok, bad = True, []
            for k, v in out.items():
                got = np.asarray(v.astype(jnp.float32))
                want = np.tile(refs[k], (chips, 1))
                tol = 5e-2 if "bfloat16" in k else 1e-5
                k_ok = (
                    np.allclose(got, want, rtol=tol, atol=tol)
                    and v.dtype == inputs[k].dtype
                )
                ok &= k_ok
                if not k_ok:
                    bad.append(k)
            record(f"op_dtype_matrix_{gname}_{mode}", ok, failed=bad)


def check_mla_pipelined_execution():
    """The chunked MLA lowering must stay exact: ragged chunk split plus
    per-chunk ragged stripes, explicit depth and model-driven depth."""
    mesh = make_mesh((4, 4), ("pod", "data"))
    rng = np.random.default_rng(29)
    xs = jnp.asarray(rng.normal(size=(16, 101)).astype(np.float32))
    want = np.asarray(xs).sum(axis=0)
    ok = True
    for algo, kw in [
        ("mla", {"pipeline_chunks": 3}),
        ("mla_pipelined", {}),  # model-driven depth
    ]:
        fn = jax.jit(
            compat.shard_map(
                partial(
                    collectives.ALGORITHMS[algo],
                    inter_axes="pod",
                    intra_axes="data",
                    **kw,
                ),
                mesh=mesh,
                in_specs=P(("pod", "data")),
                out_specs=P(("pod", "data")),
            )
        )
        got = np.asarray(fn(xs))
        ok &= np.allclose(got, np.tile(want, (16, 1)), rtol=1e-5, atol=1e-5)
    # max through an explicitly pipelined path (all-negative payload)
    neg = jnp.asarray((-np.abs(rng.normal(size=(16, 53))) - 1).astype(np.float32))
    fn = jax.jit(
        compat.shard_map(
            partial(
                collectives.mla_allreduce,
                inter_axes="pod",
                intra_axes="data",
                op="max",
                pipeline_chunks=2,
            ),
            mesh=mesh,
            in_specs=P(("pod", "data")),
            out_specs=P(("pod", "data")),
        )
    )
    got = np.asarray(fn(neg))
    ok &= np.allclose(
        got, np.tile(np.asarray(neg).max(axis=0), (16, 1)), rtol=1e-5
    )
    record("mla_pipelined_execution", ok)


def check_fixed_threshold_ppn1():
    """Regression: fixed ``small_threshold_bytes`` with ppn == 1 used to
    dispatch NAP, which raises ValueError at trace time; it must fall
    back to RS+AG like the modeled branch, for sizes on both sides of
    the threshold."""
    mesh = make_mesh((6, 1), ("pod", "data"))
    rng = np.random.default_rng(31)
    ok = True
    for size in [3, 1024]:  # below and above the 64-byte threshold
        xs = jnp.asarray(rng.normal(size=(6, size)).astype(np.float32))
        fn = jax.jit(
            compat.shard_map(
                partial(
                    collectives.hierarchical_allreduce,
                    inter_axes="pod",
                    intra_axes="data",
                    algorithm="auto",
                    small_threshold_bytes=64,
                ),
                mesh=mesh,
                in_specs=P(("pod", "data")),
                out_specs=P(("pod", "data")),
            )
        )
        got = np.asarray(fn(xs))
        want = np.asarray(xs).sum(axis=0)
        ok &= np.allclose(got, np.tile(want, (6, 1)), rtol=1e-5, atol=1e-5)
    record("fixed_threshold_ppn1", ok)


def check_grad_sync():
    from repro.core import grad_sync

    mesh = make_mesh((4, 4), ("pod", "data"))
    rng = np.random.default_rng(3)
    grads = {
        "w": jnp.asarray(rng.normal(size=(16, 4, 2)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(16, 2)).astype(np.float32)),
    }
    specs = {"w": P(("pod", "data")), "b": P(("pod", "data"))}
    cfg = grad_sync.GradSyncConfig(algorithm="nap", mean=True)
    sync = grad_sync.make_grad_sync(
        cfg, mesh, data_axes=("pod", "data"), grad_specs=specs
    )
    out = jax.jit(sync)(grads)
    ok = True
    for k in grads:
        want = np.asarray(grads[k]).mean(axis=0)
        got = np.asarray(out[k])
        ok &= np.allclose(got, np.tile(want, (16,) + (1,) * want.ndim))
    record("grad_sync_nap_mean", ok)

    # compressed path: int8 quantised allreduce stays within quant error
    cfg = grad_sync.GradSyncConfig(algorithm="nap", mean=False, compress_bits=8)
    sync = grad_sync.make_grad_sync(
        cfg, mesh, data_axes=("pod", "data"), grad_specs=specs
    )
    out = jax.jit(sync)(grads)
    ok = True
    for k in grads:
        want = np.asarray(grads[k]).sum(axis=0)
        got = np.asarray(out[k])
        scale = np.abs(np.asarray(grads[k])).max() * 16
        ok &= np.abs(got - want).max() < scale * (2.0 / 127)
    record("grad_sync_compressed", ok)


def check_grad_sync_dtypes():
    """Regression: op/mean/dtype semantics must be uniform across leaves.

    Integer leaves get the rounded mean (not a silent sum), bf16 leaves
    keep bf16, and the compressed path returns the original dtype instead
    of hardcoded float32.
    """
    from repro.core import grad_sync

    mesh = make_mesh((4, 4), ("pod", "data"))
    rng = np.random.default_rng(17)
    grads = {
        "f32": jnp.asarray(rng.normal(size=(16, 3)).astype(np.float32)),
        "bf16": jnp.asarray(
            rng.normal(size=(16, 4)).astype(np.float32)
        ).astype(jnp.bfloat16),
        "i32": jnp.asarray(
            rng.integers(-40, 40, size=(16, 2)).astype(np.int32)
        ),
    }
    specs = {k: P(("pod", "data")) for k in grads}
    cfg = grad_sync.GradSyncConfig(algorithm="auto", mean=True)
    sync = grad_sync.make_grad_sync(
        cfg, mesh, data_axes=("pod", "data"), grad_specs=specs
    )
    out = jax.jit(sync)(grads)
    ok = all(out[k].dtype == grads[k].dtype for k in grads)
    want_f32 = np.asarray(grads["f32"]).mean(axis=0)
    ok &= np.allclose(
        np.asarray(out["f32"]), np.tile(want_f32, (16, 1)), rtol=1e-5
    )
    want_bf16 = np.asarray(
        grads["bf16"].astype(jnp.float32)
    ).mean(axis=0)
    ok &= np.allclose(
        np.asarray(out["bf16"].astype(jnp.float32)),
        np.tile(want_bf16, (16, 1)),
        rtol=2e-2, atol=2e-2,
    )
    want_i32 = np.round(
        np.asarray(grads["i32"], dtype=np.float64).mean(axis=0)
    ).astype(np.int32)
    ok &= np.array_equal(np.asarray(out["i32"]), np.tile(want_i32, (16, 1)))
    record("grad_sync_dtype_semantics", ok)

    # compressed path keeps the original dtype too
    cfg = grad_sync.GradSyncConfig(
        algorithm="auto", mean=False, compress_bits=8,
        fuse_small_buckets=False,
    )
    sync = grad_sync.make_grad_sync(
        cfg, mesh, data_axes=("pod", "data"), grad_specs=specs
    )
    out = jax.jit(sync)(grads)
    ok = all(out[k].dtype == grads[k].dtype for k in grads)
    # integer leaves bypass quantisation: exact sum
    want_i32 = np.asarray(grads["i32"], dtype=np.int64).sum(axis=0)
    ok &= np.array_equal(
        np.asarray(out["i32"], dtype=np.int64), np.tile(want_i32, (16, 1))
    )
    record("grad_sync_compressed_dtypes", ok)


def check_grad_sync_mla():
    """Large buckets route through MLA and still produce the exact mean."""
    from repro.core import grad_sync

    mesh = make_mesh((4, 4), ("pod", "data"))
    rng = np.random.default_rng(19)
    grads = {
        "big": jnp.asarray(
            rng.normal(size=(16, 3000)).astype(np.float32)
        ),
        "tiny": jnp.asarray(rng.normal(size=(16, 2)).astype(np.float32)),
    }
    specs = {k: P(("pod", "data")) for k in grads}
    cfg = grad_sync.GradSyncConfig(algorithm="mla", mean=True)
    sync = grad_sync.make_grad_sync(
        cfg, mesh, data_axes=("pod", "data"), grad_specs=specs
    )
    out = jax.jit(sync)(grads)
    ok = True
    for k in grads:
        want = np.asarray(grads[k]).mean(axis=0)
        ok &= np.allclose(
            np.asarray(out[k]), np.tile(want, (16, 1)),
            rtol=1e-5, atol=1e-5,
        )
    record("grad_sync_mla_mean", ok)


def check_grad_sync_pipelined():
    """Large buckets through the pipelined MLA path (explicit depth and
    model-driven) must still produce the exact mean."""
    from repro.core import grad_sync

    mesh = make_mesh((4, 4), ("pod", "data"))
    rng = np.random.default_rng(37)
    grads = {
        "big": jnp.asarray(rng.normal(size=(16, 3001)).astype(np.float32)),
        "tiny": jnp.asarray(rng.normal(size=(16, 2)).astype(np.float32)),
    }
    specs = {k: P(("pod", "data")) for k in grads}
    ok = True
    for cfg in [
        grad_sync.GradSyncConfig(
            algorithm="auto", mean=True, pipeline_chunks=2,
            small_threshold_bytes=256,
        ),
        grad_sync.GradSyncConfig(algorithm="mla_pipelined", mean=True),
    ]:
        sync = grad_sync.make_grad_sync(
            cfg, mesh, data_axes=("pod", "data"), grad_specs=specs
        )
        out = jax.jit(sync)(grads)
        for k in grads:
            want = np.asarray(grads[k]).mean(axis=0)
            ok &= np.allclose(
                np.asarray(out[k]), np.tile(want, (16, 1)),
                rtol=1e-5, atol=1e-5,
            )
    record("grad_sync_pipelined", ok)


def check_grad_sync_bucketed():
    """The bucket scheduler: mixed-dtype trees fuse into dtype-pure
    buckets (no bf16->f32 transport inflation), int leaves ride alone,
    and the executed bucketed sync still produces the exact mean."""
    from repro.core import bucketing, grad_sync

    mesh = make_mesh((4, 4), ("pod", "data"))
    rng = np.random.default_rng(41)
    grads = {
        "w0": jnp.asarray(rng.normal(size=(16, 300)).astype(np.float32)),
        "n0": jnp.asarray(
            rng.normal(size=(16, 8)).astype(np.float32)
        ).astype(jnp.bfloat16),
        "w1": jnp.asarray(rng.normal(size=(16, 500)).astype(np.float32)),
        "n1": jnp.asarray(
            rng.normal(size=(16, 16)).astype(np.float32)
        ).astype(jnp.bfloat16),
        "steps": jnp.asarray(
            rng.integers(-30, 30, size=(16, 2)).astype(np.int32)
        ),
    }
    specs = {k: P(("pod", "data")) for k in grads}
    cfg = grad_sync.GradSyncConfig(algorithm="auto", mean=True)
    # the plan the executor will run (local leaves: lead dim 1)
    local_tree = jax.tree.map(
        lambda g: jax.ShapeDtypeStruct((1,) + g.shape[1:], g.dtype), grads
    )
    plan = grad_sync.plan_for_tree(local_tree, cfg=cfg, n=4, ppn=4)
    ok = sorted(i for b in plan.buckets for i in b.leaves) == list(range(5))
    for b in plan.buckets:
        leaves = jax.tree.flatten(local_tree)[0]
        ok &= all(leaves[i].dtype == jnp.dtype(b.dtype) for i in b.leaves)
        if b.dtype == "int32":
            ok &= len(b.leaves) == 1
        if b.dtype == "bfloat16":
            # native-width budgeting: 2 bytes/elem, not a 4-byte cast
            ok &= b.transport_bytes == 2 * b.elems
    # at least one genuinely fused (multi-leaf) bucket exists
    ok &= any(len(b.leaves) > 1 for b in plan.buckets)

    sync = grad_sync.make_grad_sync(
        cfg, mesh, data_axes=("pod", "data"), grad_specs=specs
    )
    out = jax.jit(sync)(grads)
    for k in grads:
        ref = np.asarray(grads[k].astype(jnp.float32), dtype=np.float64)
        want = ref.mean(axis=0)
        if k == "steps":
            want = np.round(want)
        got = np.asarray(out[k].astype(jnp.float32))
        tol = 2e-2 if k.startswith("n") else 1e-5
        ok &= out[k].dtype == grads[k].dtype
        ok &= np.allclose(got, np.tile(want, (16, 1)), rtol=tol, atol=tol)
    record("grad_sync_bucketed_mixed_dtype", ok)

    # single-small-leaf tree: one bucket, no fusion machinery, exact mean
    single = {"only": jnp.asarray(rng.normal(size=(16, 3)).astype(np.float32))}
    plan1 = grad_sync.plan_for_tree(
        {"only": jax.ShapeDtypeStruct((1, 3), jnp.float32)},
        cfg=cfg, n=4, ppn=4,
    )
    ok = plan1.num_buckets == 1 and plan1.buckets[0].leaves == (0,)
    sync = grad_sync.make_grad_sync(
        cfg, mesh, data_axes=("pod", "data"),
        grad_specs={"only": P(("pod", "data"))},
    )
    out = jax.jit(sync)(single)
    want = np.asarray(single["only"]).mean(axis=0)
    ok &= np.allclose(np.asarray(out["only"]), np.tile(want, (16, 1)))
    record("grad_sync_single_leaf", ok)

    # pinned plan (trainer-style issue points) == plan-free execution
    def with_plan(g):
        return grad_sync.sync_grads_local(
            g, cfg=cfg, inter_axes=("pod",), intra_axes=("data",),
            plan=plan,
        )

    fn = jax.jit(
        compat.shard_map(
            with_plan, mesh=mesh, in_specs=(specs,), out_specs=specs
        )
    )
    out2 = fn(grads)
    out1 = jax.jit(
        grad_sync.make_grad_sync(
            cfg, mesh, data_axes=("pod", "data"), grad_specs=specs
        )
    )(grads)
    ok = all(
        np.allclose(
            np.asarray(out1[k].astype(jnp.float32)),
            np.asarray(out2[k].astype(jnp.float32)),
        )
        for k in grads
    )
    record("grad_sync_pinned_plan", ok)


def check_grad_sync_compressed_int16():
    """Satellite 3: the overflow-safe accumulator width must still be
    int16 for a 16-way group (the :func:`compressed_transport_dtype`
    contract), while the fused Pallas engine moves s8 wire bytes — the
    packed width, never a wide integer — and sums within quant error."""
    from repro.core import grad_sync

    mesh = make_mesh((4, 4), ("pod", "data"))
    rng = np.random.default_rng(43)
    ok = grad_sync.compressed_transport_dtype(16, 8) == jnp.dtype(jnp.int16)
    grads = {
        "g": jnp.asarray(rng.normal(size=(16, 4000)).astype(np.float32))
    }
    specs = {"g": P(("pod", "data"))}
    cfg = grad_sync.GradSyncConfig(
        algorithm="auto", mean=False, compress_bits=8
    )
    sync = grad_sync.make_grad_sync(
        cfg, mesh, data_axes=("pod", "data"), grad_specs=specs
    )
    compiled = jax.jit(sync).lower(grads).compile()
    hlo = compiled.as_text()
    # the payload-sized transport is s8 wire bytes; a wide-integer
    # (s16/s32) payload transport would mean the packed engine regressed
    ok &= "s8[" in hlo
    ok &= "s16[4000]" not in hlo and "s32[4000]" not in hlo
    out = compiled(grads)
    want = np.asarray(grads["g"]).sum(axis=0)
    scale = np.abs(np.asarray(grads["g"])).max() * 16
    ok &= np.abs(np.asarray(out["g"]) - want).max() < scale * (2.0 / 127)
    record("grad_sync_compressed_int16", ok)

    # fused + compressed: per-leaf scales.  A tiny-magnitude leaf fused
    # next to a large-magnitude one must survive within ITS OWN quant
    # error, not be rounded to zero by a shared bucket-wide scale.
    grads2 = {
        "ln": jnp.asarray(
            (1e-4 * rng.normal(size=(16, 64))).astype(np.float32)
        ),
        "emb": jnp.asarray(rng.normal(size=(16, 64)).astype(np.float32)),
    }
    specs2 = {k: P(("pod", "data")) for k in grads2}
    cfg2 = grad_sync.GradSyncConfig(
        algorithm="auto", mean=False, compress_bits=8,
        bucket_bytes=1 << 20,  # force both leaves into one fused bucket
    )
    plan = grad_sync.plan_for_tree(
        {k: jax.ShapeDtypeStruct((1, 64), jnp.float32) for k in grads2},
        cfg=cfg2, n=4, ppn=4,
    )
    ok = any(len(b.leaves) == 2 for b in plan.buckets)  # genuinely fused
    sync = grad_sync.make_grad_sync(
        cfg2, mesh, data_axes=("pod", "data"), grad_specs=specs2
    )
    out = jax.jit(sync)(grads2)
    for k in grads2:
        arr = np.asarray(grads2[k])
        want = arr.sum(axis=0)
        tol = np.abs(arr).max() * 16 * (2.0 / 127)  # per-LEAF quant error
        ok &= np.abs(np.asarray(out[k]) - want).max() < tol
    record("grad_sync_compressed_per_leaf_scale", ok)


def check_grad_sync_compressed_int4():
    """Packed int4 transport: the wire must be u8 nibble-pairs (1/8 of
    f32 — no s8, s16 or s32 payload transport), and the sum must land
    within the 4-bit quantisation bound ``group * absmax / qmax``."""
    from repro.core import grad_sync

    mesh = make_mesh((4, 4), ("pod", "data"))
    rng = np.random.default_rng(47)
    grads = {
        "g": jnp.asarray(rng.normal(size=(16, 4096)).astype(np.float32))
    }
    specs = {"g": P(("pod", "data"))}
    cfg = grad_sync.GradSyncConfig(
        algorithm="auto", mean=False, compress_bits=4
    )
    sync = grad_sync.make_grad_sync(
        cfg, mesh, data_axes=("pod", "data"), grad_specs=specs
    )
    compiled = jax.jit(sync).lower(grads).compile()
    hlo = compiled.as_text()
    ok = "u8[" in hlo
    # no payload-sized integer transport wider than the packed bytes
    ok &= "s16[4096]" not in hlo and "s32[4096]" not in hlo
    out = compiled(grads)
    arr = np.asarray(grads["g"])
    want = arr.sum(axis=0)
    bound = np.abs(arr).max() * 16 / 7.0  # group * A / qmax(int4)
    err = np.abs(np.asarray(out["g"]) - want).max()
    ok &= err <= bound
    record("grad_sync_compressed_int4", ok, err=err, bound=bound)


def check_comm_sharded_grad_sync_compressed():
    """Satellite: the ZeRO route rides the same quantised transport —
    shards keep the stripe-block layout/shape and unshard back to the
    allreduce-route result within the shared quantisation bound."""
    from repro.core import comm, grad_sync

    mesh = make_mesh((4, 4), ("pod", "data"))
    rng = np.random.default_rng(51)
    for bits in (8, 4):
        policy = comm.CommPolicy(mean=True, compress_bits=bits)
        ctx = comm.CommContext(comm.Topology.from_mesh(mesh), policy)
        grads = {
            "w": jnp.asarray(rng.normal(size=(16, 37)).astype(np.float32)),
            "b": jnp.asarray(rng.normal(size=(16, 3)).astype(np.float32)),
        }
        specs = {k: P(("pod", "data")) for k in grads}

        def sharded_roundtrip(g):
            like = jax.tree.map(
                lambda v: jax.ShapeDtypeStruct(v.shape, v.dtype), g
            )
            return grad_sync.unshard_grads(
                ctx.sync_grads_sharded(g), like, ctx=ctx
            )

        out_sh = jax.jit(
            compat.shard_map(
                sharded_roundtrip, mesh=mesh,
                in_specs=(specs,), out_specs=specs,
            )
        )(grads)
        qmax = 2.0 ** (bits - 1) - 1
        ok = True
        for k, g in grads.items():
            arr = np.asarray(g)
            want = arr.mean(axis=0)
            # mean of a sum quantised at the group bound
            bound = np.abs(arr).max() / qmax
            err = np.abs(np.asarray(out_sh[k]) - want).max()
            ok &= err <= bound
        # shard shapes keep the uncompressed stripe-block layout
        shard_shapes = jax.eval_shape(
            compat.shard_map(
                lambda g: ctx.sync_grads_sharded(g),
                mesh=mesh, in_specs=(specs,),
                out_specs={k: P(("pod", "data")) for k in grads},
            ),
            grads,
        )
        for k, g in grads.items():
            elems = int(np.prod(g.shape[1:]))
            stripe = -(-elems // 4)  # ceil(e / ppn)
            want = -(-stripe // 4)  # ceil(stripe / n): the block size
            ok &= shard_shapes[k].shape == (16 * want,)
        record(f"comm_sharded_grad_sync_compressed_int{bits}", ok)


def check_dp_training_ef_convergence():
    """Tentpole acceptance: tiny-LM training with 4-bit error-feedback
    transport must track the uncompressed loss within tolerance after
    ``n_steps``, and be strictly worse without error feedback.

    The horizon has to be long enough for the task to actually learn
    (the synthetic zipf+motif data starts at its unigram entropy floor;
    over a few steps every transport looks identical) — at 120 steps the
    uncompressed run has left the plateau and transport fidelity is
    visible in the loss.  Without EF the quantisation error perturbs
    every update and the trajectory deviates (on this workload it
    overshoots *below* the exact loss — deviation, not improvement:
    gradient noise is extra step size here); with EF the dropped error
    re-enters the next step and the compressed trajectory stays near the
    exact one.  Asserted both at the tail (mean of the last 10 losses)
    and along the whole trajectory (mean |loss_t - base_t|)."""
    import dataclasses

    from repro.configs import ARCHS, reduced
    from repro.configs.base import OptimizerConfig
    from repro.core.grad_sync import GradSyncConfig
    from repro.launch.steps import make_dp_train_step
    from repro.models import build_model
    from repro.optim import adamw_init, ef_init
    from repro.data import SyntheticLM

    mesh = make_mesh((4, 4), ("pod", "data"))
    cfg = dataclasses.replace(reduced(ARCHS["minicpm-2b"]), dtype="float32")
    opt_cfg = OptimizerConfig(lr=1e-2, schedule="constant", warmup_steps=1)
    model = build_model(cfg)
    params0 = jax.jit(model.init)(jax.random.PRNGKey(0))
    data = SyntheticLM(
        vocab_size=cfg.vocab_size, seq_len=32, global_batch=16, seed=3,
        mesh=mesh, batch_axes=("pod", "data"),
    )
    n_steps = 120

    def run(sync_cfg):
        step = jax.jit(make_dp_train_step(cfg, opt_cfg, mesh, sync_cfg))
        state = {"params": params0, "opt": adamw_init(params0)}
        if sync_cfg.error_feedback:
            state["ef"] = ef_init(params0, group=16)
        ls = []
        for s in range(n_steps):
            state, m = step(state, data.batch(s))
            ls.append(float(m["loss"]))
        return ls

    base = run(GradSyncConfig(algorithm="nap", mean=True))
    ef4 = run(
        GradSyncConfig(
            algorithm="nap", mean=True, compress_bits=4,
            error_feedback=True,
        )
    )
    raw4 = run(GradSyncConfig(algorithm="nap", mean=True, compress_bits=4))
    tail = lambda ls: float(np.mean(ls[-10:]))  # noqa: E731
    gap_ef = abs(tail(ef4) - tail(base))
    gap_raw = abs(tail(raw4) - tail(base))
    dev_ef = float(np.mean(np.abs(np.array(ef4) - np.array(base))))
    dev_raw = float(np.mean(np.abs(np.array(raw4) - np.array(base))))
    learned = tail(base) < base[0] - 0.5  # the task left its plateau
    ok = (
        all(np.isfinite(ef4))
        and learned
        and gap_ef < 0.15 * tail(base)
        and gap_raw > gap_ef
        and dev_raw > 1.4 * dev_ef
    )
    record(
        "dp_train_ef_convergence", ok,
        base_tail=tail(base), ef4_tail=tail(ef4), raw4_tail=tail(raw4),
        gap_ef=gap_ef, gap_raw=gap_raw, dev_ef=dev_ef, dev_raw=dev_raw,
        base=base[::20], ef4=ef4[::20], raw4=raw4[::20],
    )


def check_dp_training_nap_equals_psum():
    """End-to-end: a few training steps with NAP gradient sync must match
    the psum baseline bit-for-bit-ish (same reduction, different schedule)
    and the loss must decrease."""
    import dataclasses

    from repro.configs import ARCHS, reduced
    from repro.configs.base import OptimizerConfig
    from repro.core.grad_sync import GradSyncConfig
    from repro.launch.steps import make_dp_train_step
    from repro.models import build_model
    from repro.optim import adamw_init
    from repro.data import SyntheticLM

    mesh = make_mesh((4, 4), ("pod", "data"))
    cfg = dataclasses.replace(reduced(ARCHS["minicpm-2b"]), dtype="float32")
    opt_cfg = OptimizerConfig(lr=1e-3, schedule="constant", warmup_steps=1)
    model = build_model(cfg)
    params0 = jax.jit(model.init)(jax.random.PRNGKey(0))
    data = SyntheticLM(
        vocab_size=cfg.vocab_size, seq_len=32, global_batch=16, seed=3,
        mesh=mesh, batch_axes=("pod", "data"),
    )

    losses = {}
    for algo in ["psum", "nap"]:
        step = jax.jit(
            make_dp_train_step(
                cfg, opt_cfg, mesh,
                GradSyncConfig(algorithm=algo, mean=True),
            )
        )
        state = {"params": params0, "opt": adamw_init(params0)}
        ls = []
        for s in range(4):
            state, m = step(state, data.batch(s))
            ls.append(float(m["loss"]))
        losses[algo] = ls
    close = np.allclose(losses["psum"], losses["nap"], rtol=1e-4, atol=1e-5)
    finite = all(np.isfinite(losses["nap"]))
    record(
        "dp_train_nap_equals_psum", close and finite,
        psum=losses["psum"], nap=losses["nap"],
    )


def check_nap_extensions():
    from repro.core import extensions

    mesh = make_mesh((4, 4), ("pod", "data"))
    rng = np.random.default_rng(9)
    xs = jnp.asarray(rng.normal(size=(16, 4)).astype(np.float32))

    fn = jax.jit(
        compat.shard_map(
            partial(
                extensions.nap_allgather, inter_axes="pod", intra_axes="data"
            ),
            mesh=mesh,
            in_specs=P(("pod", "data")),
            out_specs=P(("pod", "data")),
        )
    )
    got = np.asarray(fn(xs))  # every chip holds all 16 rows
    want = np.tile(np.asarray(xs).reshape(-1), (16, 1)).reshape(16, 16, 4)
    ok = np.allclose(got.reshape(16, 16, 4), want)
    record("nap_allgather", ok)

    def rs_local(x):  # x local: (1, 16, c) -> drop the sharded lead dim
        return extensions.nap_reduce_scatter(
            x[0], inter_axes="pod", intra_axes="data"
        )

    fn = jax.jit(
        compat.shard_map(
            rs_local,
            mesh=mesh,
            in_specs=P(("pod", "data"), None, None),
            out_specs=P(("pod", "data"), None),
        )
    )
    # chip i contributes its own (16, c) matrix; chip q must end up with
    # row q of the cross-chip sum.
    xs2 = jnp.asarray(rng.normal(size=(16, 16, 5)).astype(np.float32))
    got = np.asarray(fn(xs2))  # (16, 5): row q from chip q
    want = np.asarray(xs2).sum(axis=0)
    ok = np.allclose(got, want, rtol=1e-4, atol=1e-4)
    record("nap_reduce_scatter", ok)

    # large-message node-aware allreduce (§VI future work): RS + AG
    def large_local(x):
        return extensions.nap_allreduce_large(
            x[0], inter_axes="pod", intra_axes="data"
        )

    fn = jax.jit(
        compat.shard_map(
            large_local,
            mesh=mesh,
            in_specs=P(("pod", "data"), None),
            out_specs=P(("pod", "data")),
        )
    )
    xs3 = jnp.asarray(rng.normal(size=(16, 100)).astype(np.float32))
    got = np.asarray(fn(xs3))  # (16*100,) hmm: local (100,) replicated
    want = np.asarray(xs3).sum(axis=0)
    ok = np.allclose(got.reshape(16, 100), np.tile(want, (16, 1)),
                     rtol=1e-4, atol=1e-4)
    record("nap_allreduce_large", ok)


def check_comm_context_equivalence():
    """PR-4 acceptance: the deprecated shims and the CommContext facade
    produce identical dispatch and bitwise-identical results across an
    op x dtype x grid sweep, and the shims warn exactly once."""
    import warnings

    from repro.core import comm, grad_sync

    cases = [
        ((4, 4), ("pod", "data")),
        ((8, 2), ("pod", "data")),
    ]
    rng = np.random.default_rng(47)
    ok = True
    for shape, axes in cases:
        mesh = make_mesh(shape, axes)
        topo = comm.Topology.from_mesh(mesh)
        ok &= (topo.n_nodes, topo.ppn) == shape
        ctx = comm.CommContext(topo)
        for op in ["sum", "max", "min"]:
            for dt in [jnp.float32, jnp.bfloat16, jnp.int32]:
                for size in [8, 3001]:  # latency + bandwidth regimes
                    if dt == jnp.int32:
                        xs = jnp.asarray(
                            rng.integers(-50, 50, size=(16, size)).astype(
                                np.int32
                            )
                        )
                    else:
                        xs = jnp.asarray(
                            rng.normal(size=(16, size)).astype(np.float32)
                        ).astype(dt)
                    sm = lambda f: jax.jit(
                        compat.shard_map(
                            f, mesh=mesh,
                            in_specs=P(axes), out_specs=P(axes),
                        )
                    )
                    old = sm(
                        partial(
                            collectives.hierarchical_allreduce,
                            inter_axes=axes[0], intra_axes=axes[1], op=op,
                        )
                    )(xs)
                    new = sm(partial(ctx.allreduce, op=op))(xs)
                    same = np.array_equal(
                        np.asarray(old.astype(jnp.float32)),
                        np.asarray(new.astype(jnp.float32)),
                    )
                    ok &= same
    record("comm_ctx_allreduce_bitwise", ok)

    # grad sync: GradSyncConfig shim route vs CommContext.sync_grads
    mesh = make_mesh((4, 4), ("pod", "data"))
    topo = comm.Topology.from_mesh(mesh)
    grads = {
        "w": jnp.asarray(rng.normal(size=(16, 300)).astype(np.float32)),
        "n": jnp.asarray(
            rng.normal(size=(16, 8)).astype(np.float32)
        ).astype(jnp.bfloat16),
        "i": jnp.asarray(rng.integers(-30, 30, size=(16, 2)).astype(np.int32)),
    }
    specs = {k: P(("pod", "data")) for k in grads}
    comm._DEPRECATION_WARNED.clear()
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        cfg = grad_sync.GradSyncConfig(algorithm="auto", mean=True)
        grad_sync.GradSyncConfig(algorithm="auto", mean=True)
        out_old = jax.jit(
            compat.shard_map(
                lambda g: grad_sync.sync_grads_local(
                    g, cfg=cfg, inter_axes=("pod",), intra_axes=("data",)
                ),
                mesh=mesh, in_specs=(specs,), out_specs=specs,
            )
        )(grads)
    dep = [
        str(w.message)
        for w in caught
        if issubclass(w.category, DeprecationWarning)
        and "deprecated" in str(w.message)
    ]
    ctx = comm.CommContext(topo, cfg)
    out_new = jax.jit(
        compat.shard_map(
            lambda g: ctx.sync_grads(g),
            mesh=mesh, in_specs=(specs,), out_specs=specs,
        )
    )(grads)
    ok = all(
        np.array_equal(
            np.asarray(out_old[k].astype(jnp.float32)),
            np.asarray(out_new[k].astype(jnp.float32)),
        )
        for k in grads
    )
    # one warning per shim used above: GradSyncConfig (constructed twice)
    # and hierarchical_allreduce are the only deprecated entry points
    ok &= len([m for m in dep if "GradSyncConfig" in m]) == 1
    record("comm_ctx_grad_sync_bitwise", ok, warnings=len(dep))


def check_comm_reduce_scatter_allgather():
    """RS/AG as first-class collectives: the round trip equals the full
    allreduce on ragged payloads, for sum and max, and the sharded
    (ZeRO-style) grad-sync route matches the allreduce route."""
    from repro.core import comm, grad_sync

    mesh = make_mesh((4, 4), ("pod", "data"))
    topo = comm.Topology.from_mesh(mesh)
    ctx = comm.CommContext(topo)
    rng = np.random.default_rng(53)
    ok = True
    for op, ref in [("sum", np.sum), ("max", np.max)]:
        for size in [5, 37, 64, 4096]:
            xs = jnp.asarray(rng.normal(size=(16, size)).astype(np.float32))

            def rs_ag(v, _op=op, _size=size):
                shard = ctx.reduce_scatter(v, op=_op)
                return ctx.allgather(shard, elems=_size).reshape(v.shape)

            got = np.asarray(
                jax.jit(
                    compat.shard_map(
                        rs_ag, mesh=mesh,
                        in_specs=P(("pod", "data")),
                        out_specs=P(("pod", "data")),
                    )
                )(xs)
            )
            want = ref(np.asarray(xs), axis=0)
            ok &= np.allclose(
                got, np.tile(want, (16, 1)), rtol=1e-5, atol=1e-5
            )
    record("comm_rs_ag_roundtrip", ok)

    # ZeRO-style sharded sync: reduce-scattered shards allgather back to
    # exactly the allreduce-synced (mean) gradients
    grads = {
        "w": jnp.asarray(rng.normal(size=(16, 37)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(16, 3)).astype(np.float32)),
    }
    specs = {k: P(("pod", "data")) for k in grads}

    def sharded_roundtrip(g):
        like = jax.tree.map(
            lambda v: jax.ShapeDtypeStruct(v.shape, v.dtype), g
        )
        return grad_sync.unshard_grads(
            ctx.sync_grads_sharded(g), like, ctx=ctx
        )

    out_sh = jax.jit(
        compat.shard_map(
            sharded_roundtrip, mesh=mesh, in_specs=(specs,), out_specs=specs
        )
    )(grads)
    out_ar = jax.jit(
        compat.shard_map(
            lambda g: ctx.sync_grads(g),
            mesh=mesh, in_specs=(specs,), out_specs=specs,
        )
    )(grads)
    ok = all(
        np.allclose(
            np.asarray(out_sh[k]), np.asarray(out_ar[k]),
            rtol=1e-5, atol=1e-6,
        )
        for k in grads
    )
    # per-chip shard sizes follow the stripe-block layout: ceil/ceil
    shard_shapes = jax.eval_shape(
        compat.shard_map(
            lambda g: ctx.sync_grads_sharded(g),
            mesh=mesh, in_specs=(specs,),
            out_specs={k: P(("pod", "data")) for k in grads},
        ),
        grads,
    )
    for k, g in grads.items():
        elems = int(np.prod(g.shape[1:]))  # per-chip local view
        stripe = -(-elems // 4)  # ceil(e / ppn)
        want = -(-stripe // 4)  # ceil(stripe / n): the block size
        ok &= shard_shapes[k].shape == (16 * want,)  # 16 stacked shards
    record("comm_sharded_grad_sync", ok)


def check_serve_continuous_batching():
    """Continuous batching on the meshed tensor-parallel serving engine
    is bitwise identical to serial one-request-at-a-time decoding
    through the same engine: slot scatter, padded-bucket prefill and
    mid-flight admission must not perturb any request's token stream.
    Also pins the decode-collective dispatch: per-token logits land on
    the latency-regime engine (NAP), the hidden gather on mla_ag, the
    EOS min-reduce on native psum."""
    from repro.configs import get_config, reduced
    from repro.models import build_model
    from repro.serve import PromptBuckets, ServeEngine

    mesh = make_mesh((2, 4), ("pod", "data"))
    cfg = reduced(get_config("minicpm-2b"))
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.PRNGKey(0))

    def engine(num_slots):
        return ServeEngine(
            model, params, num_slots=num_slots, max_len=24,
            buckets=PromptBuckets([4, 8]), mesh=mesh,
        )

    # heterogeneous prompts (two buckets) and budgets
    workload = [
        ([3, 1, 4], 5),
        ([1, 5, 9, 2, 6], 4),
        ([2, 7, 1, 8], 6),
    ]

    # serial reference: one request at a time through the same TP path
    serial = engine(10)
    ref = []
    for prompt, budget in workload:
        req = serial.submit(prompt, budget)
        out = serial.run()
        ref.append(out[req.rid])

    # continuous batching: 10 logical slots ragged over the 8-chip
    # group (ragged_splits -> b_max=2, padded to 16 rows); the third
    # request joins while the first two are mid-decode
    cont = engine(10)
    reqs = [cont.submit(p, b) for p, b in workload[:2]]
    cont.step()
    reqs.append(cont.submit(*workload[2]))
    out = cont.run()

    ok = cont.idle and all(
        out[req.rid] == ref[i] for i, req in enumerate(reqs)
    )
    disp = cont.dispatch_report()
    ok &= disp["logits_allreduce"]["engine"] == "nap"
    ok &= disp["hidden_allgather"]["engine"] == "mla_ag"
    ok &= disp["eos_min_reduce"]["engine"] == "psum"
    record(
        "serve_continuous_batching", ok,
        tokens=[out[r.rid] for r in reqs],
        logits_engine=disp["logits_allreduce"]["engine"],
    )


def main():
    assert jax.device_count() == N_DEV, jax.device_count()
    check_allreduce_correctness()
    check_mla_allreduce()
    check_ragged_roundtrips()
    check_auto_dispatch()
    check_schedule_cache()
    check_internode_message_reduction()
    check_nonpower_mesh()
    check_multiaxis_hierarchy()
    check_op_dtype_matrix()
    check_mla_pipelined_execution()
    check_fixed_threshold_ppn1()
    check_grad_sync()
    check_grad_sync_dtypes()
    check_grad_sync_mla()
    check_grad_sync_pipelined()
    check_grad_sync_bucketed()
    check_grad_sync_compressed_int16()
    check_grad_sync_compressed_int4()
    check_comm_sharded_grad_sync_compressed()
    check_dp_training_ef_convergence()
    check_dp_training_nap_equals_psum()
    check_nap_extensions()
    check_comm_context_equivalence()
    check_comm_reduce_scatter_allgather()
    check_serve_continuous_batching()
    print("RESULTS_JSON:" + json.dumps(RESULTS))


if __name__ == "__main__":
    main()
