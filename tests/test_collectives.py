"""Wrapper running the multi-device collective checks in a subprocess.

The checks need >1 XLA CPU device, which requires setting XLA_FLAGS before
jax is first imported; the main pytest process keeps the default single
device (per the dry-run isolation rule), so these run out-of-process.
"""

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

_SCRIPT = Path(__file__).parent / "_multidevice_checks.py"
_SRC = str(Path(__file__).parent.parent / "src")


@pytest.fixture(scope="module")
def check_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["REPRO_CHECK_DEVICES"] = "16"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, str(_SCRIPT)],
        capture_output=True,
        text=True,
        env=env,
        timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [
        l for l in proc.stdout.splitlines() if l.startswith("RESULTS_JSON:")
    ]
    assert line, proc.stdout[-4000:]
    return json.loads(line[-1][len("RESULTS_JSON:") :])


_EXPECTED = [
    "correct_nap",
    "correct_rd",
    "correct_smp",
    "correct_psum",
    "correct_ring",
    "correct_rabenseifner",
    "correct_mla_pow2",
    "correct_mla_ragged",
    "correct_mla_tiny",
    "correct_mla_multiaxis",
    "ragged_roundtrip_ring",
    "ragged_roundtrip_rabenseifner",
    "ragged_roundtrip_mla",
    "auto_dispatch_model_driven",
    "schedule_cache_hits",
    "correct_nap_max",
    "correct_nap_min",
    "hlo_permute_counts",
    "correct_nap_nonpower_8x2",
    "correct_nap_multiaxis",
    "op_dtype_matrix_g4x4_fixed",
    "op_dtype_matrix_g4x4_auto",
    "op_dtype_matrix_g5x3_fixed",
    "op_dtype_matrix_g5x3_auto",
    "op_dtype_matrix_g6x1_fixed",
    "op_dtype_matrix_g6x1_auto",
    "mla_pipelined_execution",
    "fixed_threshold_ppn1",
    "grad_sync_nap_mean",
    "grad_sync_compressed",
    "grad_sync_dtype_semantics",
    "grad_sync_compressed_dtypes",
    "grad_sync_mla_mean",
    "grad_sync_pipelined",
    "grad_sync_bucketed_mixed_dtype",
    "grad_sync_single_leaf",
    "grad_sync_pinned_plan",
    "grad_sync_compressed_int16",
    "grad_sync_compressed_per_leaf_scale",
    "grad_sync_compressed_int4",
    "comm_sharded_grad_sync_compressed_int8",
    "comm_sharded_grad_sync_compressed_int4",
    "dp_train_ef_convergence",
    "dp_train_nap_equals_psum",
    "nap_allgather",
    "nap_reduce_scatter",
    "nap_allreduce_large",
    "comm_ctx_allreduce_bitwise",
    "comm_ctx_grad_sync_bitwise",
    "comm_rs_ag_roundtrip",
    "comm_sharded_grad_sync",
    "serve_continuous_batching",
]


@pytest.mark.parametrize("name", _EXPECTED)
def test_multidevice_check(check_results, name):
    assert name in check_results, f"check {name} did not run"
    assert check_results[name]["ok"], check_results[name]
