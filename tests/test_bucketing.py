"""Bucket scheduler planner + grad-sync edge cases (host-side).

Covers the PR-3 tentpole and satellites: size-targeted dtype-pure
packing, chunk-aligned bucket boundaries (ragged-split geometry, per-chip
inter-node bytes at the uneven-block lower bound), the saturated
crossover (``math.inf``, not the 4 MiB search cap), the narrowed
compressed transport dtype, the bucket-size optimum, and the simulator's
compute-port replay showing async bucketed sync <= serial sync.
Execution correctness of the same plans runs in the multi-device suite
(tests/_multidevice_checks.py).
"""

from __future__ import annotations

import dataclasses
import math

import pytest

from repro.core import bucketing, napalg, perf_model as pm, simulator as sim

TPU = pm.TPU_V5E_POD

# a machine whose bandwidth is effectively free: the alpha bill dominates
# at every size, so NAP (fewest inter-node steps) never loses and the
# NAP↔MLA crossover saturates
SATURATED = pm.MachineParams(
    alpha_l=1.0e-6,
    beta_l=1.0e-30,
    alpha=1.0e-4,
    R_b=1.0e30,
    R_N=1.0e30,
    gamma=0.0,
    name="saturated",
)


def _leaf(i, elems, itemsize=4, dtype="float32", fusible=True, tit=None):
    return bucketing.LeafSpec(
        index=i, elems=elems, itemsize=itemsize, dtype=dtype,
        fusible=fusible, transport_itemsize=tit,
    )


def _covered_indices(plan):
    out = []
    for b in plan.buckets:
        out.extend(b.leaves)
    return out


# ---------------------------------------------------------------------------
# packing invariants
# ---------------------------------------------------------------------------


def test_every_leaf_in_exactly_one_bucket():
    leaves = tuple(
        _leaf(i, 256 * (1 + i % 5)) for i in range(23)
    ) + (_leaf(23, 7, dtype="int32", fusible=False),)
    plan = bucketing.plan_buckets(leaves, 8, 16)
    got = _covered_indices(plan)
    assert sorted(got) == list(range(24))


def test_buckets_are_dtype_pure_and_issue_reverse():
    leaves = (
        _leaf(0, 100, 4, "float32"),
        _leaf(1, 100, 2, "bfloat16"),
        _leaf(2, 100, 4, "float32"),
        _leaf(3, 100, 2, "bfloat16"),
    )
    plan = bucketing.plan_buckets(leaves, 4, 4, bucket_bytes=1 << 20)
    for b in plan.buckets:
        assert len({b.dtype}) == 1
        # leaves within a bucket are in reverse-index (issue) order
        assert list(b.leaves) == sorted(b.leaves, reverse=True)
    dtypes = {b.dtype for b in plan.buckets}
    assert dtypes == {"float32", "bfloat16"}
    # mixed dtypes never share a bucket
    for b in plan.buckets:
        assert all(leaves[i].dtype == b.dtype for i in b.leaves)


def test_bf16_budgeted_at_native_width_no_inflation():
    """Regression (satellite 1): fusing bf16 by casting to f32 doubled
    transported bytes; the planner must budget post-cast (native) bytes
    and the fused bucket's transport must equal the native sum."""
    leaves = tuple(_leaf(i, 1000, 2, "bfloat16") for i in range(8))
    plan = bucketing.plan_buckets(leaves, 8, 16, bucket_bytes=16000)
    fused = [b for b in plan.buckets if len(b.leaves) > 1]
    assert fused
    for b in fused:
        assert b.transport_bytes == sum(1000 * 2 for _ in b.leaves)
        assert b.nbytes == b.transport_bytes
    # with the f32 inflation bug, 8 leaves x 4000 "cast" bytes would
    # close the 16 kB bucket after 4 leaves; native-width budgeting
    # packs all 8 (8 x 2000 = 16000)
    assert max(len(b.leaves) for b in fused) == 8


def test_int_leaves_never_fuse():
    leaves = (
        _leaf(0, 64, 4, "int32", fusible=False),
        _leaf(1, 64),
        _leaf(2, 64, 4, "int32", fusible=False),
        _leaf(3, 64),
    )
    plan = bucketing.plan_buckets(leaves, 4, 4)
    for b in plan.buckets:
        if b.dtype == "int32":
            assert len(b.leaves) == 1
    float_buckets = [b for b in plan.buckets if b.dtype == "float32"]
    assert {i for b in float_buckets for i in b.leaves} == {1, 3}


def test_single_small_leaf_no_fusion():
    plan = bucketing.plan_buckets((_leaf(0, 4),), 8, 16)
    assert plan.num_buckets == 1
    assert plan.buckets[0].leaves == (0,)
    assert plan.buckets[0].algorithm == "nap"  # latency regime
    assert plan.buckets[0].chunks == 1


def test_fuse_disabled_gives_one_bucket_per_leaf():
    leaves = tuple(_leaf(i, 128) for i in range(6))
    plan = bucketing.plan_buckets(leaves, 4, 4, fuse=False)
    assert plan.num_buckets == 6
    assert all(len(b.leaves) == 1 for b in plan.buckets)


def test_plan_is_cached():
    leaves = tuple(_leaf(i, 512) for i in range(4))
    a = bucketing.plan_buckets(leaves, 8, 16)
    b = bucketing.plan_buckets(leaves, 8, 16)
    assert a is b


# ---------------------------------------------------------------------------
# chunk alignment (tentpole geometry)
# ---------------------------------------------------------------------------


def test_chunk_offsets_and_alignment_helpers():
    assert napalg.chunk_offsets(10, 4) == (3, 6, 8)
    assert napalg.chunk_offsets(8, 1) == ()
    assert napalg.chunk_alignment((1000,) * 8, 4) == 1.0
    assert napalg.chunk_alignment((1000,) * 7, 4) == 0.0
    assert napalg.chunk_alignment((1000,) * 6, 4) == pytest.approx(1 / 3)
    assert napalg.chunk_alignment((5, 5), 1) == 1.0


def test_bucket_boundaries_snap_to_chunk_grid():
    """With uniform leaves and a pinned depth, the planner must close the
    bucket at a leaf count whose ragged chunk grid lands on leaf
    boundaries (keep=4: boundaries at L, 2L, 3L) instead of the
    byte-target close (keep=7: all three boundaries straddle leaves)."""
    L = 1100
    leaves = tuple(_leaf(i, L) for i in range(14))
    plan = bucketing.plan_buckets(
        leaves, 8, 16, bucket_bytes=30000, pipeline_chunks=4,
        algorithm="mla_pipelined",
    )
    multi = [b for b in plan.buckets if len(b.leaves) > 1]
    assert multi
    for b in multi:
        # the executed chunk splits ARE the ragged geometry
        assert b.chunk_splits == napalg.ragged_splits(b.elems, b.chunks)
        assert sum(b.chunk_splits) == b.elems
    # the snap genuinely moved the close point off the pure byte target
    # (7 leaves: alignment 0) to the aligned 4-leaf grid; the leftover
    # tail bucket (too few leaves for the pinned depth) is exempt
    snapped = [b for b in multi if len(b.leaves) == 4]
    assert snapped
    for b in snapped:
        sizes = tuple(L for _ in b.leaves)
        assert napalg.chunk_alignment(sizes, b.chunks) == 1.0


def test_fused_bucket_internode_bytes_at_lower_bound():
    """Acceptance: fused-bucket chunk boundaries coincide with the
    ragged_splits geometry, so per-chip inter-node bytes of the replayed
    schedule equal the uneven-block lower bound exactly."""
    n, ppn = 16, 16
    leaves = tuple(_leaf(i, 300_000 + 17 * i) for i in range(12))
    plan = bucketing.plan_buckets(leaves, n, ppn, bucket_bytes=4 << 20)
    checked = 0
    for b in plan.buckets:
        if b.algorithm not in ("mla", "mla_pipelined"):
            continue
        itemsize = b.transport_bytes / b.elems
        sched = (
            napalg.build_mla_pipelined_schedule(n, ppn, b.chunks, b.elems)
            if b.chunks > 1
            else napalg.build_mla_schedule(n, ppn, b.elems)
        )
        got = sched.max_internode_bytes_per_chip(float(b.transport_bytes))
        want = napalg.mla_internode_lower_bound(n, ppn, b.elems) * itemsize
        assert got == pytest.approx(want)
        checked += 1
    assert checked >= 2


# ---------------------------------------------------------------------------
# saturated crossover (satellite 2)
# ---------------------------------------------------------------------------


def test_crossover_saturation_returns_inf():
    xo = pm.crossover_bytes(16, 16, SATURATED, large="mla")
    assert math.isinf(xo)
    # normal machines keep a finite, in-range crossover
    assert 8.0 <= pm.crossover_bytes(16, 16, TPU, large="mla") <= 1 << 22


def test_saturated_crossover_dispatch():
    """inf must mean "latency regime everywhere": the dispatcher keeps
    NAP at any payload size instead of flipping to MLA at a phantom
    4 MiB switch point."""
    from repro.core import collectives

    assert math.isinf(collectives.auto_crossover_bytes(16, 16, SATURATED))
    for nbytes in [64, 1 << 22, 1 << 28]:
        assert (
            collectives.select_algorithm(nbytes, 16, 16, SATURATED) == "nap"
        )
    # and the planner follows: every fusible bucket stays on NAP
    leaves = tuple(_leaf(i, 1 << 20) for i in range(4))
    plan = bucketing.plan_buckets(leaves, 16, 16, params=SATURATED)
    assert math.isinf(plan.crossover_bytes)
    assert all(b.algorithm == "nap" for b in plan.buckets)
    # the fusion target must NOT be inf — bucket sizing is decoupled
    assert math.isfinite(plan.target_bytes)


# ---------------------------------------------------------------------------
# compressed transport dtype (satellite 3)
# ---------------------------------------------------------------------------


def test_compressed_transport_dtype_narrowest_safe():
    import jax.numpy as jnp

    from repro.core.grad_sync import compressed_transport_dtype

    assert compressed_transport_dtype(1, 8) == jnp.dtype(jnp.int8)
    # group * qmax = 256 * 127 = 32512 <= 32767
    assert compressed_transport_dtype(256, 8) == jnp.dtype(jnp.int16)
    assert compressed_transport_dtype(257, 8) == jnp.dtype(jnp.int16)
    # 1024 * 127 overflows int16
    assert compressed_transport_dtype(1024, 8) == jnp.dtype(jnp.int32)
    # byte accounting: int16 transport is half the f32 payload
    assert compressed_transport_dtype(256, 8).itemsize * 2 == 4


def test_planner_budgets_compressed_leaves_post_cast():
    """A compressed f32 leaf moves 2-byte words (group <= 257), so the
    planner must budget and dispatch it at half its raw bytes."""
    tit = 2
    elems = 30_000
    raw = tuple(_leaf(i, elems) for i in range(2))
    comp = tuple(_leaf(i, elems, tit=tit) for i in range(2))
    xo = pm.crossover_bytes(8, 16, TPU, large="mla")
    # sizes chosen so raw is above the crossover but compressed is below
    assert elems * tit < xo < elems * 4
    plan_raw = bucketing.plan_buckets(raw, 8, 16, fuse=False)
    plan_comp = bucketing.plan_buckets(comp, 8, 16, fuse=False)
    assert all(b.algorithm == "mla" for b in plan_raw.buckets)
    assert all(b.algorithm == "nap" for b in plan_comp.buckets)
    assert plan_comp.total_transport_bytes == 2 * elems * tit


# ---------------------------------------------------------------------------
# bucket-size optimum + compute-port replay (tentpole measurables)
# ---------------------------------------------------------------------------


def test_optimal_bucket_bytes_scales():
    small = pm.optimal_bucket_bytes(1024.0, 16, 16, TPU)
    assert small == 1024.0  # one bucket: nothing to overlap
    total = float(256 << 20)
    b = pm.optimal_bucket_bytes(total, 16, 16, TPU)
    assert 0 < b < total  # large payloads genuinely split
    k = total / b
    assert 2 <= k <= 64


def test_dispatched_cost_matches_regimes():
    xo = pm.crossover_bytes(16, 16, TPU, large="mla")
    s_small, s_big = xo / 4, xo * 64
    assert pm.dispatched_allreduce_cost(s_small, 16, 16, TPU) == (
        pm.cost_nap(s_small, 16, 16, TPU)
    )
    big = pm.dispatched_allreduce_cost(s_big, 16, 16, TPU)
    assert big == pm.cost_mla_pipelined(s_big, 16, 16, TPU, chunks=None)
    assert big <= pm.cost_nap(s_big, 16, 16, TPU)


def test_async_bucketed_sync_beats_serial_16x16():
    """Acceptance: on a 16x16 grid, the simulator's compute-port replay
    of a multi-bucket plan shows async wall-clock <= serial wall-clock
    (and strictly better when compute spread is comparable to comm)."""
    n, ppn = 16, 16
    leaves = tuple(
        _leaf(2 * i, 2_000_000) for i in range(6)
    ) + tuple(_leaf(2 * i + 1, 256) for i in range(6))
    plan = bucketing.plan_buckets(leaves, n, ppn)
    rows = plan.sim_rows()
    assert len(rows) >= 2  # genuinely multi-bucket
    t_flat = sim.simulate_bucketed_sync(rows, n, ppn, TPU)
    spread = [(i + 1) * t_flat / len(rows) for i in range(len(rows))]
    t_async = sim.simulate_bucketed_sync(
        rows, n, ppn, TPU, compute_times=spread, overlap=True
    )
    t_serial = sim.simulate_bucketed_sync(
        rows, n, ppn, TPU, compute_times=spread, overlap=False
    )
    assert t_async <= t_serial
    assert t_async < t_serial * 0.95  # the overlap is real, not a tie
    # zero compute spread: async degenerates to exactly the serial sum
    t0 = sim.simulate_bucketed_sync(rows, n, ppn, TPU, overlap=True)
    t1 = sim.simulate_bucketed_sync(rows, n, ppn, TPU, overlap=False)
    assert t0 == pytest.approx(t1)


def test_sim_rows_round_trip():
    leaves = tuple(_leaf(i, 10_000) for i in range(3))
    plan = bucketing.plan_buckets(leaves, 8, 16)
    rows = plan.sim_rows()
    assert len(rows) == plan.num_buckets
    for (nb, algo, chunks, elems), b in zip(rows, plan.buckets):
        assert nb == float(b.transport_bytes)
        assert algo == b.algorithm
        assert chunks == b.chunks
        assert elems == b.elems


def test_plan_for_tree_and_signature_validation():
    import jax
    import jax.numpy as jnp

    from repro.core import grad_sync

    tree = {
        "a": jax.ShapeDtypeStruct((64,), jnp.float32),
        "b": jax.ShapeDtypeStruct((32,), jnp.bfloat16),
    }
    cfg = grad_sync.GradSyncConfig()
    plan = grad_sync.plan_for_tree(tree, cfg=cfg, n=4, ppn=4)
    assert sorted(_covered_indices(plan)) == [0, 1]
    # a mismatched plan is rejected before any collective is issued
    other = {"a": jnp.zeros((65,), jnp.float32), "b": jnp.zeros((32,), jnp.bfloat16)}
    with pytest.raises(ValueError, match="bucket plan"):
        grad_sync.sync_grads_local(
            other, cfg=cfg, inter_axes=(), intra_axes=(), plan=plan
        )


def test_benchmark_payload_has_overlap_tables():
    """The BENCH_3.json artifact must carry the overlap + byte tables."""
    import benchmarks.gradsync as gs

    csv_rows, table = gs.overlap_section(2, 16)
    assert any("overlap_speedup" in name for name, _, _ in csv_rows)
    assert table["serial_s"] >= table["async_s"]
    mla_buckets = [
        b for b in table["buckets"]
        if b["algorithm"] in ("mla", "mla_pipelined")
    ]
    assert mla_buckets
    for b in mla_buckets:
        assert b["internode_bytes_per_chip"] == pytest.approx(
            b["internode_lower_bound"]
        )


def test_benchmark_compression_payload_ratios():
    """BENCH_6.json acceptance: per-chip inter-node bytes at packed int4
    are 1/8 of uncompressed f32 (int8: 1/4) on every float MLA bucket
    above the crossover, and the payload carries step-time deltas."""
    import benchmarks.gradsync as gs

    rows, payload = gs.compression_collect()
    assert payload["bench"] == "gradsync_compression"
    for grid, table in payload["grids"].items():
        assert table["ratios_ok"], grid
        for b in table["buckets"]:
            w4 = b["wire_bytes"][4]
            if "ratio_vs_f32" in w4:
                assert w4["ratio_vs_f32"] == pytest.approx(0.125, abs=1e-3)
                assert b["wire_bytes"][8]["ratio_vs_f32"] == pytest.approx(
                    0.25, abs=1e-3
                )
        assert set(table["step_speedup_vs_f32"]) == {4, 8, 16, 32}
    assert any("step_speedup" in name for name, _, _ in rows)
