"""Schedule-verifier coverage: real engines pass, mutants fail.

Three layers:

* **sweep** — every registered engine with a schedule builder passes all
  four verifier passes over the tier-1 grid matrix, including the
  degenerate (``n=1``, ``ppn=1``) and prime (3, 5, 7, 13 nodes) grids
  with ragged payloads;
* **mutation** — each verifier rule fires on a deliberately broken
  schedule (dropped recv, cyclic dep, duplicated contribution, inflated
  bytes): no vacuous passes;
* **integration** — ``comm.verify_engine`` and the verify-on-register
  gate reject a broken builder and roll the registry back.
"""

import dataclasses

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.analysis import schedule_verifier as sv
from repro.core import comm, napalg, simulator


def _builder_engines():
    return sorted(
        key
        for key, spec in comm.registered_engines().items()
        if spec.build_schedule is not None
    )


def _spec(key):
    collective, name = key.split(":", 1)
    return comm.get_engine(name, collective)


# ---------------------------------------------------------------------------
# sweep: every engine x tier-1 grid matrix (degenerate + prime + ragged)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("key", _builder_engines())
@pytest.mark.parametrize(
    "n,ppn",
    [(1, 1), (1, 4), (2, 1), (3, 1), (2, 2), (3, 2), (5, 4), (7, 3),
     (13, 2)],
)
@pytest.mark.parametrize("elems", [None, 1, 7, 193])
def test_engine_passes_verifier(key, n, ppn, elems):
    spec = _spec(key)
    chunks = 3 if spec.chunked else 1
    report = sv.verify_spec(spec, n, ppn, elems=elems, chunks=chunks)
    assert report.ok, report.violations


def test_full_grid_matrix_zero_violations():
    """The BENCH_7 sweep itself: every engine x GRID_MATRIX x payloads."""
    for key in _builder_engines():
        reports = sv.verify_spec_grid(_spec(key))
        bad = [r for r in reports if not r.ok]
        assert not bad, (key, bad[0].to_row() if bad else None)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=9),
    ppn=st.integers(min_value=1, max_value=5),
    elems=st.one_of(st.none(), st.integers(min_value=1, max_value=400)),
    key=st.sampled_from(
        ["allreduce:nap", "allreduce:rd", "allreduce:smp", "allreduce:mla",
         "allreduce:mla_pipelined", "reduce_scatter:mla_rs",
         "allgather:mla_ag"]
    ),
    chunks=st.integers(min_value=1, max_value=4),
)
def test_fuzz_all_invariants(n, ppn, elems, key, chunks):
    """Any grid the dispatcher could route to an engine verifies clean.

    Grids below an engine's declared minimum are clamped up to it (the
    compat shim has no ``assume``), so every draw exercises the four
    passes rather than skipping.
    """
    spec = _spec(key)
    n = max(n, spec.min_nodes)
    ppn = max(ppn, spec.min_ppn)
    report = sv.verify_spec(spec, n, ppn, elems=elems, chunks=chunks)
    assert report.checked == sv.RULES
    assert report.ok, report.violations


# ---------------------------------------------------------------------------
# mutation: each rule demonstrably fires
# ---------------------------------------------------------------------------


def _rules(report):
    return {v.rule for v in report.violations}


def test_dropped_recv_fires_match_rule():
    """Removing one message while keeping recv_chips leaves an orphan
    recv (fold mask would admit garbage) and a dropped contribution."""
    s = napalg.build_nap_schedule(3, 2)
    st0 = s.steps[0]
    rounds = tuple(
        tuple(rnd[:-1]) if i == 0 else rnd
        for i, rnd in enumerate(st0.rounds)
    )
    mut = dataclasses.replace(
        s, steps=(dataclasses.replace(st0, rounds=rounds),) + s.steps[1:]
    )
    report = sv.verify_schedule(mut, engine="nap")
    assert "match" in _rules(report)
    assert "reduction" in _rules(report)
    assert any("orphan recv" in v.message for v in report.violations)


def test_cyclic_dep_fires_deadlock_rule_with_trace():
    s = napalg.build_mla_pipelined_schedule(2, 2, 2, 16)
    steps = list(s.steps)
    steps[1] = dataclasses.replace(steps[1], dep=2)
    steps[2] = dataclasses.replace(steps[2], dep=1)
    mut = dataclasses.replace(s, steps=tuple(steps))
    report = sv.verify_schedule(
        mut, engine="mla_pipelined", elems=16, chunks=2
    )
    assert "deadlock" in _rules(report)
    # the counterexample trace names the cycle steps
    assert any(
        "cycle" in v.message and "step 1" in v.message and "step 2"
        in v.message
        for v in report.violations
        if v.rule == "deadlock"
    )


def test_forward_dep_fires_deadlock_rule():
    s = napalg.build_mla_pipelined_schedule(2, 2, 2, 16)
    steps = list(s.steps)
    steps[0] = dataclasses.replace(steps[0], dep=len(steps) - 1)
    mut = dataclasses.replace(s, steps=tuple(steps))
    report = sv.verify_schedule(
        mut, engine="mla_pipelined", elems=16, chunks=2
    )
    assert any(
        v.rule == "deadlock" and "forward dep" in v.message
        for v in report.violations
    )


def test_duplicated_contribution_fires_reduction_rule():
    """A duplicated self-chip double-counts that chip's partial — the
    exact bug class (duplicate contributions) the paper eliminates."""
    s = napalg.build_nap_schedule(3, 2)
    st0 = s.steps[0]
    mut = dataclasses.replace(
        s,
        steps=(
            dataclasses.replace(
                st0, self_chips=st0.self_chips + st0.recv_chips[:1]
            ),
        )
        + s.steps[1:],
    )
    report = sv.verify_schedule(mut, engine="nap")
    assert "reduction" in _rules(report)
    assert any("duplicated" in v.message for v in report.violations)


def test_duplicated_message_fires_match_and_reduction():
    s = napalg.build_rd_schedule(2, 2)
    st0 = s.steps[0]
    mut = dataclasses.replace(
        s,
        steps=(dataclasses.replace(st0, pairs=st0.pairs + st0.pairs[:1]),)
        + s.steps[1:],
    )
    report = sv.verify_schedule(mut, engine="rd")
    assert {"match", "reduction"} <= _rules(report)


def test_inflated_bytes_fires_bytes_rule():
    """Scaling every fraction x1.5 keeps the schedule well-matched (all
    fracs stay in (0, 1]) but breaks byte accounting against both the
    stripe geometry and the declared uneven-block bound."""
    s = napalg.build_mla_schedule(3, 2, 17)
    steps = tuple(
        dataclasses.replace(
            step,
            frac=step.frac * 1.5 if step.fracs is None else step.frac,
            fracs=None if step.fracs is None
            else tuple(f * 1.5 for f in step.fracs),
        )
        for step in s.steps
    )
    mut = dataclasses.replace(s, steps=tuple(steps))
    report = sv.verify_schedule(mut, engine="mla", elems=17)
    assert _rules(report) == {"bytes"}


def test_unknown_fractional_kind_is_unverifiable_not_vacuous():
    """A fractional schedule of unknown kind must *fail* verification
    (the verifier cannot prove it) instead of passing vacuously."""
    s = napalg.build_mla_schedule(2, 2, 16)
    mut = dataclasses.replace(s, kind="generic")
    report = sv.verify_schedule(mut, engine="mystery", elems=16)
    assert any(
        v.rule == "reduction" and "extend the verifier" in v.message
        for v in report.violations
    )


def test_builder_crash_is_a_verification_failure():
    def crashing_builder(n, ppn):
        raise RuntimeError("boom")

    spec = comm.EngineSpec(
        name="crash", collective="allreduce", execute=lambda x, **k: x,
        build_schedule=crashing_builder,
    )
    report = sv.verify_spec(spec, 2, 2)
    assert not report.ok
    assert any("crashed" in v.message for v in report.violations)


# ---------------------------------------------------------------------------
# accounting helpers: iter_messages + replay cross-checks
# ---------------------------------------------------------------------------


def test_iter_messages_covers_both_schedule_types():
    nap = napalg.build_nap_schedule(3, 2)
    msgs = list(napalg.iter_messages(nap))
    assert len(msgs) == sum(
        len(rnd) for step in nap.steps for rnd in step.rounds
    )
    assert all(m.frac == 1.0 and m.combine for m in msgs)
    assert all(
        m.inter == (m.src // 2 != m.dst // 2) for m in msgs
    )

    mla = napalg.build_mla_schedule(3, 2, 17)
    msgs = list(napalg.iter_messages(mla))
    assert len(msgs) == sum(len(step.pairs) for step in mla.steps)
    assert all(0.0 < m.frac <= 1.0 for m in msgs)


@pytest.mark.parametrize(
    "sched",
    [
        napalg.build_nap_schedule(5, 3),
        napalg.build_rd_schedule(3, 2),
        napalg.build_mla_schedule(5, 3, 47),
        napalg.build_mla_pipelined_schedule(3, 2, 3, 29),
    ],
    ids=["nap", "rd", "mla", "mla_pipelined"],
)
def test_replay_bytes_matches_helper_and_endpoint_sum(sched):
    s = 4096.0
    replayed = simulator.replay_internode_bytes(sched, s)
    endpoint = sv.endpoint_internode_bytes(sched, s)
    np.testing.assert_allclose(replayed, endpoint, rtol=1e-9)
    assert replayed.max(initial=0.0) == pytest.approx(
        sched.max_internode_bytes_per_chip(s)
    )


# ---------------------------------------------------------------------------
# registry integration: verify_engine + verify-on-register
# ---------------------------------------------------------------------------


def test_verify_engine_passes_for_registered_engines():
    for key in _builder_engines():
        collective, name = key.split(":", 1)
        reports = comm.verify_engine(name)
        assert reports and all(r.ok for r in reports)


def test_verify_engine_single_grid_and_topology():
    reports = comm.verify_engine("mla", n_nodes=5, ppn=4, elems=193)
    assert [r.ok for r in reports] == [True]
    topo = comm.Topology.of(3, 2)
    reports = comm.verify_engine("nap", topo)
    assert [(r.n_nodes, r.ppn, r.ok) for r in reports] == [(3, 2, True)]


def _dup_message_builder(n, ppn):
    s = napalg.build_rd_schedule(n, ppn)
    st0 = s.steps[0]
    return dataclasses.replace(
        s,
        steps=(dataclasses.replace(st0, pairs=st0.pairs + st0.pairs[:1]),)
        + s.steps[1:],
    )


def test_register_engine_rejects_unverifiable_schedule(monkeypatch):
    """conftest sets REPRO_VERIFY_ON_REGISTER: a broken builder must be
    rejected at registration and rolled back out of the registry."""
    monkeypatch.setenv("REPRO_VERIFY_ON_REGISTER", "1")
    with pytest.raises(ValueError, match="failed static verification"):
        comm.register_engine(
            "broken_rd",
            execute=lambda x, **k: x,
            build_schedule=_dup_message_builder,
        )
    assert "broken_rd" not in comm.registered_engines("allreduce")


def test_register_engine_verify_opt_out(monkeypatch):
    """``verify=False`` skips the *schedule* invariants only.  The PR-8
    jaxpr lint still runs and closes the byte link against the builder's
    declared bound, so a broken builder is caught anyway — the opt-out
    is for native lowerings with no schedule object, which register
    cleanly as long as the lowering itself lints."""
    import jax.numpy as jnp
    from jax import lax

    monkeypatch.setenv("REPRO_VERIFY_ON_REGISTER", "1")

    def native_psum(x, *, topology, op="sum", pipeline_chunks=1):
        joint = topology.axes
        if op == "sum" and jnp.issubdtype(x.dtype, jnp.floating) and (
            jnp.dtype(x.dtype).itemsize < 4
        ):
            return lax.psum(x.astype(jnp.float32), joint).astype(x.dtype)
        return {"sum": lax.psum, "max": lax.pmax, "min": lax.pmin}[op](
            x, joint
        )

    try:
        comm.register_engine(
            "native_optout",
            execute=native_psum,
            ops={"sum", "max", "min"},
            verify=False,
        )
        assert "native_optout" in comm.registered_engines("allreduce")
    finally:
        comm._REGISTRY["allreduce"].pop("native_optout", None)

    # a broken schedule builder no longer slips through the opt-out:
    # the lint recomputes inter-node bytes from the traced lowering and
    # holds them against the (corrupted) declared bound
    with pytest.raises(ValueError, match="spmd lint"):
        comm.register_engine(
            "broken_rd_optout",
            execute=lambda x, **k: x,
            build_schedule=_dup_message_builder,
            verify=False,
        )
    assert "broken_rd_optout" not in comm.registered_engines("allreduce")


def test_register_engine_no_verify_when_env_unset(monkeypatch):
    monkeypatch.delenv("REPRO_VERIFY_ON_REGISTER", raising=False)
    try:
        comm.register_engine(
            "broken_rd_noenv",
            execute=lambda x, **k: x,
            build_schedule=_dup_message_builder,
        )
        assert "broken_rd_noenv" in comm.registered_engines("allreduce")
    finally:
        comm._REGISTRY["allreduce"].pop("broken_rd_noenv", None)


def test_verify_engine_reports_are_json_safe():
    import json

    reports = comm.verify_engine("mla_pipelined")
    json.dumps([r.to_row() for r in reports])
