"""Topology-first collective API: Topology, engine registry, CommContext.

Host-side coverage of the PR-4 api_redesign (execution equivalence runs
in tests/_multidevice_checks.py):

* golden-table dispatch equivalence: ``comm.select_engine`` with the
  default policy vs a frozen reimplementation of the PR-3
  ``select_algorithm`` rules, across grids x payload sizes x ops x
  threshold modes;
* registry validation: typos raise at config/context build time with
  the engine listing (not a bare KeyError inside tracing);
* the deprecation shims warn exactly once;
* RS/AG promotion: schedule byte accounting equals the ragged one-way
  lower bounds, simulator replay included;
* ``MachineParams.fit`` recovers generating constants;
* ``compressed_transport_dtype`` refuses the silent-int64 overflow.
"""

import math
import types
import warnings

import numpy as np
import pytest

from repro.core import bucketing, collectives, comm, grad_sync, napalg
from repro.core import perf_model as pm
from repro.core import simulator as sim

GRIDS = [(1, 16), (2, 16), (4, 4), (5, 3), (6, 1), (8, 16), (16, 16), (64, 16)]
SIZES = [4, 512, 2048, 1 << 16, 1 << 20, 16 << 20, 64 << 20]


def _legacy_select(nbytes, n, ppn, op="sum", small=None, params=None):
    """Frozen copy of the PR-3 dispatch rules (the golden table)."""
    mp = params or pm.TPU_V5E_POD
    if n <= 1:
        return "psum"
    if op not in ("sum", "max", "min"):
        return "nap" if ppn > 1 else "psum"
    if small is not None:
        threshold = float(small)
    elif ppn <= 1:
        threshold = 0.0
    else:
        threshold = pm.crossover_bytes(n, ppn, mp, large="mla")
    if ppn > 1 and nbytes <= threshold:
        return "nap"
    chunks = pm.optimal_pipeline_chunks(float(nbytes), n, ppn, mp)
    return "mla_pipelined" if chunks > 1 else "mla"


# ---------------------------------------------------------------------------
# Topology
# ---------------------------------------------------------------------------


def test_topology_construction_and_validation():
    t = comm.Topology.of(8, 16)
    assert (t.n_nodes, t.ppn, t.group) == (8, 16, 128)
    assert t.has_slow_domain and t.axes == ()
    assert t.params is pm.TPU_V5E_POD
    with pytest.raises(ValueError):
        comm.Topology.of(0, 16)
    # hashable + equal instances share the cached derived state
    assert comm.Topology.of(8, 16) == t
    assert hash(comm.Topology.of(8, 16)) == hash(t)


def test_topology_from_mesh_duck_typed():
    mesh = types.SimpleNamespace(
        axis_names=("pod", "data", "model"),
        devices=np.empty((2, 4, 2)),
    )
    t = comm.Topology.from_mesh(mesh)
    # hierarchy_axes: "pod" is the slow domain, "data" the DP lane axis
    assert (t.n_nodes, t.ppn) == (2, 4)
    assert t.inter_axes == ("pod",) and t.intra_axes == ("data",)
    t2 = comm.Topology.from_mesh(
        mesh, inter_axes="pod", intra_axes=("data", "model")
    )
    assert (t2.n_nodes, t2.ppn) == (2, 8)
    with pytest.raises(ValueError):
        comm.Topology.from_mesh(mesh, inter_axes="nonexistent", intra_axes="data")
    # overriding ONE level keeps the hierarchy default for the other
    # (dropping it silently would yield a partial reduction)
    t3 = comm.Topology.from_mesh(mesh, intra_axes=("data", "model"))
    assert t3.inter_axes == ("pod",) and t3.ppn == 8
    with pytest.raises(ValueError, match="both"):
        comm.Topology.from_mesh(mesh, inter_axes="data")  # overlaps default


def test_execution_requires_axis_names():
    """A planning-only Topology (Topology.of) must refuse to execute —
    the collectives would silently return unreduced values otherwise."""
    ctx = comm.CommContext(comm.Topology.of(2, 4))
    x = np.zeros(8, np.float32)
    for call in (
        lambda: ctx.allreduce(x),
        lambda: ctx.reduce_scatter(x),
        lambda: ctx.allgather(x, elems=8),
    ):
        with pytest.raises(ValueError, match="planning-only"):
            call()
    # single-chip topologies have nothing to reduce: no axes needed
    comm.Topology.of(1, 1).require_axes()


def test_register_engine_rejects_duplicates():
    with pytest.raises(ValueError, match="already registered"):
        comm.register_engine("mla", execute=lambda x, **k: x)
    assert comm.get_engine("mla").cost is pm.cost_mla  # untouched


def test_legacy_algorithms_view_is_read_only_and_stable():
    table = collectives.ALGORITHMS
    assert collectives.ALGORITHMS is table  # identity-stable
    with pytest.raises(TypeError):
        table["custom"] = lambda x: x  # mutation fails loudly


def test_topology_owns_cached_derived_state():
    t = comm.Topology.of(16, 16)
    assert t.crossover_bytes() == collectives.auto_crossover_bytes(16, 16)
    assert t.crossover_bytes() == pm.crossover_bytes(
        16, 16, pm.TPU_V5E_POD, large="mla"
    )
    # degenerate grids: inf (no slow domain) / 0.0 (no lanes)
    assert math.isinf(comm.Topology.of(1, 16).crossover_bytes())
    assert comm.Topology.of(16, 1).crossover_bytes() == 0.0
    # schedules come from the same lru-cached builders
    assert t.schedule("nap") is napalg.build_nap_schedule(16, 16)
    assert t.schedule("mla", elems=1000) is napalg.build_mla_schedule(
        16, 16, 1000
    )
    assert t.schedule("mla_pipelined", chunks=3, elems=1000) is (
        napalg.build_mla_pipelined_schedule(16, 16, 3, 1000)
    )
    assert t.chunk_splits(10, 3) == napalg.ragged_splits(10, 3)
    assert t.internode_lower_bound(1000) == napalg.mla_internode_lower_bound(
        16, 16, 1000
    )
    assert t.internode_lower_bound(1000, "reduce_scatter") * 2 == (
        t.internode_lower_bound(1000)
    )
    assert t.optimal_pipeline_chunks(64 << 20) == pm.optimal_pipeline_chunks(
        float(64 << 20), 16, 16, pm.TPU_V5E_POD
    )


# ---------------------------------------------------------------------------
# dispatch: golden-table equivalence (acceptance criterion)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("op", ["sum", "max", "min"])
@pytest.mark.parametrize("small", [None, 2048])
def test_dispatch_golden_table(op, small):
    """CommContext default-policy dispatch == PR-3 auto dispatch, exactly,
    across grids x payload sizes x ops x threshold modes."""
    for n, ppn in GRIDS:
        topo = comm.Topology.of(n, ppn)
        ctx = comm.CommContext(
            topo, comm.CommPolicy(small_threshold_bytes=small)
        )
        for nbytes in SIZES:
            want = _legacy_select(nbytes, n, ppn, op, small)
            got = ctx.dispatch(nbytes, op).engine
            assert got == want, (n, ppn, nbytes, op, small, got, want)
            # the legacy wrapper rides the same registry path
            assert (
                collectives.select_algorithm(
                    nbytes, n, ppn, op=op, small_threshold_bytes=small
                )
                == want
            )


def test_dispatch_pinned_and_chunk_resolution():
    topo = comm.Topology.of(8, 16)
    ctx = comm.CommContext(topo)
    # pinned engines pass through with depth semantics of the planner
    assert ctx.dispatch(1 << 20, algorithm="nap") == ("nap", 1)
    assert ctx.dispatch(1 << 20, algorithm="mla") == ("mla", 1)
    assert ctx.dispatch(1 << 20, algorithm="mla", pipeline_chunks=4) == (
        "mla",
        4,
    )
    d = ctx.dispatch(64 << 20, algorithm="mla_pipelined")
    assert d.engine == "mla_pipelined"
    assert d.chunks == topo.optimal_pipeline_chunks(64 << 20) > 1
    # auto + pinned depth promotes a plain-MLA winner to its variant
    small = comm.CommContext(
        topo, comm.CommPolicy(pipeline_chunks=4)
    ).dispatch(1 << 16)
    assert small == ("mla_pipelined", 4) or small.engine == "nap"


def test_bucket_planner_decisions_ride_the_registry():
    leaves = tuple(
        bucketing.LeafSpec(
            index=i, elems=4096 * (i + 1), itemsize=4, dtype="float32",
            fusible=True,
        )
        for i in range(4)
    )
    topo = comm.Topology.of(8, 16)
    plan_t = bucketing.plan_buckets(leaves, topo)
    plan_l = bucketing.plan_buckets(leaves, 8, 16)
    assert plan_t is plan_l  # same cache entry: Topology keys the cache
    for b in plan_t.buckets:
        want = _legacy_select(b.transport_bytes, 8, 16)
        assert b.algorithm == want
    with pytest.raises(ValueError, match="registered engines"):
        bucketing.plan_buckets(leaves, topo, algorithm="mla_typo")


# ---------------------------------------------------------------------------
# registry validation (satellite: typos fail at build time, listed)
# ---------------------------------------------------------------------------


def test_engine_name_validation_lists_registry():
    with pytest.raises(ValueError) as ei:
        comm.get_engine("mla_pipelne")
    msg = str(ei.value)
    for name in ("nap", "mla", "mla_pipelined", "psum", "ring"):
        assert name in msg
    with pytest.raises(ValueError, match="registered engines"):
        comm.CommPolicy(algorithm="napp")
    with pytest.raises(ValueError, match="registered engines"):
        grad_sync.GradSyncConfig(algorithm="napp")
    # valid names (including the ones the old docstring omitted) pass
    for name in (
        "auto", "nap", "rd", "smp", "mla", "mla_pipelined", "psum",
        "ring", "rabenseifner",
    ):
        comm.CommPolicy(algorithm=name)
    with pytest.raises(ValueError, match="compress_bits"):
        comm.CommPolicy(compress_bits=1)


def test_unsupported_op_error_lists_supporting_engines():
    with pytest.raises(NotImplementedError) as ei:
        comm.select_engine(comm.Topology.of(8, 16), 1024, op="prod")
    assert "psum" in str(ei.value) and "ops" in str(ei.value)


# ---------------------------------------------------------------------------
# deprecation shims (satellite: exactly one warning per shim)
# ---------------------------------------------------------------------------


def test_gradsyncconfig_shim_warns_exactly_once():
    comm._DEPRECATION_WARNED.discard("grad_sync.GradSyncConfig")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        cfg = grad_sync.GradSyncConfig(algorithm="nap")
        grad_sync.GradSyncConfig(algorithm="mla", mean=False)
    dep = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(dep) == 1 and "GradSyncConfig" in str(dep[0].message)
    # the shim IS a CommPolicy — identical fields, usable everywhere
    assert isinstance(cfg, comm.CommPolicy)
    assert cfg.algorithm == "nap" and cfg.mean and cfg.bucket_bytes is None


# ---------------------------------------------------------------------------
# RS/AG promotion: accounting equals the ragged lower bounds
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "n,ppn", [(2, 4), (5, 3), (8, 16), (16, 16), (6, 1), (2, 16)]
)
def test_rs_ag_schedule_accounting_equals_lower_bound(n, ppn):
    for elems in [1, 5, 37, 1000, 4096]:
        s = float(elems * 4)
        rs = napalg.build_mla_rs_schedule(n, ppn, elems)
        ag = napalg.build_mla_ag_schedule(n, ppn, elems)
        assert rs.max_internode_bytes_per_chip(s) == pytest.approx(
            napalg.rs_internode_lower_bound(n, ppn, elems) * 4.0
        )
        assert ag.max_internode_bytes_per_chip(s) == pytest.approx(
            napalg.ag_internode_lower_bound(n, ppn, elems) * 4.0
        )
        # the two one-way bounds compose to the allreduce round trip
        assert (
            napalg.rs_internode_lower_bound(n, ppn, elems)
            + napalg.ag_internode_lower_bound(n, ppn, elems)
        ) == napalg.mla_internode_lower_bound(n, ppn, elems)


def test_rs_ag_simulator_replay():
    topo = comm.Topology.of(8, 16)
    elems = 1 << 16
    s = float(elems * 4)
    # the simulator replays the promoted collectives by engine name
    t_rs = sim.simulate_collective(topo, "mla_rs", s, elems=elems)
    t_ag = sim.simulate_collective(topo, "mla_ag", s, elems=elems)
    t_ar = sim.simulate_collective(topo, "mla", s, elems=elems)
    assert 0 < t_rs < t_ar and 0 < t_ag < t_ar
    # byte accounting through the public simulator API too
    got = sim.internode_bytes_per_chip("mla_rs", 8, 16, s, elems=elems)
    assert got == pytest.approx(
        napalg.rs_internode_lower_bound(8, 16, elems) * 4.0
    )


def test_rs_ag_dispatch_rows():
    assert comm.select_engine(
        comm.Topology.of(8, 16), 1 << 20, collective="reduce_scatter"
    ) == ("mla_rs", 1)
    assert comm.select_engine(
        comm.Topology.of(1, 16), 1 << 20, collective="reduce_scatter"
    ) == ("psum_scatter", 1)
    assert comm.select_engine(
        comm.Topology.of(8, 16), 1 << 20, collective="allgather"
    ) == ("mla_ag", 1)
    assert comm.select_engine(
        comm.Topology.of(1, 16), 1 << 20, collective="allgather"
    ) == ("all_gather", 1)
    # node-aware RS is cheaper than the flat baseline whenever n > 1
    mp = pm.TPU_V5E_POD
    for s in [1 << 16, 1 << 22]:
        assert pm.cost_reduce_scatter(s, 8, 16, mp) < (
            pm.cost_reduce_scatter_flat(s, 8, 16, mp)
        )


# ---------------------------------------------------------------------------
# MachineParams.fit (satellite)
# ---------------------------------------------------------------------------


def test_machine_params_fit_recovers_constants():
    P = pm.TPU_V5E_POD
    rows = []
    for s in [256, 1024, 4096, 16384, 65536, 1 << 20]:
        rows.append((s, pm.maxrate_message_cost(float(s), P, 1), 1))
        rows.append((s, pm.maxrate_message_cost(float(s), P, 16), 16))
    f = pm.MachineParams.fit(rows, base=P, name="roundtrip")
    assert f.alpha == pytest.approx(P.alpha, rel=1e-6)
    assert f.R_b == pytest.approx(P.R_b, rel=1e-6)
    assert f.R_N == pytest.approx(P.R_N, rel=1e-6)
    assert f.alpha_l == P.alpha_l and f.gamma == P.gamma
    # the fitted params drop straight into the crossover solver
    assert pm.crossover_bytes(8, 16, f, large="mla") == pytest.approx(
        pm.crossover_bytes(8, 16, P, large="mla"), rel=1e-3
    )


def test_machine_params_fit_without_injection_rows_keeps_base():
    P = pm.BLUE_WATERS
    rows = [
        (s, pm.maxrate_message_cost(float(s), P, 1))
        for s in [512, 4096, 65536]
    ]
    f = pm.MachineParams.fit(rows, base=P)
    assert f.R_N == P.R_N  # unobservable without k > 1 rows
    assert f.R_b == pytest.approx(P.R_b, rel=1e-6)


def test_machine_params_fit_underdetermined_raises():
    with pytest.raises(ValueError, match="single-sender"):
        pm.MachineParams.fit([(1024, 1e-5)])


# ---------------------------------------------------------------------------
# compressed transport overflow (satellite)
# ---------------------------------------------------------------------------


def test_compressed_transport_dtype_boundaries_and_overflow():
    import jax.numpy as jnp

    assert grad_sync.compressed_transport_dtype(1, 8) == jnp.dtype(jnp.int8)
    assert grad_sync.compressed_transport_dtype(257, 8) == jnp.dtype(
        jnp.int16
    )
    assert grad_sync.compressed_transport_dtype(300, 8) == jnp.dtype(
        jnp.int32
    )
    # int64-sized groups: explicit error instead of a dtype the runtime
    # silently degrades to int32 (jax x64 disabled is the default)
    with pytest.raises(OverflowError, match="int32"):
        grad_sync.compressed_transport_dtype(20_000_000, 8)


# ---------------------------------------------------------------------------
# registry as the single source (ALGORITHMS view, crossover resolution)
# ---------------------------------------------------------------------------


def test_legacy_algorithms_view_derives_from_registry():
    table = collectives.ALGORITHMS
    assert set(table) == {"nap", "rd", "smp", "mla", "mla_pipelined", "psum"}
    assert table["nap"] is collectives.nap_allreduce
    assert table["mla"] is collectives.mla_allreduce


def test_crossover_large_contender_resolves_via_registry():
    mp = pm.TPU_V5E_POD
    # engine-name and bare-callable forms agree
    assert pm.crossover_bytes(16, 16, mp, large="mla") == pm.crossover_bytes(
        16, 16, mp, large=pm.cost_mla
    )
    assert pm.crossover_bytes(16, 16, mp, large="smp") == pm.crossover_bytes(
        16, 16, mp, large=pm.cost_smp
    )
    with pytest.raises(ValueError, match="registered"):
        pm.crossover_bytes(16, 16, mp, large="not_an_engine")
