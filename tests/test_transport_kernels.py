"""Fused transport kernel validation + HLO regression.

Three layers, per the transport-kernel acceptance:

* interpret-mode Pallas vs the pure-jnp oracle — the wire bytes must be
  **bit-identical** (the oracle is the wire protocol; both ends of a
  link may run different impls);
* quantizer semantics — round-to-nearest/clip error bounds, packed int4
  width, per-leaf scale selection across static offsets;
* HLO regression (subprocess, multi-device) — the fused compressed
  bucket path stays exactly 4 ``pallas_call`` sites *per bucket*
  regardless of leaf count (no per-leaf launches, no stray
  convert/concat chain) and puts ``s8``/packed ``u8`` on the wire.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ref, transport


def _payload(key, rows, cols, scale=1.0):
    return (jax.random.normal(key, (rows, cols)) * scale).astype(jnp.float32)


# ---------------------------------------------------------------------------
# pallas vs oracle: wire bytes bit-identical, roundtrip identical
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [8, 4, 5, 2])
@pytest.mark.parametrize(
    "rows,cols,base,row_stride",
    [
        (1, 256, 0, 0),
        (4, 256, 0, 256),      # sequential stripe blocks
        (4, 256, 1024, 0),     # a2a-received copies of one block
        (3, 100, 512, 128),    # ragged -> column padding path
        (2, 777, 33, 1024),
    ],
)
def test_wire_bytes_bit_identical(bits, rows, cols, base, row_stride):
    x = _payload(jax.random.PRNGKey(bits * 31 + rows), rows, cols)
    # two leaves splitting the global index space mid-window
    offsets = (0, base + cols // 2)
    scales = jnp.asarray([0.11, 0.37], jnp.float32)
    kw = dict(offsets=offsets, bits=bits, base=base, row_stride=row_stride)
    w_pl = transport.quantize_pack(x, scales, impl="pallas", **kw)
    w_ref = transport.quantize_pack(x, scales, impl="xla", **kw)
    assert w_pl.dtype == transport.wire_dtype(bits)
    np.testing.assert_array_equal(np.asarray(w_pl), np.asarray(w_ref))
    d_pl = transport.unpack_dequantize(
        w_pl, scales, cols=cols, impl="pallas", **kw
    )
    d_ref = transport.unpack_dequantize(
        w_pl, scales, cols=cols, impl="xla", **kw
    )
    assert d_pl.shape == (rows, cols)
    np.testing.assert_array_equal(np.asarray(d_pl), np.asarray(d_ref))


@settings(max_examples=10, deadline=None)
@given(
    bits=st.sampled_from([2, 3, 4, 6, 8]),
    rows=st.integers(min_value=1, max_value=5),
    cols=st.integers(min_value=1, max_value=700),
    base=st.integers(min_value=0, max_value=4096),
)
def test_wire_bytes_bit_identical_fuzz(bits, rows, cols, base):
    x = _payload(jax.random.PRNGKey(cols * 7 + rows), rows, cols, scale=3.0)
    offsets = (0,)
    scales = jnp.asarray([0.2], jnp.float32)
    kw = dict(offsets=offsets, bits=bits, base=base, row_stride=cols)
    w_pl = transport.quantize_pack(x, scales, impl="pallas", **kw)
    w_ref = transport.quantize_pack(x, scales, impl="xla", **kw)
    np.testing.assert_array_equal(np.asarray(w_pl), np.asarray(w_ref))
    d_pl = transport.unpack_dequantize(
        w_pl, scales, cols=cols, impl="pallas", **kw
    )
    d_ref = transport.unpack_dequantize(
        w_pl, scales, cols=cols, impl="xla", **kw
    )
    np.testing.assert_array_equal(np.asarray(d_pl), np.asarray(d_ref))


# ---------------------------------------------------------------------------
# quantizer semantics
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [8, 4, 2])
@pytest.mark.parametrize("impl", ["pallas", "xla"])
def test_roundtrip_error_bound(bits, impl):
    x = _payload(jax.random.PRNGKey(0), 2, 600)
    qmax = float(2 ** (bits - 1) - 1)
    scale = float(jnp.max(jnp.abs(x))) / qmax
    scales = jnp.asarray([scale], jnp.float32)
    w = transport.quantize_pack(x, scales, offsets=(0,), bits=bits, impl=impl)
    d = transport.unpack_dequantize(
        w, scales, offsets=(0,), bits=bits, cols=600, impl=impl
    )
    # |x| <= qmax*scale by construction -> no clipping, only rounding
    assert float(jnp.max(jnp.abs(d - x))) <= scale / 2 + 1e-7


def test_int4_packs_two_elements_per_byte():
    x = _payload(jax.random.PRNGKey(1), 2, 512)
    scales = jnp.asarray([0.1], jnp.float32)
    w8 = transport.quantize_pack(x, scales, offsets=(0,), bits=8)
    w4 = transport.quantize_pack(x, scales, offsets=(0,), bits=4)
    assert w8.dtype == jnp.int8 and w8.shape == (2, 512)
    assert w4.dtype == jnp.uint8 and w4.shape == (2, 256)
    assert transport.wire_itemsize(4) == 0.5
    assert transport.wire_itemsize(8) == 1.0


def test_int4_split_half_nibble_layout():
    # block 8: byte k of a block = elem k (low nibble) | elem k+4 (high)
    vals = jnp.asarray([[1, 2, 3, -1, -2, 7, 0, -8.0]], jnp.float32)
    w = transport.quantize_pack(
        vals, jnp.ones((1,)), offsets=(0,), bits=4, block=8
    )
    got = np.asarray(w)[0]
    q = np.asarray([1, 2, 3, -1, -2, 7, 0, -7])  # clip at qmax=7
    want = (q[:4] & 0xF) | ((q[4:] & 0xF) << 4)
    np.testing.assert_array_equal(got, want.astype(np.uint8))


def test_per_leaf_scale_selected_by_global_index():
    # two leaves: [0, 8) scale 1, [8, 16) scale 100; rows are stripe
    # blocks so row 1 covers the second leaf via base + row_stride
    x = jnp.full((2, 8), 60.0, jnp.float32)
    scales = jnp.asarray([1.0, 100.0], jnp.float32)
    d = transport.unpack_dequantize(
        transport.quantize_pack(
            x, scales, offsets=(0, 8), bits=8, base=0, row_stride=8, block=8
        ),
        scales, offsets=(0, 8), bits=8, cols=8, base=0, row_stride=8, block=8,
    )
    np.testing.assert_allclose(np.asarray(d[0]), 60.0)   # q=60, scale 1
    np.testing.assert_allclose(np.asarray(d[1]), 100.0)  # q=round(.6)=1


def test_rejects_bad_args():
    x = jnp.zeros((1, 8), jnp.float32)
    s = jnp.ones((1,))
    with pytest.raises(ValueError, match="bits"):
        transport.quantize_pack(x, s, offsets=(0,), bits=9)
    with pytest.raises(ValueError, match="offsets"):
        transport.quantize_pack(x, jnp.ones((2,)), offsets=(8, 0), bits=8)
    with pytest.raises(ValueError, match="wire block"):
        transport.unpack_dequantize(
            jnp.zeros((1, 100), jnp.int8), s, offsets=(0,), bits=8, cols=100
        )


# ---------------------------------------------------------------------------
# HLO regression: fused path, wire dtypes (multi-device subprocess)
# ---------------------------------------------------------------------------


def _run_subprocess(script: str) -> None:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, env=env, cwd=".", timeout=600,
    )
    assert proc.returncode == 0 and "OK" in proc.stdout, (
        proc.stdout[-2000:] + proc.stderr[-2000:]
    )


def test_fused_bucket_is_four_pallas_calls_and_wire_dtype():
    """One compressed bucket = exactly 4 ``pallas_call`` sites
    (quantize-stripe, unpack-receive, requantize, unpack-gather) no
    matter how many leaves it fuses — and EF adds none (its error
    decode rides the jnp oracle).  The compiled wire is ``s8`` at
    8 bits and packed ``u8`` at 4, with no wide-integer transport."""
    _run_subprocess(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        import sys; sys.path.insert(0, "src")
        from repro import compat
        from repro.core import comm, grad_sync
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((2, 4), ("pod", "data"))

        def jaxpr_text(n_leaves, bits, ef):
            policy = comm.CommPolicy(
                algorithm="nap", mean=True, compress_bits=bits,
                error_feedback=ef,
            )
            shapes = [(64 + 32 * i,) for i in range(n_leaves)]

            def f(*leaves):
                topo = comm.Topology.from_mesh(mesh)
                ctx = comm.CommContext(topo, policy)
                grads = list(leaves[:n_leaves])
                ef_state = list(leaves[n_leaves:]) or None
                plan = grad_sync.plan_for_tree(
                    [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes],
                    cfg=policy, topology=topo,
                )
                out = grad_sync.sync_with_context(
                    grads, ctx, plan=plan, ef_state=ef_state
                )
                if ef_state is not None:
                    synced, new_ef = out
                    return (
                        jnp.concatenate(synced),
                        jnp.concatenate(new_ef),
                    )
                return jnp.concatenate(out)

            args = [jnp.zeros(s, jnp.float32) for s in shapes]
            if ef:
                args += [jnp.zeros(s, jnp.float32) for s in shapes]
            g = compat.shard_map(
                f, mesh=mesh,
                in_specs=tuple(P() for _ in args),
                out_specs=P() if not ef else (P(), P()),
                check_vma=False,
            )
            return str(jax.make_jaxpr(g)(*args)), g, args

        # the regression rules now live in repro.analysis.hlo_lint so
        # every compiled step (tests, the BENCH_7 driver, future
        # engines) checks the same invariants
        from repro.analysis import hlo_lint

        for n_leaves in (1, 3, 6):
            txt, _, _ = jaxpr_text(n_leaves, 8, False)
            hlo_lint.assert_clean(
                hlo_lint.lint_collective_counts(txt, {"pallas_call": 4}),
                f"leaves={n_leaves}",
            )
        # error feedback must not add pallas_call sites
        txt, _, _ = jaxpr_text(3, 4, True)
        hlo_lint.assert_clean(
            hlo_lint.lint_collective_counts(txt, {"pallas_call": 4}), "ef"
        )

        # compiled wire dtype: s8 at 8 bits, packed u8 at 4; the wire
        # collectives never move a wide-integer payload, and no
        # payload-sized (E = 288) f32 tensor crosses the inter-node
        # domain (ppn=4 exempts the intra-node f32 RS/AG phases)
        for bits in (8, 4):
            _, g, args = jaxpr_text(3, bits, False)
            hlo = jax.jit(g).lower(*args).compile().as_text()
            hlo_lint.assert_clean(
                hlo_lint.lint_compressed_wire(
                    hlo, bits=bits, payload_elems=288, ppn=4
                ),
                f"bits={bits}",
            )
        print("OK")
        """
    )
