"""Property + example tests for the NAP schedule math (paper §III)."""

import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import napalg


def _ref(values, op):
    red = {
        "sum": np.sum,
        "max": np.max,
        "min": np.min,
        "prod": np.prod,
    }[op](values, axis=0)
    return np.broadcast_to(red, values.shape)


# ---------------------------------------------------------------------------
# correctness: NAP schedule == reduction oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("op", ["sum", "max", "min"])
@pytest.mark.parametrize(
    "n_nodes,ppn",
    [
        (1, 4),
        (2, 2),
        (4, 4),       # single inter-node step, n == ppn (Fig. 6)
        (16, 4),      # two steps, power of ppn (Fig. 7)
        (64, 4),      # three steps
        (12, 4),      # n divisible by ppn, non-power (Fig. 8)
        (14, 4),      # ragged subgroups + donors (Fig. 9)
        (5, 4),
        (7, 3),
        (9, 2),
        (27, 3),
        (31, 16),
        (33, 16),
    ],
)
def test_nap_matches_oracle(n_nodes, ppn, op):
    sched = napalg.build_nap_schedule(n_nodes, ppn)
    rng = np.random.default_rng(n_nodes * 100 + ppn)
    values = rng.normal(size=(n_nodes * ppn, 3))
    got = napalg.simulate_allreduce(sched, values, op=op)
    np.testing.assert_allclose(got, _ref(values, op), rtol=1e-12, atol=1e-12)


@settings(max_examples=120, deadline=None)
@given(
    n_nodes=st.integers(min_value=1, max_value=40),
    ppn=st.integers(min_value=2, max_value=16),
    op=st.sampled_from(["sum", "max", "min"]),
)
def test_nap_matches_oracle_property(n_nodes, ppn, op):
    sched = napalg.build_nap_schedule(n_nodes, ppn)
    rng = np.random.default_rng(n_nodes * 1000 + ppn)
    values = rng.normal(size=(n_nodes * ppn, 2))
    got = napalg.simulate_allreduce(sched, values, op=op)
    np.testing.assert_allclose(got, _ref(values, op), rtol=1e-12, atol=1e-12)


# ---------------------------------------------------------------------------
# the paper's headline claim: log_ppn(n) inter-node steps
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "n_nodes,ppn,expected_steps",
    [
        (16, 16, 1),    # paper: "16 nodes with 16 ppn requires one step"
        (4096, 16, 3),  # paper: "4096 nodes, 16 ppn requires three steps"
        (4, 4, 1),
        (16, 4, 2),
        (64, 4, 3),
        (12, 4, 2),     # Fig. 8: non-power pays the next power's steps
        (14, 4, 2),     # Fig. 9
        (2, 16, 1),
        (1024, 2, 10),  # ppn=2 degenerates to recursive doubling counts
    ],
)
def test_internode_step_count(n_nodes, ppn, expected_steps):
    sched = napalg.build_nap_schedule(n_nodes, ppn)
    assert sched.num_internode_steps == expected_steps
    assert napalg.nap_num_steps(n_nodes, ppn) == expected_steps


@settings(max_examples=80, deadline=None)
@given(
    n_nodes=st.integers(min_value=2, max_value=300),
    ppn=st.integers(min_value=2, max_value=32),
)
def test_step_count_is_log_ppn(n_nodes, ppn):
    sched = napalg.build_nap_schedule(n_nodes, ppn)
    expected = max(1, math.ceil(math.log(n_nodes) / math.log(ppn) - 1e-12))
    assert sched.num_internode_steps == expected


def test_power_of_ppn_message_bound():
    """For power-of-ppn node counts, every chip sends exactly <= log_ppn(n)
    inter-node messages and there are no donor rounds."""
    for n_nodes, ppn in [(4, 4), (16, 4), (64, 4), (16, 16), (256, 16)]:
        sched = napalg.build_nap_schedule(n_nodes, ppn)
        assert sched.max_messages_per_chip() <= sched.num_internode_steps
        for step in sched.steps:
            assert len(step.rounds) == 1  # no donor overflow


@settings(max_examples=60, deadline=None)
@given(
    n_nodes=st.integers(min_value=2, max_value=120),
    ppn=st.integers(min_value=2, max_value=16),
)
def test_rounds_are_valid_permutations(n_nodes, ppn):
    """Each ppermute round must be a partial permutation: a chip appears at
    most once as src and at most once as dst."""
    sched = napalg.build_nap_schedule(n_nodes, ppn)
    for step in sched.steps:
        for rnd in step.rounds:
            srcs = [s for s, _ in rnd]
            dsts = [d for _, d in rnd]
            assert len(srcs) == len(set(srcs))
            assert len(dsts) == len(set(dsts))


# ---------------------------------------------------------------------------
# §III.A figure examples
# ---------------------------------------------------------------------------


def test_fig9_p14_receives_from_donor():
    """14 nodes, ppn=4 (Fig. 9): with balanced subgroups (4,4,3,3), node 3's
    rank-2 chip (P14) has no partner at position 3 of subgroup 2 and must
    receive from subgroup 2's idle rank-2 chip (P34 = node 8)."""
    sched = napalg.build_nap_schedule(14, 4)
    last = sched.steps[-1]
    sizes = [len(sg) for sg in last.groups[0]]
    assert sorted(sizes, reverse=True) == [4, 4, 3, 3]
    msgs = last.messages
    # P14 = chip 14 must receive from an idle (rank == subgroup) chip of
    # the subgroup it is missing.
    donors = [src for src, dst in msgs if dst == 14]
    assert donors, "P14 must receive a donated partial"
    (donor,) = donors
    donor_node, donor_rank = divmod(donor, 4)
    # the donor is the idle chip of its subgroup: rank == subgroup index
    subgroup_of = {}
    for gi, sg in enumerate(last.groups[0]):
        for node in sg:
            subgroup_of[node] = gi
    assert donor_rank == subgroup_of[donor_node]
    assert donor == 34  # node 8, local rank 2 — exactly the paper's P34


def test_fig8_divisible_but_not_power():
    """12 nodes, ppn 4 (Fig. 8): final step reduces over 3 subgroups; all
    rank-3 chips idle in that step (no 4th subgroup)."""
    sched = napalg.build_nap_schedule(12, 4)
    assert sched.num_internode_steps == 2
    last = sched.steps[-1]
    assert len(last.groups[0]) == 3
    for src, dst in last.messages:
        assert src % 4 != 3 and dst % 4 != 3


# ---------------------------------------------------------------------------
# baseline schedules
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_nodes,ppn", [(4, 4), (8, 16), (5, 4), (14, 4), (3, 5)])
def test_rd_and_smp_message_counts(n_nodes, ppn):
    rd = napalg.build_rd_schedule(n_nodes, ppn)
    smp = napalg.build_smp_schedule(n_nodes, ppn)
    nap = napalg.build_nap_schedule(n_nodes, ppn)
    p = n_nodes * ppn
    # RD: ceil(log2 p) (+2 fold steps for non-powers) total steps
    pow2 = 1 << (p.bit_length() - 1)
    expected = int(math.log2(pow2)) + (2 if p != pow2 else 0)
    assert len(rd.steps) == expected
    # node-aware claim: NAP max inter-node msgs/chip <= RD's and <= SMP's
    rd_max = rd.max_internode_messages_per_chip()
    smp_max = smp.max_internode_messages_per_chip()
    nap_max = nap.max_messages_per_chip()
    assert nap_max <= rd_max or n_nodes == 1
    assert nap_max <= smp_max or n_nodes == 1


def test_headline_message_reduction():
    """Paper abstract: inter-node messages drop log2(n) -> log_ppn(n)."""
    nap = napalg.build_nap_schedule(4096, 16)
    rd = napalg.build_rd_schedule(4096, 16)
    smp = napalg.build_smp_schedule(4096, 16)
    assert napalg.message_counts(nap)["max_per_chip"] == 3
    assert rd.max_internode_messages_per_chip() == 12
    assert smp.max_internode_messages_per_chip() == 12


# ---------------------------------------------------------------------------
# ragged donor rounds: per-chip message bound over a wide sweep
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("ppn", [2, 3, 4, 5, 7, 8, 16])
def test_donor_rounds_message_bound(ppn):
    """Even with ragged subgroups and donor repair, no chip sends more
    than one extra inter-node message beyond the step count."""
    for n_nodes in range(1, 41):
        sched = napalg.build_nap_schedule(n_nodes, ppn)
        bound = napalg.nap_num_steps(n_nodes, ppn) + 1
        counts = napalg.message_counts(sched)
        assert counts["max_per_chip"] <= bound, (n_nodes, ppn, counts)


# ---------------------------------------------------------------------------
# schedule construction caching (trace-time hot path)
# ---------------------------------------------------------------------------


def test_schedule_builders_are_cached():
    for builder, args in [
        (napalg.build_nap_schedule, (24, 8)),
        (napalg.build_rd_schedule, (24, 8)),
        (napalg.build_smp_schedule, (24, 8)),
        (napalg.build_mla_schedule, (24, 8)),
    ]:
        builder.cache_clear()
        a = builder(*args)
        b = builder(*args)
        assert a is b
        assert builder.cache_info().hits > 0


def test_step_mask_tables_match_schedule():
    for n_nodes, ppn in [(14, 4), (5, 4), (16, 4), (27, 3)]:
        sched = napalg.build_nap_schedule(n_nodes, ppn)
        tables = napalg.step_mask_tables(n_nodes, ppn)
        assert len(tables) == len(sched.steps)
        for step, (rmasks, smask) in zip(sched.steps, tables):
            assert len(rmasks) == len(step.rounds)
            for rnd, rmask in zip(step.rounds, rmasks):
                assert set(np.flatnonzero(rmask)) == {d for _, d in rnd}
            assert set(np.flatnonzero(smask)) == set(step.self_chips)
        # cached: same object on repeat
        assert napalg.step_mask_tables(n_nodes, ppn) is tables


# ---------------------------------------------------------------------------
# MLA striped schedule
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_nodes,ppn", [(2, 2), (4, 4), (8, 16), (3, 5)])
def test_mla_schedule_structure(n_nodes, ppn):
    import math

    sched = napalg.build_mla_schedule(n_nodes, ppn)
    assert sched.kind == "mla"
    # recursive halving/doubling: 2*ceil(log2(k)) latency steps per domain
    li = math.ceil(math.log2(ppn)) if ppn > 1 else 0
    lo = math.ceil(math.log2(n_nodes)) if n_nodes > 1 else 0
    assert len(sched.steps) == 2 * (li + lo)
    # inter-node fractions sum to the per-lane RS byte total per direction
    inter_frac_sum = sum(
        step.frac
        for step in sched.steps
        if step.combine
        and any(s // ppn != d // ppn for s, d in step.pairs)
    )
    want = (1.0 / ppn) * (n_nodes - 1) / n_nodes if n_nodes > 1 else 0.0
    assert inter_frac_sum == pytest.approx(want)


@pytest.mark.parametrize("n_nodes,ppn", [(2, 4), (4, 4), (8, 16), (64, 16)])
def test_mla_internode_bytes_are_striped(n_nodes, ppn):
    """The tentpole claim: per-chip inter-node bytes drop to ~s/ppn."""
    s = float(1 << 20)
    mla = napalg.build_mla_schedule(n_nodes, ppn)
    got = mla.max_internode_bytes_per_chip(s)
    want = 2.0 * (s / ppn) * (n_nodes - 1) / n_nodes
    assert got == pytest.approx(want)
    assert got <= 2.0 * s / ppn  # ~s/ppn per direction, per lane
    # vs NAP (full payload each step) and RD (full payload, log2(p) steps)
    nap = napalg.build_nap_schedule(n_nodes, ppn)
    rd = napalg.build_rd_schedule(n_nodes, ppn)
    assert got < nap.max_internode_bytes_per_chip(s)
    assert got < rd.max_internode_bytes_per_chip(s)
