"""Serving-spine tests: scheduler invariants, buckets, router, engine.

The scheduler is pure host-side Python, so its invariants are fuzzed
directly over request arrival traces (hypothesis when available, the
deterministic ``_hypothesis_compat`` sweep otherwise):

* no slot leaks — free + active always partitions the slot range;
* FIFO fairness under saturation — admission order is arrival order;
* silence after the end — finished/evicted/rejected requests never
  gain another token.

The engine-level check (single device) asserts continuous batching is
**bitwise identical** to the fixed-batch serial driver
(:func:`repro.launch.serve.serve_batch`), including through padded
prompt buckets and mid-flight admission.  The multidevice (meshed,
tensor-parallel) version of the same property lives in
``_multidevice_checks.py::check_serve_continuous_batching``.
"""

import random

import pytest
from _hypothesis_compat import given, settings, st

from repro.serve.scheduler import (
    ACTIVE,
    EVICTED,
    FINISHED,
    QUEUED,
    REJECTED,
    PromptBuckets,
    Scheduler,
)

# ---------------------------------------------------------------------------
# PromptBuckets


def test_bucket_len_picks_smallest_holding_bucket():
    b = PromptBuckets([16, 4, 8, 8])  # dedup + sort
    assert b.lengths == (4, 8, 16)
    assert b.bucket_len(1) == 4
    assert b.bucket_len(4) == 4
    assert b.bucket_len(5) == 8
    assert b.bucket_len(16) == 16
    with pytest.raises(ValueError):
        b.bucket_len(17)


def test_bucket_validation():
    with pytest.raises(ValueError):
        PromptBuckets([])
    with pytest.raises(ValueError):
        PromptBuckets([0, 8])
    with pytest.raises(ValueError):
        PromptBuckets.geometric(64, factor=1)


def test_geometric_ladder_covers_max_len():
    b = PromptBuckets.geometric(100, start=8, factor=2)
    assert b.lengths == (8, 16, 32, 64, 100)
    assert b.max_len == 100
    for n in range(1, 101):
        assert b.bucket_len(n) >= n
    # trace count is logarithmic, not linear
    assert len(b.lengths) <= 8


# ---------------------------------------------------------------------------
# Scheduler: directed unit tests


def test_fifo_admission_under_saturation():
    s = Scheduler(2)
    reqs = [s.submit([1], 1) for _ in range(5)]
    admitted = s.admit()
    assert [r.rid for r in admitted] == [reqs[0].rid, reqs[1].rid]
    assert [r.slot for r in admitted] == [0, 1]
    # finishing one request admits exactly the queue head into its slot
    for nxt in (2, 3, 4):
        done = s.record_token(0, 7)
        assert done is not None and done.state == FINISHED
        newly = s.admit()
        assert [r.rid for r in newly] == [reqs[nxt].rid]
        assert newly[0].slot == 0
        s.check_invariants()


def test_admission_control_rejects_past_queue_bound():
    s = Scheduler(1, max_queue=2)
    ok = [s.submit([1], 1) for _ in range(2)]
    bad = s.submit([1], 1)
    assert all(r.state == QUEUED for r in ok)
    assert bad.state == REJECTED and bad.remaining == 0
    assert s.n_rejected == 1
    # rejected requests never enter the queue or a slot
    s.admit()
    assert bad.slot is None
    s.check_invariants()


def test_eos_and_budget_finish():
    s = Scheduler(1, eos_id=99)
    r1 = s.submit([1], 4)
    s.admit()
    assert s.record_token(0, 5) is None
    assert s.record_token(0, 99) is r1  # EOS beats remaining budget
    assert r1.generated == [5, 99] and r1.state == FINISHED
    r2 = s.submit([1], 2)
    s.admit()
    s.record_token(0, 1)
    assert s.record_token(0, 2) is r2  # budget exhaustion
    assert r2.generated == [1, 2]


def test_tokens_for_free_slots_are_dropped():
    s = Scheduler(2)
    s.submit([1], 3)
    s.admit()
    # slot 1 was never filled; the engine decodes it unconditionally
    assert s.record_token(1, 123) is None
    s.check_invariants()


def test_evicted_requests_never_emit_tokens():
    s = Scheduler(1)
    r1 = s.submit([1], 5)
    r2 = s.submit([2], 5)
    s.admit()
    s.record_token(0, 11)
    s.evict(r1.rid)
    assert r1.state == EVICTED and r1.slot is None
    n_before = len(r1.generated)
    # the token the engine already computed for the freed slot is dropped
    assert s.record_token(0, 12) is None
    assert len(r1.generated) == n_before
    # eviction of a queued request removes it before it ever runs
    s.evict(r2.rid)
    assert r2.state == EVICTED and r2.generated == []
    assert s.admit() == [] and s.idle
    # terminal evict is a no-op
    assert s.evict(r1.rid) is r1
    s.check_invariants()


def test_outstanding_tokens_counts_queue_and_slots():
    s = Scheduler(1)
    r1 = s.submit([1], 5)
    s.submit([2], 3)
    assert s.outstanding_tokens() == 8
    s.admit()
    s.record_token(0, 1)
    assert s.outstanding_tokens() == 7
    s.evict(r1.rid)
    assert s.outstanding_tokens() == 3


def test_shard_geometry_is_ragged_splits():
    from repro.core import napalg

    s = Scheduler(10)
    for group in (1, 2, 3, 4, 8):
        geo = s.shard_geometry(group)
        assert geo == napalg.ragged_splits(10, group)
        assert sum(geo) == 10 and len(geo) == group


def test_request_validation():
    with pytest.raises(ValueError):
        Scheduler(0)
    s = Scheduler(1)
    with pytest.raises(ValueError):
        s.submit([], 1)
    with pytest.raises(ValueError):
        s.submit([1], 0)


# ---------------------------------------------------------------------------
# Scheduler: fuzz over arrival traces


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10**6),
    num_slots=st.integers(min_value=1, max_value=4),
    max_queue=st.one_of(st.none(), st.integers(min_value=0, max_value=3)),
)
def test_scheduler_trace_fuzz(seed, num_slots, max_queue):
    rng = random.Random(seed)
    eos = 99 if rng.random() < 0.5 else None
    s = Scheduler(num_slots, max_queue=max_queue, eos_id=eos)
    submitted = []          # arrival order
    admitted_order = []     # admission order
    frozen = {}             # rid -> generated length at terminal transition

    def note_terminals():
        for req in s.requests.values():
            if req.done:
                frozen.setdefault(req.rid, len(req.generated))
                # silence after the end: a terminal request's token list
                # must never grow again
                assert len(req.generated) == frozen[req.rid], req
                assert req.slot is None
                assert req.remaining == 0

    for _ in range(80):
        op = rng.random()
        if op < 0.35:
            req = s.submit(
                [rng.randrange(100) + 1 for _ in range(rng.randrange(1, 5))],
                rng.randrange(1, 4),
            )
            if req.state != REJECTED:
                submitted.append(req.rid)
        elif op < 0.55:
            # FIFO: admit() must take exactly the current queue head(s)
            expect = [r.rid for r in list(s.queue)[: len(s.free_slots)]]
            got = [r.rid for r in s.admit()]
            assert got == expect
            admitted_order.extend(got)
        elif op < 0.85:
            # one decode step: the engine records a token for EVERY slot
            for slot in range(num_slots):
                s.record_token(slot, rng.choice([99, rng.randrange(98)]))
        else:
            live = [
                r.rid for r in s.requests.values() if not r.done
            ]
            if live:
                s.evict(rng.choice(live))
        s.check_invariants()
        note_terminals()

    # FIFO fairness: admissions happen in arrival order (eviction from
    # the queue only removes entries; it never reorders survivors)
    pos = {rid: i for i, rid in enumerate(submitted)}
    order = [pos[rid] for rid in admitted_order]
    assert order == sorted(order)
    # no slot leak survives the whole trace
    assert len(s.free_slots) + len(s.active()) == num_slots
    # every admitted request was actually submitted (never rejected)
    assert set(admitted_order) <= set(submitted)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10**6),
    num_slots=st.integers(min_value=1, max_value=3),
)
def test_scheduler_drains_to_idle(seed, num_slots):
    """Any backlog drains to idle under admit+decode steps alone."""
    rng = random.Random(seed)
    s = Scheduler(num_slots)
    reqs = [
        s.submit([1 + rng.randrange(9)], rng.randrange(1, 5))
        for _ in range(rng.randrange(1, 9))
    ]
    steps = 0
    while not s.idle:
        s.admit()
        for slot in range(num_slots):
            s.record_token(slot, rng.randrange(50))
        s.check_invariants()
        steps += 1
        assert steps < 1000, "scheduler failed to drain"
    for r in reqs:
        assert r.state == FINISHED
        assert len(r.generated) == r.max_new_tokens


# ---------------------------------------------------------------------------
# Router + replica health


class _FakeReplica:
    """Minimal replica surface the Router needs (no device state)."""

    def __init__(self, num_slots, **kw):
        self.scheduler = Scheduler(num_slots, **kw)

    def submit(self, prompt, max_new_tokens, **kw):
        return self.scheduler.submit(prompt, max_new_tokens, **kw)

    def outstanding_tokens(self):
        return self.scheduler.outstanding_tokens()

    @property
    def idle(self):
        return self.scheduler.idle


def test_router_spreads_by_outstanding_tokens():
    from repro.serve import Router

    r = Router([_FakeReplica(2), _FakeReplica(2)])
    big = r.submit([1], 100)        # -> replica 0 (tie, lowest index)
    small = r.submit([1], 1)        # -> replica 1 (less loaded)
    nxt = r.submit([1], 1)          # -> replica 1 again (2 < 100)
    assert r.placement[big.rid] == 0
    assert r.placement[small.rid] == 1
    assert r.placement[nxt.rid] == 1
    assert r.loads() == [100, 2]


def test_router_rejected_requests_are_not_placed():
    from repro.serve import Router

    r = Router([_FakeReplica(1, max_queue=0)])
    req = r.submit([1], 1)
    assert req.state == REJECTED
    assert req.rid not in r.placement


def test_replica_health_hysteresis():
    from repro.runtime.fault import ReplicaHealth, StragglerMonitor

    h = ReplicaHealth(
        StragglerMonitor(threshold=2.0, warmup=3), recovery=3
    )
    for step in range(4):
        assert h.record(step, 1.0)
    assert not h.record(4, 10.0)        # straggler event -> degraded
    assert h.n_degraded == 1
    assert not h.record(5, 1.0)         # one clean step is not recovery
    assert not h.record(6, 1.0)
    assert h.record(7, 1.0)             # 3 consecutive clean -> healthy
    # a new event restarts the clean counter
    assert not h.record(8, 50.0)
    assert not h.record(9, 1.0)
    assert h.n_degraded == 2


def test_router_reroutes_queue_on_straggler():
    from repro.serve import Router

    a, b = _FakeReplica(1), _FakeReplica(1)
    r = Router([a, b], straggler_threshold=2.0, recovery=2)
    # saturate replica 0 and build its queue (directly: the router
    # itself would spread this backlog to the emptier replica 1)
    first = r.submit([1], 50)
    a.scheduler.admit()
    queued = [a.submit([1], 50) for _ in range(3)]
    # straggler signal on replica 0 past monitor warmup
    for step in range(4):
        assert r.observe_step(0, step, 1.0)
    assert not r.observe_step(0, 4, 25.0)
    # queued requests moved to the healthy peer; the active one stayed
    assert not r.health[0].healthy
    assert a.scheduler.queue == type(a.scheduler.queue)()
    assert first.state == ACTIVE and r.placement[first.rid] == 0
    moved = [q for q in queued if q.state == QUEUED]
    assert moved and all(r.placement[q.rid] == 1 for q in moved)
    assert r.n_rerouted == len(moved)
    # while degraded, new submissions avoid replica 0
    assert r.placement[r.submit([1], 1).rid] == 1
    # recovery hysteresis readmits it
    r.observe_step(0, 5, 1.0)
    r.observe_step(0, 6, 1.0)
    assert r.health[0].healthy


def test_router_all_degraded_still_routes():
    from repro.serve import Router
    from repro.runtime.fault import ReplicaHealth, StragglerMonitor

    h = [
        ReplicaHealth(StragglerMonitor(warmup=1), recovery=2)
        for _ in range(2)
    ]
    r = Router([_FakeReplica(1), _FakeReplica(1)], health=h)
    for i in (0, 1):
        r.observe_step(i, 0, 1.0)
        r.observe_step(i, 1, 1.0)
        r.observe_step(i, 2, 100.0)
    assert not any(x.healthy for x in r.health)
    req = r.submit([1], 1)  # stalled beats dropped
    assert req.state == QUEUED and req.rid in r.placement


def test_evict_after_reroute_goes_through_single_owner():
    # layer-0 counterexample (submit, degrade, evict-via-stale-owner):
    # before single ownership, the drained rid stayed in the source
    # registry and evicting through it crashed in deque.remove
    from repro.serve import Router

    a, b = _FakeReplica(1), _FakeReplica(1)
    r = Router([a, b], straggler_threshold=2.0, recovery=2)
    first = r.submit([1], 50)
    a.scheduler.admit()
    q = a.submit([1], 5)
    for step in range(4):
        assert r.observe_step(0, step, 1.0)
    assert not r.observe_step(0, 4, 25.0)  # degrade -> reroute
    # ownership moved with the request: exactly one registry owns it
    assert q.rid not in a.scheduler.requests
    assert q.rid in b.scheduler.requests
    with pytest.raises(KeyError):
        a.scheduler.evict(q.rid)
    # the router's placement stayed accurate, so evicting through it
    # reaches the real owner
    r.evict(q.rid)
    assert q.state == EVICTED
    assert first.state == ACTIVE  # the active request rode out the stall
    a.scheduler.check_invariants(peers=[b.scheduler])


def test_reroute_keeps_accepted_request_when_no_peer_has_room():
    # layer-0 counterexample (submit, submit, degrade): before the
    # capacity-aware reroute, draining into a full peer queue flipped
    # an accepted request to REJECTED mid-flight
    from repro.runtime.fault import ReplicaHealth, StragglerMonitor
    from repro.serve import Router

    a = _FakeReplica(1, max_queue=1)
    b = _FakeReplica(1, max_queue=1)
    h = [
        ReplicaHealth(
            StragglerMonitor(threshold=2.0, warmup=1), recovery=2
        )
        for _ in range(2)
    ]
    r = Router([a, b], health=h)
    for i in (0, 1):
        r.observe_step(i, 0, 1.0)
        r.observe_step(i, 1, 1.0)
    qa = r.submit([1], 5)     # -> replica 0 (tie, lowest index)
    qb = r.submit([1], 5)     # -> replica 1; both queues now full
    assert not r.observe_step(0, 2, 25.0)  # degrade 0 -> reroute
    # acceptance is binding: no room on the peer, so the request stays
    # queued (FIFO position intact) on the degraded replica
    assert qa.state == QUEUED and r.placement[qa.rid] == 0
    assert list(a.scheduler.queue) == [qa]
    assert qb.state == QUEUED and r.placement[qb.rid] == 1
    a.scheduler.check_invariants(peers=[b.scheduler])


def test_pick_prefers_replica_with_queue_capacity():
    from repro.serve import Router

    a = _FakeReplica(1, max_queue=1)
    b = _FakeReplica(1)
    r = Router([a, b])
    big = b.scheduler.submit([1], 100)
    b.scheduler.admit()           # replica 1 heavily loaded but roomy
    a.scheduler.submit([1], 1)    # replica 0 light but queue full
    req = r.submit([1], 1)
    # least-loaded would pick the full replica 0 and reject; capacity
    # preference routes to the loaded-but-roomy replica 1 instead
    assert req.state == QUEUED
    assert r.placement[req.rid] == 1
    assert big.state == ACTIVE


def test_fail_replica_replans_queued_and_active():
    from repro.serve import Router

    a = _FakeReplica(2, max_queue=1)
    b = _FakeReplica(1, max_queue=1)
    r = Router([a, b])
    act = r.submit([1], 10)       # -> replica 0 (tie, lowest index)
    a.scheduler.admit()
    q1 = a.submit([1], 5)         # queued on replica 0 (queue full)
    b_q = r.submit([1], 3)        # -> replica 1 (less loaded)
    moved = r.fail_replica(0)
    assert moved == 2 and 0 in r.failed
    # the dead replica is empty — its work drained into the re-plan
    assert a.scheduler.idle and not a.scheduler.requests
    # the active request lost its KV state: demoted to QUEUED, slot
    # released, generated tokens kept for the re-prefill
    assert act.state == QUEUED and act.slot is None
    # survivors keep FIFO order: b's own head, then the demoted
    # active (admitted first), then the queued mover — force-enqueued
    # past b's backpressure bound rather than dropped
    assert [x.rid for x in b.scheduler.queue] == [b_q.rid, act.rid, q1.rid]
    assert r.placement[act.rid] == 1 and r.placement[q1.rid] == 1
    # a dead replica never receives traffic again: with the survivor
    # over its bound the submit is REJECTED (honest backpressure),
    # never routed to the corpse
    rejected = r.submit([1], 1)
    assert rejected.state == REJECTED and rejected.rid not in r.placement
    while not b.scheduler.idle:  # drain the survivor
        b.scheduler.admit()
        b.scheduler.record_token(0, 1)
    assert r.placement[r.submit([1], 1).rid] == 1
    b.scheduler.check_invariants(peers=[a.scheduler])
    with pytest.raises(RuntimeError):
        r.fail_replica(1)  # no survivor to re-plan onto


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6))
def test_router_trace_fuzz_cross_replica_conservation(seed):
    """Random multi-replica traces (submit/admit/decode/health/evict/
    loss) hold the cross-replica conservation invariants at every step:
    global rid uniqueness and outstanding-token accounting."""
    from repro.runtime.fault import ReplicaHealth, StragglerMonitor
    from repro.serve import Router

    rng = random.Random(seed)
    n = rng.choice([2, 3])
    reps = [
        _FakeReplica(
            rng.randrange(1, 3),
            max_queue=rng.choice([None, 1, 2]),
            eos_id=99,
        )
        for _ in range(n)
    ]
    health = [
        ReplicaHealth(
            StragglerMonitor(threshold=2.0, warmup=1, alpha=0.5),
            recovery=2,
        )
        for _ in range(n)
    ]
    r = Router(reps, health=health)
    step = 0
    for i in range(n):
        for _ in range(2):
            r.observe_step(i, step, 1.0)
            step += 1
    for _ in range(120):
        op = rng.random()
        alive = [i for i in range(n) if i not in r.failed]
        if op < 0.30:
            r.submit([1 + rng.randrange(9)], rng.randrange(1, 4))
        elif op < 0.45:
            reps[rng.choice(alive)].scheduler.admit()
        elif op < 0.70:
            i = rng.choice(alive)
            for slot in range(reps[i].scheduler.num_slots):
                reps[i].scheduler.record_token(
                    slot, rng.choice([99, 1 + rng.randrange(9)])
                )
        elif op < 0.80:
            r.observe_step(
                rng.choice(alive), step, rng.choice([1.0, 25.0])
            )
            step += 1
        elif op < 0.92:
            live = [
                rid
                for i in alive
                for rid, req in reps[i].scheduler.requests.items()
                if not req.done
            ]
            if live:
                r.evict(rng.choice(live))
        elif len(alive) >= 2:
            r.fail_replica(rng.choice(alive))
        # cross-replica conservation after every operation
        for i, rep in enumerate(reps):
            rep.scheduler.check_invariants(
                peers=[x.scheduler for j, x in enumerate(reps) if j != i]
            )


# ---------------------------------------------------------------------------
# Engine (single device): continuous batching == serial fixed batch


@pytest.mark.parametrize("arch", ["minicpm-2b"])
def test_engine_bitwise_matches_serial_serve_batch(arch):
    import jax
    import numpy as np

    from repro.configs import get_config, reduced
    from repro.launch.serve import serve_batch
    from repro.models import build_model
    from repro.serve import PromptBuckets, ServeEngine

    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.PRNGKey(0))

    prompts = np.array([[3, 1, 4], [1, 5, 9], [2, 6, 5]], np.int32)
    gen = 5

    # serial reference: every request decoded in one fixed batch
    ref = np.asarray(
        serve_batch(
            model, params, jax.numpy.asarray(prompts),
            gen_len=gen, max_len=16,
        )
    )

    # continuous batching: 2 slots for 3 requests, the third joins a
    # slot freed in flight; prompts ride a padded bucket (3 -> 8)
    engine = ServeEngine(
        model, params, num_slots=2, max_len=16,
        buckets=PromptBuckets([8]),
    )
    reqs = [
        engine.submit(list(p), b)
        for p, b in zip(prompts, (gen, gen - 2, gen))
    ]
    out = engine.run()
    assert engine.idle
    for i, req in enumerate(reqs):
        want = ref[i, : req.max_new_tokens].tolist()
        assert out[req.rid] == want, (i, out[req.rid], want)
    # per-decode-step fit rows were recorded with the logits payload
    # (all b_max slot rows ride one allreduce, f32)
    rows = engine.fit_rows()
    want_bytes = engine.b_max * cfg.vocab_size * 4
    assert rows and all(
        n == want_bytes and t > 0 and k == 1 for (n, t, k) in rows
    )


def test_engine_eos_early_finish():
    import jax

    from repro.configs import get_config, reduced
    from repro.models import build_model
    from repro.serve import PromptBuckets, ServeEngine

    cfg = reduced(get_config("minicpm-2b"))
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.PRNGKey(0))

    # discover the greedy continuation, then declare its 3rd token EOS
    probe = ServeEngine(
        model, params, num_slots=1, max_len=16, buckets=PromptBuckets([4])
    )
    free_run = probe.run_one = probe.submit([3, 1, 4], 5)
    toks = probe.run()[free_run.rid]
    eos = toks[2]
    if toks.index(eos) != 2:  # eos token appeared earlier: shift target
        eos = toks[toks.index(eos)]

    engine = ServeEngine(
        model, params, num_slots=1, max_len=16,
        buckets=PromptBuckets([4]), eos_id=eos,
    )
    req = engine.submit([3, 1, 4], 5)
    out = engine.run()
    assert out[req.rid] == toks[: toks.index(eos) + 1]
    assert req.state == FINISHED and engine.idle


def test_engine_extras_template_is_enforced():
    import jax

    from repro.configs import get_config, reduced
    from repro.models import build_model
    from repro.serve import ServeEngine

    cfg = reduced(get_config("minicpm-2b"))
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, num_slots=1, max_len=8)
    with pytest.raises(ValueError):
        engine.submit([1], 1, extras={"frames": None})
