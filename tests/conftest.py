"""Shared test-session configuration.

Verify-on-register: with ``REPRO_VERIFY_ON_REGISTER`` set, every engine
registration (including the built-ins at ``repro.core.comm`` import
time) runs the static schedule verifier (:mod:`repro.analysis`) over
the registration grid matrix before the engine becomes visible.  A
broken schedule builder therefore fails loudly at registration — at the
first ``comm`` import of the session — instead of in whichever
example-based test happens to cover that grid.  Set *before* any test
module imports ``repro.core.comm``.
"""

import os

os.environ.setdefault("REPRO_VERIFY_ON_REGISTER", "1")
