"""Per-architecture smoke tests on reduced same-family configs (CPU).

For every assigned arch: one forward pass, one loss+grad step, and one
cached decode step — asserting shapes, finiteness, and (for decode)
agreement between the cached path and the full forward.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import build_model

ALL = sorted(ARCHS)


def _batch(cfg, key, B=2, S=16):
    ks = jax.random.split(key, 3)
    batch = {}
    if cfg.frontend == "vision_patches":
        batch["embeds"] = (
            jax.random.normal(ks[0], (B, S, cfg.d_model)) * 0.02
        )
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S)
        )
        batch["labels"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
    else:
        batch["tokens"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
    if cfg.encoder_layers:
        batch["frames"] = jax.random.normal(ks[2], (B, 12, cfg.d_model)) * 0.02
    return batch


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = dataclasses.replace(reduced(ARCHS[name]), dtype="float32")
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            cache[name] = (cfg, model, params)
        return cache[name]

    return get


@pytest.mark.parametrize("name", ALL)
def test_forward_shapes_and_finite(built, name):
    cfg, model, params = built(name)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    hidden, aux = jax.jit(model.apply)(params, batch)
    B = 2
    assert hidden.shape == (B, 16, cfg.d_model)
    assert np.isfinite(np.asarray(hidden)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("name", ALL)
def test_train_step_grads_finite(built, name):
    cfg, model, params = built(name)
    batch = _batch(cfg, jax.random.PRNGKey(2))

    def loss_fn(p):
        return model.loss(p, batch)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss)) and float(loss) > 0
    leaves = jax.tree.leaves(grads)
    assert leaves
    assert all(np.isfinite(np.asarray(g)).all() for g in leaves)
    # at least 99% of leaves receive nonzero gradient signal
    nonzero = sum(float(np.abs(np.asarray(g)).sum()) > 0 for g in leaves)
    assert nonzero / len(leaves) > 0.9, f"{nonzero}/{len(leaves)} leaves live"


@pytest.mark.parametrize("name", ALL)
def test_decode_matches_full_forward(built, name):
    """Teacher-forced cached decode must reproduce the full forward's
    logits position by position (the KV/state-cache correctness test)."""
    cfg, model, params = built(name)
    B, S = 2, 8
    batch = _batch(cfg, jax.random.PRNGKey(3), B=B, S=S)
    full_logits = jax.jit(model.logits)(params, batch)

    cache = model.init_decode(params, B, max_len=S, batch=batch)
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(S):
        if "embeds" in batch:
            tok = batch["embeds"][:, t : t + 1]
        else:
            tok = batch["tokens"][:, t : t + 1]
        logits, cache = step(params, cache, tok)
        outs.append(np.asarray(logits[:, 0]))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(
        dec, np.asarray(full_logits), rtol=2e-3, atol=2e-3
    )


def test_gemma2_window_masks_differ():
    """Local sublayer must attend differently from global at long range."""
    cfg = dataclasses.replace(
        reduced(ARCHS["gemma2-27b"]), dtype="float32", sliding_window=4
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 12
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    h1, _ = model.apply(params, {"tokens": tok})
    # zero out the early context: only positions >= S-window can matter for
    # the last position in a pure local stack; with global layers present
    # the output at the last position must change.
    tok2 = tok.at[:, :4].set(0)
    h2, _ = model.apply(params, {"tokens": tok2})
    assert not np.allclose(np.asarray(h1[:, -1]), np.asarray(h2[:, -1]))
