"""Layer-0 protocol model checker: clean-protocol closure, seeded
mutations, counterexample minimality, deterministic replay.

Mirrors the PR-7 mutation-test pattern one layer down: the checker must
(a) pass the real control plane violation-free at full small-scope
depth, and (b) reject every seeded protocol bug with a minimal
replayable counterexample trace.  The regression traces at the bottom
are the checker's own pre-fix counterexamples, replayed as pytests.
"""

import pytest

from repro.analysis import protocol_check as pc
from repro.runtime.fault import ReplicaHealth
from repro.serve.router import Router
from repro.serve.scheduler import ACTIVE, EVICTED, QUEUED, Scheduler


# ---------------------------------------------------------------------------
# seeded protocol bugs (never shipped — they exist to prove the checker
# would catch them)
# ---------------------------------------------------------------------------


class DoubleAdmitScheduler(Scheduler):
    """Seeded bug: admit reads the lowest free slot but never removes
    it from the free list, so two requests land in the same slot."""

    def admit(self, *, now=0.0):
        admitted = []
        while self._free and self.queue:
            req = self.queue.popleft()
            slot = self._free[0]  # bug: slot never popped from _free
            req.slot = slot
            req.state = ACTIVE
            req.admitted_at = now
            self.slots[slot] = req
            admitted.append(req)
        return admitted


class SlotLeakScheduler(Scheduler):
    """Seeded bug: evicting an ACTIVE request empties the slot but
    never returns it to the free list."""

    def _release(self, req, state, *, now):
        if state == EVICTED:
            slot = req.slot
            self.slots[slot] = None
            req.slot = None
            req.state = state
            req.finished_at = now
            # bug: self._free never gets the slot back
        else:
            super()._release(req, state, now=now)


class DropOnDrainScheduler(Scheduler):
    """Seeded bug: draining the queue silently loses the newest
    queued request (it stays QUEUED but is held by no container)."""

    def drain_queue(self):
        out = list(self.queue)[:-1]
        self.queue.clear()
        for req in out:
            self.requests.pop(req.rid, None)
        return out


class RerouteActiveRouter(Router):
    """Seeded bug: reroute also moves ACTIVE requests, demoting them
    to QUEUED without releasing their slot (their KV state stays on
    the degraded replica)."""

    def reroute(self, replica):
        moved = super().reroute(replica)
        src = self.replicas[replica].scheduler
        peers = [i for i in self._eligible() if i != replica]
        for req in list(src.active()):
            req.state = QUEUED  # bug: slot not released, KV orphaned
            self.replicas[peers[0]].scheduler.enqueue(req, force=True)
        return moved


class OffByOneHealth(ReplicaHealth):
    """Seeded bug: recovery demands one clean step too many."""

    def record(self, step, duration):
        event = self.monitor.record(step, duration)
        if event is not None:
            if self.healthy:
                self.n_degraded += 1
            self.healthy = False
            self._clean = 0
        elif not self.healthy:
            self._clean += 1
            if self._clean > self.recovery:  # bug: > instead of >=
                self.healthy = True
                self._clean = 0
        return self.healthy


_SMALL = pc.CheckConfig(
    replicas=2, slots=1, queue=1, requests=2, budgets=(2, 1),
    recovery=2, depth=8,
)

_MUTANTS = [
    ("double-admit", dict(scheduler_cls=DoubleAdmitScheduler),
     {"conservation", "slot-accounting", "fifo"}),
    ("slot-leak-on-evict", dict(scheduler_cls=SlotLeakScheduler),
     {"slot-accounting"}),
    ("lost-queued-on-drain", dict(scheduler_cls=DropOnDrainScheduler),
     {"conservation"}),
    ("reroute-active", dict(router_cls=RerouteActiveRouter),
     {"conservation", "slot-accounting", "ownership"}),
    # the quiesce drain exercises recovery before BFS reaches a bare
    # recover event, so the boundary bug may surface as a liveness
    # violation whose detail names the nested hysteresis failure
    ("recovery-off-by-one", dict(health_cls=OffByOneHealth),
     {"hysteresis", "liveness"}),
]


@pytest.mark.parametrize(
    "name,classes,rules", _MUTANTS, ids=[m[0] for m in _MUTANTS]
)
def test_seeded_mutation_is_caught_with_replayable_trace(
    name, classes, rules
):
    report = pc.check_protocol(_SMALL, max_violations=1, **classes)
    assert not report.ok, f"checker missed seeded bug {name!r}"
    v = report.violations[0]
    assert v.rule in rules, (name, v.rule, v.detail)
    if name == "recovery-off-by-one":
        assert "hysteresis" in v.detail or v.rule == "hysteresis"
    # the emitted counterexample replays deterministically against the
    # same mutant and reproduces the same rule
    pc.assert_trace_violates(_SMALL, v.trace, v.rule, **classes)
    # ... and it doubles as a pytest
    assert "assert_trace_clean" in v.pytest_snippet()


def test_counterexample_trace_is_minimal():
    report = pc.check_protocol(
        _SMALL, max_violations=1, scheduler_cls=SlotLeakScheduler
    )
    trace = report.violations[0].trace
    rule = report.violations[0].rule
    # 1-minimality: removing any single event kills the violation
    for i in range(len(trace)):
        cand = trace[:i] + trace[i + 1:]
        try:
            vs = pc.run_trace(
                _SMALL, cand, scheduler_cls=SlotLeakScheduler
            )
        except pc.TraceNotApplicable:
            continue
        assert not any(v.rule == rule for v in vs), (
            f"dropping event {i} of {trace} still violates {rule}"
        )


def test_clean_protocol_full_small_scope_closure():
    # full closure (no depth cap): every reachable state of the real
    # control plane at this scope, zero violations
    cfg = pc.CheckConfig(
        replicas=2, slots=1, queue=1, requests=2, budgets=(2, 1),
        recovery=2, depth=None,
    )
    report = pc.check_protocol(cfg)
    assert report.ok, report.violations[0].to_row()
    assert report.complete
    assert report.states > 100
    assert report.occupancies == (0, 1)


def test_deterministic_bit_identical_replay():
    # same events, two fresh worlds: canonical states and placements
    # must agree exactly (Router placement never depends on dict/set
    # iteration order)
    cfg = pc.CheckConfig(
        replicas=3, slots=1, queue=2, requests=4, budgets=(2, 1),
        recovery=2,
    )
    trace = (
        ("submit",), ("submit",), ("degrade", 0), ("submit",),
        ("admit", 1), ("token", 1, 0), ("recover", 0), ("recover", 0),
        ("submit",), ("loss", 2), ("admit", 0),
    )
    worlds = []
    for _ in range(2):
        w = pc.World(cfg)
        for ev in trace:
            w.apply(ev)
        worlds.append(w)
    a, b = worlds
    assert a.canonical() == b.canonical()

    def placement_by_submission(w):
        # rids are process-global, so key placement by submission index
        return {
            k: w.router.placement.get(req.rid)
            for k, req in enumerate(w.submitted)
        }

    assert placement_by_submission(a) == placement_by_submission(b)
    assert a.router.loads() == b.router.loads()


def test_layer2_geometry_link():
    # the occupancies the protocol admits are exactly the ragged slot
    # geometry the SPMD lint sweeps the decode slice over
    link = pc.verify_decode_geometry_link(8, 8)
    assert link["ok"]
    assert link["admissible_occupancies"] == list(range(9))
    assert link["b_max"] == max(link["geometry"])
    with_remainder = pc.verify_decode_geometry_link(5, 3)
    assert with_remainder["geometry"] == [2, 2, 1]
    assert with_remainder["b_max"] == 2


# ---------------------------------------------------------------------------
# regression traces: the checker's own pre-fix counterexamples
# ---------------------------------------------------------------------------


def test_regression_reroute_kept_stale_ownership():
    # pre-fix: drain_queue left the drained rid in the source
    # scheduler's registry, so after (submit, degrade) the live rid was
    # registered with both replicas — the 'ownership' violation whose
    # concrete harm is the stale-evict crash below
    pc.assert_trace_clean(_SMALL, (("submit",), ("degrade", 0)))


def test_regression_reroute_rejected_accepted_request():
    # pre-fix: rerouting into a full peer queue flipped an accepted
    # (QUEUED) request to REJECTED — the 'acceptance' violation; now
    # the request stays on the degraded replica when no peer has room
    cfg = pc.CheckConfig(
        replicas=2, slots=1, queue=1, requests=3, budgets=(2, 1),
        recovery=2, depth=8,
    )
    pc.assert_trace_clean(cfg, (("submit",), ("submit",), ("degrade", 0)))
    pc.assert_trace_clean(cfg, (("submit",), ("submit",), ("degrade", 1)))


def test_regression_evict_after_reroute_goes_to_real_owner():
    # pre-fix: evicting through the stale owner crashed in
    # deque.remove; now ownership moved with the reroute and the evict
    # succeeds through the new owner
    pc.assert_trace_clean(
        _SMALL, (("submit",), ("degrade", 0), ("evict", 0, 1))
    )


def test_regression_replica_loss_drains_into_replan():
    # replica death mid-flight: queued and active requests must drain
    # into a re-plan on the survivor, never a stall (ROADMAP item 4's
    # protocol prerequisite)
    cfg = pc.CheckConfig(
        replicas=2, slots=2, queue=2, requests=3, budgets=(2, 1),
        recovery=2,
    )
    pc.assert_trace_clean(
        cfg,
        (("submit",), ("submit",), ("admit", 0), ("token", 0, 0),
         ("submit",), ("loss", 0)),
    )
