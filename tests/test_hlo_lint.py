"""HLO lint rules: synthetic-module unit tests + dtype table coverage.

Each rule is demonstrated to fire on a hand-built violating module and
to stay quiet on the clean counterpart, so the lint carried by
``tests/test_transport_kernels.py`` and the ``python -m repro.analysis``
driver is never vacuous.
"""

import jax.numpy as jnp
import pytest

from repro.analysis import hlo_lint
from repro.launch import hlo_analysis


def _module(wire_line: str) -> str:
    """A minimal parseable module: compressed inter-node wire line +
    the legitimate intra-node f32 allgather (fast domain, ppn=4)."""
    return f"""
ENTRY %main (p0: f32[288]) -> f32[288] {{
  %p0 = f32[288]{{0}} parameter(0)
  {wire_line}
  %intra = f32[288]{{0}} all-gather(%p0), replica_groups={{{{0,1,2,3}},{{4,5,6,7}}}}, dimensions={{0}}
  ROOT %out = f32[288]{{0}} copy(%intra)
}}
"""


CLEAN_S8 = _module(
    "%wire = s8[288]{0} all-reduce(%p0), "
    "replica_groups={{0,4},{1,5},{2,6},{3,7}}, to_apply=%add"
)


# ---------------------------------------------------------------------------
# parser promotion: iter_collectives / dtype table
# ---------------------------------------------------------------------------


def test_iter_collectives_parses_kind_dtype_groups():
    cols = hlo_lint.collective_ops(CLEAN_S8)
    assert [(c.kind, c.dtypes, c.elems) for c in cols] == [
        ("all-reduce", ("s8",), 288),
        ("all-gather", ("f32",), 288),
    ]
    assert cols[0].replica_groups == ((0, 4), (1, 5), (2, 6), (3, 7))
    assert cols[1].replica_groups == ((0, 1, 2, 3), (4, 5, 6, 7))


def test_iter_collectives_folds_async_start_variants():
    txt = _module(
        "%wire = s8[288]{0} all-reduce-start(%p0), "
        "replica_groups={{0,4},{1,5},{2,6},{3,7}}, to_apply=%add"
    )
    cols = hlo_lint.collective_ops(txt)
    assert cols[0].kind == "all-reduce"
    assert cols[0].op == "all-reduce-start"


def test_dtype_table_prices_packed_int4():
    """PR 6's packed-int4 transport: s4/u4 are half a byte, so traffic
    analysis prices them instead of silently dropping the bytes."""
    assert hlo_analysis._DTYPE_BYTES["s4"] == 0.5
    assert hlo_analysis._DTYPE_BYTES["u4"] == 0.5
    assert hlo_analysis._shape_bytes("s4[16]") == 8
    assert hlo_analysis._shape_bytes("u4[10]{0}") == 5
    assert hlo_analysis._shape_bytes("(u4[8], s8[4])") == 8


def test_parse_hlo_public_handle():
    comps, entry = hlo_analysis.parse_hlo(CLEAN_S8)
    assert entry == "main"
    assert "wire" in comps["main"].instrs


# ---------------------------------------------------------------------------
# wire-dtype rule
# ---------------------------------------------------------------------------


def test_compressed_wire_clean_module_passes():
    assert (
        hlo_lint.lint_compressed_wire(
            CLEAN_S8, bits=8, payload_elems=288, ppn=4
        )
        == []
    )


def test_compressed_wire_missing_dtype_fires():
    # a 4-bit config must ship packed u8 — an s8 wire is the wrong width
    vs = hlo_lint.lint_compressed_wire(
        CLEAN_S8, bits=4, payload_elems=288, ppn=4
    )
    assert any("u8" in v.message for v in vs)
    assert all(v.rule == "wire-dtype" for v in vs)


def test_compressed_wire_wide_int_fires():
    txt = _module(
        "%wire = s32[288]{0} all-reduce(%p0), "
        "replica_groups={{0,4},{1,5},{2,6},{3,7}}, to_apply=%add"
    )
    vs = hlo_lint.lint_compressed_wire(txt, bits=8, payload_elems=288, ppn=4)
    assert any("wide-integer" in v.message for v in vs)
    # the payload-sized s32 text screen fires too
    assert any("s32[288]" in v.message for v in vs)


def test_compressed_wire_s16_text_screen_fires():
    txt = CLEAN_S8.replace("ROOT %out = f32[288]{0} copy(%intra)",
                           "ROOT %out = s16[288]{0} copy(%intra)")
    vs = hlo_lint.lint_compressed_wire(txt, bits=8, payload_elems=288, ppn=4)
    assert any("s16[" in v.message for v in vs)


def test_compressed_wire_intra_node_f32_exempt_only_with_ppn():
    """The payload-sized f32 intra-node allgather is legitimate (the
    fast domain is uncompressed by design) — but only replica groups
    that provably stay inside one node earn the exemption."""
    clean = hlo_lint.lint_compressed_wire(
        CLEAN_S8, bits=8, payload_elems=288, ppn=4
    )
    assert clean == []
    # without ppn the same module is conservatively flagged
    strict = hlo_lint.lint_compressed_wire(
        CLEAN_S8, bits=8, payload_elems=288
    )
    assert any("payload-sized f32" in v.message for v in strict)


def test_compressed_wire_inter_node_f32_payload_fires():
    txt = _module(
        "%wire = f32[288]{0} all-reduce(%p0), "
        "replica_groups={{0,4},{1,5},{2,6},{3,7}}, to_apply=%add"
    )
    vs = hlo_lint.lint_compressed_wire(txt, bits=8, payload_elems=288, ppn=4)
    kinds = {v.rule for v in vs}
    assert kinds == {"wire-dtype"}
    assert any("uncompressed wire" in v.message for v in vs)
    # sub-payload floats (scale exchange etc.) stay allowed
    assert not any("f32[3]" in v.message for v in vs)


def test_expected_wire_dtype_bounds():
    assert hlo_lint.expected_wire_dtype(8) == "s8"
    assert hlo_lint.expected_wire_dtype(5) == "s8"
    assert hlo_lint.expected_wire_dtype(4) == "u8"
    assert hlo_lint.expected_wire_dtype(2) == "u8"
    with pytest.raises(ValueError):
        hlo_lint.expected_wire_dtype(9)


# ---------------------------------------------------------------------------
# collective-count budgets
# ---------------------------------------------------------------------------


def test_collective_counts_on_parsed_hlo():
    assert (
        hlo_lint.lint_collective_counts(
            CLEAN_S8, {"all-reduce": 1, "all-gather": (0, 1)}
        )
        == []
    )
    vs = hlo_lint.lint_collective_counts(CLEAN_S8, {"all-reduce": 2})
    assert vs and vs[0].rule == "collective-count"
    assert "1 x 'all-reduce'" in vs[0].message


def test_collective_counts_substring_mode_for_jaxpr():
    jaxpr = "a = pallas_call[x] b\nc = pallas_call[y] d\n"
    assert hlo_lint.lint_collective_counts(jaxpr, {"pallas_call": 2}) == []
    vs = hlo_lint.lint_collective_counts(jaxpr, {"pallas_call": 4})
    assert vs and "budget 4" in vs[0].message


def test_assert_clean_raises_with_listing():
    vs = hlo_lint.lint_collective_counts("", {"pallas_call": 1})
    with pytest.raises(AssertionError, match="pallas_call"):
        hlo_lint.assert_clean(vs, "ctx")
    hlo_lint.assert_clean([], "ctx")  # no-op when clean


# ---------------------------------------------------------------------------
# replica-group partition rule
# ---------------------------------------------------------------------------


def test_replica_groups_clean_partition_passes():
    assert hlo_lint.lint_replica_groups(CLEAN_S8, num_devices=8) == []


def test_replica_groups_overlap_fires():
    txt = _module(
        "%wire = s8[288]{0} all-reduce(%p0), "
        "replica_groups={{0,1},{1,2},{3,4},{5,6,7}}, to_apply=%add"
    )
    vs = hlo_lint.lint_replica_groups(txt, num_devices=8)
    assert any("overlap" in v.message and "[1]" in v.message for v in vs)
    assert all(v.rule == "replica-groups" for v in vs)


def test_replica_groups_gap_fires():
    # 4-device module whose only group covers {0, 1}: ranks 2 and 3
    # never join — the classic static hang
    txt = """
ENTRY %main (p0: f32[16]) -> f32[16] {
  %p0 = f32[16]{0} parameter(0)
  %wire = f32[16]{0} all-reduce(%p0), replica_groups={{0,1}}, to_apply=%add
  ROOT %out = f32[16]{0} copy(%wire)
}
"""
    vs = hlo_lint.lint_replica_groups(txt, num_devices=4)
    assert any("gap" in v.message and "[2, 3]" in v.message for v in vs)


def test_replica_groups_out_of_range_fires():
    txt = """
ENTRY %main (p0: f32[16]) -> f32[16] {
  %p0 = f32[16]{0} parameter(0)
  %wire = f32[16]{0} all-reduce(%p0), replica_groups={{0,1},{2,9}}, to_apply=%add
  ROOT %out = f32[16]{0} copy(%wire)
}
"""
    vs = hlo_lint.lint_replica_groups(txt, num_devices=4)
    assert any("outside" in v.message and "[9]" in v.message for v in vs)
    assert any("gap" in v.message and "[3]" in v.message for v in vs)


def test_replica_groups_iota_product_checked():
    good = """
ENTRY %main (p0: f32[16]) -> f32[16] {
  %p0 = f32[16]{0} parameter(0)
  %wire = f32[16]{0} all-reduce(%p0), replica_groups=[2,4], to_apply=%add
  ROOT %out = f32[16]{0} copy(%wire)
}
"""
    assert hlo_lint.lint_replica_groups(good, num_devices=8) == []
    bad = good.replace("replica_groups=[2,4]", "replica_groups=[2,3]")
    vs = hlo_lint.lint_replica_groups(bad, num_devices=8)
    assert vs and "cover 6 devices, module has 8" in vs[0].message


def test_replica_groups_implicit_all_devices_clean():
    txt = """
ENTRY %main (p0: f32[16]) -> f32[16] {
  %p0 = f32[16]{0} parameter(0)
  %wire = f32[16]{0} all-reduce(%p0), to_apply=%add
  ROOT %out = f32[16]{0} copy(%wire)
}
"""
    assert hlo_lint.lint_replica_groups(txt, num_devices=8) == []


# ---------------------------------------------------------------------------
# stable-lowering rule
# ---------------------------------------------------------------------------


def test_stable_lowering_clean_on_pure_fn():
    assert hlo_lint.lint_stable_lowering(
        lambda x: x * 2.0 + 1.0, jnp.zeros((4,), jnp.float32)
    ) == []


def test_stable_lowering_fires_on_varying_capture():
    """A traced fn baking in a fresh constant per call lowers
    differently every time — under jit that's a silent recompile per
    train step, which is exactly what the rule exists to catch."""
    state = {"n": 0}

    def unstable(x):
        state["n"] += 1
        return x + float(state["n"])

    vs = hlo_lint.lint_stable_lowering(
        unstable, jnp.zeros((4,), jnp.float32)
    )
    assert vs and vs[0].rule == "stable-lowering"
    assert "recompile" in vs[0].message
