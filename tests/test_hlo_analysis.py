"""Validate the trip-count-aware HLO analyzer against known-cost programs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import normalize_cost_analysis
from repro.launch.hlo_analysis import analyze_hlo


def _hlo(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_plain_matmul_flops():
    def f(x, w):
        return x @ w

    txt = _hlo(f, jnp.ones((64, 128)), jnp.ones((128, 32)))
    st = analyze_hlo(txt)
    assert st.flops == pytest.approx(2 * 64 * 128 * 32, rel=0.01)
    assert st.dots == 1
    assert st.unknown_trip_whiles == 0


def test_scan_multiplies_by_trip_count():
    """The exact case cost_analysis() gets wrong by the trip count."""

    def f(x):
        def body(c, _):
            return c @ c, None

        out, _ = jax.lax.scan(body, x, None, length=17)
        return out

    txt = _hlo(f, jnp.ones((64, 64)))
    st = analyze_hlo(txt)
    expected = 17 * 2 * 64**3
    assert st.flops == pytest.approx(expected, rel=0.02)
    # cost_analysis undercounts by ~17x — that's why this module exists
    cost = normalize_cost_analysis(
        jax.jit(f).lower(jnp.ones((64, 64))).compile().cost_analysis()
    )
    assert cost["flops"] < expected / 8


def test_nested_scan_multipliers():
    def f(x):
        def inner(c, _):
            return c @ c, None

        def outer(c, _):
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None

        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out

    txt = _hlo(f, jnp.ones((32, 32)))
    st = analyze_hlo(txt)
    assert st.flops == pytest.approx(15 * 2 * 32**3, rel=0.05)


def test_einsum_batched_flops():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    txt = _hlo(f, jnp.ones((4, 16, 32)), jnp.ones((4, 32, 8)))
    st = analyze_hlo(txt)
    assert st.flops == pytest.approx(2 * 4 * 16 * 32 * 8, rel=0.01)


def test_memory_traffic_scales_with_trips():
    def f(x):
        def body(c, _):
            return c + 1.0, None

        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    n = 1 << 16
    txt = _hlo(f, jnp.ones((n,)))
    st = analyze_hlo(txt)
    # each iteration reads + writes the carry: >= 2 * 4B * n * 10
    assert st.memory_bytes >= 2 * 4 * n * 10
    assert st.memory_bytes < 50 * 4 * n * 10  # same order of magnitude


def test_collectives_inside_scan_multiply():
    import os
    import subprocess
    import sys
    import textwrap

    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        import sys; sys.path.insert(0, "src")
        from repro import compat
        from repro.launch.mesh import make_mesh
        from repro.launch.hlo_analysis import analyze_hlo

        mesh = make_mesh((8,), ("d",))
        def f(x):
            def body(c, _):
                return jax.lax.psum(c, "d"), None
            out, _ = jax.lax.scan(body, x, None, length=6)
            return out
        g = compat.shard_map(f, mesh=mesh, in_specs=P(None), out_specs=P(None))
        txt = jax.jit(g).lower(jnp.ones((1024,))).compile().as_text()
        st = analyze_hlo(txt)
        # 6 all-reduces of 4 KiB each, wire = 2*size*(7/8)
        expect = 6 * 2 * 4096 * 7 / 8
        assert abs(st.collectives["all-reduce"]["count"] - 6) < 1e-6, st
        assert abs(st.collective_bytes - expect) / expect < 0.05, st
        print("OK")
        """
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, env=env, cwd=".", timeout=300,
    )
    assert proc.returncode == 0 and "OK" in proc.stdout, proc.stderr[-2000:]
