"""Validation of the paper's §IV model and §V measured claims."""

import math

import numpy as np
import pytest

from repro.core import napalg, perf_model as pm, simulator as sim

P = pm.BLUE_WATERS


def test_eq_reduces_to_postal_when_bandwidth_achieved():
    """Eq 3 'reduces to Equation 2 when inter-process bandwidth is
    achieved' — i.e. when ppn*R_b <= R_N the max-rate term equals s/R_b."""
    p = pm.MachineParams(
        alpha_l=1e-6, beta_l=1e-10, alpha=2e-6, R_b=1e9, R_N=1e12,
        gamma=1e-11,
    )
    s = 1024.0
    assert pm.maxrate_message_cost(s, p, active_per_node=4) == pytest.approx(
        p.alpha + s / p.R_b, rel=1e-12
    )


def test_nap_wins_small_messages_at_32k_procs():
    """Paper Figs 11/14: at 32 768 processes NAP is fastest below ~2 KiB,
    SMP fastest for large reductions."""
    n, ppn = 2048, 16
    for s in [8, 64, 512, 1024]:
        nap = pm.cost_nap(s, n, ppn, P)
        assert nap < pm.cost_rd(s, n, ppn, P)
        assert nap < pm.cost_smp(s, n, ppn, P)
    for s in [8192, 65536]:
        smp = pm.cost_smp(s, n, ppn, P)
        assert smp < pm.cost_rd(s, n, ppn, P)
        assert smp < pm.cost_nap(s, n, ppn, P)


def test_crossover_near_2048_bytes():
    """Paper §V: 'NAP allreduce yields improved performance up to a
    reduction size of 2048 bytes'."""
    xo = pm.crossover_bytes(2048, 16, P)
    assert 1024 <= xo <= 4096


def test_speedup_grows_with_process_count():
    """Paper Fig 10/13: NAP's advantage increases with process count."""
    s = 8.0
    speedups = [
        pm.cost_rd(s, n, 16, P) / pm.cost_nap(s, n, 16, P)
        for n in [16, 256, 4096, 65536]
    ]
    assert speedups[0] > 1.0
    assert speedups[-1] > speedups[0]
    assert all(b >= a * 0.95 for a, b in zip(speedups, speedups[1:]))


def test_simulator_matches_model_ordering():
    """The event-driven simulator must reproduce the model's ordering in
    both regimes (small: NAP wins; large: SMP wins)."""
    n, ppn = 256, 16
    small = {
        a: sim.simulate_algorithm(a, n, ppn, 8.0, P)
        for a in ["rd", "smp", "nap"]
    }
    assert small["nap"] < small["rd"]
    assert small["nap"] < small["smp"]
    large = {
        a: sim.simulate_algorithm(a, n, ppn, 65536.0, P)
        for a in ["rd", "smp", "nap"]
    }
    assert large["smp"] < large["nap"]


def test_simulator_within_model_envelope():
    """Simulated times should be the same order of magnitude as Eq 4-6
    (they share constants; the simulator adds pipelining/imbalance)."""
    n, ppn = 512, 16
    for algo, fn in [("rd", pm.cost_rd), ("smp", pm.cost_smp), ("nap", pm.cost_nap)]:
        t_sim = sim.simulate_algorithm(algo, n, ppn, 8.0, P)
        t_model = fn(8.0, n, ppn, P)
        assert 0.2 < t_sim / t_model < 5.0, (algo, t_sim, t_model)


def test_power_of_ppn_is_best_case():
    """Paper §VI: non-power node counts pay the next power's inter-node
    steps, so per-byte speedup peaks at powers of ppn."""
    ppn = 16
    t_256 = sim.simulate_algorithm("nap", 256, ppn, 8.0, P)
    t_257 = sim.simulate_algorithm("nap", 257, ppn, 8.0, P)
    assert t_257 >= t_256  # 257 nodes needs 3 steps, 256 needs 2
    assert napalg.nap_num_steps(256, ppn) == 2
    assert napalg.nap_num_steps(257, ppn) == 3


def test_nap_internode_bytes_vs_rd():
    """Node-pair de-duplication: NAP moves fewer inter-node bytes than RD
    for the same reduction."""
    n, ppn, s = 64, 16, 8
    nap = napalg.build_nap_schedule(n, ppn)
    rd = napalg.build_rd_schedule(n, ppn)
    nap_bytes = napalg.message_counts(nap)["total"] * s
    rd_inter = sum(
        sum(1 for a, b in st.pairs if a // ppn != b // ppn) for st in rd.steps
    )
    assert nap_bytes < rd_inter * s


def test_hierarchical_auto_switch_is_model_driven():
    """The 'auto' dispatcher must take its NAP↔MLA switch point from
    perf_model.crossover_bytes for the actual grid, not a constant
    (checked at the HLO level in the multi-device suite; here: the
    decision logic)."""
    from repro.core import collectives

    for n, ppn in [(2, 16), (4, 4), (64, 16)]:
        xo = collectives.auto_crossover_bytes(n, ppn)
        assert xo == pm.crossover_bytes(n, ppn, pm.TPU_V5E_POD, large="mla")
        assert collectives.select_algorithm(int(xo) - 8, n, ppn) == "nap"
        assert collectives.select_algorithm(int(xo) + 8, n, ppn) == "mla"
    # no slow domain -> plain psum regardless of size
    assert collectives.select_algorithm(1 << 30, 1, 16) == "psum"
    # different grids genuinely move the switch point (not one constant)
    assert (
        collectives.auto_crossover_bytes(2, 16)
        != collectives.auto_crossover_bytes(4, 4)
    )


# ---------------------------------------------------------------------------
# MLA cost model + striped simulator replay
# ---------------------------------------------------------------------------


def test_cost_mla_wins_bandwidth_regime():
    """MLA must beat NAP (and the SMP-style single-lane path) for large
    reductions and lose the latency regime to NAP."""
    for params in [pm.BLUE_WATERS, pm.TPU_V5E_POD]:
        n, ppn = 64, 16
        for s in [8.0, 64.0]:
            assert pm.cost_nap(s, n, ppn, params) < pm.cost_mla(
                s, n, ppn, params
            )
        for s in [1 << 20, 1 << 24]:
            mla = pm.cost_mla(float(s), n, ppn, params)
            assert mla < pm.cost_nap(float(s), n, ppn, params)
            assert mla < pm.cost_smp(float(s), n, ppn, params)
            assert mla < pm.cost_rd(float(s), n, ppn, params)


def test_crossover_mla_is_finite_and_ordered():
    for n, ppn in [(2, 16), (8, 16), (64, 16), (4, 4)]:
        xo = pm.crossover_bytes(n, ppn, pm.TPU_V5E_POD, large="mla")
        assert 8.0 <= xo <= 1 << 22
        assert pm.cost_nap(xo / 4, n, ppn, pm.TPU_V5E_POD) <= pm.cost_mla(
            xo / 4, n, ppn, pm.TPU_V5E_POD
        )
        assert pm.cost_mla(xo * 4, n, ppn, pm.TPU_V5E_POD) <= pm.cost_nap(
            xo * 4, n, ppn, pm.TPU_V5E_POD
        )


def test_simulator_mla_striping():
    """Replaying the striped schedule: per-chip inter-node bytes are
    ~2*(s/ppn)*(n-1)/n — a ppn-fold drop vs the single-lane path — and
    the simulated time beats NAP in the bandwidth regime."""
    n, ppn = 8, 16
    s = float(1 << 22)
    got = sim.internode_bytes_per_chip("mla", n, ppn, s)
    assert got == pytest.approx(2.0 * (s / ppn) * (n - 1) / n)
    assert got <= 2.0 * s / ppn
    assert got < sim.internode_bytes_per_chip("nap", n, ppn, s)
    t_mla = sim.simulate_algorithm("mla", n, ppn, s, pm.TPU_V5E_POD)
    t_nap = sim.simulate_algorithm("nap", n, ppn, s, pm.TPU_V5E_POD)
    assert t_mla < t_nap
    # latency regime: NAP stays the winner
    t_mla8 = sim.simulate_algorithm("mla", n, ppn, 8.0, pm.TPU_V5E_POD)
    t_nap8 = sim.simulate_algorithm("nap", n, ppn, 8.0, pm.TPU_V5E_POD)
    assert t_nap8 < t_mla8


def test_simulator_agrees_with_model_crossover():
    """The simulator's replay must not contradict the model-driven switch:
    just above the modeled NAP↔MLA crossover, simulated MLA must already
    beat (or at least match) simulated NAP — the log-step RS/AG
    realization, not a ring whose alpha-steps would bury the crossover."""
    for n, ppn in [(8, 16), (64, 16)]:
        xo = pm.crossover_bytes(n, ppn, pm.TPU_V5E_POD, large="mla")
        s = 2.0 * xo
        t_mla = sim.simulate_algorithm("mla", n, ppn, s, pm.TPU_V5E_POD)
        t_nap = sim.simulate_algorithm("nap", n, ppn, s, pm.TPU_V5E_POD)
        assert t_mla <= t_nap * 1.1, (n, ppn, s, t_mla, t_nap)
        # and the simulated time is the same order as the closed form
        t_model = pm.cost_mla(s, n, ppn, pm.TPU_V5E_POD)
        assert 0.2 < t_mla / t_model < 5.0, (t_mla, t_model)
