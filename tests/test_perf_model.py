"""Validation of the paper's §IV model and §V measured claims."""

import math

import numpy as np
import pytest

from repro.core import napalg, perf_model as pm, simulator as sim

P = pm.BLUE_WATERS


def test_eq_reduces_to_postal_when_bandwidth_achieved():
    """Eq 3 'reduces to Equation 2 when inter-process bandwidth is
    achieved' — i.e. when ppn*R_b <= R_N the max-rate term equals s/R_b."""
    p = pm.MachineParams(
        alpha_l=1e-6, beta_l=1e-10, alpha=2e-6, R_b=1e9, R_N=1e12,
        gamma=1e-11,
    )
    s = 1024.0
    assert pm.maxrate_message_cost(s, p, active_per_node=4) == pytest.approx(
        p.alpha + s / p.R_b, rel=1e-12
    )


def test_nap_wins_small_messages_at_32k_procs():
    """Paper Figs 11/14: at 32 768 processes NAP is fastest below ~2 KiB,
    SMP fastest for large reductions."""
    n, ppn = 2048, 16
    for s in [8, 64, 512, 1024]:
        nap = pm.cost_nap(s, n, ppn, P)
        assert nap < pm.cost_rd(s, n, ppn, P)
        assert nap < pm.cost_smp(s, n, ppn, P)
    for s in [8192, 65536]:
        smp = pm.cost_smp(s, n, ppn, P)
        assert smp < pm.cost_rd(s, n, ppn, P)
        assert smp < pm.cost_nap(s, n, ppn, P)


def test_crossover_near_2048_bytes():
    """Paper §V: 'NAP allreduce yields improved performance up to a
    reduction size of 2048 bytes'."""
    xo = pm.crossover_bytes(2048, 16, P)
    assert 1024 <= xo <= 4096


def test_speedup_grows_with_process_count():
    """Paper Fig 10/13: NAP's advantage increases with process count."""
    s = 8.0
    speedups = [
        pm.cost_rd(s, n, 16, P) / pm.cost_nap(s, n, 16, P)
        for n in [16, 256, 4096, 65536]
    ]
    assert speedups[0] > 1.0
    assert speedups[-1] > speedups[0]
    assert all(b >= a * 0.95 for a, b in zip(speedups, speedups[1:]))


def test_simulator_matches_model_ordering():
    """The event-driven simulator must reproduce the model's ordering in
    both regimes (small: NAP wins; large: SMP wins)."""
    n, ppn = 256, 16
    small = {
        a: sim.simulate_algorithm(a, n, ppn, 8.0, P)
        for a in ["rd", "smp", "nap"]
    }
    assert small["nap"] < small["rd"]
    assert small["nap"] < small["smp"]
    large = {
        a: sim.simulate_algorithm(a, n, ppn, 65536.0, P)
        for a in ["rd", "smp", "nap"]
    }
    assert large["smp"] < large["nap"]


def test_simulator_within_model_envelope():
    """Simulated times should be the same order of magnitude as Eq 4-6
    (they share constants; the simulator adds pipelining/imbalance)."""
    n, ppn = 512, 16
    for algo, fn in [("rd", pm.cost_rd), ("smp", pm.cost_smp), ("nap", pm.cost_nap)]:
        t_sim = sim.simulate_algorithm(algo, n, ppn, 8.0, P)
        t_model = fn(8.0, n, ppn, P)
        assert 0.2 < t_sim / t_model < 5.0, (algo, t_sim, t_model)


def test_power_of_ppn_is_best_case():
    """Paper §VI: non-power node counts pay the next power's inter-node
    steps, so per-byte speedup peaks at powers of ppn."""
    ppn = 16
    t_256 = sim.simulate_algorithm("nap", 256, ppn, 8.0, P)
    t_257 = sim.simulate_algorithm("nap", 257, ppn, 8.0, P)
    assert t_257 >= t_256  # 257 nodes needs 3 steps, 256 needs 2
    assert napalg.nap_num_steps(256, ppn) == 2
    assert napalg.nap_num_steps(257, ppn) == 3


def test_nap_internode_bytes_vs_rd():
    """Node-pair de-duplication: NAP moves fewer inter-node bytes than RD
    for the same reduction."""
    n, ppn, s = 64, 16, 8
    nap = napalg.build_nap_schedule(n, ppn)
    rd = napalg.build_rd_schedule(n, ppn)
    nap_bytes = napalg.message_counts(nap)["total"] * s
    rd_inter = sum(
        sum(1 for a, b in st.pairs if a // ppn != b // ppn) for st in rd.steps
    )
    assert nap_bytes < rd_inter * s


def test_hierarchical_auto_switch_threshold():
    """The 'auto' dispatcher must pick NAP below the paper's crossover and
    the RS+AG path above it (checked at the HLO level in the multi-device
    suite; here: the decision logic)."""
    import jax.numpy as jnp

    from repro.core import collectives

    small = jnp.zeros((256,), jnp.float32)   # 1 KiB  -> nap
    large = jnp.zeros((4096,), jnp.float32)  # 16 KiB -> rabenseifner
    # the dispatcher resolves the algorithm before touching axes; probing
    # via the size rule it applies:
    t = 2048
    assert small.size * small.dtype.itemsize <= t
    assert large.size * large.dtype.itemsize > t
