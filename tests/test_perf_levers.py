"""Correctness of hillclimb perf levers (must be output-invariant)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models import build_model


def test_window_kv_slice_is_output_invariant():
    """Slicing K/V to the sliding window per q-chunk must not change the
    attention output (the mask already zeroed out-of-window keys)."""
    base = dataclasses.replace(
        reduced(ARCHS["gemma2-27b"]),
        sliding_window=8,
        window_kv_slice=False,
    )
    opt = dataclasses.replace(base, window_kv_slice=True)
    B, S = 2, 64  # q_chunk forced small via direct attention call below

    from repro.models import attention as attn_mod

    key = jax.random.PRNGKey(0)
    params = attn_mod.init_attention(key, base, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, base.d_model)) * 0.1
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    class P:  # no-op policy
        mesh = None

        @staticmethod
        def act(x, kind):
            return x

    out_base = attn_mod.attention_full(
        params, x, cfg=base, policy=P, positions=pos,
        causal=True, window=8, q_chunk=16,
    )
    out_opt = attn_mod.attention_full(
        params, x, cfg=opt, policy=P, positions=pos,
        causal=True, window=8, q_chunk=16,
    )
    np.testing.assert_allclose(
        np.asarray(out_base), np.asarray(out_opt), rtol=1e-5, atol=1e-6
    )


def test_window_kv_slice_full_model():
    cfg = dataclasses.replace(
        reduced(ARCHS["gemma2-27b"]), sliding_window=8
    )
    tok = jax.random.randint(jax.random.PRNGKey(2), (1, 48), 0, cfg.vocab_size)
    outs = {}
    for flag in [False, True]:
        c = dataclasses.replace(cfg, window_kv_slice=flag)
        model = build_model(c)
        params = model.init(jax.random.PRNGKey(0))
        h, _ = jax.jit(model.apply)(params, {"tokens": tok})
        outs[flag] = np.asarray(h)
    np.testing.assert_allclose(outs[False], outs[True], rtol=1e-5, atol=1e-6)
