"""Topology-first collective API report (BENCH_4.json).

Three sections, all host-side (no devices needed):

* **registry** — the engine registry listing: every registered engine
  per collective family with its declared capabilities (ops, grid
  constraints, regime) and whether it carries a cost model / schedule
  builder — the extension surface a new engine or backend plugs into.
* **dispatch tables** — the (engine, pipeline depth) decision of
  ``comm.select_engine`` per collective across grids x payload sizes x
  ops: the machine-readable form of the ROADMAP dispatch table,
  including the new reduce_scatter / allgather rows.
* **rs_ag_accounting** — per-chip inter-node bytes of the striped
  reduce-scatter / allgather / allreduce schedules (event-replay
  accounting) against the ragged uneven-block lower bounds, with an
  equality flag per row — the acceptance criterion of the RS/AG
  promotion, tracked per commit.

Prints ``name,value,derived`` CSV; ``--json PATH`` writes the full
payload — CI uploads it as ``BENCH_4.json`` next to the gradsync
overlap artifact.
"""

from __future__ import annotations

import json
import math
import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
for _p in (str(_ROOT), str(_ROOT / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from repro.core import comm, napalg, simulator as sim

GRIDS = [(1, 16), (2, 16), (6, 1), (8, 16), (64, 16)]
SIZES = [4, 2048, 1 << 16, 1 << 20, 16 << 20, 64 << 20]
OPS = ["sum", "max"]
RS_AG_ELEMS = [37, 1000, 1 << 16]
ITEMSIZE = 4  # f32 accounting

_BOUNDS = {
    "mla": napalg.mla_internode_lower_bound,
    "mla_rs": napalg.rs_internode_lower_bound,
    "mla_ag": napalg.ag_internode_lower_bound,
}


def registry_section() -> dict:
    return {
        coll: [
            spec.describe()
            for spec in comm.registered_engines(coll).values()
        ]
        for coll in comm.COLLECTIVES
    }


def dispatch_section() -> dict:
    tables: dict[str, list] = {c: [] for c in comm.COLLECTIVES}
    for n, ppn in GRIDS:
        topo = comm.Topology.of(n, ppn)
        for coll in comm.COLLECTIVES:
            for nbytes in SIZES:
                for op in OPS if coll != "allgather" else ["sum"]:
                    engine, chunks = comm.select_engine(
                        topo, nbytes, op=op, collective=coll
                    )
                    tables[coll].append(
                        {
                            "n": n,
                            "ppn": ppn,
                            "nbytes": nbytes,
                            "op": op,
                            "engine": engine,
                            "chunks": chunks,
                        }
                    )
    return tables


def rs_ag_section() -> tuple[list, list, int]:
    """(csv rows, JSON rows, mismatch count) of byte accounting vs the
    ragged lower bounds."""
    csv_rows, json_rows, mismatches = [], [], 0
    for n, ppn in GRIDS:
        if n <= 1:
            continue  # no slow domain: inter-node bytes are zero
        topo = comm.Topology.of(n, ppn)
        for elems in RS_AG_ELEMS:
            s = float(elems * ITEMSIZE)
            group_equal = True  # this (grid, elems) cell only
            for engine, bound_fn in _BOUNDS.items():
                sched = topo.schedule(engine, elems=elems)
                got = sched.max_internode_bytes_per_chip(s)
                bound = bound_fn(n, ppn, elems) * float(ITEMSIZE)
                equal = math.isclose(got, bound, rel_tol=1e-9, abs_tol=1e-9)
                mismatches += 0 if equal else 1
                group_equal &= equal
                json_rows.append(
                    {
                        "n": n,
                        "ppn": ppn,
                        "elems": elems,
                        "engine": engine,
                        "internode_bytes_per_chip": got,
                        "ragged_lower_bound": bound,
                        "equals_bound": equal,
                    }
                )
            csv_rows.append(
                (
                    f"comm_rs_bytes_per_chip_pods{n}x{ppn}_e{elems}",
                    topo.schedule("mla_rs", elems=elems)
                    .max_internode_bytes_per_chip(s),
                    "== ragged lower bound"
                    if group_equal
                    else "BOUND MISMATCH",
                )
            )
    return csv_rows, json_rows, mismatches


def collect() -> tuple[list, dict, int]:
    registry = registry_section()
    dispatch = dispatch_section()
    rs_csv, rs_json, mismatches = rs_ag_section()

    rows = [
        (
            f"comm_registered_engines_{coll}",
            len(engines),
            ",".join(e["name"] for e in engines),
        )
        for coll, engines in registry.items()
    ]
    # one replayed wall-clock per collective at a bandwidth-regime size,
    # so the artifact tracks RS ~= AG ~= allreduce/2 per commit
    topo = comm.Topology.of(8, 16)
    elems = 1 << 16
    s = float(elems * ITEMSIZE)
    for engine in ("mla", "mla_rs", "mla_ag"):
        rows.append(
            (
                f"comm_sim_us_{engine}_pods8x16",
                sim.simulate_collective(topo, engine, s, elems=elems) * 1e6,
                f"{elems} f32 elems",
            )
        )
    rows.extend(rs_csv)
    rows.append(
        (
            "comm_rs_ag_bound_mismatches",
            mismatches,
            "must be 0",
        )
    )
    payload = {
        "bench": "comm_api",
        "machine": comm.Topology.of(1, 1).params.name,
        "registry": registry,
        "dispatch": dispatch,
        "rs_ag_accounting": rs_json,
        "rows": [
            {"name": n, "value": v, "derived": d} for n, v, d in rows
        ],
    }
    return rows, payload, mismatches


def main(json_path: str | None = None) -> int:
    rows, payload, mismatches = collect()
    for name, value, derived in rows:
        print(f"{name},{value},{derived}")
    if json_path:
        out = Path(json_path)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(payload, indent=2))
        print(f"# wrote {out}", file=sys.stderr)
    return 0 if mismatches == 0 else 1


if __name__ == "__main__":
    argv = sys.argv[1:]
    path = None
    if "--json" in argv:
        path = argv[argv.index("--json") + 1]
    sys.exit(main(path))
