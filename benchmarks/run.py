"""Benchmark runner: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Sections:
  * paper_figures   — Figs 10-18 + §III message-count tables
  * gradsync        — gradient-sync schedule comparison (training buckets)
  * roofline_report — per-(arch x shape) roofline terms, if dry-run
                      artifacts exist under reports/dryrun/

``--quick`` runs a CPU smoke instead: one NAP shape (latency regime),
one MLA shape (bandwidth regime) and one chunk-pipelined MLA shape
(ragged payload, C=2) are *executed* end to end on a virtual 2x4 device
mesh, checked against the NumPy oracle and timed — so perf or
correctness regressions on the hot path are catchable without hardware.
"""

from __future__ import annotations

import os
import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
for p in (str(_ROOT), str(_ROOT / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)


def quick_smoke() -> int:
    """Execute one NAP + one MLA allreduce on a virtual CPU mesh."""
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 "
        + os.environ.get("XLA_FLAGS", "")
    )
    import time
    from functools import partial

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.core import collectives
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((2, 4), ("pod", "data"))
    rng = np.random.default_rng(0)
    failures = 0
    print("name,us_per_call,derived")
    cases = [
        ("nap", 8, {}),
        ("mla", 1 << 16, {}),
        # ragged payload through the chunked lowering
        ("mla_pipelined", (1 << 16) + 37, {"pipeline_chunks": 2}),
    ]
    for algo, size, kw in cases:
        xs = jnp.asarray(rng.normal(size=(8, size)).astype(np.float32))
        fn = jax.jit(
            compat.shard_map(
                partial(
                    collectives.ALGORITHMS[algo],
                    inter_axes="pod",
                    intra_axes="data",
                    **kw,
                ),
                mesh=mesh,
                in_specs=P(("pod", "data")),
                out_specs=P(("pod", "data")),
            )
        )
        got = np.asarray(fn(xs))  # compile + correctness
        want = np.asarray(xs).sum(axis=0)
        ok = np.allclose(got, np.tile(want, (8, 1)), rtol=1e-4, atol=1e-4)
        failures += 0 if ok else 1
        iters = 50
        jax.block_until_ready(fn(xs))
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(xs)
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / iters * 1e6
        print(
            f"quick_{algo}_s{size * 4},{us:.3f},"
            f"{'ok' if ok else 'MISMATCH'}"
        )

    # compressed transport smoke: the fused quantize-pack engine end to
    # end (interpret-mode Pallas kernels on CPU), int8 and packed int4
    from repro.core import comm

    size = 1 << 15
    xs = jnp.asarray(rng.normal(size=(8, size)).astype(np.float32))
    want = np.asarray(xs).mean(axis=0)
    qtol = float(np.abs(np.asarray(xs)).max())
    for bits in (8, 4):
        policy = comm.CommPolicy(
            algorithm="nap", mean=True, compress_bits=bits
        )

        def f(x):
            topo = comm.Topology.from_mesh(mesh)
            ctx = comm.CommContext(topo, policy)
            return ctx.sync_grads({"w": x})["w"]

        fn = jax.jit(
            compat.shard_map(
                f, mesh=mesh,
                in_specs=P(("pod", "data")),
                out_specs=P(("pod", "data")),
            )
        )
        got = np.asarray(fn(xs))
        # mean-of-sum error bound: group*A/qmax on the sum -> A/qmax here
        atol = qtol / float(2 ** (bits - 1) - 1) * 1.01 + 1e-6
        ok = bool(np.all(np.abs(got - np.tile(want, (8, 1))) <= atol))
        failures += 0 if ok else 1
        iters = 20
        jax.block_until_ready(fn(xs))
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(xs)
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / iters * 1e6
        print(
            f"quick_compressed_int{bits}_s{size * 4},{us:.3f},"
            f"{'ok' if ok else 'MISMATCH'}"
        )

    # serving spine smoke: continuous batching through the meshed
    # tensor-parallel decode path (repro.serve), staggered arrivals
    from repro.configs import get_config, reduced
    from repro.models import build_model
    from repro.serve import PromptBuckets, ServeEngine

    cfg = reduced(get_config("minicpm-2b"))
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.PRNGKey(0))
    engine = ServeEngine(
        model, params, num_slots=8, max_len=24,
        buckets=PromptBuckets([8]), mesh=mesh,
    )
    disp = engine.dispatch_report()
    reqs = [engine.submit([1, 2, 3], 6), engine.submit([4, 5], 4)]
    engine.step()
    reqs.append(engine.submit([6, 7, 8, 9], 5))  # joins in flight
    t0 = time.perf_counter()
    out = engine.run()
    dt = time.perf_counter() - t0
    ok = (
        all(len(out[r.rid]) == r.max_new_tokens for r in reqs)
        and engine.idle
        and disp["logits_allreduce"]["engine"] == "nap"
    )
    failures += 0 if ok else 1
    us = dt / max(engine.n_decode_steps, 1) * 1e6
    print(
        f"quick_serve_engine_{disp['logits_allreduce']['engine']},"
        f"{us:.3f},{'ok' if ok else 'MISMATCH'}"
    )
    return failures


def main() -> None:
    if "--quick" in sys.argv[1:]:
        sys.exit(quick_smoke())

    print("name,us_per_call,derived")
    from benchmarks import paper_figures

    for fn in paper_figures.ALL:
        fn()

    from benchmarks import gradsync

    gradsync.main()

    from benchmarks import roofline_report

    roofline_report.main()


if __name__ == "__main__":
    main()
