"""Benchmark runner: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Sections:
  * paper_figures   — Figs 10-17 + §III message-count tables
  * gradsync        — gradient-sync schedule comparison (training buckets)
  * roofline_report — per-(arch x shape) roofline terms, if dry-run
                      artifacts exist under reports/dryrun/
"""

from __future__ import annotations

import sys


def main() -> None:
    print("name,us_per_call,derived")
    from benchmarks import paper_figures

    for fn in paper_figures.ALL:
        fn()

    from benchmarks import gradsync

    gradsync.main()

    from benchmarks import roofline_report

    roofline_report.main()


if __name__ == "__main__":
    main()
