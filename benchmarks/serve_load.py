"""Serving load benchmark: offered QPS -> throughput / latency (BENCH_9).

Two halves, split so the CI gate is deterministic:

1. **Measurement** — a real meshed :class:`repro.serve.ServeEngine` on
   the virtual 2x4 CPU grid decodes with every slot busy at two slot
   widths, recording per-decode-step wall-clock as
   ``MachineParams.fit``-shaped ``(size_bytes, seconds, senders)`` rows
   (the logits-allreduce payload is the size axis; effective
   single-message rows, senders=1).  The rows feed a
   ``MachineParams.fit`` self-check — open item 4's recalibration loop
   eating real serving data.

2. **Load curve** — a deterministic discrete-event simulation drives
   the *real* :class:`Scheduler` + :class:`Router` classes (admission,
   slots, FIFO, outstanding-token routing, straggler rerouting) with
   the measured per-step time as the service clock, sweeping offered
   QPS to saturation.  Simulated time keeps the CI assertion — tokens/s
   monotone non-decreasing in offered QPS below saturation — exact
   rather than wall-clock flaky, while every control-plane decision is
   made by the production code under test.

The dispatch table reports the (engine, chunks) decision for each
decode-step collective on the executed grid and on representative
production grids; the gate asserts the per-token logits allreduce lands
on the latency-regime NAP engine for every multi-node grid.

Usage:
  python benchmarks/serve_load.py --json reports/BENCH_9.json [--quick]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from collections import deque
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
for p in (str(_ROOT), str(_ROOT / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import numpy as np  # noqa: E402


# ---------------------------------------------------------------------------
# 1. measurement: real engine per-decode-step wall-clock
# ---------------------------------------------------------------------------


def measure_engine(num_slots: int, *, slices: int, gen_len: int):
    """Decode with every slot busy on the 2x4 grid; returns (fit rows,
    median seconds per decode step, dispatch report)."""
    import jax

    from repro.configs import get_config, reduced
    from repro.launch.mesh import make_mesh
    from repro.models import build_model
    from repro.serve import PromptBuckets, ServeEngine

    cfg = reduced(get_config("minicpm-2b"))
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.PRNGKey(0))
    mesh = make_mesh((2, 4), ("pod", "data"))
    engine = ServeEngine(
        model, params, num_slots=num_slots, max_len=32,
        buckets=PromptBuckets([8]), mesh=mesh,
    )
    rng = np.random.default_rng(0)
    for _ in range(num_slots):  # saturate every slot
        engine.submit(
            rng.integers(0, cfg.vocab_size, size=6).tolist(),
            max_new_tokens=gen_len,
        )
    warm = engine.step()  # admission + first slice: compile, not timed
    assert not warm
    engine.step_times.clear()
    for _ in range(slices):
        engine.step()
    rows = engine.fit_rows()
    per_step = float(np.median([t for _, t, _ in rows])) if rows else 0.0
    return rows, per_step, engine.dispatch_report()


def fit_self_check(rows) -> dict:
    """Feed the measured rows to ``MachineParams.fit`` (>= 2 k==1 rows at
    distinct sizes required) and sanity-check the constants."""
    from repro.core.perf_model import MachineParams

    fitted = MachineParams.fit(rows, name="serve_fit")
    ok = (
        np.isfinite(fitted.alpha)
        and np.isfinite(fitted.R_b)
        and fitted.alpha >= 0
        and fitted.R_b > 0
    )
    return {
        "ok": bool(ok),
        "alpha_s": float(fitted.alpha),
        "R_b_bytes_per_s": float(fitted.R_b),
        "n_rows": len(rows),
    }


# ---------------------------------------------------------------------------
# 2. deterministic load simulation over the real Scheduler + Router
# ---------------------------------------------------------------------------


class SimReplica:
    """A serving replica for the discrete-event load model: the *real*
    :class:`repro.serve.Scheduler` drives slots/admission/FIFO; only the
    device slice is simulated (one decode step = ``tau`` simulated
    seconds, prefill = ``bucket_len * tau``), using the wall-clock
    measured on the real engine."""

    def __init__(self, num_slots: int, tau: float, *, max_queue=None):
        from repro.serve import PromptBuckets, Scheduler

        self.scheduler = Scheduler(
            num_slots, max_queue=max_queue, buckets=PromptBuckets([8, 16])
        )
        self.tau = tau
        self.slow = 1.0  # straggler injection multiplier
        self.clock = 0.0
        self.steps = 0

    # Router surface -------------------------------------------------------
    def submit(self, prompt, max_new_tokens, *, arrival=0.0, extras=None):
        self.clock = max(self.clock, arrival)
        return self.scheduler.submit(
            prompt, max_new_tokens, arrival=arrival, extras=extras
        )

    def outstanding_tokens(self) -> int:
        return self.scheduler.outstanding_tokens()

    @property
    def idle(self) -> bool:
        return self.scheduler.idle

    # simulation -----------------------------------------------------------
    def step(self) -> float:
        """One decode-step boundary; returns the *decode* wall-clock
        (what the real engine's slice timing covers — prefill for the
        admitted requests advances the clock but is not the straggler
        signal, mirroring ``ServeEngine.step``)."""
        admitted = self.scheduler.admit(now=self.clock)
        decode_dt = self.tau * self.slow
        dt = decode_dt
        for req in admitted:  # sequential B=1 bucketed prefill
            dt += req.bucket_len * self.tau * self.slow
        self.clock += dt
        for slot, req in enumerate(self.scheduler.slots):
            if req is not None:
                self.scheduler.record_token(slot, 1, now=self.clock)
        self.steps += 1
        return decode_dt


def run_load_point(
    offered_qps: float,
    *,
    tau: float,
    n_requests: int,
    n_replicas: int,
    num_slots: int,
    prompt_len: int,
    gen_len: int,
    straggle_at: int | None = None,
) -> dict:
    """One point of the QPS curve: deterministic arrivals at
    ``offered_qps`` through the real Router into SimReplicas."""
    from repro.serve import Router

    replicas = [SimReplica(num_slots, tau) for _ in range(n_replicas)]
    router = Router(replicas, straggler_threshold=2.0, recovery=3)
    arrivals = [i / offered_qps for i in range(n_requests)]
    prompt = list(range(1, prompt_len + 1))

    pending = deque(arrivals)
    submitted = []
    while pending or not router.idle:
        busy = [i for i, r in enumerate(replicas) if not r.idle]
        t_dec = min((replicas[i].clock for i in busy), default=np.inf)
        if pending and pending[0] <= t_dec:
            t_arr = pending.popleft()
            submitted.append(
                router.submit(prompt, gen_len, arrival=t_arr)
            )
            continue
        i = min(busy, key=lambda r: replicas[r].clock)
        rep = replicas[i]
        if straggle_at is not None and rep.steps == straggle_at and i == 0:
            rep.slow = 5.0  # inject a straggler on replica 0
        dt = rep.step()
        router.observe_step(i, rep.steps, dt)
        if rep.slow > 1.0 and rep.steps > (straggle_at or 0) + 4:
            rep.slow = 1.0  # stall clears; health recovers after N clean

    done = [r for r in submitted if r.state == "finished"]
    assert len(done) == n_requests, "simulation lost requests"
    total_tokens = sum(len(r.generated) for r in done)
    t_end = max(r.token_times[-1] for r in done)
    makespan = t_end - arrivals[0]
    gaps = []
    for r in done:
        prev = r.arrival
        for t in r.token_times:
            gaps.append(t - prev)
            prev = t
    gaps = np.asarray(gaps)
    return {
        "offered_qps": float(offered_qps),
        "tokens_per_s": float(total_tokens / makespan),
        "p50_token_latency_s": float(np.percentile(gaps, 50)),
        "p99_token_latency_s": float(np.percentile(gaps, 99)),
        "completed": len(done),
        "makespan_s": float(makespan),
        "rerouted": router.n_rerouted,
        "degraded_episodes": sum(h.n_degraded for h in router.health),
    }


# ---------------------------------------------------------------------------
# dispatch table
# ---------------------------------------------------------------------------


def dispatch_grids(vocab: int, d_model: int, b_max: int) -> dict:
    """Model-driven dispatch for the decode collectives on production
    grids (host-side: ``Topology.of`` needs no device axes)."""
    from repro.core import comm

    out = {}
    # per-replica TP serving grids (scale beyond these is the Router's
    # data-parallel job, not a wider tensor-parallel group)
    for n, ppn in [(1, 8), (2, 4), (2, 8), (4, 8), (8, 8)]:
        topo = comm.Topology.of(n, ppn)
        ctx = comm.CommContext(topo)
        group = topo.group
        rows = group * b_max
        d_cols = -(-d_model // group)
        grid = {}
        for name, (nbytes, op, coll, pin) in {
            "logits_allreduce": (rows * vocab * 4, "sum", "allreduce", None),
            "hidden_allgather": (
                rows * d_cols * group * 4, "sum", "allgather",
                "mla_ag" if topo.has_slow_domain else None,
            ),
            "eos_min_reduce": (4, "min", "allreduce", "psum"),
        }.items():
            d = ctx.dispatch(int(nbytes), op, collective=coll, algorithm=pin)
            grid[name] = {
                "nbytes": int(nbytes),
                "engine": d.engine,
                "chunks": d.chunks,
            }
        out[f"{n}x{ppn}"] = grid
    return out


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()

    slices = 6 if args.quick else 20
    gen_len = 8 if args.quick else 16

    # 1. real-engine measurement at two slot widths (two payload sizes:
    # MachineParams.fit needs >= 2 distinct k==1 sizes)
    fit_rows = []
    per_step = {}
    dispatch_executed = None
    for num_slots in (8, 16):
        rows, tau, disp = measure_engine(
            num_slots, slices=slices, gen_len=max(gen_len, slices + 2)
        )
        fit_rows.extend(rows)
        per_step[str(num_slots)] = tau
        if dispatch_executed is None:
            dispatch_executed = disp
    fit = fit_self_check(fit_rows)

    # 2. QPS sweep through the real Scheduler/Router (simulated clock)
    tau = per_step["8"]
    n_replicas, num_slots = 2, 8
    prompt_len, sim_gen = 6, 16
    # tokens/s capacity ~ n_replicas * num_slots / tau; saturating QPS
    # ~ capacity / tokens-per-request — sweep from 1/8x to 4x that
    qps_sat = (n_replicas * num_slots / tau) / (sim_gen + prompt_len)
    multipliers = (
        [0.25, 1.0, 4.0] if args.quick
        else [0.125, 0.25, 0.5, 1.0, 2.0, 4.0]
    )
    n_requests = 24 if args.quick else 96
    curve = [
        run_load_point(
            m * qps_sat, tau=tau, n_requests=n_requests,
            n_replicas=n_replicas, num_slots=num_slots,
            prompt_len=prompt_len, gen_len=sim_gen,
        )
        for m in multipliers
    ]
    # straggler scenario: same load, slowdown injected on replica 0
    straggler = run_load_point(
        qps_sat, tau=tau, n_requests=n_requests,
        n_replicas=n_replicas, num_slots=num_slots,
        prompt_len=prompt_len, gen_len=sim_gen, straggle_at=4,
    )

    # 3. dispatch decisions per decode collective across grids
    from repro.configs import get_config, reduced

    cfg = reduced(get_config("minicpm-2b"))
    grids = dispatch_grids(cfg.vocab_size, cfg.d_model, b_max=1)

    # -- checks (the CI gate) ----------------------------------------------
    checks = {}
    # tokens/s monotone non-decreasing in offered QPS up to the peak
    tput = [pt["tokens_per_s"] for pt in curve]
    peak = int(np.argmax(tput))
    checks["monotone_below_saturation"] = bool(
        all(tput[i + 1] >= tput[i] * (1 - 1e-9) for i in range(peak))
    )
    # the per-token logits allreduce rides NAP on every multi-node grid
    checks["nap_on_multinode"] = all(
        g["logits_allreduce"]["engine"] == "nap"
        for key, g in grids.items()
        if not key.startswith("1x")
    )
    checks["nap_executed_grid"] = (
        dispatch_executed["logits_allreduce"]["engine"] == "nap"
    )
    checks["fit_ok"] = fit["ok"]
    checks["straggler_rerouted"] = straggler["rerouted"] > 0

    report = {
        "bench": "serve_load",
        "quick": bool(args.quick),
        "measured": {
            "grid": "2x4",
            "fit_rows": [[int(s), float(t), int(k)] for s, t, k in fit_rows],
            "per_step_s": per_step,
            "machine_params_fit": fit,
        },
        "dispatch": {"executed_2x4": dispatch_executed, "grids": grids},
        "load": {
            "n_replicas": n_replicas,
            "num_slots": num_slots,
            "prompt_len": prompt_len,
            "gen_len": sim_gen,
            "saturation_qps_model": float(qps_sat),
            "curve": curve,
            "straggler_scenario": straggler,
        },
        "checks": checks,
    }

    if args.json:
        out = Path(args.json)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(report, indent=2))
        print(f"wrote {out}")

    failures = sum(1 for ok in checks.values() if not ok)
    for name, ok in checks.items():
        print(f"check {name}: {'ok' if ok else 'FAIL'}")
    print(
        f"qps curve: {[round(pt['tokens_per_s'], 1) for pt in curve]} tok/s "
        f"at {[round(pt['offered_qps'], 2) for pt in curve]} qps"
    )
    return failures


if __name__ == "__main__":
    sys.exit(main())
