"""Roofline report: per-(arch x shape x mesh) terms from dry-run artifacts.

Reads the JSON files produced by ``repro.launch.dryrun`` under
``reports/dryrun/`` and prints the three roofline terms (seconds), the
dominant bottleneck, and the useful-FLOPs ratio for every cell.
Run ``PYTHONPATH=src python -m repro.launch.dryrun --all`` first.
"""

from __future__ import annotations

import json
from pathlib import Path

REPORTS = Path(__file__).resolve().parent.parent / "reports" / "dryrun"


def main() -> None:
    files = sorted(REPORTS.glob("*.json")) if REPORTS.exists() else []
    if not files:
        print("roofline_report,0,no_dryrun_artifacts_run_launch.dryrun")
        return
    for f in files:
        cell = json.loads(f.read_text())
        r = cell.get("roofline")
        if not r:
            continue
        tag = f"_{cell['tag']}" if cell.get("tag") else ""
        name = f"roofline_{cell['arch']}_{cell['shape']}_{cell['mesh']}{tag}"
        memk = r.get("memory_kernel_s") or r["memory_s"]
        terms = {
            "compute": r["compute_s"],
            "memory": memk,
            "collective": r["collective_s"],
        }
        bound = max(terms, key=terms.get)
        total_us = max(terms.values()) * 1e6
        print(
            f"{name},{total_us:.3f},"
            f"bound={bound};useful_flops_ratio={r['useful_flops_ratio']:.3f}"
        )


if __name__ == "__main__":
    main()
