"""Generate EXPERIMENTS.md tables from reports/dryrun/*.json.

Prints markdown to stdout:
  * §Dry-run summary (per cell: compile ok, memory, HLO collective counts)
  * §Roofline table (three terms, dominant, useful ratio, bottleneck note)

Usage: PYTHONPATH=src python -m benchmarks.gen_tables [--tag TAG]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

REPORTS = Path(__file__).resolve().parent.parent / "reports" / "dryrun"

NOTE = {
    "compute": "MXU-bound: more useful flops/byte won't help; cut remat "
    "recompute or raise per-chip batch",
    "memory": "HBM-bound: fuse/loop-tile, shrink activation traffic, "
    "bf16ify residuals",
    "collective": "ICI/DCI-bound: reshard to move activations not "
    "weights, batch small collectives (NAP), overlap with compute",
}


def load(tag: str | None):
    cells = []
    for f in sorted(REPORTS.glob("*.json")):
        r = json.loads(f.read_text())
        if (r.get("tag") or "") != (tag or ""):
            continue
        cells.append(r)
    return cells


def dryrun_table(cells):
    print(
        "| arch | shape | mesh | ok | compile s | n_micro | arg GB/chip | "
        "temp GB/chip | AR | AG | RS | A2A | CP |"
    )
    print("|---|---|---|---|---|---|---|---|---|---|---|---|---|")
    for r in cells:
        mem = r.get("memory", {})
        coll = r.get("roofline", {}).get("collectives", {})

        def cnt(k):
            return int(coll.get(k, {}).get("count", 0))

        print(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{'Y' if r['ok'] else 'FAIL'} | {r.get('compile_s','-')} | "
            f"{r.get('n_micro','-')} | "
            f"{(mem.get('argument_bytes') or 0)/1e9:.2f} | "
            f"{(mem.get('temp_bytes') or 0)/1e9:.2f} | "
            f"{cnt('all-reduce')} | {cnt('all-gather')} | "
            f"{cnt('reduce-scatter')} | {cnt('all-to-all')} | "
            f"{cnt('collective-permute')} |"
        )


def roofline_table(cells):
    print(
        "| arch | shape | mesh | compute ms | memory ms (xla / kernel) | "
        "collective ms | dominant | useful ratio | step ms | MFU-proxy |"
    )
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in cells:
        if not r["ok"]:
            continue
        rl = r["roofline"]
        memk = rl.get("memory_kernel_s") or rl["memory_s"]
        step = max(rl["compute_s"], memk, rl["collective_s"])
        terms = {
            "compute": rl["compute_s"],
            "memory": memk,
            "collective": rl["collective_s"],
        }
        dom = max(terms, key=terms.get)
        mfu = rl["model_flops_per_chip"] / (step * 197e12) if step else 0.0
        print(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{rl['compute_s']*1e3:.2f} | {rl['memory_s']*1e3:.1f} / "
            f"{memk*1e3:.1f} | "
            f"{rl['collective_s']*1e3:.2f} | **{dom}** | "
            f"{rl['useful_flops_ratio']:.3f} | {step*1e3:.2f} | "
            f"{mfu*100:.1f}% |"
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default=None)
    ap.add_argument("--section", choices=["dryrun", "roofline", "both"],
                    default="both")
    args = ap.parse_args()
    cells = load(args.tag)
    if args.section in ("dryrun", "both"):
        print("### Dry-run summary\n")
        dryrun_table(cells)
        print()
    if args.section in ("roofline", "both"):
        print("### Roofline table\n")
        roofline_table(cells)


if __name__ == "__main__":
    main()
