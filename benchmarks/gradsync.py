"""Gradient-sync benchmark: the paper's technique inside a training step.

Simulates the per-step gradient synchronisation of a data-parallel
training job across pods (node = pod, ppn = chips per pod — DESIGN.md §2)
under the TPU max-rate parameters, for a realistic bucket-size mix:

  * latency-bound small payloads: loss scalar, grad-norm scalar, fused
    norm/bias bucket (the paper's core regime),
  * bandwidth-bound large payloads: fused parameter-gradient buckets.

Compares pure-RD, pure-SMP, pure-NAP, the striped multi-lane MLA path,
the chunked *pipelined* MLA path (model-optimal depth), and the
model-driven "auto" switch (NAP below the per-grid
``perf_model.crossover_bytes`` NAP↔MLA crossover, MLA above it,
pipelined once ``optimal_pipeline_chunks`` says the bucket amortises
the extra latency steps).
"""

from __future__ import annotations

from repro.core import perf_model as pm, simulator as sim

P = pm.TPU_V5E_POD

# simulator is per-message; above this the closed forms (Eq 4-6 + MLA) are
# both faster to evaluate and the regime where they are accurate
_SIM_LIMIT = 1 << 16

_COSTS = {
    "rd": pm.cost_rd,
    "smp": pm.cost_smp,
    "nap": pm.cost_nap,
    "mla": pm.cost_mla,
    "mla_pip": lambda s, n, ppn, p: pm.cost_mla_pipelined(s, n, ppn, p),
}

# benchmark label -> simulator algorithm name
_SIM_NAMES = {"mla_pip": "mla_pipelined"}

# (name, bytes, count) — a ~100M-param model with fused buckets
BUCKETS = [
    ("loss_scalar", 4, 1),
    ("grad_norm_scalar", 4, 1),
    ("small_fused_norms", 2048, 1),
    ("grad_bucket_16MB", 16 << 20, 6),
]


def _bucket_time(algo: str, s: float, n: int, ppn: int) -> float:
    if s <= _SIM_LIMIT:
        return sim.simulate_algorithm(_SIM_NAMES.get(algo, algo), n, ppn, s, P)
    return _COSTS[algo](s, n, ppn, P)


def main() -> None:
    rows = []
    for n_pods, ppn in [(2, 16), (8, 16), (64, 16)]:
        crossover = pm.crossover_bytes(n_pods, ppn, P, large="mla")
        algos = ["rd", "smp", "nap", "mla", "mla_pip"]
        totals = {a: 0.0 for a in algos + ["auto"]}
        for _, s, count in BUCKETS:
            for algo in algos:
                totals[algo] += _bucket_time(algo, float(s), n_pods, ppn) * count
            # model-driven three-contender switch: the same decision
            # collectives.select_algorithm makes
            if s <= crossover:
                auto_algo = "nap"
            elif pm.optimal_pipeline_chunks(float(s), n_pods, ppn, P) > 1:
                auto_algo = "mla_pip"
            else:
                auto_algo = "mla"
            totals["auto"] += (
                _bucket_time(auto_algo, float(s), n_pods, ppn) * count
            )
        for algo, t in totals.items():
            rows.append(
                (
                    f"gradsync_{algo}_pods{n_pods}",
                    t * 1e6,
                    f"chips={n_pods*ppn}",
                )
            )
        rows.append(
            (
                f"gradsync_crossover_bytes_pods{n_pods}",
                crossover,
                "nap<=x<mla",
            )
        )
        rows.append(
            (
                f"gradsync_auto_speedup_vs_rd_pods{n_pods}",
                totals["rd"] / totals["auto"],
                "model-switched",
            )
        )
        rows.append(
            (
                f"gradsync_mla_speedup_vs_smp_pods{n_pods}",
                totals["smp"] / totals["mla"],
                "striped lanes",
            )
        )
        rows.append(
            (
                f"gradsync_pipelined_speedup_vs_mla_pods{n_pods}",
                totals["mla"] / totals["mla_pip"],
                "chunk overlap",
            )
        )
        # the tentpole quantity: per-chip inter-node bytes for one 16 MiB
        # bucket, striped vs single-lane paths
        s_big = float(16 << 20)
        for algo in ["rd", "smp", "nap", "mla"]:
            rows.append(
                (
                    f"gradsync_internode_MB_per_chip_{algo}_pods{n_pods}",
                    sim.internode_bytes_per_chip(algo, n_pods, ppn, s_big)
                    / (1 << 20),
                    "16MiB bucket",
                )
            )
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main()
