"""Gradient-sync benchmark: the paper's technique inside a training step.

Simulates the per-step gradient synchronisation of a data-parallel
training job across pods (node = pod, ppn = chips per pod — DESIGN.md §2)
under the TPU max-rate parameters, for a realistic bucket-size mix:

  * latency-bound small payloads: loss scalar, grad-norm scalar, fused
    norm/bias bucket (the paper's core regime),
  * bandwidth-bound large payloads: fused parameter-gradient buckets.

Compares pure-RD, pure-SMP, pure-NAP, the striped multi-lane MLA path,
the chunked *pipelined* MLA path (model-optimal depth), and the
model-driven "auto" switch (NAP below the per-grid
``perf_model.crossover_bytes`` NAP↔MLA crossover, MLA above it,
pipelined once ``optimal_pipeline_chunks`` says the bucket amortises
the extra latency steps).

The *bucketed scheduler* section plans a transformer-style gradient
pytree through :func:`repro.core.bucketing.plan_buckets` and replays the
plan with the simulator's compute port
(:func:`repro.core.simulator.simulate_bucketed_sync`): serial sync
(everything after the last gradient) vs the async executor (buckets
issued as backward produces them) — the overlap win as wall-clock, plus
the per-chip inter-node byte table against the uneven-block lower bound.

``--json PATH`` additionally writes the full result set (overlap + byte
tables) as a JSON artifact — CI uploads it as ``BENCH_3.json`` so the
perf trajectory is tracked per commit.

``--fit [MEASUREMENTS.json]`` runs the :meth:`MachineParams.fit`
calibration hook instead (ROADMAP open item: "measure the real
crossover … and fit MachineParams"): given a JSON file of measured
``[nbytes, seconds, active_per_node]`` rows from real hardware it
emits the fitted machine constants — plus the NAP↔MLA crossovers the
fit implies — as JSON on stdout.  Without a file it self-checks: it
synthesises "measurements" from the reference machine model and
verifies the fit recovers the constants that generated them.
"""

from __future__ import annotations

import json
import math
import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
for _p in (str(_ROOT), str(_ROOT / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from repro.core import bucketing, napalg, perf_model as pm, simulator as sim

P = pm.TPU_V5E_POD

# simulator is per-message; above this the closed forms (Eq 4-6 + MLA) are
# both faster to evaluate and the regime where they are accurate
_SIM_LIMIT = 1 << 16

_COSTS = {
    "rd": pm.cost_rd,
    "smp": pm.cost_smp,
    "nap": pm.cost_nap,
    "mla": pm.cost_mla,
    "mla_pip": lambda s, n, ppn, p: pm.cost_mla_pipelined(s, n, ppn, p),
}

# benchmark label -> simulator algorithm name
_SIM_NAMES = {"mla_pip": "mla_pipelined"}

# (name, bytes, count) — a ~100M-param model with fused buckets
BUCKETS = [
    ("loss_scalar", 4, 1),
    ("grad_norm_scalar", 4, 1),
    ("small_fused_norms", 2048, 1),
    ("grad_bucket_16MB", 16 << 20, 6),
]


def _bucket_time(algo: str, s: float, n: int, ppn: int) -> float:
    if s <= _SIM_LIMIT:
        return sim.simulate_algorithm(_SIM_NAMES.get(algo, algo), n, ppn, s, P)
    return _COSTS[algo](s, n, ppn, P)


def _model_leaf_specs() -> tuple[bucketing.LeafSpec, ...]:
    """A transformer-ish gradient pytree: big matmul grads interleaved
    with tiny norm/bias grads, mixed bf16/f32 — ~100M params."""
    specs = []
    idx = 0

    def add(elems: int, itemsize: int, dtype: str, fusible: bool = True):
        nonlocal idx
        specs.append(
            bucketing.LeafSpec(
                index=idx, elems=elems, itemsize=itemsize,
                dtype=dtype, fusible=fusible,
            )
        )
        idx += 1

    add(32_000 * 1024, 4, "float32")  # embedding
    for _ in range(12):  # 12 layers
        add(1024, 4, "float32")  # ln scale
        add(3 * 1024 * 1024, 2, "bfloat16")  # qkv
        add(1024 * 1024, 2, "bfloat16")  # proj
        add(1024, 4, "float32")  # ln scale
        add(4 * 1024 * 1024, 2, "bfloat16")  # mlp up
        add(4 * 1024 * 1024, 2, "bfloat16")  # mlp down
    add(1024, 4, "float32")  # final ln
    add(1, 4, "int32", fusible=False)  # step counter (int leaf)
    return tuple(specs)


def overlap_section(n_pods: int, ppn: int) -> tuple[list, dict]:
    """Bucketed-scheduler rows + JSON table for one grid."""
    plan = bucketing.plan_buckets(_model_leaf_specs(), n_pods, ppn)
    rows = plan.sim_rows()
    # compute port: backward produces buckets uniformly over a window the
    # size of the serial network time (the comm ~= compute regime)
    t_net = sim.simulate_bucketed_sync(rows, n_pods, ppn, P)
    k = len(rows)
    compute_times = [(i + 1) * t_net / k for i in range(k)]
    t_async = sim.simulate_bucketed_sync(
        rows, n_pods, ppn, P, compute_times=compute_times, overlap=True
    )
    t_serial = sim.simulate_bucketed_sync(
        rows, n_pods, ppn, P, compute_times=compute_times, overlap=False
    )
    buckets_json = []
    for b in plan.buckets:
        entry = {
            "leaves": list(b.leaves),
            "dtype": b.dtype,
            "transport_bytes": b.transport_bytes,
            "algorithm": b.algorithm,
            "chunks": b.chunks,
        }
        if b.algorithm in ("mla", "mla_pipelined") and n_pods > 1:
            itemsize = b.transport_bytes / max(b.elems, 1)
            sched = (
                napalg.build_mla_pipelined_schedule(
                    n_pods, ppn, b.chunks, b.elems
                )
                if b.chunks > 1
                else napalg.build_mla_schedule(n_pods, ppn, b.elems)
            )
            entry["internode_bytes_per_chip"] = sched.max_internode_bytes_per_chip(
                float(b.transport_bytes)
            )
            entry["internode_lower_bound"] = (
                napalg.mla_internode_lower_bound(n_pods, ppn, b.elems)
                * itemsize
            )
        buckets_json.append(entry)
    csv_rows = [
        (f"gradsync_bucketed_num_buckets_pods{n_pods}", plan.num_buckets,
         f"target={plan.target_bytes:.0f}B"),
        (f"gradsync_bucketed_serial_us_pods{n_pods}", t_serial * 1e6,
         "all-after-backward"),
        (f"gradsync_bucketed_async_us_pods{n_pods}", t_async * 1e6,
         "compute-port overlap"),
        (f"gradsync_bucketed_overlap_speedup_pods{n_pods}",
         t_serial / t_async if t_async else 1.0, "serial/async"),
    ]
    table = {
        "n_pods": n_pods,
        "ppn": ppn,
        "num_buckets": plan.num_buckets,
        "target_bytes": plan.target_bytes,
        "crossover_bytes": plan.crossover_bytes,
        "serial_s": t_serial,
        "async_s": t_async,
        "speedup": t_serial / t_async if t_async else 1.0,
        "buckets": buckets_json,
    }
    return csv_rows, table


# wire widths the compressed transport can execute (bits -> bytes/elem);
# 16 rides the legacy int16 accumulator width, 32 is uncompressed f32
_WIRE_ITEMSIZE = {4: 0.5, 8: 1.0, 16: 2.0, 32: 4.0}


def compression_collect() -> tuple[list, dict]:
    """Per-bucket bytes-on-wire at 4/8/16/32-bit transport widths
    against the uncompressed inter-node lower bound, plus step-time
    deltas from the simulator's compute-port replay.

    The bucket partition is pinned at the uncompressed plan so widths
    compare bucket-for-bucket.  Wire bytes per float bucket are
    ``ceil(elems * bits/8)`` — exactly what the planner budgets and the
    packed kernels move; the replay prices compressed buckets with
    :func:`repro.core.perf_model.cost_mla_compressed` (f32 intra
    pre-combine, wire-width inter hops, quantize/unpack compute) via the
    5-element ``(wire, algo, chunks, elems, raw)`` simulator rows.
    """
    rows, grids = [], {}
    for n_pods, ppn in [(2, 16), (8, 16), (64, 16)]:
        plan = bucketing.plan_buckets(_model_leaf_specs(), n_pods, ppn)
        crossover = plan.crossover_bytes
        buckets_json = []
        ratios_ok = True
        sim_rows_w = {bits: [] for bits in _WIRE_ITEMSIZE}
        for b in plan.buckets:
            is_float = b.dtype.startswith(("float", "bfloat"))
            raw32 = b.elems * 4
            entry = {
                "leaves": len(b.leaves),
                "elems": b.elems,
                "dtype": b.dtype,
                "algorithm": b.algorithm,
                "uncompressed_f32_bytes": raw32,
                "wire_bytes": {},
            }
            if b.algorithm in ("mla", "mla_pipelined") and n_pods > 1:
                sched = (
                    napalg.build_mla_pipelined_schedule(
                        n_pods, ppn, b.chunks, b.elems
                    )
                    if b.chunks > 1
                    else napalg.build_mla_schedule(n_pods, ppn, b.elems)
                )
                entry["internode_lower_bound_f32"] = (
                    napalg.mla_internode_lower_bound(n_pods, ppn, b.elems)
                    * 4.0
                )
            else:
                sched = None
            for bits, it in _WIRE_ITEMSIZE.items():
                wire = (
                    int(math.ceil(b.elems * it)) if is_float
                    else b.transport_bytes
                )
                w_entry = {"bytes": wire}
                if sched is not None:
                    per_chip = sched.max_internode_bytes_per_chip(
                        float(wire)
                    )
                    w_entry["internode_bytes_per_chip"] = per_chip
                    if bits != 32 and is_float and raw32 > crossover:
                        per_chip32 = sched.max_internode_bytes_per_chip(
                            float(raw32)
                        )
                        # packed width must move <= bits/32 of the f32
                        # bytes on the wire (+1 byte/leaf ceil slack)
                        budget = per_chip32 * (bits / 32.0)
                        slack = len(b.leaves) * float(ppn)
                        w_entry["ratio_vs_f32"] = per_chip / per_chip32
                        if per_chip > budget + slack:
                            ratios_ok = False
                entry["wire_bytes"][bits] = w_entry
                row = (float(wire), b.algorithm, b.chunks, b.elems)
                if bits != 32 and is_float and wire < raw32:
                    row = row + (float(raw32),)
                sim_rows_w[bits].append(row)
            buckets_json.append(entry)
        # compute-port replay: same uniform backward window as the
        # overlap section, priced per transport width
        t32 = sim.simulate_bucketed_sync(sim_rows_w[32], n_pods, ppn, P)
        k = len(sim_rows_w[32])
        compute_times = [(i + 1) * t32 / k for i in range(k)]
        times = {}
        for bits in _WIRE_ITEMSIZE:
            times[bits] = sim.simulate_bucketed_sync(
                sim_rows_w[bits], n_pods, ppn, P,
                compute_times=compute_times, overlap=True,
            )
        for bits in (4, 8):
            rows.append(
                (
                    f"gradsync_compressed_int{bits}_step_speedup_pods{n_pods}",
                    times[32] / times[bits] if times[bits] else 1.0,
                    f"wire={_WIRE_ITEMSIZE[bits]}B/elem vs f32",
                )
            )
        rows.append(
            (
                f"gradsync_compressed_ratios_ok_pods{n_pods}",
                int(ratios_ok),
                "int4<=1/8, int8<=1/4 per chip above crossover",
            )
        )
        grids[f"pods{n_pods}x{ppn}"] = {
            "n_pods": n_pods,
            "ppn": ppn,
            "crossover_bytes": crossover,
            "ratios_ok": ratios_ok,
            "step_time_s": times,
            "step_speedup_vs_f32": {
                bits: (times[32] / times[bits] if times[bits] else 1.0)
                for bits in _WIRE_ITEMSIZE
            },
            "buckets": buckets_json,
        }
    payload = {
        "bench": "gradsync_compression",
        "machine": P.name,
        "rows": [
            {"name": name, "value": _json_safe(value), "derived": derived}
            for name, value, derived in rows
        ],
        "grids": _json_safe(grids),
    }
    return rows, payload


def collect() -> tuple[list, dict]:
    """All benchmark rows plus the JSON artifact payload."""
    rows = []
    overlap_tables = {}
    for n_pods, ppn in [(2, 16), (8, 16), (64, 16)]:
        crossover = pm.crossover_bytes(n_pods, ppn, P, large="mla")
        algos = ["rd", "smp", "nap", "mla", "mla_pip"]
        totals = {a: 0.0 for a in algos + ["auto"]}
        for _, s, count in BUCKETS:
            for algo in algos:
                totals[algo] += _bucket_time(algo, float(s), n_pods, ppn) * count
            # model-driven three-contender switch: the same decision
            # collectives.select_algorithm makes
            if s <= crossover:
                auto_algo = "nap"
            elif pm.optimal_pipeline_chunks(float(s), n_pods, ppn, P) > 1:
                auto_algo = "mla_pip"
            else:
                auto_algo = "mla"
            totals["auto"] += (
                _bucket_time(auto_algo, float(s), n_pods, ppn) * count
            )
        for algo, t in totals.items():
            rows.append(
                (
                    f"gradsync_{algo}_pods{n_pods}",
                    t * 1e6,
                    f"chips={n_pods*ppn}",
                )
            )
        rows.append(
            (
                f"gradsync_crossover_bytes_pods{n_pods}",
                crossover,
                "nap<=x<mla (inf = NAP never loses)",
            )
        )
        rows.append(
            (
                f"gradsync_auto_speedup_vs_rd_pods{n_pods}",
                totals["rd"] / totals["auto"],
                "model-switched",
            )
        )
        rows.append(
            (
                f"gradsync_mla_speedup_vs_smp_pods{n_pods}",
                totals["smp"] / totals["mla"],
                "striped lanes",
            )
        )
        rows.append(
            (
                f"gradsync_pipelined_speedup_vs_mla_pods{n_pods}",
                totals["mla"] / totals["mla_pip"],
                "chunk overlap",
            )
        )
        # the tentpole quantity: per-chip inter-node bytes for one 16 MiB
        # bucket, striped vs single-lane paths
        s_big = float(16 << 20)
        for algo in ["rd", "smp", "nap", "mla"]:
            rows.append(
                (
                    f"gradsync_internode_MB_per_chip_{algo}_pods{n_pods}",
                    sim.internode_bytes_per_chip(algo, n_pods, ppn, s_big)
                    / (1 << 20),
                    "16MiB bucket",
                )
            )
        csv_rows, table = overlap_section(n_pods, ppn)
        rows.extend(csv_rows)
        overlap_tables[f"pods{n_pods}x{ppn}"] = table
    payload = {
        "bench": "gradsync",
        "machine": P.name,
        "rows": [
            {"name": name, "value": _json_safe(value), "derived": derived}
            for name, value, derived in rows
        ],
        "overlap": _json_safe(overlap_tables),
    }
    return rows, payload


def _json_safe(v):
    """RFC 8259-safe values: a saturated crossover is ``math.inf`` by
    design, but bare ``Infinity`` is invalid JSON — strict consumers of
    the CI artifact (jq, JSON.parse) would reject the whole file."""
    if isinstance(v, dict):
        return {k: _json_safe(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_json_safe(x) for x in v]
    if isinstance(v, float) and not math.isfinite(v):
        return str(v)  # "inf" / "-inf" / "nan"
    return v


def _synthetic_measurements() -> list:
    """Self-check rows: single-message step times straight from the
    reference model, at k=1 (per-process regime) and k=ppn (injection
    regime) — the fit must recover the generating constants."""
    rows = []
    for s in [256, 1024, 4096, 16384, 65536, 1 << 20, 4 << 20]:
        rows.append([s, pm.maxrate_message_cost(float(s), P, 1), 1])
        rows.append([s, pm.maxrate_message_cost(float(s), P, 16), 16])
    return rows


def fit_main(measurements_path: str | None) -> int:
    """``--fit`` hook: calibrate MachineParams, emit them as JSON."""
    import dataclasses

    if measurements_path:
        rows = json.loads(Path(measurements_path).read_text())
        source = measurements_path
    else:
        rows = _synthetic_measurements()
        source = f"synthetic({P.name})"
    fitted = pm.MachineParams.fit(rows, base=P, name="fitted")
    payload = {
        "bench": "gradsync_fit",
        "source": source,
        "n_measurements": len(rows),
        "fitted": dataclasses.asdict(fitted),
        "implied_crossover_bytes": {
            f"pods{n}x{ppn}": _json_safe(
                pm.crossover_bytes(n, ppn, fitted, large="mla")
            )
            for n, ppn in [(2, 16), (8, 16), (64, 16)]
        },
    }
    ok = 0
    if not measurements_path:
        # roundtrip self-check: fitted constants vs the generator's
        rel = {
            k: abs(getattr(fitted, k) - getattr(P, k)) / getattr(P, k)
            for k in ("alpha", "R_b", "R_N")
        }
        payload["recovery_relative_error"] = rel
        ok = 0 if all(v < 0.01 for v in rel.values()) else 1
    print(json.dumps(payload, indent=2))
    return ok


def main(
    json_path: str | None = None,
    compression_json_path: str | None = None,
) -> None:
    rows, payload = collect()
    c_rows, c_payload = compression_collect()
    for name, us, derived in rows + c_rows:
        print(f"{name},{us:.3f},{derived}")
    if json_path:
        out = Path(json_path)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(payload, indent=2))
        print(f"# wrote {out}", file=sys.stderr)
    if compression_json_path:
        out = Path(compression_json_path)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(c_payload, indent=2))
        print(f"# wrote {out}", file=sys.stderr)


if __name__ == "__main__":
    argv = sys.argv[1:]
    if "--fit" in argv:
        i = argv.index("--fit")
        arg = argv[i + 1] if i + 1 < len(argv) else None
        sys.exit(fit_main(arg if arg and not arg.startswith("--") else None))
    path = None
    if "--json" in argv:
        path = argv[argv.index("--json") + 1]
    cpath = None
    if "--compression-json" in argv:
        cpath = argv[argv.index("--compression-json") + 1]
    main(path, cpath)
