"""Gradient-sync benchmark: the paper's technique inside a training step.

Simulates the per-step gradient synchronisation of a data-parallel
training job across pods (node = pod, ppn = chips per pod — DESIGN.md §2)
under the TPU max-rate parameters, for a realistic bucket-size mix:

  * latency-bound small payloads: loss scalar, grad-norm scalar, fused
    norm/bias bucket (the paper's core regime),
  * bandwidth-bound large payloads: fused parameter-gradient buckets.

Compares pure-RD, pure-SMP, pure-NAP and the paper-faithful "auto" switch
(NAP under 2 KiB, pod-local reduce + RS/AG above).
"""

from __future__ import annotations

from repro.core import perf_model as pm, simulator as sim

P = pm.TPU_V5E_POD

# (name, bytes, count) — a ~100M-param model with fused buckets
BUCKETS = [
    ("loss_scalar", 4, 1),
    ("grad_norm_scalar", 4, 1),
    ("small_fused_norms", 2048, 1),
    ("grad_bucket_16MB", 16 << 20, 6),
]


def _large_cost(s: float, n: int, ppn: int) -> float:
    """Pod-local reduce + Rabenseifner RS/AG over pods (bandwidth path)."""
    import math

    intra = (P.alpha_l + P.beta_l * s) * (
        math.log2(ppn) if ppn > 1 else 0.0
    )
    steps = 2 * math.ceil(math.log2(n)) if n > 1 else 0
    bytes_moved = 2.0 * s * (n - 1) / n
    inter = steps * P.alpha + bytes_moved / P.R_b
    return intra + inter + P.gamma * s * 2


def main() -> None:
    rows = []
    for n_pods, ppn in [(2, 16), (8, 16), (64, 16)]:
        totals = {"rd": 0.0, "smp": 0.0, "nap": 0.0, "auto": 0.0}
        for _, s, count in BUCKETS:
            for algo in ["rd", "smp", "nap"]:
                if s <= 1 << 16:
                    t = sim.simulate_algorithm(algo, n_pods, ppn, float(s), P)
                else:  # simulator is per-message; large buckets use Eq 4-6
                    t = {
                        "rd": pm.cost_rd,
                        "smp": pm.cost_smp,
                        "nap": pm.cost_nap,
                    }[algo](float(s), n_pods, ppn, P)
                totals[algo] += t * count
            t_auto = (
                sim.simulate_algorithm("nap", n_pods, ppn, float(s), P)
                if s <= 2048
                else _large_cost(float(s), n_pods, ppn)
            )
            totals["auto"] += t_auto * count
        for algo, t in totals.items():
            rows.append(
                (
                    f"gradsync_{algo}_pods{n_pods}",
                    t * 1e6,
                    f"chips={n_pods*ppn}",
                )
            )
        rows.append(
            (
                f"gradsync_auto_speedup_vs_rd_pods{n_pods}",
                totals["rd"] / totals["auto"],
                "size-switched",
            )
        )
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main()
