"""Attribute roofline bytes/flops of a dry-run cell to jax ops.

Walks the saved HLO with loop multipliers (same machinery as the
roofline) and aggregates collective wire bytes and memory traffic by the
op_name metadata tail — the "profile" used to pick hillclimb levers.

Usage:
  PYTHONPATH=src python -m benchmarks.attribute <cell-name> [--top 20]
  (cell-name as in reports/dryrun/<cell>.json, without extension)
"""

from __future__ import annotations

import argparse
import gzip
import re
from collections import defaultdict
from pathlib import Path

from repro.launch import hlo_analysis as H

REPORTS = Path(__file__).resolve().parent.parent / "reports" / "dryrun"
_OPNAME = re.compile(r'op_name="([^"]+)"')


def attribute(hlo_text: str, *, bf16_native: bool = True):
    comps, entry = H._parse(hlo_text)
    mem = defaultdict(float)
    coll = defaultdict(float)

    def key_of(inst):
        m = _OPNAME.search(inst.rest)
        name = m.group(1) if m else inst.op
        tail = "/".join(name.split("/")[-2:])
        return re.sub(r"[.\d]+", "", tail)[:60]

    def walk(comp_name, mult, timescan=False):
        comp = comps.get(comp_name)
        if comp is None:
            return
        for iname in comp.order:
            inst = comp.instrs[iname]
            if inst.op == "while":
                m = H._TRIP_RE.search(inst.rest)
                trips = int(m.group(1)) if m else 1
                body = H._called_comp(inst.rest, "body")
                if body:
                    walk(body, mult * trips,
                         timescan or trips >= H.TIMESCAN_TRIPS)
                continue
            kind = next(
                (k for k in H._COLLECTIVES
                 if inst.op == k or inst.op == k + "-start"), None
            )
            if kind is not None:
                rb = H._shape_bytes(inst.shape)
                if (bf16_native and "dot_general" in inst.rest
                        and "f32[" in inst.shape
                        and "bf16[" not in inst.shape):
                    rb *= 0.5
                g = H._group_size(inst.rest)
                wire = {
                    "all-reduce": 2.0 * rb * (g - 1) / g,
                    "all-gather": rb * (g - 1) / g,
                    "reduce-scatter": rb * (g - 1),
                    "all-to-all": rb * (g - 1) / g,
                    "collective-permute": float(rb),
                }[kind]
                coll[(kind, key_of(inst))] += wire * mult
                continue
            if inst.op in H._SKIP_MEM_OPS:
                continue
            b = H._instr_mem_bytes(comp, inst, comps) * mult
            tag = "[scan]" if timescan else ""
            mem[(inst.op + tag, key_of(inst))] += b

    walk(entry, 1.0)
    return mem, coll


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("cell")
    ap.add_argument("--top", type=int, default=20)
    args = ap.parse_args()
    path = REPORTS / f"{args.cell}.hlo.gz"
    with gzip.open(path, "rt") as fh:
        txt = fh.read()
    mem, coll = attribute(txt)
    print(f"== collective wire bytes (top {args.top}) ==")
    for (kind, key), b in sorted(coll.items(), key=lambda kv: -kv[1])[: args.top]:
        print(f"  {b/1e9:10.2f} GB  {kind:18s} {key}")
    print(f"== memory traffic (top {args.top}) ==")
    for (op, key), b in sorted(mem.items(), key=lambda kv: -kv[1])[: args.top]:
        print(f"  {b/1e9:10.2f} GB  {op:22s} {key}")


if __name__ == "__main__":
    main()
