"""Paper-figure benchmarks (Bienz/Olson/Gropp 2019, Figs 10-17 + §III).

Each function prints CSV rows ``name,us_per_call,derived`` and returns the
rows for run.py.  Model rows use Eq 4-6 (perf_model); "sim" rows execute
the real schedules in the event-driven simulator (the measured analogue —
see DESIGN.md §2).  Blue Waters parameters throughout, as in the paper.
"""

from __future__ import annotations

import math
import time

from repro.core import napalg, perf_model as pm, simulator as sim

P = pm.BLUE_WATERS
PPN = 16  # the paper's Blue Waters configuration


def _emit(rows):
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")
    return rows


def fig10_model_scaling():
    """Modeled allreduce cost of one 8-byte value vs process count."""
    rows = []
    for nodes in [2, 8, 32, 128, 512, 2048, 8192]:
        p = nodes * PPN
        for algo, fn in [
            ("rd", pm.cost_rd),
            ("smp", pm.cost_smp),
            ("nap", pm.cost_nap),
        ]:
            us = fn(8.0, nodes, PPN, P) * 1e6
            rows.append((f"fig10_model_{algo}_p{p}", us, f"nodes={nodes}"))
    return _emit(rows)


def fig11_model_sizes():
    """Modeled cost vs reduction size at 32 768 processes."""
    rows = []
    nodes = 2048
    for s in [8, 32, 128, 512, 2048, 8192, 32768, 131072]:
        for algo, fn in [
            ("rd", pm.cost_rd),
            ("smp", pm.cost_smp),
            ("nap", pm.cost_nap),
            ("mla", pm.cost_mla),
        ]:
            us = fn(float(s), nodes, PPN, P) * 1e6
            rows.append((f"fig11_model_{algo}_s{s}", us, f"bytes={s}"))
    xo = pm.crossover_bytes(nodes, PPN, P)
    rows.append(("fig11_nap_smp_crossover_bytes", xo, "paper:~2048"))
    xo_mla = pm.crossover_bytes(nodes, PPN, P, large="mla")
    rows.append(("fig11_nap_mla_crossover_bytes", xo_mla, "dispatcher"))
    return _emit(rows)


def fig12_sim_scaling():
    """Simulated (schedule-executed) cost of an 8-byte allreduce vs p."""
    rows = []
    for nodes in [2, 8, 32, 128, 512, 2048]:
        p = nodes * PPN
        for algo in ["rd", "smp", "nap"]:
            us = sim.simulate_algorithm(algo, nodes, PPN, 8.0, P) * 1e6
            rows.append((f"fig12_sim_{algo}_p{p}", us, f"nodes={nodes}"))
    return _emit(rows)


def fig13_speedup():
    """NAP speedup over RD and SMP for a single-value reduction vs p."""
    rows = []
    for nodes in [16, 64, 256, 1024, 4096]:
        p = nodes * PPN
        nap = sim.simulate_algorithm("nap", nodes, PPN, 8.0, P)
        for base in ["rd", "smp"]:
            b = sim.simulate_algorithm(base, nodes, PPN, 8.0, P)
            rows.append(
                (f"fig13_speedup_vs_{base}_p{p}", b / nap, f"x{b / nap:.2f}")
            )
    return _emit(rows)


def fig14_sim_sizes():
    """Simulated cost and NAP speedup vs reduction size at 32 768 procs."""
    rows = []
    nodes = 2048
    for s in [8, 64, 512, 2048, 8192, 65536]:
        times = {
            algo: sim.simulate_algorithm(algo, nodes, PPN, float(s), P)
            for algo in ["rd", "smp", "nap", "mla"]
        }
        for algo, t in times.items():
            rows.append((f"fig14_sim_{algo}_s{s}", t * 1e6, f"bytes={s}"))
        rows.append(
            (
                f"fig15_speedup_vs_smp_s{s}",
                times["smp"] / times["nap"],
                "nap_wins" if times["nap"] < times["smp"] else "smp_wins",
            )
        )
    return _emit(rows)


def fig18_mla_striping():
    """Beyond-paper: the striped MLA bandwidth path (§VI executed).

    Per-chip inter-node bytes and simulated times for the bandwidth
    regime: MLA moves ``~2*(s/ppn)*(n-1)/n`` bytes per chip — a ppn-fold
    drop vs the single-lane SMP-style path — and the modeled NAP↔MLA
    crossover that drives ``hierarchical_allreduce("auto")``.
    """
    rows = []
    for nodes in [8, 64, 512]:
        s = float(1 << 20)
        for algo in ["rd", "smp", "nap", "mla"]:
            rows.append(
                (
                    f"fig18_internode_KB_per_chip_{algo}_n{nodes}",
                    sim.internode_bytes_per_chip(algo, nodes, PPN, s) / 1024,
                    "1MiB reduction",
                )
            )
        t_mla = pm.cost_mla(s, nodes, PPN, P)
        t_smp = pm.cost_smp(s, nodes, PPN, P)
        rows.append(
            (
                f"fig18_mla_speedup_vs_smp_n{nodes}",
                t_smp / t_mla,
                f"x{t_smp / t_mla:.2f}",
            )
        )
        rows.append(
            (
                f"fig18_crossover_bytes_n{nodes}",
                pm.crossover_bytes(nodes, PPN, P, large="mla"),
                "auto switch point",
            )
        )
    return _emit(rows)


def fig19_pipelined_mla():
    """Beyond-paper: chunked, pipelined MLA (the §VI regime, pipelined).

    Pipeline-depth sweep on a 16x16 grid under the TPU parameters: the
    replayed wall-time vs chunk count C, the model-optimal depth and its
    overlap win over unpipelined MLA, plus the ragged-stripe byte
    accounting (uneven-block lower bound vs pad-to-divisible striping).
    """
    rows = []
    TP = pm.TPU_V5E_POD
    n, ppn = 16, 16
    for s in [1 << 20, 4 << 20, 16 << 20, 64 << 20]:
        mib = s >> 20
        for c in [1, 2, 4, 8]:
            t = sim.simulate_algorithm(
                "mla_pipelined", n, ppn, float(s), TP, chunks=c
            )
            rows.append(
                (f"fig19_sim_pipelined_s{mib}MiB_c{c}", t * 1e6, f"C={c}")
            )
        c_star = pm.optimal_pipeline_chunks(float(s), n, ppn, TP)
        t1 = sim.simulate_algorithm(
            "mla_pipelined", n, ppn, float(s), TP, chunks=1
        )
        t_star = sim.simulate_algorithm(
            "mla_pipelined", n, ppn, float(s), TP, chunks=c_star
        )
        rows.append(
            (
                f"fig19_overlap_win_s{mib}MiB",
                t1 / t_star,
                f"C*={c_star}",
            )
        )
    # ragged striping: per-chip inter-node bytes hit the uneven-block
    # lower bound — zero padded bytes cross the slow domain
    for nn, pp, e in [(5, 3, 12289), (14, 4, 99999)]:
        lb = napalg.mla_internode_lower_bound(nn, pp, e) * 4.0
        got = sim.internode_bytes_per_chip("mla", nn, pp, e * 4.0, elems=e)
        padded_stripe = math.ceil(e / pp)
        padded = 2.0 * math.ceil(padded_stripe / nn) * (nn - 1) * 4.0
        rows.append(
            (
                f"fig19_ragged_KB_per_chip_n{nn}_ppn{pp}",
                got / 1024,
                f"lower_bound={'yes' if abs(got - lb) < 1e-6 else 'NO'}",
            )
        )
        rows.append(
            (
                f"fig19_padded_KB_per_chip_n{nn}_ppn{pp}",
                padded / 1024,
                "pad-to-divisible",
            )
        )
    return _emit(rows)


def fig16_overhead():
    """Figs 16/17 analogue: per-step dispatch overhead vs fused schedule.

    The paper shows NAP-on-top-of-MPI pays per-call overhead that an
    in-MPICH implementation would not.  Our equivalent: executing each NAP
    step as a separate XLA dispatch vs one fused HLO.  We measure the real
    single-op dispatch latency on this host and model the difference.
    """
    import jax
    import jax.numpy as jnp

    f = jax.jit(lambda x: x + 1.0)
    x = jnp.zeros((16,))
    f(x).block_until_ready()
    t0 = time.perf_counter()
    iters = 200
    for _ in range(iters):
        f(x).block_until_ready()
    delta = (time.perf_counter() - t0) / iters  # per-dispatch overhead

    rows = []
    nodes = 2048
    for s in [8, 2048]:
        fused = sim.simulate_algorithm("nap", nodes, PPN, float(s), P)
        n_dispatch = napalg.nap_num_steps(nodes, PPN) * 2 + 2
        stepwise = fused + n_dispatch * delta
        rows.append((f"fig16_nap_fused_s{s}", fused * 1e6, "in-XLA"))
        rows.append(
            (f"fig16_nap_stepwise_s{s}", stepwise * 1e6, "on-top dispatch")
        )
        rows.append(
            (
                f"fig16_overhead_ratio_s{s}",
                stepwise / fused,
                f"dispatch={delta*1e6:.1f}us",
            )
        )
    return _emit(rows)


def table_msgcounts():
    """§III claims: max inter-node messages per chip, RD vs SMP vs NAP."""
    rows = []
    for nodes, ppn in [(16, 16), (256, 16), (4096, 16), (14, 4), (64, 4)]:
        nap = napalg.build_nap_schedule(nodes, ppn)
        rd = napalg.build_rd_schedule(nodes, ppn)
        smp = napalg.build_smp_schedule(nodes, ppn)
        rows.append(
            (
                f"msgs_nap_n{nodes}_ppn{ppn}",
                napalg.message_counts(nap)["max_per_chip"],
                f"steps={nap.num_internode_steps}",
            )
        )
        rows.append(
            (
                f"msgs_rd_n{nodes}_ppn{ppn}",
                rd.max_internode_messages_per_chip(),
                "log2(n)",
            )
        )
        rows.append(
            (
                f"msgs_smp_n{nodes}_ppn{ppn}",
                smp.max_internode_messages_per_chip(),
                "log2(n)",
            )
        )
    return _emit(rows)


ALL = [
    fig10_model_scaling,
    fig11_model_sizes,
    fig12_sim_scaling,
    fig13_speedup,
    fig14_sim_sizes,
    fig16_overhead,
    fig18_mla_striping,
    fig19_pipelined_mla,
    table_msgcounts,
]
