"""The paper's technique inside training: NAP gradient synchronisation.

Trains the same small LM twice on a virtual 4-pods x 4-chips mesh — once
with XLA's stock psum gradient sync, once with the explicit NAP schedule
(paper §III) — and shows:

  1. losses match step for step (the schedule is numerically equivalent),
  2. the compiled HLO of the NAP step carries its inter-node traffic in
     log_ppn(n) collective-permutes per bucket (vs the baseline's opaque
     all-reduce),
  3. the simulated inter-pod cost of the scalar/bucket sync under the
     max-rate model (what the schedule would cost on a real 2-level
     fabric).

Run:  PYTHONPATH=src python examples/nap_gradient_sync.py
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"

import jax
import numpy as np

from repro.configs.base import ModelConfig, OptimizerConfig, SubLayer
from repro.core import perf_model as pm, simulator as sim
from repro.core.grad_sync import GradSyncConfig
from repro.data import SyntheticLM
from repro.launch.mesh import make_mesh
from repro.launch.steps import make_dp_train_step
from repro.models import build_model
from repro.optim import adamw_init

CFG = ModelConfig(
    name="nap-demo-lm",
    family="dense",
    num_layers=4,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    d_ff=512,
    vocab_size=1024,
    pattern=(SubLayer("attn"),),
    dtype="float32",
    remat="none",
)


def main():
    mesh = make_mesh((4, 4), ("pod", "data"))
    opt_cfg = OptimizerConfig(lr=1e-3, schedule="constant", warmup_steps=1)
    model = build_model(CFG)
    params0 = jax.jit(model.init)(jax.random.PRNGKey(0))
    data = SyntheticLM(
        vocab_size=CFG.vocab_size, seq_len=64, global_batch=16, seed=0,
        mesh=mesh, batch_axes=("pod", "data"),
    )

    losses = {}
    for algo in ["psum", "nap"]:
        step = jax.jit(
            make_dp_train_step(
                CFG, opt_cfg, mesh, GradSyncConfig(algorithm=algo)
            )
        )
        state = {"params": params0, "opt": adamw_init(params0)}
        ls = []
        for s in range(5):
            state, m = step(state, data.batch(s))
            ls.append(float(m["loss"]))
        losses[algo] = ls
        if algo == "nap":
            hlo = step.lower(state, data.batch(0)).compile().as_text()
            print(
                f"NAP train-step HLO: {hlo.count('collective-permute(')} "
                f"collective-permutes, {hlo.count('all-reduce(')} all-reduces"
            )
    print("psum losses:", [f"{l:.4f}" for l in losses["psum"]])
    print("nap  losses:", [f"{l:.4f}" for l in losses["nap"]])
    assert np.allclose(losses["psum"], losses["nap"], rtol=1e-4, atol=1e-5)
    print("=> numerically identical gradient sync\n")

    print("simulated scalar-sync cost on a 2048-node x 16-ppn fabric:")
    for algo in ["rd", "smp", "nap"]:
        t = sim.simulate_algorithm(algo, 2048, 16, 8.0, pm.BLUE_WATERS)
        print(f"  {algo:4s}: {t*1e6:7.2f} us")


if __name__ == "__main__":
    main()
