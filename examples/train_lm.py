"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Uses the full production substrate on CPU: synthetic data pipeline,
AdamW + cosine schedule, async atomic checkpoints with auto-resume, and
straggler monitoring.  A mid-run process "crash" is simulated to show
checkpoint/restart working (the loop resumes from the last checkpoint and
reaches the same final state).

The model is a 12-layer llama-style decoder (~100M params), per the
"train a ~100M model for a few hundred steps" deliverable.  Expect the
loss to drop by >1 nat in ~200 steps on the synthetic mixture.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]

``--compressed-smoke`` instead runs a short multi-device training smoke
of the packed gradient transport (int8, then packed int4 with error
feedback) on a virtual 2x4 CPU mesh — the Pallas transport kernels in
interpret mode, end to end through ``make_dp_train_step``.
"""

import argparse
import os
import shutil
import sys
import time

if "--compressed-smoke" in sys.argv:
    # must be set before jax initialises (import side effect below)
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 "
        + os.environ.get("XLA_FLAGS", "")
    )

from repro.configs.base import (
    ModelConfig,
    OptimizerConfig,
    SubLayer,
    TrainConfig,
)
from repro.launch.train import build_training

LM_100M = ModelConfig(
    name="repro-lm-100m",
    family="dense",
    num_layers=12,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=32_000,
    pattern=(SubLayer("attn"),),
    dtype="float32",
    remat="none",
)


def compressed_smoke(steps: int) -> None:
    """Train the reduced LM a few steps over each compressed transport:
    int8, then packed int4 with error-feedback residuals in the train
    state.  Asserts finite losses — kernels, scale agreement, EF
    threading and the planner all run for real on 8 CPU devices."""
    import jax

    from repro.configs.archs import reduced
    from repro.core import comm
    from repro.data import SyntheticLM
    from repro.launch.mesh import make_mesh
    from repro.launch.steps import make_dp_train_step
    from repro.models import build_model
    from repro.optim import adamw_init, ef_init

    mesh = make_mesh((2, 4), ("pod", "data"))
    cfg = reduced(LM_100M)
    opt_cfg = OptimizerConfig(lr=1e-3, schedule="constant", warmup_steps=1)
    model = build_model(cfg)
    params0 = jax.jit(model.init)(jax.random.PRNGKey(0))
    data = SyntheticLM(
        vocab_size=cfg.vocab_size, seq_len=64, global_batch=8, seed=0,
        mesh=mesh, batch_axes=("pod", "data"),
    )
    cases = [
        ("int8", comm.CommPolicy(algorithm="nap", mean=True, compress_bits=8)),
        (
            "int4+ef",
            comm.CommPolicy(
                algorithm="nap", mean=True, compress_bits=4,
                error_feedback=True,
            ),
        ),
    ]
    for label, policy in cases:
        step = jax.jit(make_dp_train_step(cfg, opt_cfg, mesh, policy))
        state = {"params": params0, "opt": adamw_init(params0)}
        if policy.error_feedback:
            state["ef"] = ef_init(params0, group=8)
        losses = []
        for s in range(steps):
            state, m = step(state, data.batch(s))
            losses.append(float(m["loss"]))
        assert all(l == l and abs(l) < 1e6 for l in losses), losses
        print(
            f"[compressed-smoke] {label}: "
            f"loss {losses[0]:.3f} -> {losses[-1]:.3f} ({len(losses)} steps)"
        )
    print("[compressed-smoke] ok")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_lm")
    ap.add_argument("--compressed-smoke", action="store_true")
    args = ap.parse_args()

    if args.compressed_smoke:
        compressed_smoke(min(args.steps, 8))
        return

    shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    print(f"params ~= {LM_100M.param_count()/1e6:.1f}M")

    train_cfg = TrainConfig(
        steps=args.steps,
        seq_len=args.seq,
        global_batch=args.batch,
        checkpoint_every=50,
        optimizer=OptimizerConfig(
            lr=6e-4, schedule="cosine",
            warmup_steps=20, decay_steps=args.steps,
        ),
    )

    # phase 1: train to 60% of the run, then simulate a crash
    t0 = time.time()
    loop = build_training(LM_100M, train_cfg, ckpt_dir=args.ckpt_dir)
    crash_at = int(args.steps * 0.6)
    loop.run(crash_at)
    first = loop.metrics_log[0]["loss"]
    print(f"[phase 1] step {crash_at}: loss {loop.metrics_log[-1]['loss']:.3f}")
    del loop  # "crash": process state gone; checkpoints survive

    # phase 2: a fresh loop auto-resumes from the newest checkpoint
    loop = build_training(LM_100M, train_cfg, ckpt_dir=args.ckpt_dir)
    assert loop.start_step > 0, "must resume from checkpoint, not scratch"
    print(f"[phase 2] auto-resumed at step {loop.start_step}")
    loop.run(args.steps)
    last = loop.metrics_log[-1]["loss"]
    print(
        f"[done] steps={args.steps} loss {first:.3f} -> {last:.3f} "
        f"({time.time()-t0:.0f}s, stragglers={len(loop.monitor.events)})"
    )
    assert last < first - 0.5, "loss must drop materially"


if __name__ == "__main__":
    main()
