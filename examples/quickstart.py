"""Quickstart: the paper's NAP allreduce in 30 lines.

Builds a virtual 4-pods x 4-chips mesh on CPU, runs the NAP allreduce
next to recursive doubling and SMP, and prints the inter-node
(collective-permute) step counts from the compiled HLO — the quantity
the paper minimises: log_ppn(n) vs log2(n).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import collectives
from repro.launch.mesh import make_mesh


def main():
    mesh = make_mesh((4, 4), ("pod", "data"))  # 4 "nodes" x 4 "ppn"
    x = jnp.arange(16.0).reshape(16, 1)  # one value per chip

    for algo in ["rd", "smp", "nap"]:
        fn = jax.jit(
            compat.shard_map(
                partial(
                    collectives.ALGORITHMS[algo],
                    inter_axes="pod",
                    intra_axes="data",
                ),
                mesh=mesh,
                in_specs=P(("pod", "data")),
                out_specs=P(("pod", "data")),
            )
        )
        result = np.unique(np.asarray(fn(x)))
        hlo = fn.lower(x).compile().as_text()
        permutes = hlo.count("collective-permute(")
        print(
            f"{algo:4s} allreduce -> {result} "
            f"(expected {float(np.asarray(x).sum())}), "
            f"inter-chip permute steps = {permutes}"
        )
    print("\nNAP: log_ppn(n) = log_4(4) = 1 step; RD: log2(16) = 4 steps.")


if __name__ == "__main__":
    main()
