"""Batched serving example: prefill + cached greedy decode.

Serves three very different cached architectures — a dense GQA model
(KV cache), the RWKV6 SSM (constant-size state), and whisper (enc-dec
with cross-attention) — through the same ``decode_step`` API, and checks
the sliding-window ring buffer by decoding past the window on a
gemma2-style local+global miniature.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced
from repro.launch.serve import serve_batch
from repro.models import build_model


def demo(arch: str, batch=2, prompt_len=12, gen=8):
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.PRNGKey(0))
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (batch, prompt_len), 0, cfg.vocab_size
    ).astype(jnp.int32)
    extras = None
    if cfg.encoder_layers:
        extras = {
            "frames": jax.random.normal(
                jax.random.PRNGKey(2), (batch, 16, cfg.d_model)
            ).astype(jnp.dtype(cfg.dtype))
        }
    t0 = time.time()
    gen_toks = serve_batch(
        model, params, prompts, gen_len=gen, batch_extras=extras,
        max_len=prompt_len + gen + 4,
    )
    dt = time.time() - t0
    print(
        f"{arch:24s} cache={'state' if cfg.family=='ssm' else 'kv':5s} "
        f"generated {gen_toks.shape[1]} toks/req in {dt:5.2f}s -> "
        f"{np.asarray(gen_toks[0, :6])}"
    )
    assert np.isfinite(dt) and gen_toks.shape == (batch, gen)


def main():
    for arch in ["qwen2-72b", "rwkv6-1.6b", "whisper-tiny", "gemma2-27b"]:
        demo(arch)
    print("\nall families served through one decode_step API")


if __name__ == "__main__":
    main()
