"""Continuous-batching serving example on the `repro.serve` engine.

Serves three very different cached architectures — a dense GQA model
(KV cache), the RWKV6 SSM (constant-size state), and whisper (enc-dec
with cross-attention: per-request encoder frames ride the request's
``extras`` and land in the slot cache at prefill) — through the same
:class:`repro.serve.ServeEngine`, with requests of *different* prompt
lengths and token budgets joining the batch in flight (the seed-era
version of this example padded everything into one fixed batch).

Each engine uses padded prompt buckets, so the three distinct prompt
lengths compile at most two prefill programs, and the staggered second
wave of requests is admitted into slots freed by the first — continuous
batching, not batch-at-a-time.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""

import time

import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models import build_model
from repro.serve import PromptBuckets, ServeEngine


def demo(arch: str, gen=8):
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.PRNGKey(0))

    extras_template = None
    if cfg.encoder_layers:
        extras_template = {
            "frames": jax.ShapeDtypeStruct((1, 16, cfg.d_model), cfg.dtype)
        }
    engine = ServeEngine(
        model, params,
        num_slots=2,                      # smaller than the request count:
        max_len=32,                       # the 3rd request joins in flight
        buckets=PromptBuckets([8, 16]),
        extras_template=extras_template,
    )

    rng = np.random.default_rng(1)
    def make_extras():
        if extras_template is None:
            return None
        return {
            "frames": jax.numpy.asarray(
                rng.standard_normal((1, 16, cfg.d_model)), cfg.dtype
            )
        }

    t0 = time.time()
    # staggered arrivals with heterogeneous prompt lengths and budgets
    reqs = [
        engine.submit(
            rng.integers(0, cfg.vocab_size, size=n).tolist(),
            max_new_tokens=g, extras=make_extras(),
        )
        for n, g in [(12, gen), (5, gen + 2)]
    ]
    engine.step()  # both admitted; third arrives mid-decode
    reqs.append(
        engine.submit(
            rng.integers(0, cfg.vocab_size, size=9).tolist(),
            max_new_tokens=gen - 2, extras=make_extras(),
        )
    )
    out = engine.run()
    dt = time.time() - t0

    kind = "state" if cfg.family == "ssm" else "kv"
    toks = sum(len(v) for v in out.values())
    print(
        f"{arch:24s} cache={kind:5s} {len(out)} reqs, {toks} toks "
        f"in {dt:5.2f}s -> {out[reqs[0].rid][:6]}"
    )
    for req in reqs:
        assert req.state == "finished" and len(req.generated) == req.max_new_tokens
    assert engine.idle


def main():
    for arch in ["qwen2-72b", "rwkv6-1.6b", "whisper-tiny", "gemma2-27b"]:
        demo(arch)
    print("\nall families served through one continuous-batching engine")


if __name__ == "__main__":
    main()
