"""Step builders: train_step / prefill_step / serve_step per (arch, shape).

These are the functions the dry-run lowers and the drivers jit:

* ``train_step``  — microbatched grad-accumulation loss/grad/AdamW update;
  gradient sync is XLA-propagated (FSDP reduce-scatter) by default, with
  the paper's NAP collective handling the latency-bound scalar sync
  (loss / grad-norm metrics) in the explicit path.
* ``prefill_step`` — forward over the full prompt; returns the final-
  position logits window (full (B, S, V) logits never materialise).
* ``serve_step``  — one-token cached decode (greedy next token).

``input_specs(...)`` produces ShapeDtypeStruct stand-ins (+ shardings)
for every model input of an (arch x shape x mesh) cell — the dry-run
lowers against these, so no host memory is ever allocated for the 72B/
398B configs.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .. import compat
from ..configs import SHAPES, get_config
from ..models import ShardingPolicy, build_model
from ..optim import adamw_init, adamw_update, make_schedule
from ..configs.base import OptimizerConfig, ShapeConfig
from .mesh import dp_axes as mesh_dp_axes

__all__ = [
    "make_policy",
    "make_train_step",
    "make_dp_train_step",
    "make_prefill_step",
    "make_serve_step",
    "input_specs",
    "state_specs",
    "microbatch_split",
]


def make_policy(
    cfg,
    mesh: Mesh | None,
    *,
    seq_parallel: bool = False,
    mode: str = "train",
) -> ShardingPolicy:
    if mesh is None:
        return ShardingPolicy()
    dp = mesh_dp_axes(mesh)
    return ShardingPolicy(
        mesh=mesh,
        dp_axes=dp if mode != "serve2d" else (),
        tp_axis="model" if "model" in mesh.axis_names else None,
        fsdp_axes=dp,
        seq_parallel=seq_parallel,
        mode=mode,
    )


def microbatch_split(cfg, shape: ShapeConfig, mesh: Mesh | None) -> int:
    """Number of grad-accumulation microbatches for a train cell.

    Sized so the scan-over-layers residual carry (num_super x B_m x S x D
    bf16 per chip) stays ~<= 6 GB; must divide the per-chip batch.
    """
    if shape.kind != "train":
        return 1
    dp = 1
    if mesh is not None:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        dp = int(np.prod([sizes[a] for a in mesh_dp_axes(mesh)]))
    b_local = max(1, shape.global_batch // dp)
    carry_per_sample = cfg.num_super_layers * shape.seq_len * cfg.d_model * 2
    b_m = max(1, int(6e9 // max(carry_per_sample, 1)))
    b_m = min(b_m, b_local)
    while b_local % b_m:
        b_m -= 1
    return b_local // b_m


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------


def make_train_step(
    model,
    opt_cfg: OptimizerConfig,
    *,
    n_micro: int = 1,
    grad_shardings=None,
):
    """grad_shardings: optional pytree of NamedShardings (same structure
    as params).  Annotating the grad-accumulation carry keeps gradients
    in the parameters' sharded layout — without it XLA resolves the
    unannotated zeros carry to replicated and synchronises every
    microbatch with full all-reduces instead of reduce-scatters
    (measured: 2.9 TB -> reduce-scatter-sized traffic on qwen2-72b)."""
    sched = make_schedule(opt_cfg)

    def _constrain(tree):
        if grad_shardings is None:
            return tree
        return jax.tree.map(
            jax.lax.with_sharding_constraint, tree, grad_shardings
        )

    def train_step(state, batch):
        params, opt = state["params"], state["opt"]

        def loss_fn(p, mb):
            return model.loss(p, mb)

        if n_micro == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True
            )(params, batch)
            grads = _constrain(grads)
        else:
            def micro(b):
                return jax.tree.map(
                    lambda x: x.reshape((n_micro, -1) + x.shape[1:]), b
                )

            mbs = micro(batch)

            def body(carry, mb):
                acc, lsum = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb
                )
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32), acc, g
                )
                return (_constrain(acc), lsum + l), None

            zeros = _constrain(
                jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )
            )
            (grads, lsum), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), mbs
            )
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = lsum / n_micro
            metrics = {"loss": loss}

        lr = sched(opt.step)
        new_params, new_opt, om = adamw_update(
            grads,
            opt,
            params,
            lr=lr,
            betas=opt_cfg.betas,
            eps=opt_cfg.eps,
            weight_decay=opt_cfg.weight_decay,
            grad_clip=opt_cfg.grad_clip,
        )
        out_metrics = {"loss": loss, "lr": lr, **om}
        return {"params": new_params, "opt": new_opt}, out_metrics

    return train_step


def make_dp_train_step(cfg, opt_cfg: OptimizerConfig, mesh, sync_cfg):
    """Pure data-parallel train step with *explicit* paper collectives.

    Parameters are replicated; each chip computes gradients on its batch
    shard; gradient buckets and the loss scalar are synchronised through
    a :class:`repro.core.comm.CommContext` built from the mesh topology
    and the configured policy (``nap`` / ``rd`` / ``smp`` / ``psum`` /
    ``auto`` …) inside one ``shard_map`` — the paper's technique
    integrated end-to-end in training.  Numerically equivalent to the
    ``psum`` baseline (asserted in tests).

    With ``sync_cfg.error_feedback`` the train state carries the
    per-chip compression residuals under ``"ef"`` — build them with
    :func:`repro.optim.error_feedback.ef_init(params, group=topo.group)
    <repro.optim.error_feedback.ef_init>`: every leaf has a leading
    group axis laid out over the mesh (residuals are chip-local state
    and must never be stored replicated).  Each step syncs ``g + r``
    through the quantised transport and stores back what the wire
    dropped.
    """
    from ..core import comm, grad_sync
    from ..models import ShardingPolicy
    from .mesh import mesh_topology

    model = build_model(cfg, ShardingPolicy())  # all compute chip-local
    sched = make_schedule(opt_cfg)
    topo = mesh_topology(mesh)
    ctx = comm.CommContext(topo, sync_cfg)
    group = topo.group

    # the trainer owns the per-bucket issue points: the bucket schedule is
    # planned once from the abstract gradient tree (same structure/dtypes
    # as the parameters) and pinned into every traced step, so the issue
    # order the scheduler decided is exactly what the SPMD program runs
    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    bucket_plan = grad_sync.plan_for_tree(
        params_sds, cfg=sync_cfg, topology=topo
    )

    use_ef = bool(getattr(sync_cfg, "error_feedback", False))

    def local_step(state, batch):
        params, opt = state["params"], state["opt"]
        (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch
        )
        if use_ef:
            ef = jax.tree.map(lambda e: e[0], state["ef"])
            grads, new_ef = ctx.sync_grads(
                grads, plan=bucket_plan, ef_state=ef
            )
        else:
            grads = ctx.sync_grads(grads, plan=bucket_plan)
        # the paper's canonical workload: single-scalar latency-bound
        # allreduce (loss mean) through the same algorithm
        if topo.inter_axes:
            loss = ctx.allreduce(
                loss,
                algorithm=sync_cfg.algorithm
                if sync_cfg.algorithm != "auto" else "nap",
            ) / group
        else:
            loss = jax.lax.pmean(loss, topo.intra_axes)
        lr = sched(opt.step)
        new_params, new_opt, om = adamw_update(
            grads, opt, params,
            lr=lr, betas=opt_cfg.betas, eps=opt_cfg.eps,
            weight_decay=opt_cfg.weight_decay, grad_clip=opt_cfg.grad_clip,
        )
        new_state = {"params": new_params, "opt": new_opt}
        if use_ef:
            # residuals are per-chip: keep the leading group axis
            new_state["ef"] = jax.tree.map(lambda e: e[None], new_ef)
        return new_state, {"loss": loss, "lr": lr, **om}

    state_spec = {"params": P(), "opt": P()}
    if use_ef:
        state_spec["ef"] = P(topo.axes)
    batch_spec = P(topo.axes, None)
    return compat.shard_map(
        local_step,
        mesh=mesh,
        in_specs=(state_spec, batch_spec),
        out_specs=(state_spec, P()),
        check_vma=False,
    )


def make_prefill_step(model, *, tail: int = 128):
    """Forward the prompt; emit logits for the last ``tail`` positions."""

    def prefill_step(params, batch):
        hidden, _ = model.apply(params, batch)
        h_tail = hidden[:, -tail:, :]
        if model.cfg.tie_embeddings:
            head = params["embedding"].T
        else:
            head = params["lm_head"]
        logits = jnp.einsum(
            "bsd,dv->bsv", h_tail, head.astype(h_tail.dtype),
            preferred_element_type=jnp.float32,
        )
        return logits

    return prefill_step


def make_serve_step(model, ctx=None):
    """One-token cached greedy decode — the serving spine's shared step
    (:func:`repro.serve.decode.greedy_step`).  With ``ctx`` the head is
    the tensor-parallel ``CommContext``-routed path; without, the
    model's own head (identical contraction, local)."""
    from ..serve.decode import greedy_step

    return greedy_step(model, ctx)


# ---------------------------------------------------------------------------
# abstract inputs (ShapeDtypeStructs + shardings) for the dry-run
# ---------------------------------------------------------------------------


def _sharded_sds(shape, dtype, mesh, spec):
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    # drop axes that don't divide (mirror ShardingPolicy._fit)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def ok(dim, entry):
        if entry is None:
            return None
        axes = entry if isinstance(entry, tuple) else (entry,)
        total = int(np.prod([sizes[a] for a in axes]))
        return entry if dim % total == 0 else None

    fitted = P(*(ok(d, e) for d, e in zip(shape, tuple(spec) + (None,) * len(shape))))
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=NamedSharding(mesh, fitted)
    )


def input_specs(
    arch: str, shape_name: str, mesh: Mesh | None, *, serve2d: bool = False
) -> dict[str, jax.ShapeDtypeStruct]:
    """Abstract batch for one (arch x shape) cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len
    dp = mesh_dp_axes(mesh) if mesh is not None else None
    if serve2d:
        dp = None  # serving layout: batch replicated, weights 2D-sharded
    tok = functools.partial(
        _sharded_sds, mesh=mesh, spec=P(dp, None), dtype=jnp.int32
    )
    batch: dict[str, Any] = {}
    if shape.kind == "decode":
        batch["tokens"] = tok((B, 1))
        if cfg.frontend == "vision_patches":
            batch["embeds"] = _sharded_sds(
                (B, 1, cfg.d_model), jnp.dtype(cfg.dtype), mesh, P(dp, None, None)
            )
            del batch["tokens"]
        if cfg.encoder_layers:  # enc-dec: encoder context at cache init
            batch["frames"] = _sharded_sds(
                (B, S, cfg.d_model), jnp.dtype(cfg.dtype), mesh,
                P(dp, None, None),
            )
        return batch
    if cfg.frontend == "vision_patches":
        batch["embeds"] = _sharded_sds(
            (B, S, cfg.d_model), jnp.dtype(cfg.dtype), mesh, P(dp, None, None)
        )
        batch["positions"] = _sharded_sds(
            (3, B, S), jnp.int32, mesh, P(None, dp, None)
        )
    else:
        batch["tokens"] = tok((B, S))
    if cfg.encoder_layers:
        batch["frames"] = _sharded_sds(
            (B, S, cfg.d_model), jnp.dtype(cfg.dtype), mesh, P(dp, None, None)
        )
    if shape.kind == "train":
        batch["labels"] = tok((B, S))
        batch["loss_mask"] = _sharded_sds(
            (B, S), jnp.float32, mesh, P(dp, None)
        )
    return batch


def state_specs(
    arch: str,
    shape_name: str,
    mesh: Mesh | None,
    *,
    opt_cfg: OptimizerConfig | None = None,
    seq_parallel: bool = False,
    cfg_overrides: dict | None = None,
    serve2d: bool = False,
):
    """Abstract (state/params/cache) trees with shardings for a cell.

    Returns (model, policy, abstract_tree) where abstract_tree is
    {"params", "opt"} for train, {"params"} for prefill, and
    {"params", "cache"} for decode shapes.
    """
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    policy = make_policy(
        cfg, mesh, seq_parallel=seq_parallel,
        mode="serve2d" if serve2d else "train",
    )
    model = build_model(cfg, policy)
    opt_cfg = opt_cfg or OptimizerConfig(
        moment_dtype="bfloat16" if cfg.param_count() > 1e11 else "float32"
    )

    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    out: dict[str, Any] = {"params": params_sds}
    if shape.kind == "train":
        out["opt"] = jax.eval_shape(
            functools.partial(adamw_init, moment_dtype=opt_cfg.moment_dtype),
            params_sds,
        )
    if shape.kind == "decode":
        batch = input_specs(arch, shape_name, mesh, serve2d=serve2d)
        out["cache"] = jax.eval_shape(
            functools.partial(
                model.init_decode,
                batch_size=shape.global_batch,
                max_len=shape.seq_len,
            ),
            params_sds,
            batch=batch if cfg.encoder_layers else None,
        )

    if mesh is not None:
        out["params"] = _attach_param_shardings(out["params"], policy)
        if "opt" in out:
            opt = out["opt"]
            out["opt"] = type(opt)(
                step=jax.ShapeDtypeStruct(
                    (), jnp.int32, sharding=NamedSharding(mesh, P())
                ),
                mu=_attach_param_shardings(opt.mu, policy),
                nu=_attach_param_shardings(opt.nu, policy),
            )
        if "cache" in out:
            out["cache"] = _attach_cache_shardings(out["cache"], policy)
    return model, policy, out, opt_cfg


def _attach_param_shardings(params_sds, policy: ShardingPolicy):
    specs = policy.param_specs(
        jax.tree.map(lambda s: s, params_sds)
    )
    return jax.tree.map(
        lambda s, spec: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(policy.mesh, spec)
        ),
        params_sds,
        specs,
    )


def _cache_spec(path_leaf_shape, policy: ShardingPolicy, name: str, shape):
    dp, tp = policy.dp, policy.tp_axis
    sizes_ok = lambda dim, axes: dim % _prod_axis(policy, axes) == 0

    if policy.mode == "serve2d":
        joint = ((tp,) if tp else ()) + tuple(policy.fsdp_axes or ())
        if name in ("k", "v"):  # (n_super, B, KV, S, hd): S over the grid
            if sizes_ok(shape[3], joint):
                return P(None, None, None, joint, None)
            return P(None, None, None, tp if sizes_ok(shape[3], (tp,)) else None, None)
        if name == "state":  # mamba (n,B,d_in,N) / rwkv (n,B,H,hd,hd)
            ax = joint if sizes_ok(shape[2], joint) else (
                tp if tp and sizes_ok(shape[2], (tp,)) else None
            )
            return P(None, None, ax)
        if name == "conv":  # (n, B, k, d_in)
            ax = joint if sizes_ok(shape[3], joint) else None
            return P(None, None, None, ax)
        if name == "enc_out":
            return P(None, None, None)
        return P()

    if name in ("k", "v"):  # (n_super, B, KV, size, hd)
        _, B, KV, _, _ = shape
        if tp and KV % policy.tp_size == 0 and sizes_ok(B, dp):
            return P(None, dp, tp, None, None)
        if tp and shape[3] % policy.tp_size == 0:
            return P(None, dp if sizes_ok(B, dp) else None, None, tp, None)
        return P(None, dp if sizes_ok(B, dp) else None, None, None, None)
    if name == "pos":
        return P(None, None)
    if name in ("state",):  # mamba (n,B,d_in,N) / rwkv (n,B,H,hd,hd)
        spec = [None, dp if sizes_ok(shape[1], dp) else None]
        if tp and shape[2] % policy.tp_size == 0:
            spec.append(tp)
        return P(*spec)
    if name in ("conv", "x_prev", "cm_x_prev"):
        return P(None, dp if sizes_ok(shape[1], dp) else None, None)
    if name == "enc_out":
        return P(dp if sizes_ok(shape[0], dp) else None, None, None)
    if name == "index":
        return P()
    return P()


def _prod_axis(policy, axes):
    if not axes:
        return 1
    axes = axes if isinstance(axes, tuple) else (axes,)
    sizes = dict(zip(policy.mesh.axis_names, policy.mesh.devices.shape))
    return int(np.prod([sizes[a] for a in axes]))


def _attach_cache_shardings(cache_sds, policy: ShardingPolicy):
    def walk(node, name):
        if isinstance(node, dict):
            return {k: walk(v, k) for k, v in node.items()}
        spec = _cache_spec(None, policy, name, node.shape)
        return jax.ShapeDtypeStruct(
            node.shape, node.dtype, sharding=NamedSharding(policy.mesh, spec)
        )

    return walk(cache_sds, "")
