import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first (before any jax import) — jax locks
the device count at first backend init; the 512 virtual CPU devices make
``make_production_mesh()`` buildable on this single-CPU container.

For each cell this script:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. builds abstract state/batch (ShapeDtypeStruct only — no allocation),
  3. ``jax.jit(step).lower(...).compile()`` — proving the sharding config
     is coherent (no mismatched collectives, no OOM at compile),
  4. records memory_analysis / cost_analysis / HLO collective stats and
     the three roofline terms into reports/dryrun/<cell>.json.

Usage:
  python -m repro.launch.dryrun --arch gemma2-27b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
  python -m repro.launch.dryrun --list
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402

from .. import compat  # noqa: E402
from ..configs import ARCHS, SHAPES, get_config  # noqa: E402
from . import roofline as rl  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402
from .steps import (  # noqa: E402
    input_specs,
    make_prefill_step,
    make_serve_step,
    make_train_step,
    microbatch_split,
    state_specs,
)

REPORTS = Path(__file__).resolve().parents[3] / "reports" / "dryrun"

# Applicability rules (DESIGN.md §4): long_500k only for sub-quadratic
# context growth (SSM / hybrid / windowed+alternating attention).
LONG_OK = {"gemma2-27b", "jamba-1.5-large-398b", "rwkv6-1.6b"}


def cells():
    for arch in sorted(ARCHS):
        for shape in SHAPES:
            if shape == "long_500k" and arch not in LONG_OK:
                continue
            yield arch, shape


def cell_name(arch: str, shape: str, multi_pod: bool) -> str:
    mesh = "pod2x16x16" if multi_pod else "pod16x16"
    return f"{arch}__{shape}__{mesh}"


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    *,
    seq_parallel: bool = False,
    opts: dict | None = None,
    tag: str = "",
    force: bool = False,
) -> dict:
    """opts (hillclimb levers; absent = paper/naive baseline):
    grad_fix=1       annotate grad-accum carry with param shardings
    remat=dots|none  scanned-stack remat policy override
    mamba_chunked=1  chunked mamba scan (checkpointed chunks)
    window_kv_slice=1  slice K/V to the sliding window per q chunk
    serve2d=1        serving layout: weights/cache over (model x data),
                     batch replicated — activation-sized collectives
    """
    opts = opts or {}
    name = cell_name(arch, shape_name, multi_pod) + (f"__{tag}" if tag else "")
    out_path = REPORTS / f"{name}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    t0 = time.time()
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(mesh.devices.size)
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "tag": tag,
        "opts": opts,
        "n_chips": n_chips,
        "ok": False,
    }
    try:
        import dataclasses as _dc

        cfg_overrides = {}
        for key in ("remat",):
            if key in opts:
                cfg_overrides[key] = opts[key]
        if "scan_unroll" in opts:
            cfg_overrides["scan_unroll"] = int(opts["scan_unroll"])
        for key in ("window_kv_slice", "bf16_bwd", "mamba_bf16_io"):
            if key in opts:
                cfg_overrides[key] = bool(int(opts[key]))
        model, policy, state, opt_cfg = state_specs(
            arch, shape_name, mesh,
            seq_parallel=seq_parallel,
            cfg_overrides=cfg_overrides,
            serve2d=bool(int(opts.get("serve2d", 0))),
        )
        cfg = model.cfg
        batch = input_specs(
            arch, shape_name, mesh,
            serve2d=bool(int(opts.get("serve2d", 0))),
        )

        if shape.kind == "train":
            n_micro = microbatch_split(cfg, shape, mesh)
            record["n_micro"] = n_micro
            grad_shardings = None
            if bool(int(opts.get("grad_fix", 0))):
                grad_shardings = jax.tree.map(
                    lambda s: s.sharding, state["params"]
                )
            step = make_train_step(
                model, opt_cfg, n_micro=n_micro,
                grad_shardings=grad_shardings,
            )
            args = ({"params": state["params"], "opt": state["opt"]}, batch)
        elif shape.kind == "prefill":
            step = make_prefill_step(model)
            args = (state["params"], batch)
        else:  # decode
            step = make_serve_step(model)
            tok = batch.get("tokens", batch.get("embeds"))
            args = (state["params"], state["cache"], tok)

        # donate the mutable state (train state / decode cache): real
        # deployments always do, and it lets XLA update caches in place
        # instead of copying the full KV buffer every step.
        donate = (0,) if shape.kind == "train" else (
            (1,) if shape.kind == "decode" else ()
        )
        with mesh:
            lowered = jax.jit(step, donate_argnums=donate).lower(*args)
            compiled = lowered.compile()
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()

        record["memory"] = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None
            ),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        }
        cost = dict(compat.normalize_cost_analysis(cost)) if cost else {}
        record["cost"] = {
            k: float(v)
            for k, v in cost.items()
            if isinstance(v, (int, float)) and k in (
                "flops", "bytes accessed", "transcendentals",
                "bytes accessed output", "optimal_seconds",
            )
        }
        mf = rl.model_flops(cfg, shape)
        roof = rl.analyze(
            cost=cost, hlo_text=hlo, n_chips=n_chips, model_flops_total=mf
        )
        record["roofline"] = roof.to_dict()
        record["hlo_bytes"] = len(hlo)
        import gzip

        (REPORTS / f"{name}.hlo.gz").parent.mkdir(parents=True, exist_ok=True)
        with gzip.open(REPORTS / f"{name}.hlo.gz", "wt") as fh:
            fh.write(hlo)
        record["ok"] = True
    except Exception as e:  # record failures — they are bugs to fix
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    record["compile_s"] = round(time.time() - t0, 2)

    REPORTS.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(record, indent=2, default=str))
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument(
        "--opts", default="",
        help="comma list key=val (grad_fix=1,remat=dots,mamba_chunked=1,"
        "window_kv_slice=1,serve2d=1)",
    )
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()
    opts = dict(
        kv.split("=", 1) for kv in args.opts.split(",") if "=" in kv
    )

    if args.list:
        for a, s in cells():
            print(f"{a} {s}")
        return

    if args.all:
        meshes = []
        if not args.multi_pod_only:
            meshes.append(False)
        if not args.single_pod_only:
            meshes.append(True)
        n_fail = 0
        for arch, shape in cells():
            for mp in meshes:
                rec = run_cell(
                    arch, shape, mp,
                    seq_parallel=args.seq_parallel,
                    opts=opts, tag=args.tag, force=args.force,
                )
                status = "OK " if rec["ok"] else "FAIL"
                n_fail += 0 if rec["ok"] else 1
                dom = rec.get("roofline", {}).get("dominant", "-")
                print(
                    f"{status} {cell_name(arch, shape, mp):56s} "
                    f"compile={rec.get('compile_s', 0):7.1f}s dominant={dom}",
                    flush=True,
                )
        print(f"failures: {n_fail}")
        raise SystemExit(1 if n_fail else 0)

    rec = run_cell(
        args.arch, args.shape, args.multi_pod,
        seq_parallel=args.seq_parallel, opts=opts, tag=args.tag,
        force=args.force,
    )
    print(json.dumps(rec, indent=2, default=str))
    raise SystemExit(0 if rec["ok"] else 1)


if __name__ == "__main__":
    main()
