"""Mesh construction for single-pod and multi-pod production runs.

Everything is a *function* (never module-level device state) so importing
this module touches no jax backend — required for the dry-run's
``xla_force_host_platform_device_count`` trick to work.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

from ..compat import mesh_axis_types_kwargs

__all__ = [
    "make_mesh",
    "make_production_mesh",
    "mesh_axis_sizes",
    "mesh_topology",
    "DATA_AXES",
    "MODEL_AXIS",
    "POD_AXIS",
]

POD_AXIS = "pod"
DATA_AXIS = "data"
MODEL_AXIS = "model"
DATA_AXES = (POD_AXIS, DATA_AXIS)  # gradient-sync (DP) axes when present


def make_mesh(shape, axes):
    """Mesh over the first prod(shape) devices (Auto axis types).

    Unlike ``jax.make_mesh`` this tolerates a process exposing *more*
    devices than the mesh uses — the dry-run builds the 256-chip
    single-pod mesh inside a 512-virtual-device process.
    """
    shape = tuple(shape)
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise ValueError(
            f"mesh {shape} needs {n} devices, have {len(devs)}"
        )
    return Mesh(
        np.asarray(devs[:n]).reshape(shape),
        tuple(axes),
        **mesh_axis_types_kwargs(len(axes)),
    )


def make_production_mesh(*, multi_pod: bool = False):
    """The assignment's production meshes.

    single-pod: 16 x 16 = 256 chips, axes ("data", "model")
    multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model");
    the "pod" axis is the slow (inter-pod DCI) domain — the paper's
    "inter-node network" — while "data"/"model" live on intra-pod ICI.
    """
    if multi_pod:
        return make_mesh((2, 16, 16), ("pod", "data", "model"))
    return make_mesh((16, 16), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_axes(mesh) -> tuple[str, ...]:
    """The data-parallel (gradient sync) axes present in this mesh."""
    return tuple(ax for ax in (POD_AXIS, DATA_AXIS) if ax in mesh.axis_names)


def hierarchy_axes(mesh) -> tuple[tuple[str, ...], tuple[str, ...]]:
    """(inter, intra) split of the DP axes for node-aware collectives.

    With a "pod" axis the slow domain is the pod boundary; single-pod
    meshes have no slow domain and the split is ((), ("data",)).
    """
    names = mesh.axis_names
    if POD_AXIS in names:
        return (POD_AXIS,), tuple(
            ax for ax in (DATA_AXIS,) if ax in names
        )
    return (), tuple(ax for ax in (DATA_AXIS,) if ax in names)


def mesh_topology(mesh, *, params=None):
    """The :class:`repro.core.comm.Topology` of a production mesh.

    The mesh→topology entry point of the topology-first collective API:
    the DP hierarchy split comes from :func:`hierarchy_axes` (a "pod"
    axis is the slow domain), the grid shape from the mesh axis sizes,
    and ``params`` optionally overrides the machine constants.  Lazy
    import keeps this module free of jax-backend state at import time.
    """
    from ..core.comm import Topology

    return Topology.from_mesh(mesh, params=params)
