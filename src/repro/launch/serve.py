"""Batched serving driver: prefill (chunked) + cached greedy decode.

A minimal production shape: requests are batched, the prompt is prefilled
token-group-wise through ``decode_step`` (filling the KV/state caches),
then decoded greedily.  Works for every decoder arch including the
hybrid/SSM families (their caches are states, not KV).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --reduced \\
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, reduced
from ..models import build_model
from .steps import make_policy, make_serve_step


def serve_batch(
    model,
    params,
    prompts: jnp.ndarray,
    *,
    gen_len: int,
    max_len: int | None = None,
    batch_extras: dict | None = None,
):
    """prompts: (B, P) int32. Returns (B, gen_len) generated tokens."""
    B, P = prompts.shape
    max_len = max_len or (P + gen_len)
    cache = model.init_decode(params, B, max_len=max_len, batch=batch_extras)
    step = jax.jit(model.decode_step)

    logits = None
    for t in range(P):  # prefill via teacher forcing (cache fill)
        logits, cache = step(params, cache, prompts[:, t : t + 1])
    out = []
    tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
    for _ in range(gen_len):
        out.append(tok)
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg, make_policy(cfg, None))
    params = jax.jit(model.init)(jax.random.PRNGKey(0))

    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size
    ).astype(jnp.int32)
    extras = None
    if cfg.encoder_layers:
        extras = {
            "frames": jax.random.normal(
                jax.random.PRNGKey(2), (args.batch, 16, cfg.d_model)
            ).astype(jnp.dtype(cfg.dtype))
        }
    t0 = time.time()
    gen = serve_batch(
        model, params, prompts, gen_len=args.gen, batch_extras=extras
    )
    dt = time.time() - t0
    toks = args.batch * (args.prompt_len + args.gen)
    print(
        f"generated {gen.shape} tokens; {toks/dt:.1f} tok/s total "
        f"({dt:.2f}s wall)"
    )
    print(np.asarray(gen[:2]))


if __name__ == "__main__":
    main()
