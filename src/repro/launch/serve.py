"""Batched serving driver: prefill (chunked) + cached greedy decode.

A minimal production shape: requests are batched, the prompt is prefilled
token-group-wise through ``decode_step`` (filling the KV/state caches),
then decoded greedily inside one jitted ``lax.while_loop``
(:func:`repro.serve.decode.make_decode_loop`).  Works for every decoder
arch including the hybrid/SSM families (their caches are states, not KV).

This module is now a **thin wrapper over the serving spine**
(:mod:`repro.serve`): the decode loop, the lint-clean EOS early exit and
the tensor-parallel head all live there, shared with the
continuous-batching :class:`repro.serve.ServeEngine`.  What remains
here is the fixed-batch driver shape — every request enters and leaves
together — kept because it is the right tool for offline eval sweeps
and as the serial reference the engine's continuous batching is tested
bitwise against.  For request-level serving (admission, in-flight
insertion, replica routing) use :mod:`repro.serve`.

With a ``CommContext`` bound, the early-exit predicate ("every sequence
hit EOS") is agreed across the serving group with a tiny
``ctx.allreduce(..., op="min")`` each step.  The seed-era shape — each
rank testing only its *local* done flags — is exactly what the spmd
lint's collective-uniformity rule rejects: ranks would disagree on
whether the next iteration (and any collective inside it) is reached,
the static signature of a decode-time hang.  With ``mesh`` given,
:func:`serve_batch` shard_maps prefill + decode over the batch and
routes the stop flag through the comm layer.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --reduced \\
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from .. import compat
from ..configs import get_config, reduced
from ..core import comm
from ..models import build_model
from ..serve.decode import make_decode_loop  # noqa: F401  (re-export)
from .steps import make_policy, make_serve_step  # noqa: F401  (re-export)


def make_serve_shard(model, ctx: comm.CommContext | None, *, gen_len: int,
                     max_len: int, eos_id: int | None = None):
    """The per-shard serve program: prefill (``fori_loop``) + decode
    loop, everything traced — this is the function the ``--spmd`` sweep
    lints as "the serve decode step"."""
    decode = make_decode_loop(model, ctx, gen_len=gen_len, eos_id=eos_id)

    def shard_fn(params, prompts):
        _b, p = prompts.shape
        cache = model.init_decode(
            params, prompts.shape[0], max_len=max_len, batch=None
        )
        logits, cache = model.decode_step(params, cache, prompts[:, :1])

        def pre_body(t, carry):
            _logits, cache = carry
            step_tok = lax.dynamic_slice_in_dim(prompts, t, 1, axis=1)
            return model.decode_step(params, cache, step_tok)

        logits, cache = lax.fori_loop(1, p, pre_body, (logits, cache))
        tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        return decode(params, cache, tok)

    return shard_fn


def serve_batch(
    model,
    params,
    prompts: jnp.ndarray,
    *,
    gen_len: int,
    max_len: int | None = None,
    batch_extras: dict | None = None,
    mesh=None,
    ctx: comm.CommContext | None = None,
    eos_id: int | None = None,
):
    """prompts: (B, P) int32. Returns (B, gen_len) generated tokens.

    Single-host default: prefill with a jitted per-token step, then run
    :func:`make_decode_loop`.  With ``mesh`` the batch is sharded over
    the mesh's joint axes and the whole prefill + decode runs inside
    one ``shard_map``, with the decode early-exit routed through
    ``ctx`` (built from the mesh if not given) — the comm-layer path
    the ``--spmd`` sweep lints.
    """
    B, P_len = prompts.shape
    max_len = max_len or (P_len + gen_len)

    if mesh is None:
        cache = model.init_decode(
            params, B, max_len=max_len, batch=batch_extras
        )
        step = jax.jit(model.decode_step)
        logits = None
        for t in range(P_len):  # prefill via teacher forcing (cache fill)
            logits, cache = step(params, cache, prompts[:, t : t + 1])
        tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        decode = jax.jit(
            make_decode_loop(model, ctx, gen_len=gen_len, eos_id=eos_id)
        )
        return decode(params, cache, tok)

    if batch_extras is not None:
        raise NotImplementedError(
            "batch_extras (encoder frames) are not supported on the "
            "meshed serve path yet"
        )
    if ctx is None:
        ctx = comm.CommContext(comm.Topology.from_mesh(mesh))
    joint = ctx.topology.axes
    shards = int(np.prod([mesh.shape[a] for a in joint]))
    if B % shards:
        raise ValueError(
            f"batch {B} does not shard over {shards} chips ({joint})"
        )
    shard_fn = make_serve_shard(
        model, ctx, gen_len=gen_len, max_len=max_len, eos_id=eos_id
    )
    fn = compat.shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(P(), P(joint)),
        out_specs=P(joint),
        check_vma=False,
    )
    return jax.jit(fn)(params, prompts)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--eos-id", type=int, default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg, make_policy(cfg, None))
    params = jax.jit(model.init)(jax.random.PRNGKey(0))

    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size
    ).astype(jnp.int32)
    extras = None
    if cfg.encoder_layers:
        extras = {
            "frames": jax.random.normal(
                jax.random.PRNGKey(2), (args.batch, 16, cfg.d_model)
            ).astype(jnp.dtype(cfg.dtype))
        }
    t0 = time.time()
    gen = serve_batch(
        model, params, prompts, gen_len=args.gen, batch_extras=extras,
        eos_id=args.eos_id,
    )
    dt = time.time() - t0
    toks = args.batch * (args.prompt_len + args.gen)
    print(
        f"generated {gen.shape} tokens; {toks/dt:.1f} tok/s total "
        f"({dt:.2f}s wall)"
    )
    print(np.asarray(gen[:2]))


if __name__ == "__main__":
    main()
