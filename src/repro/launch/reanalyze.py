"""Re-derive roofline terms from saved dry-run HLO (no recompilation).

The dry-run saves each cell's optimized per-device HLO as
``reports/dryrun/<cell>.hlo.gz``; analyzer improvements (trip-count
handling, slice aliasing) can be re-applied to all 66 cells in seconds:

    PYTHONPATH=src python -m repro.launch.reanalyze
"""

from __future__ import annotations

import gzip
import json
from pathlib import Path

from ..configs import SHAPES, get_config
from . import roofline as rl

REPORTS = Path(__file__).resolve().parents[3] / "reports" / "dryrun"


def main() -> None:
    n = 0
    for jf in sorted(REPORTS.glob("*.json")):
        hf = jf.with_suffix("").with_suffix("")  # strip .json
        hf = REPORTS / (jf.stem + ".hlo.gz")
        if not hf.exists():
            continue
        rec = json.loads(jf.read_text())
        if not rec.get("ok"):
            continue
        with gzip.open(hf, "rt") as fh:
            hlo = fh.read()
        cfg = get_config(rec["arch"])
        shape = SHAPES[rec["shape"]]
        roof = rl.analyze(
            cost=rec.get("cost", {}),
            hlo_text=hlo,
            n_chips=rec["n_chips"],
            model_flops_total=rl.model_flops(cfg, shape),
        )
        rec["roofline"] = roof.to_dict()
        jf.write_text(json.dumps(rec, indent=2, default=str))
        n += 1
    print(f"reanalyzed {n} cells")


if __name__ == "__main__":
    main()
