"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell (TPU v5e constants):

  compute_s    = HLO_FLOPs_per_chip / peak_FLOPs        (197 TF bf16)
  memory_s     = HLO_bytes_per_chip / HBM_bw            (819 GB/s)
  collective_s = collective_bytes_per_chip / link_bw    (~50 GB/s/link)

``compiled.cost_analysis()`` reports the per-device (post-SPMD) module,
so its flops/bytes are already per-chip.  Collective bytes are not in
cost_analysis: we parse the optimized HLO and sum the operand sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction.

Also reported: MODEL_FLOPS = 6*N_active*D tokens (train) or 2*N_active*D
(inference) and the useful-compute ratio MODEL_FLOPS / HLO_FLOPs, which
exposes remat recompute and dispatch overheads.
"""

from __future__ import annotations

import dataclasses
import re

import numpy as np

__all__ = ["HW", "Roofline", "collective_bytes", "analyze"]


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12    # bf16 per chip
    hbm_bw: float = 819e9         # B/s
    ici_bw: float = 50e9          # B/s per link
    name: str = "tpu_v5e"


V5E = HW()

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# shape tokens like bf16[8,128]{1,0} or f32[] (scalars)
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))  # [num_groups, group_size]
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


def collective_bytes(hlo_text: str) -> dict[str, dict[str, float]]:
    """Per-collective-kind *wire* bytes (per chip) + instruction counts.

    The optimized HLO prints operand names without inline types, so bytes
    are derived from the instruction's RESULT shape and replica-group
    size g, using the standard ring-traffic model per participating chip:

      all-reduce:          2 * size * (g-1)/g   (reduce-scatter+allgather)
      all-gather:          size * (g-1)/g        (size = gathered output)
      reduce-scatter:      size * (g-1)          (input = size * g)
      all-to-all:          size * (g-1)/g
      collective-permute:  size                  (one send per chip)
    """
    out = {k: {"bytes": 0.0, "count": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s*(.*?)\s*\b([a-z0-9\-]+)\(", stripped)
        if not m:
            continue
        op = m.group(2)
        kind = next(
            (
                k
                for k in _COLLECTIVES
                if op == k or op == k + "-start" or op == k + "-done"
            ),
            None,
        )
        if kind is None or op.endswith("-done"):
            continue
        result_bytes = sum(
            _shape_bytes(d, dims)
            for d, dims in _SHAPE_RE.findall(m.group(1))
        )
        g = _group_size(stripped)
        if kind == "all-reduce":
            wire = 2.0 * result_bytes * (g - 1) / g
        elif kind == "all-gather":
            wire = result_bytes * (g - 1) / g
        elif kind == "reduce-scatter":
            wire = result_bytes * (g - 1)
        elif kind == "all-to-all":
            wire = result_bytes * (g - 1) / g
        else:  # collective-permute
            wire = float(result_bytes)
        out[kind]["bytes"] += wire
        out[kind]["count"] += 1
    return out


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    flops_per_chip: float
    bytes_per_chip: float
    collective_bytes_per_chip: float
    model_flops_per_chip: float
    useful_flops_ratio: float
    collectives: dict
    hw: str = "tpu_v5e"
    # TPU-target memory term with the Pallas SSM scan kernels (state
    # resident in VMEM; HBM traffic = chunk slice I/O only). Equals
    # memory_s for models without per-token scans.
    memory_kernel_s: float = 0.0
    timescan_bytes_per_chip: float = 0.0

    def to_dict(self):
        return dataclasses.asdict(self)


def analyze(
    *,
    cost: dict,
    hlo_text: str,
    n_chips: int,
    model_flops_total: float,
    hw: HW = V5E,
) -> Roofline:
    """Roofline terms from the compiled per-device HLO.

    Uses the trip-count-aware analyzer (:mod:`repro.launch.hlo_analysis`)
    for flops / memory / collective bytes — ``cost_analysis()`` counts
    while-loop (scan) bodies once, undercounting an 80-layer x
    16-microbatch step by ~3 orders of magnitude (see its tests).  The
    raw cost_analysis numbers stay recorded upstream for reference.
    """
    from .hlo_analysis import analyze_hlo

    st = analyze_hlo(hlo_text, bf16_native=True)
    flops = st.flops or float(cost.get("flops", 0.0))
    nbytes = st.memory_bytes or float(cost.get("bytes accessed", 0.0))
    coll = st.collectives
    cbytes = st.collective_bytes

    compute_s = flops / hw.peak_flops
    memory_s = nbytes / hw.hbm_bw
    collective_s = cbytes / hw.ici_bw
    terms = {
        "compute": compute_s,
        "memory": memory_s,
        "collective": collective_s,
    }
    dominant = max(terms, key=terms.get)
    model_flops = model_flops_total / n_chips
    return Roofline(
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        flops_per_chip=flops,
        bytes_per_chip=nbytes,
        collective_bytes_per_chip=cbytes,
        model_flops_per_chip=model_flops,
        useful_flops_ratio=(model_flops / flops) if flops else 0.0,
        collectives=coll,
        hw=hw.name,
        memory_kernel_s=st.memory_bytes_kernel / hw.hbm_bw,
        timescan_bytes_per_chip=st.timescan_memory_bytes,
    )


def model_flops(cfg, shape) -> float:
    """6*N_active*tokens for train, 2*N_active*tokens for inference."""
    n = cfg.active_param_count()
    tokens = shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens
