"""End-to-end training driver.

Drives ``make_train_step`` with the full substrate stack: synthetic data
pipeline with prefetch, AdamW + schedule, async atomic checkpoints,
auto-resume and straggler monitoring (runtime/fault.py).  Works on a
single CPU device (reduced configs) and on a mesh (full configs).

The paper integration: with ``--grad-sync nap|rd|smp|auto`` the scalar
metrics and (in pure-DP mode) the gradient buckets are synchronised with
the explicit NAP/baseline collectives instead of XLA's default psum —
exercised end-to-end by examples/train_lm.py and the integration tests.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b \\
      --reduced --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import logging
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager
from ..configs import SHAPES, get_config, reduced
from ..configs.base import OptimizerConfig, TrainConfig
from ..data import Prefetcher, SyntheticLM
from ..models import build_model
from ..optim import adamw_init
from ..runtime import ResumableLoop, StragglerMonitor
from .mesh import dp_axes as mesh_dp_axes
from .steps import make_policy, make_train_step

log = logging.getLogger("repro.train")


def build_training(
    cfg,
    train_cfg: TrainConfig,
    *,
    mesh=None,
    ckpt_dir: str | Path,
):
    """Assemble (loop, data, step_fn) for a config. Returns the loop."""
    policy = make_policy(cfg, mesh)
    model = build_model(cfg, policy)

    data = SyntheticLM(
        vocab_size=cfg.vocab_size,
        seq_len=train_cfg.seq_len,
        global_batch=train_cfg.global_batch,
        seed=train_cfg.seed,
        mesh=mesh,
        batch_axes=mesh_dp_axes(mesh) if mesh is not None else (),
    )

    n_micro = 1
    if train_cfg.microbatch:
        n_micro = train_cfg.global_batch // train_cfg.microbatch
    train_step = make_train_step(
        model, train_cfg.optimizer, n_micro=n_micro
    )
    jit_step = jax.jit(train_step, donate_argnums=(0,))

    def make_state():
        params = jax.jit(model.init)(jax.random.PRNGKey(train_cfg.seed))
        if mesh is not None:
            params = policy.shard_params(params)
        opt = adamw_init(
            params, moment_dtype=train_cfg.optimizer.moment_dtype
        )
        return {"params": params, "opt": opt}

    def step_fn(state, step):
        batch = data.batch(step)
        state, metrics = jit_step(state, batch)
        return state, {
            k: float(v) for k, v in metrics.items() if jnp.ndim(v) == 0
        }

    ckpt = CheckpointManager(
        ckpt_dir, keep=train_cfg.keep_checkpoints, async_save=True
    )
    loop = ResumableLoop(
        step_fn=step_fn,
        make_state=make_state,
        ckpt=ckpt,
        checkpoint_every=train_cfg.checkpoint_every,
        monitor=StragglerMonitor(),
    )
    return loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="same-family miniature config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="cosine")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--microbatch", type=int, default=None)
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    train_cfg = TrainConfig(
        steps=args.steps,
        seq_len=args.seq,
        global_batch=args.batch,
        microbatch=args.microbatch,
        checkpoint_every=args.ckpt_every,
        optimizer=OptimizerConfig(
            lr=args.lr,
            schedule=args.schedule,
            warmup_steps=max(5, args.steps // 10),
            decay_steps=args.steps,
        ),
    )
    loop = build_training(cfg, train_cfg, ckpt_dir=args.ckpt_dir)
    t0 = time.time()
    loop.run(args.steps)
    losses = [m["loss"] for m in loop.metrics_log]
    if losses:
        print(
            f"steps={len(losses)} first_loss={losses[0]:.4f} "
            f"last_loss={losses[-1]:.4f} wall_s={time.time()-t0:.1f} "
            f"stragglers={len(loop.monitor.events)}"
        )


if __name__ == "__main__":
    main()
