"""Trip-count-aware HLO analysis: flops / memory traffic / collectives.

``compiled.cost_analysis()`` visits a while-loop body ONCE, so any
scanned model (layers scan, microbatch accumulation, q-chunked attention)
is undercounted by the trip count — for an 80-layer x 16-microbatch
train step that's a ~1000x error (verified in tests).  XLA's optimized
HLO text, however, carries ``backend_config={"known_trip_count":{"n":..}}``
on every scan-derived while op, so this module re-derives the roofline
inputs by walking the call graph with multipliers:

* flops: every ``dot`` costs 2 * |result| * contraction_size (operand
  shapes resolved from the instruction table); fusion computations are
  recursed for their dots; while bodies multiply by trip count.
* memory traffic: per top-level instruction, operand + result bytes at
  fusion boundaries (fusion internals NOT counted — XLA materialises
  only fusion inputs/outputs), bookkeeping ops skipped; while bodies
  multiplied by trip count.
* collective wire bytes: same ring-traffic model as
  :mod:`repro.launch.roofline`, multiplied through loops.

This is a deliberately small structural parser — enough for models made
of dots, elementwise fusions, scans and collectives (everything in this
repo), not a general HLO semantics tool.
"""

from __future__ import annotations

import dataclasses
import re

__all__ = [
    "HloStats", "analyze_hlo", "CollectiveOp", "iter_collectives",
    "parse_hlo",
]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5, "pred": 1,
    "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HEADER_RE = re.compile(
    r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*(.*?)\s*\{\s*$"
)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+([a-z0-9\-]+)\((.*)$"
)
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{(\{[0-9, ]+\}(?:, ?\{[0-9, ]+\})*)\}")
_CALLED_RE = re.compile(r"(?:calls|body|to_apply|branch_computations)=.?%?([\w.\-{}, %]+)")

_SKIP_MEM_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "reshape", "after-all", "partition-id", "replica-id", "tuple-select",
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(shape_str: str) -> int:
    return sum(
        _DTYPE_BYTES.get(d, 0) * (eval("*".join(dims.split(",")) or "1")
                                  if dims else 1)
        for d, dims in _SHAPE_RE.findall(shape_str)
    )


def _shape_dims(shape_str: str) -> list[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(x) for x in dims.split(",")] if dims else []


@dataclasses.dataclass
class _Instr:
    name: str
    shape: str
    op: str
    rest: str  # args + attributes (raw tail of the line)


@dataclasses.dataclass
class _Comp:
    name: str
    instrs: dict[str, _Instr]
    order: list[str]


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    memory_bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives: dict = dataclasses.field(
        default_factory=lambda: {
            k: {"bytes": 0.0, "count": 0.0} for k in _COLLECTIVES
        }
    )
    dots: int = 0
    unknown_trip_whiles: int = 0
    # time-scan (trip count >= TIMESCAN_TRIPS, i.e. per-token SSM
    # recurrences, not layer/microbatch scans) accounting: total body
    # traffic vs pure slice I/O.  A VMEM-resident Pallas kernel
    # (repro.kernels.{mamba,rwkv6}_scan) reduces the former to the
    # latter; memory_bytes_kernel reports that TPU-target number.
    timescan_memory_bytes: float = 0.0
    timescan_io_bytes: float = 0.0
    # attention-score traffic (op_name-tagged: the S x S einsums, masks,
    # softmax) vs its flash-kernel replacement (q/k/v/o streams only —
    # scores never leave VMEM).  repro.kernels.flash_attention is the
    # validated TPU implementation.
    attn_memory_bytes: float = 0.0
    attn_io_bytes: float = 0.0

    @property
    def memory_bytes_kernel(self) -> float:
        return (
            self.memory_bytes
            - self.timescan_memory_bytes
            + self.timescan_io_bytes
            - self.attn_memory_bytes
            + self.attn_io_bytes
        )


TIMESCAN_TRIPS = 256

# attention-score op_name signatures: the GQA einsum labels used by
# repro.models.attention plus the mask select and softmax (attention is
# the only softmax user outside the tiny MoE router).
_ATTN_TAGS = ("bqkgh", "bkgqs", "bqkgh,bksh", "bkgqs,bksh")


def _is_attn_tagged(rest: str) -> bool:
    if any(t in rest for t in _ATTN_TAGS):
        return True
    if "jit(_where)/select_n" in rest and "shard_map" not in rest:
        return True
    return "softmax" in rest and "shard_map" not in rest


def _parse(text: str) -> tuple[dict[str, _Comp], str | None]:
    comps: dict[str, _Comp] = {}
    entry = None
    cur: _Comp | None = None
    for raw in text.splitlines():
        header = _COMP_HEADER_RE.match(raw)
        if header:
            cur = _Comp(header.group(2), {}, [])
            comps[cur.name] = cur
            if header.group(1):
                entry = cur.name
            # parameters from the signature
            for pm in re.finditer(
                r"%?([\w.\-]+):\s*(\(.*?\)|[a-z0-9]+\[[0-9,]*\])",
                header.group(3),
            ):
                inst = _Instr(pm.group(1), pm.group(2), "parameter", "")
                cur.instrs[inst.name] = inst
            continue
        if cur is None:
            continue
        if raw.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(raw)
        if m:
            inst = _Instr(m.group(1), m.group(2), m.group(3), m.group(4))
            cur.instrs[inst.name] = inst
            cur.order.append(inst.name)
    return comps, entry


def parse_hlo(text: str) -> tuple[dict[str, _Comp], str | None]:
    """Public handle on the structural parser: ``(computations, entry)``.

    Each computation maps instruction name -> instruction (``name`` /
    ``shape`` / ``op`` / ``rest``) plus emission ``order``.  Used by
    :mod:`repro.analysis.hlo_lint` to build rules on the same parse the
    traffic analysis trusts.
    """
    return _parse(text)


@dataclasses.dataclass(frozen=True)
class CollectiveOp:
    """One collective instruction of a compiled module, as lint input."""

    kind: str  # canonical collective name ("-start" variants folded in)
    op: str  # raw opcode as written in the HLO
    name: str  # instruction name
    computation: str  # owning computation (while bodies included)
    shape: str  # raw result shape string
    dtypes: tuple[str, ...]  # every dtype appearing in the result shape
    elems: int  # total element count across the result shape
    bytes: float  # result bytes (packed s4/u4 at 0.5 bytes/elem)
    group_size: int
    replica_groups: tuple[tuple[int, ...], ...]  # () when iota-format
    rest: str  # raw argument/attribute tail


def iter_collectives(text: str):
    """Yield every collective op of every computation of an HLO module.

    Unlike :func:`analyze_hlo` this walks *all* computations rather than
    the entry call graph — a lint rule must see collectives inside while
    bodies and fusions regardless of trip-count metadata.
    """
    comps, _ = _parse(text)
    for comp in comps.values():
        for iname in comp.order:
            inst = comp.instrs[iname]
            kind = next(
                (
                    k
                    for k in _COLLECTIVES
                    if inst.op == k or inst.op == k + "-start"
                ),
                None,
            )
            if kind is None:
                continue
            shapes = _SHAPE_RE.findall(inst.shape)
            elems = 0
            for _, dims in shapes:
                cnt = 1
                for d in dims.split(","):
                    if d:
                        cnt *= int(d)
                elems += cnt
            gm = _GROUPS_LIST_RE.search(inst.rest)
            groups = (
                tuple(
                    tuple(int(x) for x in g.split(","))
                    for g in re.findall(r"\{([0-9, ]+)\}", gm.group(1))
                )
                if gm
                else ()
            )
            yield CollectiveOp(
                kind=kind,
                op=inst.op,
                name=inst.name,
                computation=comp.name,
                shape=inst.shape,
                dtypes=tuple(d for d, _ in shapes),
                elems=elems,
                bytes=float(_shape_bytes(inst.shape)),
                group_size=_group_size(inst.rest),
                replica_groups=groups,
                rest=inst.rest,
            )


def _group_size(rest: str) -> int:
    m = _GROUPS_RE.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(rest)
    if m:
        return len(m.group(1).split(","))
    return 2


def _operand_names(rest: str) -> list[str]:
    """Names referenced in the argument list (before attributes)."""
    args = rest.split("), ")[0] if "), " in rest else rest.rstrip(")")
    return re.findall(r"%([\w.\-]+)", args)


def _called_comp(rest: str, key: str) -> str | None:
    m = re.search(rf"{key}=%?([\w.\-]+)", rest)
    return m.group(1) if m else None


_SLICE_OPS = {"dynamic-slice", "slice", "gather"}
_VIEW_OPS = {"bitcast", "reshape", "copy", "transpose", "convert",
             "broadcast"}


def _instr_mem_bytes(
    comp: _Comp, inst: _Instr, comps: dict, *, bf16_native: bool = False
) -> float:
    """HBM traffic of one top-level instruction.

    Slicing/in-place semantics matter enormously inside scans: a
    ``dynamic-update-slice`` on an (S, B, D) carry touches only the
    updated slice (XLA aliases the buffer in place), and a
    ``dynamic-slice`` reads only its result — counting whole operands
    would overcount a 4096-step scan body by ~1000x.

    * raw dynamic-slice / slice / gather: 2 x result bytes;
    * raw dynamic-update-slice: 2 x update-operand bytes;
    * fusion: per-parameter usage analysis of the fused computation —
      a parameter consumed *only* by slice ops contributes the slice
      bytes, a parameter that is the in-place target of a
      dynamic-update-slice contributes the update bytes, anything else
      contributes its full size; the fusion result contributes the DUS
      update size when the root is an in-place update, else its size.
    """
    op = inst.op
    result = _shape_bytes(inst.shape)
    opnames = _operand_names(inst.rest)
    opbytes = [
        _shape_bytes(comp.instrs[o].shape) if o in comp.instrs else 0
        for o in opnames
    ]

    if op in _SLICE_OPS:
        return 2.0 * result
    if op == "dynamic-update-slice":
        upd = opbytes[1] if len(opbytes) > 1 else result
        return 2.0 * min(upd, result)
    if op != "fusion":
        return result + sum(opbytes)

    called = _called_comp(inst.rest, "calls")
    sub = comps.get(called) if called else None
    if sub is None:
        return result + sum(opbytes)

    # signature params in positional order = fusion operand order
    params = [n for n in sub.instrs if sub.instrs[n].op == "parameter"]
    pset = set(params)
    sliced: dict[str, float] = {p: 0.0 for p in params}
    full_use: dict[str, bool] = {p: False for p in params}
    dus_target: set[str] = set()
    dus_update_bytes = 0.0
    result_is_dus = False
    result_dims = _shape_dims(inst.shape)
    # view chains (convert/bitcast/reshape/... incl. the CPU bf16->f32
    # legalisation converts) are transparent: usage is attributed to the
    # root parameter they alias.
    alias: dict[str, str] = {}

    def root_of(name: str) -> str | None:
        r = alias.get(name, name)
        return r if r in pset else None

    for iname in sub.order:
        ii = sub.instrs[iname]
        ops_i = _operand_names(ii.rest)
        if ii.op in _VIEW_OPS and len(ops_i) == 1:
            r = root_of(ops_i[0])
            if r is not None:
                alias[iname] = r
                continue
        if ii.op == "dynamic-update-slice":
            upd = ops_i[1] if len(ops_i) > 1 else None
            if upd and upd in sub.instrs:
                dus_update_bytes += _shape_bytes(sub.instrs[upd].shape)
            elif upd and alias.get(upd):
                dus_update_bytes += _shape_bytes(
                    sub.instrs[alias[upd]].shape
                )
            if _shape_dims(ii.shape) == result_dims:
                result_is_dus = True
            for j, o in enumerate(ops_i):
                r = root_of(o)
                if r is None:
                    continue
                if j == 0:
                    dus_target.add(r)
                elif j == 1:
                    full_use[r] = True  # update read in full
            # the dus result may feed further converts: make it alias the
            # in-place target so downstream uses don't re-count it
            if ops_i and root_of(ops_i[0]):
                alias[iname] = root_of(ops_i[0])
            continue
        for o in ops_i:
            r = root_of(o)
            if r is None:
                continue
            if ii.op in _SLICE_OPS:
                sliced[r] += 2.0 * _shape_bytes(ii.shape)
            else:
                full_use[r] = True

    # pure-convert fusion: XLA:CPU's bf16->f32 dot legalisation; does not
    # exist in a TPU lowering of a bf16 model.
    body_ops = {
        sub.instrs[n].op for n in sub.order
    } - {"parameter", "constant", "bitcast", "reshape", "copy"}
    if bf16_native and body_ops <= {"convert"} and "f32[" in inst.shape:
        return 0.0

    result_elems = 1
    for d in result_dims:
        result_elems *= d
    traffic = 0.0
    for p, ob in zip(params, opbytes):
        p_elems = 1
        for d in _shape_dims(sub.instrs[p].shape if p in sub.instrs else ""):
            p_elems *= d
        same_size = result_elems > 1 and p_elems == result_elems
        if p in dus_target or (result_is_dus and same_size):
            traffic += 0.0  # aliased in-place buffer (however consumed)
        elif full_use[p]:
            traffic += ob
        elif sliced[p]:
            traffic += min(sliced[p], ob)
        # untouched param: 0
    traffic += dus_update_bytes if result_is_dus else result
    return traffic


def analyze_hlo(text: str, *, bf16_native: bool = False) -> HloStats:
    """``bf16_native``: XLA:CPU cannot execute bf16 dots, so its
    legalisation converts dot inputs to f32 *before* SPMD collectives —
    weight all-gathers and dot-adjacent all-reduces appear at twice their
    TPU width (verified with a minimal FSDP matmul).  With this flag, f32
    collectives whose op_name metadata stems from a dot_general are
    counted at bf16 width, matching the TPU-native lowering of a bf16
    model.  Raw bytes remain available via bf16_native=False.
    """
    comps, entry = _parse(text)
    stats = HloStats()
    if entry is None:
        return stats

    flop_memo: dict[str, tuple[float, int]] = {}

    def dot_flops(comp: _Comp, inst: _Instr) -> float:
        result_elems = 1
        for d in _shape_dims(inst.shape):
            result_elems *= d
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", inst.rest)
        cdims = (
            [int(x) for x in m.group(1).split(",") if x] if m else []
        )
        ops = _operand_names(inst.rest)
        contract = 1
        if ops and ops[0] in comp.instrs:
            lhs_dims = _shape_dims(comp.instrs[ops[0]].shape)
            for c in cdims:
                if c < len(lhs_dims):
                    contract *= lhs_dims[c]
        return 2.0 * result_elems * contract

    def fusion_flops(comp_name: str) -> tuple[float, int]:
        """flops of dots inside a fusion/call computation (mult 1)."""
        if comp_name in flop_memo:
            return flop_memo[comp_name]
        comp = comps.get(comp_name)
        if comp is None:
            return (0.0, 0)
        total, n = 0.0, 0
        for iname in comp.order:
            inst = comp.instrs[iname]
            if inst.op == "dot":
                total += dot_flops(comp, inst)
                n += 1
            elif inst.op in ("fusion", "call", "map"):
                c = _called_comp(inst.rest, "calls") or _called_comp(
                    inst.rest, "to_apply"
                )
                if c:
                    f, k = fusion_flops(c)
                    total += f
                    n += k
        flop_memo[comp_name] = (total, n)
        return total, n

    def walk(comp_name: str, mult: float, in_timescan: bool = False):
        comp = comps.get(comp_name)
        if comp is None:
            return
        for iname in comp.order:
            inst = comp.instrs[iname]
            op = inst.op
            if op == "while":
                tm = _TRIP_RE.search(inst.rest)
                trips = int(tm.group(1)) if tm else 1
                if not tm:
                    stats.unknown_trip_whiles += 1
                body = _called_comp(inst.rest, "body")
                if body:
                    walk(
                        body,
                        mult * trips,
                        in_timescan or trips >= TIMESCAN_TRIPS,
                    )
                continue
            if op in ("call", "custom-call") and op == "call":
                c = _called_comp(inst.rest, "to_apply")
                if c:
                    walk(c, mult)
                continue
            if op == "conditional":
                # count the largest branch (upper bound)
                m = re.search(
                    r"(?:branch_computations|true_computation)=\{?([^}]+)\}?",
                    inst.rest,
                )
                continue  # branches negligible in this repo
            # collectives
            kind = next(
                (
                    k
                    for k in _COLLECTIVES
                    if op == k or op == k + "-start"
                ),
                None,
            )
            if kind is not None:
                result_bytes = _shape_bytes(inst.shape)
                if (
                    bf16_native
                    and "dot_general" in inst.rest
                    and "f32[" in inst.shape
                    and "bf16[" not in inst.shape
                ):
                    result_bytes *= 0.5  # TPU keeps these bf16
                g = _group_size(inst.rest)
                if kind == "all-reduce":
                    wire = 2.0 * result_bytes * (g - 1) / g
                elif kind == "all-gather":
                    wire = result_bytes * (g - 1) / g
                elif kind == "reduce-scatter":
                    wire = result_bytes * (g - 1)
                elif kind == "all-to-all":
                    wire = result_bytes * (g - 1) / g
                else:
                    wire = float(result_bytes)
                stats.collectives[kind]["bytes"] += wire * mult
                stats.collectives[kind]["count"] += mult
                stats.collective_bytes += wire * mult
                # collectives also move HBM bytes
                stats.memory_bytes += result_bytes * mult
                continue
            # flops
            if op == "dot":
                stats.flops += dot_flops(comp, inst) * mult
                stats.dots += 1
            elif op in ("fusion", "map"):
                c = _called_comp(inst.rest, "calls") or _called_comp(
                    inst.rest, "to_apply"
                )
                if c:
                    f, k = fusion_flops(c)
                    stats.flops += f * mult
                    stats.dots += k
            # memory traffic at fusion boundaries
            if op in _SKIP_MEM_OPS:
                continue
            nbytes = (
                _instr_mem_bytes(comp, inst, comps, bf16_native=bf16_native)
                * mult
            )
            stats.memory_bytes += nbytes
            if _is_attn_tagged(inst.rest):
                stats.attn_memory_bytes += nbytes
                if op == "dot":
                    # flash replacement: q/k/v/o streams, not the S x S
                    # scores (= the largest tensor of the dot)
                    sizes = [_shape_bytes(inst.shape)] + [
                        _shape_bytes(comp.instrs[o].shape)
                        for o in _operand_names(inst.rest)
                        if o in comp.instrs
                    ]
                    stats.attn_io_bytes += (sum(sizes) - max(sizes)) * mult
            if in_timescan:
                stats.timescan_memory_bytes += nbytes
                # slice I/O = what a fused VMEM kernel must still move
                if op in _SLICE_OPS or op == "dynamic-update-slice":
                    stats.timescan_io_bytes += nbytes
                elif op == "fusion":
                    called = _called_comp(inst.rest, "calls")
                    sub = comps.get(called) if called else None
                    if sub is not None:
                        io = 0.0
                        for jn in sub.order:
                            ji = sub.instrs[jn]
                            if ji.op in _SLICE_OPS or ji.op == (
                                "dynamic-update-slice"
                            ):
                                io += 2.0 * (
                                    _shape_bytes(ji.shape)
                                    if ji.op != "dynamic-update-slice"
                                    else min(
                                        (
                                            _shape_bytes(
                                                sub.instrs[o].shape
                                            )
                                            for o in _operand_names(ji.rest)[1:2]
                                            if o in sub.instrs
                                        ),
                                        default=0,
                                    )
                                )
                        stats.timescan_io_bytes += min(io * mult, nbytes)

    walk(entry, 1.0)
    return stats
