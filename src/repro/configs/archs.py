"""The ten assigned architectures, exactly as specified in the assignment.

``[source; verified-tier]`` notes are inherited from the assignment table.
``reduced(cfg)`` produces a same-family miniature for CPU smoke tests.
"""

from __future__ import annotations

import dataclasses

from .base import MambaConfig, ModelConfig, MoEConfig, SubLayer

__all__ = ["ARCHS", "get_config", "reduced"]


# --- dense -----------------------------------------------------------------

# gemma2-27b: local+global alternating attention, logit softcaps
# [arXiv:2408.00118; hf].  head_dim=128 per the public HF config (the
# assignment lists d_model/heads only; gemma2 projects 32*128=4096 != 4608).
GEMMA2_27B = ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256_000,
    pattern=(SubLayer("attn_local"), SubLayer("attn")),
    sliding_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    act="gelu",
    tie_embeddings=True,
    sandwich_norm=True,
    scale_embeddings=True,
)

# minicpm-2b: llama-like dense, trained with WSD [arXiv:2404.06395; hf]
MINICPM_2B = ModelConfig(
    name="minicpm-2b",
    family="dense",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    d_ff=5760,
    vocab_size=122_753,
    pattern=(SubLayer("attn"),),
    tie_embeddings=True,
)

# qwen2-72b: GQA with QKV bias [arXiv:2407.10671; hf]
QWEN2_72B = ModelConfig(
    name="qwen2-72b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152_064,
    pattern=(SubLayer("attn"),),
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
)

# granite-20b: llama-arch code model, MQA (kv=1) [arXiv:2405.04324; hf]
GRANITE_20B = ModelConfig(
    name="granite-20b",
    family="dense",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49_152,
    pattern=(SubLayer("attn"),),
    tie_embeddings=True,
)

# --- hybrid ----------------------------------------------------------------

# jamba-1.5-large-398b: mamba+attention 1:7, MoE 16e top-2 every other
# sublayer [arXiv:2403.19887; hf]
JAMBA_1_5_LARGE = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65_536,
    # 8-sublayer block: attention at position 4, mamba elsewhere (1:7);
    # MoE on odd sublayers (every other), dense FFN on the rest.
    pattern=tuple(
        SubLayer(
            mixer="attn" if i == 4 else "mamba",
            ffn="moe" if i % 2 == 1 else "dense",
        )
        for i in range(8)
    ),
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=24576),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    tie_embeddings=False,
)

# --- vlm -------------------------------------------------------------------

# qwen2-vl-2b: M-RoPE, dynamic resolution (vision frontend stubbed)
# [arXiv:2409.12191; hf]
QWEN2_VL_2B = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151_936,
    pattern=(SubLayer("attn"),),
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),
    frontend="vision_patches",
    tie_embeddings=True,
)

# --- moe -------------------------------------------------------------------

# moonshot-v1-16b-a3b (moonlight): 64e top-6, 2 shared
# [hf:moonshotai/Moonlight-16B-A3B; hf]
MOONSHOT_16B = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=163_840,
    pattern=(SubLayer("attn", ffn="moe"),),
    moe=MoEConfig(
        num_experts=64, top_k=6, d_expert=1408, num_shared_experts=2
    ),
    tie_embeddings=True,
)

# deepseek-moe-16b: fine-grained 64 routed top-6 + 2 shared
# [arXiv:2401.06066; hf]
DEEPSEEK_MOE_16B = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102_400,
    pattern=(SubLayer("attn", ffn="moe"),),
    moe=MoEConfig(
        num_experts=64, top_k=6, d_expert=1408, num_shared_experts=2
    ),
    tie_embeddings=True,
)

# --- ssm -------------------------------------------------------------------

# rwkv6-1.6b "Finch": attention-free, data-dependent decay
# [arXiv:2404.05892; unverified]
RWKV6_1_6B = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,          # d_model / rwkv_head_size
    num_kv_heads=32,
    d_ff=7168,
    vocab_size=65_536,
    pattern=(SubLayer("rwkv6"),),
    rwkv_head_size=64,
    tie_embeddings=False,
)

# --- audio -----------------------------------------------------------------

# whisper-tiny: enc-dec, conv frontend stubbed (input_specs provides frame
# embeddings) [arXiv:2212.04356; unverified]
WHISPER_TINY = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,          # decoder layers
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51_865,
    pattern=(SubLayer("attn"),),
    encoder_layers=4,
    encoder_pattern=(SubLayer("attn"),),
    cross_attention=True,
    frontend="audio_frames",
    act="gelu",
    tie_embeddings=True,
)

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        GEMMA2_27B,
        MINICPM_2B,
        QWEN2_72B,
        GRANITE_20B,
        JAMBA_1_5_LARGE,
        QWEN2_VL_2B,
        MOONSHOT_16B,
        DEEPSEEK_MOE_16B,
        RWKV6_1_6B,
        WHISPER_TINY,
    ]
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Same-family miniature for CPU smoke tests: small width/depth, tiny
    vocab, few experts — structure (pattern, mixers, MoE, enc-dec) intact.
    """
    pattern_len = len(cfg.pattern)
    changes = dict(
        name=cfg.name + "-smoke",
        num_layers=pattern_len * (2 if pattern_len <= 2 else 1),
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        sliding_window=32 if cfg.sliding_window else None,
        # CPU executes the smoke configs; XLA:CPU cannot run bf16 dots
        # with f32 accumulation, so miniatures run in f32 (the full
        # configs keep bf16 — they are compiled, not executed, here).
        dtype="float32",
    )
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=2, d_expert=64,
            num_shared_experts=min(cfg.moe.num_shared_experts, 1),
        )
    if cfg.mamba is not None:
        changes["mamba"] = MambaConfig(d_state=4, d_conv=4, expand=2)
    if cfg.encoder_layers:
        changes["encoder_layers"] = 2
    if cfg.mrope_sections:
        changes["mrope_sections"] = (4, 2, 2)
    if cfg.pattern[0].mixer == "rwkv6":
        changes["num_heads"] = 4
        changes["head_dim"] = None
        changes["rwkv_head_size"] = 16
    return dataclasses.replace(cfg, **changes)
