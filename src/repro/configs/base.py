"""Config dataclasses for models, shapes, training and meshes.

Every assigned architecture is expressed as a :class:`ModelConfig`; the
transformer stack consumes configs through the *super-layer pattern*: a
repeating block of sublayers (attention / mamba / rwkv mixers with dense
or MoE FFNs) scanned ``num_super_layers`` times.  Uniform decoder models
use a 1-sublayer pattern; gemma2 alternates (local, global); jamba uses a
1-attn : 7-mamba block with MoE on every other sublayer.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Literal, Sequence

__all__ = [
    "MoEConfig",
    "MambaConfig",
    "SubLayer",
    "ModelConfig",
    "ShapeConfig",
    "OptimizerConfig",
    "TrainConfig",
    "SHAPES",
]

Mixer = Literal["attn", "attn_local", "mamba", "rwkv6", "none"]
FFN = Literal["dense", "moe", "none"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden size
    num_shared_experts: int = 0   # deepseek-style always-on experts
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int | None = None    # default ceil(d_model/16)


@dataclasses.dataclass(frozen=True)
class SubLayer:
    """One sublayer of the super-layer pattern: a mixer plus an FFN."""

    mixer: Mixer = "attn"
    ffn: FFN = "dense"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int               # total sublayers (as in the assignment)
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None   # default d_model // num_heads
    pattern: tuple[SubLayer, ...] = (SubLayer(),)

    # attention features
    sliding_window: int | None = None   # width of "attn_local" sublayers
    attn_logit_softcap: float | None = None
    final_logit_softcap: float | None = None
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    mrope_sections: tuple[int, int, int] | None = None  # qwen2-vl M-RoPE

    moe: MoEConfig | None = None
    mamba: MambaConfig | None = None
    rwkv_head_size: int = 64

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_pattern: tuple[SubLayer, ...] = ()
    cross_attention: bool = False
    frontend: str | None = None   # "audio_frames" | "vision_patches" stubs

    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    act: str = "silu"             # silu | gelu
    sandwich_norm: bool = False   # gemma2 post-mixer/post-ffn norms
    scale_embeddings: bool = False  # gemma: embed * sqrt(d_model)
    # numerical
    dtype: str = "bfloat16"
    # checkpointing policy for the scanned stack
    remat: str = "full"           # full | dots | none
    # perf levers (hillclimb; default = paper/naive baseline)
    window_kv_slice: bool = False  # slice K/V to the window per q-chunk
    scan_unroll: int = 1           # SSM time-scan unroll (fusion width)
    bf16_bwd: bool = False         # bf16 cotangents through projections
    mamba_bf16_io: bool = False    # dt/B/C streamed in bf16 (f32 state)

    def __post_init__(self):
        if self.num_layers % len(self.pattern) != 0:
            raise ValueError(
                f"{self.name}: num_layers {self.num_layers} not divisible "
                f"by pattern length {len(self.pattern)}"
            )

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def num_super_layers(self) -> int:
        return self.num_layers // len(self.pattern)

    @property
    def is_decoder_only(self) -> bool:
        return self.encoder_layers == 0

    @property
    def max_attention_window(self) -> int | None:
        """None if any sublayer attends globally (unbounded KV)."""
        widths = []
        for sub in self.pattern:
            if sub.mixer == "attn":
                return None
            if sub.mixer == "attn_local":
                widths.append(self.sliding_window)
        return max(widths) if widths else 0

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic context growth: SSM / hybrid / windowed attention.

        Used for the long_500k applicability rule (DESIGN.md §4).
        """
        return all(sub.mixer != "attn" for sub in self.pattern) or any(
            sub.mixer in ("mamba", "rwkv6") for sub in self.pattern
        ) or self.name.startswith("gemma2")

    def param_count(self) -> int:
        """Analytic parameter count (embedding + stacks), for roofline."""
        d, hd = self.d_model, self.resolved_head_dim
        q = self.num_heads * hd
        kv = self.num_kv_heads * hd
        total = self.vocab_size * d  # embed (tied head)
        if not self.tie_embeddings:
            total += self.vocab_size * d
        def ffn_params(sub: SubLayer) -> int:
            if sub.ffn == "dense":
                return 3 * d * self.d_ff
            if sub.ffn == "moe":
                m = self.moe
                per = 3 * d * m.d_expert
                return (m.num_experts + m.num_shared_experts) * per + d * m.num_experts
            return 0
        def mixer_params(sub: SubLayer) -> int:
            if sub.mixer in ("attn", "attn_local"):
                return d * (q + 2 * kv) + q * d
            if sub.mixer == "mamba":
                m = self.mamba or MambaConfig()
                d_in = m.expand * d
                dt_rank = m.dt_rank or math.ceil(d / 16)
                return (
                    d * 2 * d_in          # in_proj
                    + d_in * m.d_conv     # conv
                    + d_in * (dt_rank + 2 * m.d_state)  # x_proj
                    + dt_rank * d_in      # dt_proj
                    + d_in * m.d_state    # A
                    + d_in                # D
                    + d_in * d            # out_proj
                )
            if sub.mixer == "rwkv6":
                return 4 * d * d + 2 * d * 32  # r,k,v,o + lora decay approx
            return 0
        per_pattern = sum(
            ffn_params(s) + mixer_params(s) + 2 * d for s in self.pattern
        )
        total += per_pattern * self.num_super_layers
        if self.encoder_layers:
            enc = sum(
                ffn_params(s) + mixer_params(s) + 2 * d
                for s in (self.encoder_pattern or (SubLayer(),))
            )
            total += enc * self.encoder_layers // max(
                1, len(self.encoder_pattern or (SubLayer(),))
            )
            if self.cross_attention:
                total += (d * (q + 2 * kv) + q * d) * self.num_layers
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        full = self.param_count()
        per_expert = 3 * self.d_model * m.d_expert
        n_moe_layers = sum(
            1 for s in self.pattern if s.ffn == "moe"
        ) * self.num_super_layers
        inactive = (m.num_experts - m.top_k) * per_expert * n_moe_layers
        return full - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 3e-4
    betas: tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "cosine"      # cosine | wsd | constant
    warmup_steps: int = 100
    decay_steps: int = 10_000
    stable_steps: int = 0         # WSD plateau
    moment_dtype: str = "float32" # bf16 for >100B models (memory)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    seq_len: int = 512
    global_batch: int = 8
    microbatch: int | None = None     # gradient accumulation
    seed: int = 0
    checkpoint_every: int = 50
    keep_checkpoints: int = 3
    log_every: int = 10
    optimizer: OptimizerConfig = OptimizerConfig()
    grad_sync_algorithm: str = "auto"  # paper integration point
    grad_sync_compress_bits: int | None = None
