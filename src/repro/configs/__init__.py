from .base import (
    MambaConfig,
    ModelConfig,
    MoEConfig,
    OptimizerConfig,
    ShapeConfig,
    SubLayer,
    TrainConfig,
    SHAPES,
)
from .archs import ARCHS, get_config, reduced

__all__ = [
    "ARCHS",
    "MambaConfig",
    "ModelConfig",
    "MoEConfig",
    "OptimizerConfig",
    "ShapeConfig",
    "SubLayer",
    "TrainConfig",
    "SHAPES",
    "get_config",
    "reduced",
]
