"""Gradient synchronisation: bucket-scheduled allreduce of a pytree.

Two call styles:

* :func:`sync_grads_local` — used *inside* an existing ``jax.shard_map``
  (the trainer's explicit-collectives path).  Takes per-chip local
  gradients, returns synchronised gradients.
* :func:`make_grad_sync` — standalone: wraps ``sync_grads_local`` in its
  own ``shard_map`` given the gradient PartitionSpecs (tests, benchmarks).

Since PR 3 grad_sync is a *bucket scheduler subsystem*, not a loop over
leaves:

* the **planner** (:func:`repro.core.bucketing.plan_buckets`) packs
  leaves into size-targeted, dtype-pure buckets whose size optimum comes
  from :func:`perf_model.optimal_bucket_bytes` and whose boundaries are
  snapped to the ragged pipeline-chunk grid
  (:func:`napalg.ragged_splits`) — so a fused bucket's MLA chunks align
  with leaf boundaries and per-chip inter-node bytes stay at the
  uneven-block lower bound;
* the **executor** (this module) issues buckets in reverse-leaf order —
  the order backward produces gradients — with each bucket's algorithm
  and pipeline depth pinned by the planner.  The buckets carry no data
  dependencies on each other, so inside SPMD the interleaved issue order
  feeds XLA's latency-hiding scheduler independent collectives it can
  overlap with remaining backward compute (bucket-level async);
* the **simulator** (:func:`repro.core.simulator.simulate_bucketed_sync`)
  replays the same plan with a compute port, so the overlap win is
  measurable as wall-clock.

Dispatch per bucket is the model-driven three-regime switch: NAP below
the modeled NAP↔MLA crossover (``perf_model.crossover_bytes`` for the
actual grid; ``math.inf`` when NAP never loses — the saturated case),
striped MLA above it, chunk-pipelined once
``perf_model.optimal_pipeline_chunks`` says the bucket amortises the
extra latency steps, plain psum when there is no slow domain.

Optional *int8 gradient compression* quantises float leaves with
NAP-pmax-agreed max-abs scales — **per leaf**, even inside a fused
bucket (the per-leaf absmaxes travel as one fused small-vector
max-allreduce, so a layer-norm grad fused next to an embedding grad
keeps its own scale instead of being rounded to zero) — and transports
the sums in the **narrowest integer dtype that cannot overflow**
(``int16`` up to 257-way groups — half the bytes of the f32 payload, a
quarter of the old int32 transport); the planner budgets compressed
leaves at their post-cast width so the regime decision sees the bytes
that actually move.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from . import bucketing, collectives
from .. import compat

__all__ = [
    "GradSyncConfig",
    "sync_grads_local",
    "make_grad_sync",
    "plan_for_tree",
    "compressed_transport_dtype",
]


@dataclasses.dataclass(frozen=True)
class GradSyncConfig:
    """Configuration of the gradient allreduce.

    algorithm: "nap" | "rd" | "smp" | "mla" | "psum" | "ring" |
      "rabenseifner" | "auto" (model-driven three-regime switch).
    mean: divide by the DP group size (data-parallel averaging).  Applies
      to *every* leaf: integer gradients are averaged in float32 and
      rounded back to their dtype rather than silently left as sums.
    compress_bits: None (off) or 8 — quantised transport with a shared
      max-abs scale (float leaves only), summed in the narrowest safe
      integer dtype (:func:`compressed_transport_dtype`).
    small_threshold_bytes: NAP↔MLA dispatch crossover override.  ``None``
      (default) derives it from the §IV cost model
      (:func:`collectives.auto_crossover_bytes`) for the actual grid —
      possibly ``inf`` when NAP never loses (saturated crossover).
    fuse_small_buckets: let the planner fuse same-dtype float leaves into
      shared buckets (False = one bucket per leaf).
    bucket_bytes: fusion bucket size target.  ``None`` (default) takes
      the overlap optimum from :func:`perf_model.optimal_bucket_bytes`;
      an int pins it.
    pipeline_chunks: MLA pipeline depth for bandwidth-regime buckets.
      ``None`` (default) lets the model pick per bucket
      (:func:`perf_model.optimal_pipeline_chunks`); an int pins the
      depth.
    """

    algorithm: str = "auto"
    mean: bool = True
    compress_bits: int | None = None
    small_threshold_bytes: int | None = None
    fuse_small_buckets: bool = True
    bucket_bytes: int | None = None
    pipeline_chunks: int | None = None


# NOTE: the old ``_resolved_threshold`` helper (whose ``isfinite`` guard
# silently accepted ``crossover_bytes``'s former behaviour of returning
# its 4 MiB search cap) is gone with its only caller: the dispatch
# threshold now flows through ``bucketing.plan_buckets`` into
# ``collectives.select_algorithm``, where a saturated (``math.inf``)
# crossover correctly means "latency regime for every payload", and the
# *fusion* bucket target is the separate, always-finite
# :func:`perf_model.optimal_bucket_bytes` optimum.


def compressed_transport_dtype(group: int, bits: int) -> jnp.dtype:
    """Narrowest integer dtype that can hold a ``group``-way sum of
    ``bits``-bit quantised values without overflow.

    Quantised magnitudes are bounded by ``qmax = 2**(bits-1) - 1``, so
    the reduced sum is bounded by ``group * qmax``: int8 suffices only
    for a single rank, int16 up to 257-way groups (257 * 127 = 32639),
    int32 beyond.  Transporting int16 instead of the old int32 halves
    the bytes the "compressed" path actually moves — with int32 an
    8-bit-quantised f32 payload shipped exactly as many bytes as the
    uncompressed one.
    """
    qmax = 2 ** (bits - 1) - 1
    peak = max(1, int(group)) * qmax
    for dt in (jnp.int8, jnp.int16, jnp.int32):
        if peak <= jnp.iinfo(dt).max:
            return jnp.dtype(dt)
    return jnp.dtype(jnp.int64)


def _one_allreduce(x, cfg: GradSyncConfig, inter_axes, intra_axes):
    if not inter_axes:
        # single-level mesh: no slow domain; plain psum over the DP axes.
        return lax.psum(x, intra_axes)
    return collectives.hierarchical_allreduce(
        x,
        inter_axes=inter_axes,
        intra_axes=intra_axes,
        algorithm=cfg.algorithm,
        small_threshold_bytes=cfg.small_threshold_bytes,
        pipeline_chunks=cfg.pipeline_chunks,
    )


def _compressed_allreduce(x, cfg: GradSyncConfig, inter_axes, intra_axes, group):
    """Quantised allreduce with a globally agreed max-abs scale.

    Returns float32; :func:`_reduce_leaf` restores the caller's dtype.
    The quantised payload travels in the narrowest integer dtype safe
    for a ``group``-way sum (:func:`compressed_transport_dtype`), so the
    byte accounting — and the planner's regime decision, which budgets
    compressed leaves at this width — reflects the compression instead
    of shipping int32 words as wide as the original f32 payload.
    """
    bits = cfg.compress_bits
    qmax = float(2 ** (bits - 1) - 1)
    tdtype = compressed_transport_dtype(group, bits)
    # byte accounting: whenever the group-sum bound fits int16, the
    # transport must genuinely be narrower than the f32 it replaces
    # (int32 moved exactly as many bytes as uncompressed f32)
    if int(group) * int(qmax) <= jnp.iinfo(jnp.int16).max:
        assert tdtype.itemsize < jnp.dtype(jnp.float32).itemsize
    absmax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    if inter_axes:
        absmax = collectives.nap_allreduce(
            absmax, inter_axes=inter_axes, intra_axes=intra_axes, op="max"
        )
    else:
        absmax = lax.pmax(absmax, intra_axes)
    scale = jnp.maximum(absmax / qmax, 1e-30)
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(tdtype)
    summed = _one_allreduce(q, cfg, inter_axes, intra_axes)
    return summed.astype(jnp.float32) * scale


def _compressed_fused_allreduce(
    parts, cfg: GradSyncConfig, inter_axes, intra_axes, group
):
    """Quantised allreduce of a fused bucket with *per-leaf* scales.

    One shared max-abs scale across a whole fused bucket would be set by
    its largest-magnitude leaf, rounding a small-magnitude neighbour
    (layer-norm grads next to embedding grads) entirely to zero.  Each
    leaf keeps its own scale instead: the per-leaf absmaxes are agreed
    in a *single* fused small-vector max-allreduce (one latency-bound
    collective, not one per leaf — the paper's canonical workload), the
    quantised leaves are concatenated and summed in one transport-dtype
    allreduce, and each segment is dequantised with its own scale.
    Returns the per-leaf float32 sums, in ``parts`` order.
    """
    bits = cfg.compress_bits
    qmax = float(2 ** (bits - 1) - 1)
    tdtype = compressed_transport_dtype(group, bits)
    if int(group) * int(qmax) <= jnp.iinfo(jnp.int16).max:
        assert tdtype.itemsize < jnp.dtype(jnp.float32).itemsize
    absmax = jnp.stack(
        [jnp.max(jnp.abs(p)).astype(jnp.float32) for p in parts]
    )
    if inter_axes:
        absmax = collectives.nap_allreduce(
            absmax, inter_axes=inter_axes, intra_axes=intra_axes, op="max"
        )
    else:
        absmax = lax.pmax(absmax, intra_axes)
    scales = jnp.maximum(absmax / qmax, 1e-30)
    q = jnp.concatenate(
        [
            jnp.clip(jnp.round(p / scales[i]), -qmax, qmax).astype(tdtype)
            for i, p in enumerate(parts)
        ]
    )
    summed = _one_allreduce(q, cfg, inter_axes, intra_axes)
    outs, off = [], 0
    for i, p in enumerate(parts):
        seg = summed[off : off + p.size].astype(jnp.float32) * scales[i]
        outs.append(seg)
        off += p.size
    return outs


def _reduce_leaf(g, cfg: GradSyncConfig, inter_axes, intra_axes, group):
    """Allreduce one payload with op/mean/dtype semantics in one place.

    Every payload — float, bf16, integer, fused flat bucket — funnels
    through here so the transport dtype, the mean division and the
    round-trip back to the original dtype cannot diverge between code
    paths (they used to: integer leaves skipped ``mean`` silently and
    the compressed path returned hardcoded float32).
    """
    dtype = g.dtype
    is_float = jnp.issubdtype(dtype, jnp.floating)
    if cfg.compress_bits and is_float:
        red = _compressed_allreduce(g, cfg, inter_axes, intra_axes, group)
    else:
        red = _one_allreduce(g, cfg, inter_axes, intra_axes)
    if cfg.mean and group > 1:
        if is_float:
            red = red / group
        else:
            red = jnp.round(red.astype(jnp.float32) / group)
    return red.astype(dtype)


# ---------------------------------------------------------------------------
# planner interface
# ---------------------------------------------------------------------------


def _leaf_specs(leaves, cfg: GradSyncConfig, group: int):
    def transport_itemsize(dt, fusible):
        if cfg.compress_bits and fusible:
            return int(
                compressed_transport_dtype(group, cfg.compress_bits).itemsize
            )
        return None

    return bucketing.leaf_specs_for(
        leaves, transport_itemsize_fn=transport_itemsize
    )


def _plan(leaves, cfg: GradSyncConfig, n: int, ppn: int, group: int):
    threshold = (
        cfg.small_threshold_bytes
        if cfg.small_threshold_bytes is None
        else int(cfg.small_threshold_bytes)
    )
    return bucketing.plan_buckets(
        _leaf_specs(leaves, cfg, group),
        n,
        ppn,
        algorithm=cfg.algorithm,
        small_threshold_bytes=threshold,
        pipeline_chunks=cfg.pipeline_chunks,
        bucket_bytes=cfg.bucket_bytes,
        fuse=cfg.fuse_small_buckets,
    )


def plan_for_tree(
    tree: Any, *, cfg: GradSyncConfig, n: int, ppn: int
) -> bucketing.BucketPlan:
    """Bucket plan for a gradient pytree (arrays or ShapeDtypeStructs).

    Host-side and trace-free: the trainer calls this once on the
    abstract gradient tree (``jax.eval_shape``) to own the per-bucket
    issue points, then hands the plan to :func:`sync_grads_local` so the
    traced program executes exactly the schedule that was planned (and
    that the simulator prices).
    """
    leaves = jax.tree.flatten(tree)[0]
    group = max(1, n) * max(1, ppn)
    return _plan(leaves, cfg, n, ppn, group)


# ---------------------------------------------------------------------------
# executor
# ---------------------------------------------------------------------------


def _bucket_cfg(cfg: GradSyncConfig, bucket) -> GradSyncConfig:
    """The per-bucket config: the planner's decision, pinned.

    ``small_threshold_bytes`` is cleared because the algorithm is already
    resolved — the trace-time dispatcher must not re-decide."""
    return dataclasses.replace(
        cfg,
        algorithm=bucket.algorithm,
        pipeline_chunks=bucket.chunks,
        small_threshold_bytes=None,
    )


def _execute_plan(leaves, plan, cfg, inter_axes, intra_axes, group):
    """Issue every bucket's collective in plan (reverse-leaf) order.

    Buckets are data-independent; issuing them as separate collectives
    in backward-completion order is what lets XLA's latency-hiding
    scheduler overlap bucket ``b``'s transfer with the compute that
    produces bucket ``b+1`` — the in-SPMD form of bucket-level async.
    """
    out = [None] * len(leaves)
    for bucket in plan.buckets:
        bcfg = _bucket_cfg(cfg, bucket)
        if len(bucket.leaves) == 1:
            i = bucket.leaves[0]
            out[i] = _reduce_leaf(
                leaves[i], bcfg, inter_axes, intra_axes, group
            )
            continue
        parts = [leaves[i].reshape(-1) for i in bucket.leaves]
        is_float = jnp.issubdtype(leaves[bucket.leaves[0]].dtype, jnp.floating)
        if cfg.compress_bits and is_float:
            # fused + compressed: per-leaf scales (a shared scale would
            # zero out small-magnitude leaves), mean/dtype per segment
            segs = _compressed_fused_allreduce(
                parts, bcfg, inter_axes, intra_axes, group
            )
            for i, seg in zip(bucket.leaves, segs):
                g = leaves[i]
                if cfg.mean and group > 1:
                    seg = seg / group
                out[i] = seg.reshape(g.shape).astype(g.dtype)
            continue
        flat = jnp.concatenate(parts)
        red = _reduce_leaf(flat, bcfg, inter_axes, intra_axes, group)
        off = 0
        for i in bucket.leaves:
            g = leaves[i]
            out[i] = red[off : off + g.size].reshape(g.shape)
            off += g.size
    return out


def sync_grads_local(
    grads: Any,
    *,
    cfg: GradSyncConfig,
    inter_axes: tuple[str, ...],
    intra_axes: tuple[str, ...],
    plan: bucketing.BucketPlan | None = None,
) -> Any:
    """Synchronise a pytree of per-chip local gradients (inside shard_map).

    ``plan`` (optional) is a precomputed :func:`plan_for_tree` result —
    the trainer's per-bucket issue points.  When omitted, the plan is
    solved here (host-side, cached per pytree signature x grid x config).
    """
    axes = tuple(inter_axes) + tuple(intra_axes)
    group = int(
        np.prod([compat.axis_size(a) for a in axes]) if axes else 1
    )
    leaves, treedef = jax.tree.flatten(grads)
    if not leaves:
        return grads

    if plan is None:
        n = int(
            np.prod([compat.axis_size(a) for a in inter_axes])
            if inter_axes
            else 1
        )
        ppn = int(
            np.prod([compat.axis_size(a) for a in intra_axes])
            if intra_axes
            else 1
        )
        plan = _plan(leaves, cfg, n, ppn, group)
    else:
        sig = tuple(
            (int(np.prod(g.shape)) if g.shape else 1, np.dtype(g.dtype).name)
            for g in leaves
        )
        if sig != plan.signature:
            raise ValueError(
                "bucket plan does not match the gradient pytree "
                f"(plan for {plan.signature}, got {sig})"
            )
    out = _execute_plan(leaves, plan, cfg, inter_axes, intra_axes, group)
    return jax.tree.unflatten(treedef, out)


def make_grad_sync(
    cfg: GradSyncConfig,
    mesh,
    *,
    data_axes: tuple[str, ...],
    grad_specs: Any,
):
    """Standalone grad-sync callable over global arrays.

    ``grad_specs`` is a pytree of PartitionSpecs matching the gradients;
    leaves must not be sharded along ``data_axes`` dims other than the
    stacked per-replica leading dim used in DP.
    """
    from ..launch.mesh import POD_AXIS

    inter = tuple(a for a in data_axes if a == POD_AXIS)
    intra = tuple(a for a in data_axes if a != POD_AXIS)

    def _local(grads):
        return sync_grads_local(
            grads, cfg=cfg, inter_axes=inter, intra_axes=intra
        )

    return compat.shard_map(
        _local, mesh=mesh, in_specs=(grad_specs,), out_specs=grad_specs
    )
