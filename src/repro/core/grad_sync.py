"""Gradient synchronisation: bucket-scheduled collectives of a pytree.

Since PR 4 this module is the *executor* half of the grad-sync subsystem,
driven by the topology-first API (:mod:`repro.core.comm`):

* the **context** (:class:`comm.CommContext` = :class:`comm.Topology` +
  :class:`comm.CommPolicy`) owns the grid shape, the machine model and
  the dispatch policy — no ``(inter_axes, intra_axes, n, ppn, params)``
  keyword soup;
* the **planner** (:func:`repro.core.bucketing.plan_buckets`) packs
  leaves into size-targeted, dtype-pure buckets whose size optimum comes
  from :meth:`comm.Topology.optimal_bucket_bytes` and whose boundaries
  are snapped to the ragged pipeline-chunk grid;
* the **executor** (this module) issues buckets in reverse-leaf order
  with each bucket's engine and pipeline depth pinned by the planner —
  inside SPMD the interleaved issue order feeds XLA's latency-hiding
  scheduler independent collectives (bucket-level async);
* the **simulator** (:func:`repro.core.simulator.simulate_bucketed_sync`)
  replays the same plan with a compute port.

Two sync routes:

* :func:`CommContext.sync_grads` / :func:`sync_grads_local` — replicated
  allreduce sync (every chip gets the full averaged gradients);
* :func:`sync_grads_sharded` — ZeRO-style sharded sync: each leaf is
  reduce-scattered and every chip keeps only its 1-D shard (its
  optimizer partition's slice), halving per-chip inter-node bytes;
  :func:`unshard_grads` allgathers back when needed.

Optional *int8 gradient compression* quantises float leaves with
NAP-pmax-agreed max-abs scales — **per leaf**, even inside a fused
bucket — and transports the sums in the **narrowest integer dtype that
cannot overflow** (:func:`compressed_transport_dtype`; int16 up to
257-way groups).  The planner budgets compressed leaves at their
post-cast width so the regime decision sees the bytes that actually
move.

:class:`GradSyncConfig` is kept as a deprecated alias of
:class:`comm.CommPolicy` (warns once): it still works everywhere, but
new code should build a ``Topology`` + ``CommContext`` instead.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from . import bucketing, collectives, comm
from .. import compat

__all__ = [
    "GradSyncConfig",
    "sync_grads_local",
    "sync_with_context",
    "sync_grads_sharded",
    "unshard_grads",
    "make_grad_sync",
    "plan_for_tree",
    "compressed_transport_dtype",
]


@dataclasses.dataclass(frozen=True)
class GradSyncConfig(comm.CommPolicy):
    """Deprecated alias of :class:`repro.core.comm.CommPolicy`.

    .. deprecated::
        Thin shim kept for existing callers — constructing one warns
        once and behaves exactly like a ``CommPolicy``; the sync entry
        points build a :class:`comm.Topology` + :class:`comm.CommContext`
        from it internally.  New code: ``CommContext(topology,
        CommPolicy(...)).sync_grads(grads)``.

    algorithm: "auto" (model-driven dispatch) or a registered allreduce
      engine — "nap" | "rd" | "smp" | "mla" | "mla_pipelined" | "psum" |
      "ring" | "rabenseifner".  Validated at construction: a typo raises
      immediately with the list of registered engines instead of a bare
      ``KeyError`` deep inside tracing.
    mean: divide by the DP group size (data-parallel averaging).  Applies
      to *every* leaf: integer gradients are averaged in float32 and
      rounded back to their dtype rather than silently left as sums.
    compress_bits: None (off) or 8 — quantised transport with per-leaf
      max-abs scales, summed in the narrowest safe integer dtype
      (:func:`compressed_transport_dtype`).
    small_threshold_bytes: NAP↔MLA dispatch crossover override.  ``None``
      (default) derives it from the §IV cost model for the actual grid —
      possibly ``inf`` when NAP never loses (saturated crossover).
    fuse_small_buckets: let the planner fuse same-dtype float leaves into
      shared buckets (False = one bucket per leaf).
    bucket_bytes: fusion bucket size target.  ``None`` (default) takes
      the overlap optimum from :meth:`comm.Topology.optimal_bucket_bytes`;
      an int pins it.
    pipeline_chunks: MLA pipeline depth for bandwidth-regime buckets.
      ``None`` (default) lets the model pick per bucket; an int pins it.
    """

    def __post_init__(self):
        comm.warn_deprecated_once(
            "grad_sync.GradSyncConfig",
            "comm.CommPolicy with comm.CommContext",
        )
        super().__post_init__()


def compressed_transport_dtype(group: int, bits: int) -> jnp.dtype:
    """Narrowest integer dtype that can hold a ``group``-way sum of
    ``bits``-bit quantised values without overflow.

    Quantised magnitudes are bounded by ``qmax = 2**(bits-1) - 1``, so
    the reduced sum is bounded by ``group * qmax``: int8 suffices only
    for a single rank, int16 up to 257-way groups (257 * 127 = 32639),
    int32 beyond.  Transporting int16 instead of the old int32 halves
    the bytes the "compressed" path actually moves.

    Groups too large even for int32 (> ~16.9M ranks at 8 bits) would
    need int64 — which jax silently degrades to int32 when x64 is
    disabled (the default), re-introducing the exact overflow this
    function exists to prevent.  That case raises ``OverflowError``
    instead of returning a dtype the runtime won't honor; chunk the
    reduction (hierarchical partial sums) or enable ``jax_enable_x64``.
    """
    qmax = 2 ** (bits - 1) - 1
    peak = max(1, int(group)) * qmax
    for dt in (jnp.int8, jnp.int16, jnp.int32):
        if peak <= jnp.iinfo(dt).max:
            return jnp.dtype(dt)
    if not jax.config.jax_enable_x64:
        raise OverflowError(
            f"a {group}-way sum of {bits}-bit quantised values overflows "
            "int32, and jax x64 is disabled so an int64 transport would "
            "silently degrade to int32 — re-introducing the overflow. "
            "Chunk the reduction into sub-groups or enable "
            "jax.config.jax_enable_x64."
        )
    return jnp.dtype(jnp.int64)


# ---------------------------------------------------------------------------
# per-payload reduction primitives (context-driven)
# ---------------------------------------------------------------------------


def _one_allreduce(x, ctx: comm.CommContext):
    topo = ctx.topology
    if not topo.inter_axes:
        # single-level mesh: no slow domain; plain psum over the DP axes.
        return lax.psum(x, topo.intra_axes)
    return ctx.allreduce(x)


def _agreed_absmax(parts, ctx: comm.CommContext):
    """Per-part max-abs scales agreed across the group in ONE fused
    small-vector max-allreduce (the paper's canonical latency-bound
    workload) — never one collective per leaf."""
    topo = ctx.topology
    absmax = jnp.stack(
        [jnp.max(jnp.abs(p)).astype(jnp.float32) for p in parts]
    )
    if topo.inter_axes:
        return collectives.nap_allreduce(
            absmax,
            inter_axes=topo.inter_axes,
            intra_axes=topo.intra_axes,
            op="max",
        )
    return lax.pmax(absmax, topo.intra_axes)


def _compressed_fused_allreduce(parts, ctx: comm.CommContext, group):
    """Quantised allreduce of one or more fused parts with *per-leaf*
    scales.

    One shared max-abs scale across a whole fused bucket would be set by
    its largest-magnitude leaf, rounding a small-magnitude neighbour
    (layer-norm grads next to embedding grads) entirely to zero.  Each
    leaf keeps its own scale: the per-leaf absmaxes travel as one fused
    max-allreduce, the quantised leaves are concatenated and summed in
    one transport-dtype allreduce, and each segment is dequantised with
    its own scale.  Returns the per-leaf float32 sums, in ``parts``
    order.
    """
    bits = ctx.policy.compress_bits
    qmax = float(2 ** (bits - 1) - 1)
    tdtype = compressed_transport_dtype(group, bits)
    # byte accounting: whenever the group-sum bound fits int16, the
    # transport must genuinely be narrower than the f32 it replaces
    # (int32 moved exactly as many bytes as uncompressed f32)
    if int(group) * int(qmax) <= jnp.iinfo(jnp.int16).max:
        assert tdtype.itemsize < jnp.dtype(jnp.float32).itemsize
    scales = jnp.maximum(_agreed_absmax(parts, ctx) / qmax, 1e-30)
    q = jnp.concatenate(
        [
            jnp.clip(jnp.round(p / scales[i]), -qmax, qmax).astype(tdtype)
            for i, p in enumerate(parts)
        ]
    )
    summed = _one_allreduce(q, ctx)
    outs, off = [], 0
    for i, p in enumerate(parts):
        seg = summed[off : off + p.size].astype(jnp.float32) * scales[i]
        outs.append(seg)
        off += p.size
    return outs


def _compressed_allreduce(x, ctx: comm.CommContext, group):
    """Single-leaf quantised allreduce (float32 out; caller re-dtypes)."""
    return _compressed_fused_allreduce([x.reshape(-1)], ctx, group)[0].reshape(
        x.shape
    )


def _reduce_leaf(g, ctx: comm.CommContext, group):
    """Allreduce one payload with op/mean/dtype semantics in one place.

    Every payload — float, bf16, integer, fused flat bucket — funnels
    through here so the transport dtype, the mean division and the
    round-trip back to the original dtype cannot diverge between code
    paths.
    """
    dtype = g.dtype
    is_float = jnp.issubdtype(dtype, jnp.floating)
    if ctx.policy.compress_bits and is_float:
        red = _compressed_allreduce(g, ctx, group)
    else:
        red = _one_allreduce(g, ctx)
    if ctx.policy.mean and group > 1:
        if is_float:
            red = red / group
        else:
            red = jnp.round(red.astype(jnp.float32) / group)
    return red.astype(dtype)


# ---------------------------------------------------------------------------
# planner interface
# ---------------------------------------------------------------------------


def _leaf_specs(leaves, policy: comm.CommPolicy, group: int):
    def transport_itemsize(dt, fusible):
        if policy.compress_bits and fusible:
            return int(
                compressed_transport_dtype(
                    group, policy.compress_bits
                ).itemsize
            )
        return None

    return bucketing.leaf_specs_for(
        leaves, transport_itemsize_fn=transport_itemsize
    )


def _plan(leaves, policy: comm.CommPolicy, topology: comm.Topology):
    threshold = (
        policy.small_threshold_bytes
        if policy.small_threshold_bytes is None
        else int(policy.small_threshold_bytes)
    )
    return bucketing.plan_buckets(
        _leaf_specs(leaves, policy, topology.group),
        topology,
        algorithm=policy.algorithm,
        small_threshold_bytes=threshold,
        pipeline_chunks=policy.pipeline_chunks,
        bucket_bytes=policy.bucket_bytes,
        fuse=policy.fuse_small_buckets,
    )


def plan_for_tree(
    tree: Any,
    *,
    cfg: comm.CommPolicy,
    n: int | None = None,
    ppn: int | None = None,
    topology: comm.Topology | None = None,
) -> bucketing.BucketPlan:
    """Bucket plan for a gradient pytree (arrays or ShapeDtypeStructs).

    Host-side and trace-free: the trainer calls this once on the
    abstract gradient tree (``jax.eval_shape``) to own the per-bucket
    issue points, then hands the plan to the executor so the traced
    program executes exactly the schedule that was planned (and that the
    simulator prices).  Pass a :class:`comm.Topology` (preferred) or the
    legacy ``(n, ppn)`` pair.
    """
    if topology is None:
        topology = comm.Topology.of(int(n or 1), int(ppn or 1))
    leaves = jax.tree.flatten(tree)[0]
    return _plan(leaves, cfg, topology)


# ---------------------------------------------------------------------------
# executor
# ---------------------------------------------------------------------------


def _bucket_ctx(ctx: comm.CommContext, bucket) -> comm.CommContext:
    """The per-bucket context: the planner's decision, pinned.

    ``small_threshold_bytes`` is cleared because the engine is already
    resolved — the trace-time dispatcher must not re-decide."""
    return comm.CommContext(
        ctx.topology,
        dataclasses.replace(
            ctx.policy,
            algorithm=bucket.algorithm,
            pipeline_chunks=bucket.chunks,
            small_threshold_bytes=None,
        ),
    )


def _execute_plan(leaves, plan, ctx: comm.CommContext):
    """Issue every bucket's collective in plan (reverse-leaf) order.

    Buckets are data-independent; issuing them as separate collectives
    in backward-completion order is what lets XLA's latency-hiding
    scheduler overlap bucket ``b``'s transfer with the compute that
    produces bucket ``b+1`` — the in-SPMD form of bucket-level async.
    """
    group = ctx.topology.group
    out = [None] * len(leaves)
    for bucket in plan.buckets:
        bctx = _bucket_ctx(ctx, bucket)
        if len(bucket.leaves) == 1:
            i = bucket.leaves[0]
            out[i] = _reduce_leaf(leaves[i], bctx, group)
            continue
        parts = [leaves[i].reshape(-1) for i in bucket.leaves]
        is_float = jnp.issubdtype(leaves[bucket.leaves[0]].dtype, jnp.floating)
        if ctx.policy.compress_bits and is_float:
            # fused + compressed: per-leaf scales (a shared scale would
            # zero out small-magnitude leaves), mean/dtype per segment
            segs = _compressed_fused_allreduce(parts, bctx, group)
            for i, seg in zip(bucket.leaves, segs):
                g = leaves[i]
                if ctx.policy.mean and group > 1:
                    seg = seg / group
                out[i] = seg.reshape(g.shape).astype(g.dtype)
            continue
        flat = jnp.concatenate(parts)
        red = _reduce_leaf(flat, bctx, group)
        off = 0
        for i in bucket.leaves:
            g = leaves[i]
            out[i] = red[off : off + g.size].reshape(g.shape)
            off += g.size
    return out


def sync_with_context(
    grads: Any,
    ctx: comm.CommContext,
    *,
    plan: bucketing.BucketPlan | None = None,
) -> Any:
    """Bucket-scheduled allreduce sync under a :class:`comm.CommContext`
    (the canonical entry — :meth:`comm.CommContext.sync_grads`).

    ``plan`` (optional) is a precomputed :func:`plan_for_tree` result —
    the trainer's per-bucket issue points.  When omitted, the plan is
    solved here (host-side, cached per pytree signature x topology x
    policy).
    """
    ctx.topology.require_axes()
    leaves, treedef = jax.tree.flatten(grads)
    if not leaves:
        return grads
    if plan is None:
        plan = _plan(leaves, ctx.policy, ctx.topology)
    else:
        sig = tuple(
            (int(np.prod(g.shape)) if g.shape else 1, np.dtype(g.dtype).name)
            for g in leaves
        )
        if sig != plan.signature:
            raise ValueError(
                "bucket plan does not match the gradient pytree "
                f"(plan for {plan.signature}, got {sig})"
            )
    out = _execute_plan(leaves, plan, ctx)
    return jax.tree.unflatten(treedef, out)


def sync_grads_local(
    grads: Any,
    *,
    cfg: comm.CommPolicy,
    inter_axes: tuple[str, ...],
    intra_axes: tuple[str, ...],
    plan: bucketing.BucketPlan | None = None,
) -> Any:
    """Synchronise a pytree of per-chip local gradients (inside shard_map).

    Axis-names entry point: builds a :class:`comm.Topology` from the
    named mesh axes (sizes resolved from the traced context) and a
    :class:`comm.CommContext` from ``cfg``, then runs
    :func:`sync_with_context`.
    """
    ctx = comm.CommContext(
        comm.Topology.from_axes(inter_axes, intra_axes), cfg
    )
    return sync_with_context(grads, ctx, plan=plan)


def sync_grads_sharded(
    grads: Any, *, ctx: comm.CommContext
) -> Any:
    """ZeRO-style sharded gradient sync (inside shard_map).

    Every leaf is *reduce-scattered* instead of allreduced: each chip
    keeps only its 1-D shard of the reduced (optionally averaged)
    gradient — the slice its optimizer partition owns — so per-chip
    inter-node bytes are half the allreduce round trip and the full
    gradient never materialises per chip.  Returns a pytree of 1-D
    shards (leaf ``i``'s shard has ``ceil(ceil(n_i/ppn)/n)`` elements,
    the MLA stripe-block layout); :func:`unshard_grads` inverts.

    Compression is not supported on this route (quantised shards would
    need their scales re-agreed post-scatter); configure
    ``compress_bits=None``.
    """
    if ctx.policy.compress_bits:
        raise NotImplementedError(
            "sharded (reduce-scatter) grad sync does not support "
            "compressed transport; use the allreduce route or set "
            "compress_bits=None"
        )
    ctx.topology.require_axes()
    group = ctx.topology.group
    leaves, treedef = jax.tree.flatten(grads)
    out = []
    for g in leaves:
        dtype = g.dtype
        is_float = jnp.issubdtype(dtype, jnp.floating)
        red = ctx.reduce_scatter(g.reshape(-1), op="sum")
        if ctx.policy.mean and group > 1:
            if is_float:
                red = red / group
            else:
                red = jnp.round(red.astype(jnp.float32) / group)
        out.append(red.astype(dtype))
    return jax.tree.unflatten(treedef, out)


def unshard_grads(shards: Any, like: Any, *, ctx: comm.CommContext) -> Any:
    """Allgather a :func:`sync_grads_sharded` result back to full leaves.

    ``like`` is a pytree of arrays or ShapeDtypeStructs giving the
    original leaf shapes (the padding stripped per leaf).
    """
    shard_leaves, treedef = jax.tree.flatten(shards)
    like_leaves = jax.tree.flatten(like)[0]
    out = []
    for s, g in zip(shard_leaves, like_leaves):
        elems = int(np.prod(g.shape)) if g.shape else 1
        full = ctx.allgather(s, elems=elems)
        out.append(full.reshape(g.shape).astype(g.dtype))
    return jax.tree.unflatten(treedef, out)


def make_grad_sync(
    cfg: comm.CommPolicy,
    mesh,
    *,
    data_axes: tuple[str, ...],
    grad_specs: Any,
):
    """Standalone grad-sync callable over global arrays.

    ``grad_specs`` is a pytree of PartitionSpecs matching the gradients;
    leaves must not be sharded along ``data_axes`` dims other than the
    stacked per-replica leading dim used in DP.
    """
    from ..launch.mesh import POD_AXIS

    inter = tuple(a for a in data_axes if a == POD_AXIS)
    intra = tuple(a for a in data_axes if a != POD_AXIS)

    def _local(grads):
        return sync_grads_local(
            grads, cfg=cfg, inter_axes=inter, intra_axes=intra
        )

    return compat.shard_map(
        _local, mesh=mesh, in_specs=(grad_specs,), out_specs=grad_specs
    )
