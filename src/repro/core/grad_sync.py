"""Gradient synchronisation: bucket-scheduled collectives of a pytree.

Since PR 4 this module is the *executor* half of the grad-sync subsystem,
driven by the topology-first API (:mod:`repro.core.comm`):

* the **context** (:class:`comm.CommContext` = :class:`comm.Topology` +
  :class:`comm.CommPolicy`) owns the grid shape, the machine model and
  the dispatch policy — no ``(inter_axes, intra_axes, n, ppn, params)``
  keyword soup;
* the **planner** (:func:`repro.core.bucketing.plan_buckets`) packs
  leaves into size-targeted, dtype-pure buckets whose size optimum comes
  from :meth:`comm.Topology.optimal_bucket_bytes` and whose boundaries
  are snapped to the ragged pipeline-chunk grid;
* the **executor** (this module) issues buckets in reverse-leaf order
  with each bucket's engine and pipeline depth pinned by the planner —
  inside SPMD the interleaved issue order feeds XLA's latency-hiding
  scheduler independent collectives (bucket-level async);
* the **simulator** (:func:`repro.core.simulator.simulate_bucketed_sync`)
  replays the same plan with a compute port.

Two sync routes:

* :func:`CommContext.sync_grads` / :func:`sync_grads_local` — replicated
  allreduce sync (every chip gets the full averaged gradients);
* :func:`sync_grads_sharded` — ZeRO-style sharded sync: each leaf is
  reduce-scattered and every chip keeps only its 1-D shard (its
  optimizer partition's slice), halving per-chip inter-node bytes;
  :func:`unshard_grads` allgathers back when needed.

Optional *quantised gradient compression* (``compress_bits=8`` → int8
wire, ``compress_bits=4`` → two int4 nibbles packed per byte) runs on
the fused Pallas transport kernels
(:mod:`repro.kernels.transport`): per-leaf max-abs scales are agreed in
one NAP-pmax collective, then each transport hop is **one
quantize-pack kernel pass** writing wire bytes directly in stripe
layout.  The collective shape is a node-aware two-level exchange —
exact f32 intra-node ``psum_scatter`` pre-combine, packed inter-node
``all_to_all`` + local fold (the RS half), requantize at the group
bound, packed inter-node ``all_gather`` + unpack (the AG half), intra
``all_gather`` — so per-chip inter-node bytes are
``~2 * (s * bits/8 / ppn) * (n-1)/n``: 1/4 of uncompressed f32 at 8
bits, 1/8 at packed 4 bits.  The planner budgets compressed leaves at
the *packed* width (``bits/8`` bytes/elem) so the regime decision sees
the bytes that actually move, and **error-feedback residuals**
(:mod:`repro.optim.error_feedback`, threaded via
``sync_with_context(..., ef_state=...)``) carry each chip's
quantization error into its next step so 4-bit transport converges.
:func:`compressed_transport_dtype` remains the overflow-safe
*accumulator* width for summing quantised values outside the packed
engine (analysis + legacy callers).

:class:`GradSyncConfig` is kept as a deprecated alias of
:class:`comm.CommPolicy` (warns once): it still works everywhere, but
new code should build a ``Topology`` + ``CommContext`` instead.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from . import bucketing, collectives, comm
from .. import compat
from ..kernels import transport

__all__ = [
    "GradSyncConfig",
    "sync_grads_local",
    "sync_with_context",
    "sync_grads_sharded",
    "unshard_grads",
    "make_grad_sync",
    "plan_for_tree",
    "compressed_transport_dtype",
]


@dataclasses.dataclass(frozen=True)
class GradSyncConfig(comm.CommPolicy):
    """Deprecated alias of :class:`repro.core.comm.CommPolicy`.

    .. deprecated::
        Thin shim kept for existing callers — constructing one warns
        once and behaves exactly like a ``CommPolicy``; the sync entry
        points build a :class:`comm.Topology` + :class:`comm.CommContext`
        from it internally.  New code: ``CommContext(topology,
        CommPolicy(...)).sync_grads(grads)``.

    algorithm: "auto" (model-driven dispatch) or a registered allreduce
      engine — "nap" | "rd" | "smp" | "mla" | "mla_pipelined" | "psum" |
      "ring" | "rabenseifner".  Validated at construction: a typo raises
      immediately with the list of registered engines instead of a bare
      ``KeyError`` deep inside tracing.
    mean: divide by the DP group size (data-parallel averaging).  Applies
      to *every* leaf: integer gradients are averaged in float32 and
      rounded back to their dtype rather than silently left as sums.
    compress_bits: None (off) or 2..8 — quantised transport on the fused
      Pallas kernels with per-leaf max-abs scales; 8 moves int8 wire
      bytes (1/4 of f32), 4 packs two nibbles per byte (1/8).
    small_threshold_bytes: NAP↔MLA dispatch crossover override.  ``None``
      (default) derives it from the §IV cost model for the actual grid —
      possibly ``inf`` when NAP never loses (saturated crossover).
    fuse_small_buckets: let the planner fuse same-dtype float leaves into
      shared buckets (False = one bucket per leaf).
    bucket_bytes: fusion bucket size target.  ``None`` (default) takes
      the overlap optimum from :meth:`comm.Topology.optimal_bucket_bytes`;
      an int pins it.
    pipeline_chunks: MLA pipeline depth for bandwidth-regime buckets.
      ``None`` (default) lets the model pick per bucket; an int pins it.
    """

    def __post_init__(self):
        comm.warn_deprecated_once(
            "grad_sync.GradSyncConfig",
            "comm.CommPolicy with comm.CommContext",
        )
        super().__post_init__()


def compressed_transport_dtype(group: int, bits: int) -> jnp.dtype:
    """Narrowest integer dtype that can hold a ``group``-way sum of
    ``bits``-bit quantised values without overflow.

    Quantised magnitudes are bounded by ``qmax = 2**(bits-1) - 1``, so
    the reduced sum is bounded by ``group * qmax``: int8 suffices only
    for a single rank, int16 up to 257-way groups (257 * 127 = 32639),
    int32 beyond.  Transporting int16 instead of the old int32 halves
    the bytes the "compressed" path actually moves.

    Groups too large even for int32 (> ~16.9M ranks at 8 bits) would
    need int64 — which jax silently degrades to int32 when x64 is
    disabled (the default), re-introducing the exact overflow this
    function exists to prevent.  That case raises ``OverflowError``
    instead of returning a dtype the runtime won't honor; chunk the
    reduction (hierarchical partial sums) or enable ``jax_enable_x64``.
    """
    qmax = 2 ** (bits - 1) - 1
    peak = max(1, int(group)) * qmax
    for dt in (jnp.int8, jnp.int16, jnp.int32):
        if peak <= jnp.iinfo(dt).max:
            return jnp.dtype(dt)
    if not jax.config.jax_enable_x64:
        raise OverflowError(
            f"a {group}-way sum of {bits}-bit quantised values overflows "
            "int32, and jax x64 is disabled so an int64 transport would "
            "silently degrade to int32 — re-introducing the overflow. "
            "Chunk the reduction into sub-groups or enable "
            "jax.config.jax_enable_x64."
        )
    return jnp.dtype(jnp.int64)


# ---------------------------------------------------------------------------
# per-payload reduction primitives (context-driven)
# ---------------------------------------------------------------------------


def _one_allreduce(x, ctx: comm.CommContext):
    topo = ctx.topology
    if not topo.inter_axes:
        # single-level mesh: no slow domain; plain psum over the DP axes.
        return lax.psum(x, topo.intra_axes)
    return ctx.allreduce(x)


def _agreed_absmax(parts, ctx: comm.CommContext):
    """Per-part max-abs scales agreed across the group in ONE fused
    small-vector max-allreduce (the paper's canonical latency-bound
    workload) — never one collective per leaf."""
    topo = ctx.topology
    absmax = jnp.stack(
        [jnp.max(jnp.abs(p)).astype(jnp.float32) for p in parts]
    )
    if topo.inter_axes:
        return collectives.nap_allreduce(
            absmax,
            inter_axes=topo.inter_axes,
            intra_axes=topo.intra_axes,
            op="max",
        )
    return lax.pmax(absmax, topo.intra_axes)


def _wire_split(topo: comm.Topology):
    """(pre_axes, wire_axes, pre, g): the f32 pre-combine domain and the
    packed-wire exchange domain of the compressed transport.

    With a slow domain the node is the pre-combine (exact f32
    ``psum_scatter`` over ``ppn`` lanes) and the wire crosses nodes —
    anything else would move ``ppn``× more inter-node bytes than the
    node-aware shape.  Degenerate grids collapse a level:
    single-lane nodes wire over ``inter`` alone, single-node meshes wire
    over ``intra``.  Always ``pre * g == group``.
    """
    if topo.n_nodes > 1 and topo.ppn > 1:
        return topo.intra_axes, topo.inter_axes, topo.ppn, topo.n_nodes
    if topo.n_nodes > 1:
        return (), topo.inter_axes, 1, topo.n_nodes
    return (), topo.intra_axes, 1, topo.ppn


def _flat_index(axes) -> jax.Array:
    idx = jnp.zeros((), jnp.int32)
    for ax in axes:
        idx = idx * compat.axis_size(ax) + lax.axis_index(ax)
    return idx


def _leaf_offsets(parts) -> tuple[int, ...]:
    offs, off = [], 0
    for p in parts:
        offs.append(off)
        off += int(p.size)
    return tuple(offs)


def _wire_scale(x, offsets, sizes, base, wire_axes, qmax):
    """Agreed (L,) per-leaf wire scale for the window ``[base, base+|x|)``
    of the fused flat payload: masked absmax of ``x`` per leaf, maxed
    over the wire group (every peer quantizes/dequantizes the same hop
    with the same scales), divided by ``qmax``.  Leaves outside the
    window get the 1e-30 floor — they carry no data on this hop."""
    idx = base + jnp.arange(int(x.size), dtype=jnp.int32)
    ax = jnp.abs(x.reshape(-1))
    m = jnp.stack([
        jnp.max(jnp.where((idx >= o) & (idx < o + n), ax, 0.0))
        for o, n in zip(offsets, sizes)
    ])
    if wire_axes:
        m = lax.pmax(m, wire_axes)
    return jnp.maximum(m / qmax, 1e-30)


def _compressed_fused_allreduce(
    parts, ctx: comm.CommContext, group, with_err=False
):
    """Quantised allreduce of one or more fused parts with *per-leaf*
    scales, on the fused Pallas transport kernels.

    One shared max-abs scale across a whole fused bucket would be set by
    its largest-magnitude leaf, rounding a small-magnitude neighbour
    (layer-norm grads next to embedding grads) entirely to zero.  Each
    leaf keeps its own scale: the per-leaf absmaxes travel as one fused
    max-allreduce, and every transport hop quantizes/unpacks all leaf
    segments in a single kernel pass (leaf boundaries are static index
    maps, not per-leaf launches).  Two-level shape — see the module
    docstring; ``pallas_call`` count per bucket is exactly 4 regardless
    of how many leaves the bucket fuses (quantize-stripe, unpack on
    receive, requantize at the group bound, unpack after allgather).

    Scale plumbing (``qmax = 2**(bits-1)-1``): each hop quantizes at the
    *measured* per-leaf absmax of what actually goes on the wire — the
    post-pre-combine stripe for hop 1, the RS-half fold for hop 2 —
    agreed across the wire group as one fused (L,) ``pmax`` per hop.
    The analytic bounds (stripe ≤ ``pre*A``, fold ≤ ``group*A`` with
    ``A`` the leaf absmax) hold but are worst-case by the full fan-in;
    quantizing at them would burn ~``log2(group)`` of the wire's
    ``bits`` on headroom real sums never use.  Total absolute error
    stays ≤ ``group*A/qmax`` (measured scales only shrink it).

    With ``with_err=True`` the call also returns the chip's share of the
    rounding error, *measured at the two compression points*: the hop-1
    error ``stripe - dequant(Q(stripe))`` on the chip's own stripe and
    the hop-2 error ``blk - dequant(Q(blk))`` on the block it owns.
    Every coordinate's total error is split across the group with each
    piece held by exactly one chip (stripe owner per node + one block
    owner), so re-injecting it into next step's input (``c = g + r``)
    compensates the full quantisation error — this is exact distributed
    error feedback, not a per-chip model of it.  The error is computed
    with the pure-jnp reference path (``impl="xla"``) so EF adds zero
    ``pallas_call`` sites: the fused count stays 4 per bucket.

    Returns ``(outs, scales, err)``: per-leaf float32 *sums* in
    ``parts`` order, the (L,) hop-1 wire scales, and the flat (E,)
    per-chip error (``None`` unless ``with_err``).
    """
    bits = ctx.policy.compress_bits
    qmax = float(2 ** (bits - 1) - 1)
    offsets = _leaf_offsets(parts)
    flat = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
    flat = flat.astype(jnp.float32)
    E = int(flat.size)

    def split(full):
        outs = []
        for i, p in enumerate(parts):
            outs.append(full[offsets[i] : offsets[i] + p.size])
        return outs

    sizes = tuple(int(p.size) for p in parts)

    if group <= 1:
        # single chip: no wire — but keep the quantize round trip so the
        # compression semantics (and EF residuals) match any grid size
        scales = _wire_scale(flat, offsets, sizes, 0, (), qmax)
        w = transport.quantize_pack(
            flat.reshape(1, E), scales, offsets=offsets, bits=bits,
            donate_input=not with_err,
        )
        full = transport.unpack_dequantize(
            w, scales, offsets=offsets, bits=bits, cols=E,
            donate_input=True,
        ).reshape(-1)
        return split(full), scales, (flat - full if with_err else None)

    pre_axes, wire_axes, pre, g = _wire_split(ctx.topology)
    # ---- level 1: exact f32 pre-combine, striping the payload ----------
    if pre > 1:
        S = -(-E // pre)
        if pre * S != E:
            flat = jnp.concatenate(
                [flat, jnp.zeros((pre * S - E,), jnp.float32)]
            )
        stripe = lax.psum_scatter(
            flat.reshape(pre, S), pre_axes, scatter_dimension=0, tiled=False
        )
        base_stripe = _flat_index(pre_axes) * S
    else:
        S = E
        stripe = flat
        base_stripe = jnp.zeros((), jnp.int32)
    # ---- one-pass quantize-pack of the stripe into g wire blocks -------
    B = -(-S // g)
    if g * B != S:
        stripe = jnp.concatenate(
            [stripe, jnp.zeros((g * B - S,), jnp.float32)]
        )
    s1 = _wire_scale(stripe, offsets, sizes, base_stripe, wire_axes, qmax)
    # the stripe buffer is only donated when EF is off — the error path
    # re-reads it after the call (the lint's alias-donation rule proves
    # this statically)
    w = transport.quantize_pack(
        stripe.reshape(g, B), s1, offsets=offsets, bits=bits,
        base=base_stripe, row_stride=B, donate_input=not with_err,
    )
    # ---- RS half: packed all_to_all; every row lands on the same block
    # window (base + t*B, row_stride=0), unpack + exact f32 fold --------
    recv = lax.all_to_all(
        w[:, None, :], wire_axes, split_axis=0, concat_axis=1, tiled=False
    )[0]
    block_base = base_stripe + _flat_index(wire_axes) * B
    blk = jnp.sum(
        transport.unpack_dequantize(
            recv, s1, offsets=offsets, bits=bits, cols=B,
            base=block_base, row_stride=0, donate_input=True,
        ),
        axis=0,
    )
    # ---- requantize the reduced fold at its measured bound; AG half ----
    s2 = _wire_scale(blk, offsets, sizes, block_base, wire_axes, qmax)
    w2 = transport.quantize_pack(
        blk.reshape(1, B), s2, offsets=offsets, bits=bits,
        base=block_base, row_stride=0, donate_input=not with_err,
    )
    gathered = lax.all_gather(w2[0], wire_axes, axis=0, tiled=False)
    stripe_sum = transport.unpack_dequantize(
        gathered, s2, offsets=offsets, bits=bits, cols=B,
        base=base_stripe, row_stride=B, donate_input=True,
    ).reshape(-1)[:S]
    # ---- level 1 inverse: rebuild the flat sum inside the node ---------
    if pre > 1:
        full = lax.all_gather(
            stripe_sum, pre_axes, axis=0, tiled=False
        ).reshape(-1)
    else:
        full = stripe_sum
    err = None
    if with_err:
        # this chip's share of the rounding error (see docstring): the
        # stripe it quantised on hop 1 and the block it requantised on
        # hop 2 (the block lies inside the stripe, so the two add).
        # Pure-jnp decode — no extra pallas_call sites under EF.
        vhat = transport.unpack_dequantize(
            w, s1, offsets=offsets, bits=bits, cols=B,
            base=base_stripe, row_stride=B, impl="xla",
        ).reshape(-1)
        e1 = (stripe - vhat)[:S]
        blkhat = transport.unpack_dequantize(
            w2, s2, offsets=offsets, bits=bits, cols=B,
            base=block_base, row_stride=0, impl="xla",
        )[0]
        # padded scratch: the last stripe's block window may run past
        # pre*S (block g*B > S); the overhang is all-zero padding
        P = (pre - 1) * S + g * B
        err = lax.dynamic_update_slice(
            jnp.zeros((P,), jnp.float32), e1, (base_stripe,)
        )
        cur = lax.dynamic_slice(err, (block_base,), (B,))
        err = lax.dynamic_update_slice(
            err, cur + (blk - blkhat), (block_base,)
        )[:E]
    return split(full[:E]), s1, err


def _compressed_allreduce(x, ctx: comm.CommContext, group):
    """Single-leaf quantised allreduce (float32 out; caller re-dtypes)."""
    outs, _, _ = _compressed_fused_allreduce([x.reshape(-1)], ctx, group)
    return outs[0].reshape(x.shape)


def _reduce_leaf(g, ctx: comm.CommContext, group):
    """Allreduce one payload with op/mean/dtype semantics in one place.

    Every payload — float, bf16, integer, fused flat bucket — funnels
    through here so the transport dtype, the mean division and the
    round-trip back to the original dtype cannot diverge between code
    paths.
    """
    dtype = g.dtype
    is_float = jnp.issubdtype(dtype, jnp.floating)
    if ctx.policy.compress_bits and is_float:
        red = _compressed_allreduce(g, ctx, group)
    else:
        red = _one_allreduce(g, ctx)
    if ctx.policy.mean and group > 1:
        if is_float:
            red = red / group
        else:
            red = jnp.round(red.astype(jnp.float32) / group)
    return red.astype(dtype)


# ---------------------------------------------------------------------------
# planner interface
# ---------------------------------------------------------------------------


def _leaf_specs(leaves, policy: comm.CommPolicy, group: int):
    def transport_itemsize(dt, fusible):
        if policy.compress_bits and fusible:
            # the *packed* wire width (0.5 B/elem at 4 bits, 1 B at 8):
            # the planner must budget the bytes the fused kernels move
            return transport.wire_itemsize(policy.compress_bits)
        return None

    return bucketing.leaf_specs_for(
        leaves, transport_itemsize_fn=transport_itemsize
    )


def _plan(leaves, policy: comm.CommPolicy, topology: comm.Topology):
    threshold = (
        policy.small_threshold_bytes
        if policy.small_threshold_bytes is None
        else int(policy.small_threshold_bytes)
    )
    return bucketing.plan_buckets(
        _leaf_specs(leaves, policy, topology.group),
        topology,
        algorithm=policy.algorithm,
        small_threshold_bytes=threshold,
        pipeline_chunks=policy.pipeline_chunks,
        bucket_bytes=policy.bucket_bytes,
        fuse=policy.fuse_small_buckets,
    )


def plan_for_tree(
    tree: Any,
    *,
    cfg: comm.CommPolicy,
    n: int | None = None,
    ppn: int | None = None,
    topology: comm.Topology | None = None,
) -> bucketing.BucketPlan:
    """Bucket plan for a gradient pytree (arrays or ShapeDtypeStructs).

    Host-side and trace-free: the trainer calls this once on the
    abstract gradient tree (``jax.eval_shape``) to own the per-bucket
    issue points, then hands the plan to the executor so the traced
    program executes exactly the schedule that was planned (and that the
    simulator prices).  Pass a :class:`comm.Topology` (preferred) or the
    legacy ``(n, ppn)`` pair.
    """
    if topology is None:
        topology = comm.Topology.of(int(n or 1), int(ppn or 1))
    leaves = jax.tree.flatten(tree)[0]
    return _plan(leaves, cfg, topology)


# ---------------------------------------------------------------------------
# executor
# ---------------------------------------------------------------------------


def _bucket_ctx(ctx: comm.CommContext, bucket) -> comm.CommContext:
    """The per-bucket context: the planner's decision, pinned.

    ``small_threshold_bytes`` is cleared because the engine is already
    resolved — the trace-time dispatcher must not re-decide."""
    return comm.CommContext(
        ctx.topology,
        dataclasses.replace(
            ctx.policy,
            algorithm=bucket.algorithm,
            pipeline_chunks=bucket.chunks,
            small_threshold_bytes=None,
        ),
    )


def _execute_plan(leaves, plan, ctx: comm.CommContext, ef=None):
    """Issue every bucket's collective in plan (reverse-leaf) order.

    Buckets are data-independent; issuing them as separate collectives
    in backward-completion order is what lets XLA's latency-hiding
    scheduler overlap bucket ``b``'s transfer with the compute that
    produces bucket ``b+1`` — the in-SPMD form of bucket-level async.

    ``ef`` (optional) is the flat list of per-chip error-feedback
    residuals: compressed float buckets sync ``c = g + r`` and each
    chip's new residual is its exact share of the transport's rounding
    error (see :func:`_compressed_fused_allreduce` — measured at the
    compression points, not modelled per chip); every other leaf's
    residual passes through untouched.  Returns ``(out, new_ef)``.
    """
    group = ctx.topology.group
    bits = ctx.policy.compress_bits
    out = [None] * len(leaves)
    new_ef = None if ef is None else list(ef)
    for bucket in plan.buckets:
        bctx = _bucket_ctx(ctx, bucket)
        idxs = bucket.leaves
        is_float = jnp.issubdtype(leaves[idxs[0]].dtype, jnp.floating)
        if bits and is_float:
            # fused + compressed: per-leaf scales (a shared scale would
            # zero out small-magnitude leaves), mean/dtype per segment
            parts = []
            for i in idxs:
                p = leaves[i].reshape(-1).astype(jnp.float32)
                if ef is not None:
                    p = p + ef[i].reshape(-1)
                parts.append(p)
            segs, scales, err = _compressed_fused_allreduce(
                parts, bctx, group, with_err=ef is not None
            )
            offs = _leaf_offsets(parts)
            for k, i in enumerate(idxs):
                g = leaves[i]
                if ef is not None:
                    new_ef[i] = err[
                        offs[k] : offs[k] + g.size
                    ].reshape(g.shape)
                seg = segs[k]
                if ctx.policy.mean and group > 1:
                    seg = seg / group
                out[i] = seg.reshape(g.shape).astype(g.dtype)
            continue
        if len(idxs) == 1:
            i = idxs[0]
            out[i] = _reduce_leaf(leaves[i], bctx, group)
            continue
        flat = jnp.concatenate([leaves[i].reshape(-1) for i in idxs])
        red = _reduce_leaf(flat, bctx, group)
        off = 0
        for i in idxs:
            g = leaves[i]
            out[i] = red[off : off + g.size].reshape(g.shape)
            off += g.size
    return out, new_ef


def sync_with_context(
    grads: Any,
    ctx: comm.CommContext,
    *,
    plan: bucketing.BucketPlan | None = None,
    ef_state: Any | None = None,
) -> Any:
    """Bucket-scheduled allreduce sync under a :class:`comm.CommContext`
    (the canonical entry — :meth:`comm.CommContext.sync_grads`).

    ``plan`` (optional) is a precomputed :func:`plan_for_tree` result —
    the trainer's per-bucket issue points.  When omitted, the plan is
    solved here (host-side, cached per pytree signature x topology x
    policy).

    ``ef_state`` (optional) is the per-chip error-feedback residual tree
    (:func:`repro.optim.error_feedback.ef_init`) matching ``grads``
    leaf-for-leaf; when given, the call returns ``(synced, new_ef)``
    instead of just the synced tree.  Requires compressed transport —
    residuals of an exact sync would be identically zero.
    """
    ctx.topology.require_axes()
    leaves, treedef = jax.tree.flatten(grads)
    if not leaves:
        return grads if ef_state is None else (grads, ef_state)
    ef_leaves = None
    if ef_state is not None:
        if not ctx.policy.compress_bits:
            raise ValueError(
                "ef_state given but compress_bits is None — error "
                "feedback only applies to quantised transport"
            )
        ef_leaves = jax.tree.flatten(ef_state)[0]
        if len(ef_leaves) != len(leaves):
            raise ValueError(
                f"error-feedback state has {len(ef_leaves)} leaves for "
                f"{len(leaves)} gradient leaves"
            )
    if plan is None:
        plan = _plan(leaves, ctx.policy, ctx.topology)
    else:
        sig = tuple(
            (int(np.prod(g.shape)) if g.shape else 1, np.dtype(g.dtype).name)
            for g in leaves
        )
        if sig != plan.signature:
            raise ValueError(
                "bucket plan does not match the gradient pytree "
                f"(plan for {plan.signature}, got {sig})"
            )
    out, new_ef = _execute_plan(leaves, plan, ctx, ef=ef_leaves)
    synced = jax.tree.unflatten(treedef, out)
    if ef_state is None:
        return synced
    return synced, jax.tree.unflatten(jax.tree.structure(ef_state), new_ef)


def sync_grads_local(
    grads: Any,
    *,
    cfg: comm.CommPolicy,
    inter_axes: tuple[str, ...],
    intra_axes: tuple[str, ...],
    plan: bucketing.BucketPlan | None = None,
) -> Any:
    """Synchronise a pytree of per-chip local gradients (inside shard_map).

    Axis-names entry point: builds a :class:`comm.Topology` from the
    named mesh axes (sizes resolved from the traced context) and a
    :class:`comm.CommContext` from ``cfg``, then runs
    :func:`sync_with_context`.
    """
    ctx = comm.CommContext(
        comm.Topology.from_axes(inter_axes, intra_axes), cfg
    )
    return sync_with_context(grads, ctx, plan=plan)


def _compressed_reduce_scatter(flat, scale, ctx: comm.CommContext):
    """RS half of the packed transport for one leaf: exact f32 intra
    ``psum_scatter``, one-pass quantize-pack of the stripe, packed
    inter-node ``all_to_all`` + unpack + f32 fold.  Returns the chip's
    f32 shard of the *sum*, ``ceil(ceil(e/ppn)/n)`` elements in the MLA
    stripe-block layout (bit-compatible with :func:`unshard_grads`).

    The scale is agreed globally *before* the scatter (one fused NAP-max
    collective for every leaf together), so all shards quantise on the
    same grid — there is nothing left to re-agree post-scatter, and no
    AG hop on this route means no second requantization either.
    """
    bits = ctx.policy.compress_bits
    topo = ctx.topology
    n, ppn = topo.n_nodes, topo.ppn
    scales = scale.reshape(1)
    offsets = (0,)
    e = int(flat.size)
    S = -(-e // ppn)
    if ppn > 1:
        if ppn * S != e:
            flat = jnp.concatenate([flat, jnp.zeros((ppn * S - e,), jnp.float32)])
        stripe = lax.psum_scatter(
            flat.reshape(ppn, S), topo.intra_axes,
            scatter_dimension=0, tiled=False,
        )
        base = _flat_index(topo.intra_axes) * S
        s1 = scales * float(ppn)
    else:
        stripe = flat
        base = jnp.zeros((), jnp.int32)
        s1 = scales
    B = -(-S // n)
    if n <= 1:
        return stripe
    if n * B != S:
        stripe = jnp.concatenate([stripe, jnp.zeros((n * B - S,), jnp.float32)])
    w = transport.quantize_pack(
        stripe.reshape(n, B), s1, offsets=offsets, bits=bits,
        base=base, row_stride=B, donate_input=True,
    )
    recv = lax.all_to_all(
        w[:, None, :], topo.inter_axes, split_axis=0, concat_axis=1,
        tiled=False,
    )[0]
    block_base = base + _flat_index(topo.inter_axes) * B
    return jnp.sum(
        transport.unpack_dequantize(
            recv, s1, offsets=offsets, bits=bits, cols=B,
            base=block_base, row_stride=0, donate_input=True,
        ),
        axis=0,
    )


def sync_grads_sharded(
    grads: Any, *, ctx: comm.CommContext
) -> Any:
    """ZeRO-style sharded gradient sync (inside shard_map).

    Every leaf is *reduce-scattered* instead of allreduced: each chip
    keeps only its 1-D shard of the reduced (optionally averaged)
    gradient — the slice its optimizer partition owns — so per-chip
    inter-node bytes are half the allreduce round trip and the full
    gradient never materialises per chip.  Returns a pytree of 1-D
    shards (leaf ``i``'s shard has ``ceil(ceil(n_i/ppn)/n)`` elements,
    the MLA stripe-block layout); :func:`unshard_grads` inverts.

    With ``compress_bits`` set, float leaves ride the packed transport's
    RS half (:func:`_compressed_reduce_scatter`): per-leaf scales are
    agreed in ONE fused NAP-max collective before the scatter (so every
    shard quantises on the same grid), then each leaf moves as wire
    bytes over the slow domain — the same ``bits/8`` per-chip inter-node
    byte ratio as the allreduce route, at half the hops.  Integer leaves
    stay exact.
    """
    ctx.topology.require_axes()
    group = ctx.topology.group
    leaves, treedef = jax.tree.flatten(grads)
    bits = ctx.policy.compress_bits
    qmax = float(2 ** (bits - 1) - 1) if bits else None
    compressed = [
        i for i, g in enumerate(leaves)
        if bits and jnp.issubdtype(g.dtype, jnp.floating)
    ]
    scales = {}
    if compressed and group > 1:
        # ONE fused scale agreement for every compressed leaf together
        agreed = _agreed_absmax(
            [leaves[i].reshape(-1) for i in compressed], ctx
        )
        scales = {
            i: jnp.maximum(agreed[k] / qmax, 1e-30)
            for k, i in enumerate(compressed)
        }
    out = []
    for i, g in enumerate(leaves):
        dtype = g.dtype
        is_float = jnp.issubdtype(dtype, jnp.floating)
        if i in scales:
            red = _compressed_reduce_scatter(
                g.reshape(-1).astype(jnp.float32), scales[i], ctx
            )
        else:
            red = ctx.reduce_scatter(g.reshape(-1), op="sum")
        if ctx.policy.mean and group > 1:
            if is_float:
                red = red / group
            else:
                red = jnp.round(red.astype(jnp.float32) / group)
        out.append(red.astype(dtype))
    return jax.tree.unflatten(treedef, out)


def unshard_grads(shards: Any, like: Any, *, ctx: comm.CommContext) -> Any:
    """Allgather a :func:`sync_grads_sharded` result back to full leaves.

    ``like`` is a pytree of arrays or ShapeDtypeStructs giving the
    original leaf shapes (the padding stripped per leaf).
    """
    shard_leaves, treedef = jax.tree.flatten(shards)
    like_leaves = jax.tree.flatten(like)[0]
    out = []
    for s, g in zip(shard_leaves, like_leaves):
        elems = int(np.prod(g.shape)) if g.shape else 1
        full = ctx.allgather(s, elems=elems)
        out.append(full.reshape(g.shape).astype(g.dtype))
    return jax.tree.unflatten(treedef, out)


def make_grad_sync(
    cfg: comm.CommPolicy,
    mesh,
    *,
    data_axes: tuple[str, ...],
    grad_specs: Any,
):
    """Standalone grad-sync callable over global arrays.

    ``grad_specs`` is a pytree of PartitionSpecs matching the gradients;
    leaves must not be sharded along ``data_axes`` dims other than the
    stacked per-replica leading dim used in DP.
    """
    from ..launch.mesh import POD_AXIS

    inter = tuple(a for a in data_axes if a == POD_AXIS)
    intra = tuple(a for a in data_axes if a != POD_AXIS)

    def _local(grads):
        return sync_grads_local(
            grads, cfg=cfg, inter_axes=inter, intra_axes=intra
        )

    return compat.shard_map(
        _local, mesh=mesh, in_specs=(grad_specs,), out_specs=grad_specs
    )
