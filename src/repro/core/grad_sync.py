"""Gradient synchronisation strategies built on the paper's collectives.

Two call styles:

* :func:`sync_grads_local` — used *inside* an existing ``jax.shard_map``
  (the trainer's explicit-collectives path).  Takes per-chip local
  gradients, returns synchronised gradients.
* :func:`make_grad_sync` — standalone: wraps ``sync_grads_local`` in its
  own ``shard_map`` given the gradient PartitionSpecs (tests, benchmarks).

Features, per the "distributed optimisation tricks" requirement:

* paper-faithful *size switch*: buckets below the paper's ~2 KiB crossover
  go through NAP (latency-bound regime, the contribution); large buckets
  go through pod-local reduce + Rabenseifner RS/AG (bandwidth regime) —
  exactly the hybrid the paper's §VI recommends.
* *flat-bucket fusion*: small leaves are concatenated into one flat buffer
  so the whole latency-bound sync costs a single NAP schedule rather than
  one collective per tensor.
* optional *int8 gradient compression* with a NAP-pmax shared scale (the
  scale reduction itself is a single-scalar allreduce — the paper's
  canonical small-message workload).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from . import collectives

__all__ = ["GradSyncConfig", "sync_grads_local", "make_grad_sync"]


@dataclasses.dataclass(frozen=True)
class GradSyncConfig:
    """Configuration of the gradient allreduce.

    algorithm: "nap" | "rd" | "smp" | "psum" | "ring" | "rabenseifner" |
      "auto" (paper size switch).
    mean: divide by the DP group size (data-parallel averaging).
    compress_bits: None (off) or 8 — int8 quantised transport with a
      shared max-abs scale.
    small_threshold_bytes: the NAP/RS+AG crossover for "auto" (paper's
      measured ~2048 bytes, Figs 14/15).
    fuse_small_buckets: concatenate small leaves into one flat payload.
    """

    algorithm: str = "auto"
    mean: bool = True
    compress_bits: int | None = None
    small_threshold_bytes: int = 2048
    fuse_small_buckets: bool = True


def _one_allreduce(x, cfg: GradSyncConfig, inter_axes, intra_axes):
    if not inter_axes:
        # single-level mesh: no slow domain; plain psum over the DP axes.
        return lax.psum(x, intra_axes)
    return collectives.hierarchical_allreduce(
        x,
        inter_axes=inter_axes,
        intra_axes=intra_axes,
        algorithm=cfg.algorithm,
        small_threshold_bytes=cfg.small_threshold_bytes,
    )


def _compressed_allreduce(x, cfg: GradSyncConfig, inter_axes, intra_axes):
    """int8-quantised allreduce with a globally agreed max-abs scale."""
    bits = cfg.compress_bits
    qmax = float(2 ** (bits - 1) - 1)
    absmax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    if inter_axes:
        absmax = collectives.nap_allreduce(
            absmax, inter_axes=inter_axes, intra_axes=intra_axes, op="max"
        )
    else:
        absmax = lax.pmax(absmax, intra_axes)
    scale = jnp.maximum(absmax / qmax, 1e-30)
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int32)
    summed = _one_allreduce(q, cfg, inter_axes, intra_axes)
    return summed.astype(jnp.float32) * scale


def sync_grads_local(
    grads: Any,
    *,
    cfg: GradSyncConfig,
    inter_axes: tuple[str, ...],
    intra_axes: tuple[str, ...],
) -> Any:
    """Synchronise a pytree of per-chip local gradients (inside shard_map)."""
    axes = tuple(inter_axes) + tuple(intra_axes)
    group = int(
        np.prod([lax.axis_size(a) for a in axes]) if axes else 1
    )
    leaves, treedef = jax.tree.flatten(grads)
    if not leaves:
        return grads

    reduce_fn = (
        functools.partial(_compressed_allreduce, cfg=cfg)
        if cfg.compress_bits
        else functools.partial(_one_allreduce, cfg=cfg)
    )

    small_idx = [
        i
        for i, g in enumerate(leaves)
        if cfg.fuse_small_buckets
        and g.size * g.dtype.itemsize <= cfg.small_threshold_bytes
        and jnp.issubdtype(g.dtype, jnp.floating)
    ]
    out = list(leaves)
    if len(small_idx) > 1:
        flat = jnp.concatenate(
            [leaves[i].astype(jnp.float32).reshape(-1) for i in small_idx]
        )
        flat = reduce_fn(flat, inter_axes=inter_axes, intra_axes=intra_axes)
        off = 0
        for i in small_idx:
            g = leaves[i]
            out[i] = flat[off : off + g.size].reshape(g.shape).astype(g.dtype)
            off += g.size
        rest = [i for i in range(len(leaves)) if i not in set(small_idx)]
    else:
        rest = list(range(len(leaves)))
    for i in rest:
        out[i] = reduce_fn(
            leaves[i], inter_axes=inter_axes, intra_axes=intra_axes
        )
    if cfg.mean and group > 1:
        out = [
            (g / group).astype(g.dtype)
            if jnp.issubdtype(g.dtype, jnp.floating)
            else g
            for g in out
        ]
    return jax.tree.unflatten(treedef, out)


def make_grad_sync(
    cfg: GradSyncConfig,
    mesh,
    *,
    data_axes: tuple[str, ...],
    grad_specs: Any,
):
    """Standalone grad-sync callable over global arrays.

    ``grad_specs`` is a pytree of PartitionSpecs matching the gradients;
    leaves must not be sharded along ``data_axes`` dims other than the
    stacked per-replica leading dim used in DP.
    """
    from ..launch.mesh import POD_AXIS

    inter = tuple(a for a in data_axes if a == POD_AXIS)
    intra = tuple(a for a in data_axes if a != POD_AXIS)

    def _local(grads):
        return sync_grads_local(
            grads, cfg=cfg, inter_axes=inter, intra_axes=intra
        )

    return jax.shard_map(
        _local, mesh=mesh, in_specs=(grad_specs,), out_specs=grad_specs
    )
