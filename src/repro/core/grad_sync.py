"""Gradient synchronisation strategies built on the paper's collectives.

Two call styles:

* :func:`sync_grads_local` — used *inside* an existing ``jax.shard_map``
  (the trainer's explicit-collectives path).  Takes per-chip local
  gradients, returns synchronised gradients.
* :func:`make_grad_sync` — standalone: wraps ``sync_grads_local`` in its
  own ``shard_map`` given the gradient PartitionSpecs (tests, benchmarks).

Features, per the "distributed optimisation tricks" requirement:

* model-driven *three-regime switch*: buckets below the modeled NAP↔MLA
  crossover (``perf_model.crossover_bytes`` for the actual grid shape;
  the paper measured ~2 KiB on Blue Waters) go through NAP (latency
  regime, the contribution); large buckets go through the striped
  multi-lane MLA path (bandwidth regime, ``s/ppn`` bytes per lane) —
  chunk-*pipelined* once ``perf_model.optimal_pipeline_chunks`` says the
  bucket amortises the extra latency steps, so the biggest fused
  parameter buckets overlap their intra-pod striping with the inter-pod
  transfers; single-level meshes use plain psum — §VI's hybrid, with
  every switch point solved from §IV instead of hardcoded.
* *flat-bucket fusion*: small leaves are concatenated into one flat buffer
  so the whole latency-bound sync costs a single NAP schedule rather than
  one collective per tensor.
* optional *int8 gradient compression* with a NAP-pmax shared scale (the
  scale reduction itself is a single-scalar allreduce — the paper's
  canonical small-message workload).
* uniform dtype/op semantics: every leaf funnels through
  :func:`_reduce_leaf`, so mean division and dtype round-trips behave the
  same for float, bf16 and integer gradients on every code path.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from . import collectives
from .. import compat

__all__ = ["GradSyncConfig", "sync_grads_local", "make_grad_sync"]


@dataclasses.dataclass(frozen=True)
class GradSyncConfig:
    """Configuration of the gradient allreduce.

    algorithm: "nap" | "rd" | "smp" | "mla" | "psum" | "ring" |
      "rabenseifner" | "auto" (model-driven three-regime switch).
    mean: divide by the DP group size (data-parallel averaging).  Applies
      to *every* leaf: integer gradients are averaged in float32 and
      rounded back to their dtype rather than silently left as sums.
    compress_bits: None (off) or 8 — int8 quantised transport with a
      shared max-abs scale (float leaves only).
    small_threshold_bytes: NAP↔MLA crossover for "auto" and the fusion
      bucket bound.  ``None`` (default) derives it from the §IV cost model
      (:func:`collectives.auto_crossover_bytes`) for the actual grid.
    fuse_small_buckets: concatenate small leaves into one flat payload.
    pipeline_chunks: MLA pipeline depth for bandwidth-regime buckets.
      ``None`` (default) lets the model pick per bucket
      (:func:`perf_model.optimal_pipeline_chunks` — large fused buckets
      get chunk-level intra/inter overlap, small ones stay unpipelined);
      an int pins the depth.
    """

    algorithm: str = "auto"
    mean: bool = True
    compress_bits: int | None = None
    small_threshold_bytes: int | None = None
    fuse_small_buckets: bool = True
    pipeline_chunks: int | None = None


# fallback fusion bound when no slow domain exists (nothing to switch;
# the threshold only decides which leaves share the fused flat bucket)
_DEFAULT_FUSE_BYTES = 2048


def _resolved_threshold(
    cfg: GradSyncConfig, inter_axes, intra_axes
) -> float:
    """The byte threshold actually in force (fixed or model-driven)."""
    if cfg.small_threshold_bytes is not None:
        return float(cfg.small_threshold_bytes)
    if not inter_axes:
        return float(_DEFAULT_FUSE_BYTES)
    import math

    n = int(np.prod([compat.axis_size(a) for a in inter_axes]))
    ppn = int(np.prod([compat.axis_size(a) for a in intra_axes]))
    xo = collectives.auto_crossover_bytes(n, ppn)
    return xo if math.isfinite(xo) else float(_DEFAULT_FUSE_BYTES)


def _one_allreduce(x, cfg: GradSyncConfig, inter_axes, intra_axes):
    if not inter_axes:
        # single-level mesh: no slow domain; plain psum over the DP axes.
        return lax.psum(x, intra_axes)
    return collectives.hierarchical_allreduce(
        x,
        inter_axes=inter_axes,
        intra_axes=intra_axes,
        algorithm=cfg.algorithm,
        small_threshold_bytes=cfg.small_threshold_bytes,
        pipeline_chunks=cfg.pipeline_chunks,
    )


def _compressed_allreduce(x, cfg: GradSyncConfig, inter_axes, intra_axes):
    """int8-quantised allreduce with a globally agreed max-abs scale.

    Returns float32; :func:`_reduce_leaf` restores the caller's dtype.
    """
    bits = cfg.compress_bits
    qmax = float(2 ** (bits - 1) - 1)
    absmax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    if inter_axes:
        absmax = collectives.nap_allreduce(
            absmax, inter_axes=inter_axes, intra_axes=intra_axes, op="max"
        )
    else:
        absmax = lax.pmax(absmax, intra_axes)
    scale = jnp.maximum(absmax / qmax, 1e-30)
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int32)
    summed = _one_allreduce(q, cfg, inter_axes, intra_axes)
    return summed.astype(jnp.float32) * scale


def _reduce_leaf(g, cfg: GradSyncConfig, inter_axes, intra_axes, group):
    """Allreduce one leaf with op/mean/dtype semantics in one place.

    Every leaf — float, bf16, integer, fused flat bucket — funnels through
    here so the transport dtype, the mean division and the round-trip back
    to the original dtype cannot diverge between code paths (they used to:
    integer leaves skipped ``mean`` silently and the compressed path
    returned hardcoded float32).
    """
    dtype = g.dtype
    is_float = jnp.issubdtype(dtype, jnp.floating)
    if cfg.compress_bits and is_float:
        red = _compressed_allreduce(g, cfg, inter_axes, intra_axes)
    else:
        red = _one_allreduce(g, cfg, inter_axes, intra_axes)
    if cfg.mean and group > 1:
        if is_float:
            red = red / group
        else:
            red = jnp.round(red.astype(jnp.float32) / group)
    return red.astype(dtype)


def sync_grads_local(
    grads: Any,
    *,
    cfg: GradSyncConfig,
    inter_axes: tuple[str, ...],
    intra_axes: tuple[str, ...],
) -> Any:
    """Synchronise a pytree of per-chip local gradients (inside shard_map)."""
    axes = tuple(inter_axes) + tuple(intra_axes)
    group = int(
        np.prod([compat.axis_size(a) for a in axes]) if axes else 1
    )
    leaves, treedef = jax.tree.flatten(grads)
    if not leaves:
        return grads

    threshold = _resolved_threshold(cfg, inter_axes, intra_axes)
    small_idx = [
        i
        for i, g in enumerate(leaves)
        if cfg.fuse_small_buckets
        and g.size * g.dtype.itemsize <= threshold
        and jnp.issubdtype(g.dtype, jnp.floating)
    ]
    out = list(leaves)
    if len(small_idx) > 1:
        flat = jnp.concatenate(
            [leaves[i].astype(jnp.float32).reshape(-1) for i in small_idx]
        )
        flat = _reduce_leaf(flat, cfg, inter_axes, intra_axes, group)
        off = 0
        for i in small_idx:
            g = leaves[i]
            out[i] = flat[off : off + g.size].reshape(g.shape).astype(g.dtype)
            off += g.size
        rest = [i for i in range(len(leaves)) if i not in set(small_idx)]
    else:
        rest = list(range(len(leaves)))
    for i in rest:
        out[i] = _reduce_leaf(leaves[i], cfg, inter_axes, intra_axes, group)
    return jax.tree.unflatten(treedef, out)


def make_grad_sync(
    cfg: GradSyncConfig,
    mesh,
    *,
    data_axes: tuple[str, ...],
    grad_specs: Any,
):
    """Standalone grad-sync callable over global arrays.

    ``grad_specs`` is a pytree of PartitionSpecs matching the gradients;
    leaves must not be sharded along ``data_axes`` dims other than the
    stacked per-replica leading dim used in DP.
    """
    from ..launch.mesh import POD_AXIS

    inter = tuple(a for a in data_axes if a == POD_AXIS)
    intra = tuple(a for a in data_axes if a != POD_AXIS)

    def _local(grads):
        return sync_grads_local(
            grads, cfg=cfg, inter_axes=inter, intra_axes=intra
        )

    return compat.shard_map(
        _local, mesh=mesh, in_specs=(grad_specs,), out_specs=grad_specs
    )
