"""Node-aware performance models — paper §IV, Equations (1)-(6).

Implements the postal model (Eq 1), the intra/inter split model (Eq 2) and
the max-rate model with injection-bandwidth limiting (Eq 3), plus the
closed-form costs of the three allreduce algorithms:

  Eq 4  recursive doubling:  intra log2(ppn) + inter log2(n) (max-rate) + γ
  Eq 5  SMP:                 intra log2(ppn) + inter log2(n) (full R_b) + γ
  Eq 6  NAP:                 intra log2(p)   + inter log_ppn(n) (max-rate)
                             + γ (log2(p) + log_ppn(n))

Two parameter sets ship:

* ``BLUE_WATERS`` — Cray XE6/Gemini-class constants in the range measured
  by the max-rate papers ([11], [12]); these reproduce the *qualitative*
  paper results (NAP best below ~2 KiB at 32 768 processes, SMP best
  above, speedup growing with process count).
* ``TPU_V5E_POD`` — the TPU mapping: "node" = pod (ICI domain), inter-node
  = inter-pod DCI; used by the roofline/collective analysis.

All sizes are bytes, all times seconds.
"""

from __future__ import annotations

import dataclasses
import functools
import math

__all__ = [
    "MachineParams",
    "BLUE_WATERS",
    "TPU_V5E_POD",
    "postal_cost",
    "maxrate_message_cost",
    "cost_rd",
    "cost_smp",
    "cost_nap",
    "cost_mla",
    "cost_mla_compressed",
    "cost_mla_pipelined",
    "cost_psum",
    "cost_reduce_scatter",
    "cost_allgather",
    "cost_reduce_scatter_flat",
    "cost_allgather_flat",
    "optimal_pipeline_chunks",
    "crossover_bytes",
    "dispatched_allreduce_cost",
    "optimal_bucket_bytes",
]


@dataclasses.dataclass(frozen=True)
class MachineParams:
    """Two-level max-rate machine model (paper Eq 3)."""

    alpha_l: float  # intra-node per-message latency  [s]
    beta_l: float   # intra-node per-byte cost        [s/B]
    alpha: float    # inter-node per-message latency  [s]
    R_b: float      # inter-node per-process bandwidth [B/s] (1/beta)
    R_N: float      # per-node injection bandwidth     [B/s]
    gamma: float    # local reduction cost             [s/B]
    name: str = "machine"

    @classmethod
    def fit(
        cls,
        measurements,
        *,
        base: "MachineParams | None" = None,
        name: str = "fitted",
    ) -> "MachineParams":
        """Least-squares fit of the inter-node constants from measured
        message times (ROADMAP open item: "measure the real crossover …
        and fit MachineParams").

        ``measurements`` is an iterable of ``(nbytes, seconds)`` or
        ``(nbytes, seconds, active_per_node)`` rows, each the measured
        wall time of ONE inter-node message step with
        ``active_per_node`` concurrent senders per node (default 1) —
        the quantity :func:`maxrate_message_cost` models as
        ``alpha + k*s / min(R_N, k*R_b)``:

        * ``alpha`` and ``R_b`` come from an ordinary linear
          least-squares fit of ``t = alpha + s/R_b`` over the ``k == 1``
          rows (at least two distinct sizes required);
        * ``R_N`` (injection bandwidth) comes from the ``k > 1`` rows:
          a through-origin least-squares fit of ``t - alpha = k*s/R_N``
          restricted to injection-limited rows (those slower than the
          fitted per-process model predicts).  Without such rows the
          ``base`` injection constant is kept.

        Intra-node constants (``alpha_l``/``beta_l``/``gamma``) are
        inherited from ``base`` (default :data:`TPU_V5E_POD`) — they are
        not observable from inter-node message timings.
        """
        import numpy as np

        base = base or TPU_V5E_POD
        rows = [
            (float(r[0]), float(r[1]), int(r[2]) if len(r) > 2 else 1)
            for r in measurements
        ]
        single = [(s, t) for s, t, k in rows if k <= 1]
        if len({s for s, _ in single}) < 2:
            raise ValueError(
                "MachineParams.fit needs >= 2 single-sender (k == 1) "
                "measurements at distinct sizes to identify alpha and R_b"
            )
        A = np.array([[1.0, s] for s, _ in single])
        t = np.array([tt for _, tt in single])
        (alpha, slope), *_ = np.linalg.lstsq(A, t, rcond=None)
        alpha = max(float(alpha), 0.0)
        if slope <= 0:
            raise ValueError(
                "measured times do not grow with message size; cannot "
                "identify R_b (check the measurement units)"
            )
        R_b = 1.0 / float(slope)
        R_N = base.R_N
        multi = [(s, t, k) for s, t, k in rows if k > 1]
        if multi:
            # keep only rows the per-process model cannot explain — the
            # injection-limited regime where min(R_N, k*R_b) == R_N
            limited = [
                (k * s, tt - alpha)
                for s, tt, k in multi
                if tt - alpha > (s / R_b) * 1.02
            ]
            if limited:
                x = np.array([v for v, _ in limited])
                y = np.array([v for _, v in limited])
                inv_rn = float((x * y).sum() / (x * x).sum())
                if inv_rn > 0:
                    R_N = 1.0 / inv_rn
        return cls(
            alpha_l=base.alpha_l,
            beta_l=base.beta_l,
            alpha=alpha,
            R_b=R_b,
            R_N=R_N,
            gamma=base.gamma,
            name=name,
        )


# Gemini-class constants (order of magnitude from the max-rate papers).
BLUE_WATERS = MachineParams(
    alpha_l=5.0e-7,
    beta_l=1.8e-10,   # ~5.5 GB/s shared-memory copy
    alpha=2.6e-6,
    R_b=2.3e9,        # ~2.3 GB/s per process pair
    R_N=5.5e9,        # ~5.5 GB/s node injection
    gamma=2.5e-11,    # ~40 GB/s local reduce stream
    name="blue_waters",
)

# TPU mapping: node = pod. Intra-"node" transport is ICI (per-link ~50 GB/s,
# ~1 us software latency through XLA collectives); inter-pod is the data
# centre network with per-host NICs shared by 4 chips.
TPU_V5E_POD = MachineParams(
    alpha_l=1.0e-6,
    beta_l=2.2e-11,   # ~45 GB/s ICI effective
    alpha=1.0e-5,
    R_b=6.25e9,       # ~6.25 GB/s per chip across the DCN
    R_N=2.5e10,       # ~25 GB/s per-host NIC (4 chips)
    gamma=1.25e-12,   # 819 GB/s HBM-bound vector add
    name="tpu_v5e_pod",
)


def _log2(x: int) -> float:
    return math.log2(x) if x > 1 else 0.0


def _log_ppn(n: int, ppn: int) -> int:
    """ceil(log_ppn(n)) — inter-node steps of NAP (non-powers pay the next
    power's step count, paper §VI)."""
    if n <= 1:
        return 0
    if ppn < 2:
        return max(0, math.ceil(_log2(n)))
    return max(1, math.ceil(math.log(n) / math.log(ppn) - 1e-12))


def postal_cost(t: float, s: float, c: float, p: MachineParams) -> float:
    """Eq 1: T = alpha t + beta s + gamma c (node-agnostic postal model)."""
    return p.alpha * t + s / p.R_b + p.gamma * c


def maxrate_message_cost(
    s: float, p: MachineParams, active_per_node: int = 1
) -> float:
    """Eq 3 inter-node term for one message step with ``active_per_node``
    concurrent senders per node: alpha + ppn_act*s / min(R_N, ppn_act*R_b).
    """
    k = max(1, active_per_node)
    return p.alpha + (k * s) / min(p.R_N, k * p.R_b)


def cost_rd(s: float, n: int, ppn: int, p: MachineParams) -> float:
    """Eq 4: recursive doubling. Every chip crosses the network log2(n)
    times with ppn concurrent senders per node (injection-limited)."""
    intra = (p.alpha_l + p.beta_l * s) * _log2(ppn)
    inter = maxrate_message_cost(s, p, active_per_node=ppn) * _log2(n)
    comp = p.gamma * s * _log2(n * ppn)
    return intra + inter + comp


def cost_smp(s: float, n: int, ppn: int, p: MachineParams) -> float:
    """Eq 5: SMP/master algorithm. One active chip per node: full R_b."""
    intra = (p.alpha_l + p.beta_l * s) * _log2(ppn)
    inter = (p.alpha + s / p.R_b) * _log2(n)
    comp = p.gamma * s * _log2(n * ppn)
    return intra + inter + comp


def cost_nap(s: float, n: int, ppn: int, p: MachineParams) -> float:
    """Eq 6: NAP. log_ppn(n) inter steps (all ppn chips inject), intra
    cost grows to log2(p), plus log_ppn(n) extra local combines."""
    steps = _log_ppn(n, ppn)
    intra = (p.alpha_l + p.beta_l * s) * _log2(n * ppn)
    inter = maxrate_message_cost(s, p, active_per_node=ppn) * steps
    comp = p.gamma * s * (_log2(n * ppn) + steps)
    return intra + inter + comp


def cost_mla(s: float, n: int, ppn: int, p: MachineParams) -> float:
    """Multi-lane node-aware (MLA) allreduce under the max-rate model.

    Intra: psum_scatter + allgather each move ``s*(ppn-1)/ppn`` bytes over
    the fast domain in ``log2(ppn)`` message rounds.  Inter: all ``ppn``
    lanes run reduce-scatter + allgather concurrently, so each chip crosses
    the slow domain with ``2*(s/ppn)*(n-1)/n`` bytes at the per-chip rate
    ``min(R_b, R_N/ppn)`` (all lanes inject at once) over ``2*log2(n)``
    latency steps.  The serialized sum of the shared stage times — the
    one-chunk special case of :func:`cost_mla_pipelined`.
    """
    t_rs, t_inter, t_ag = _mla_stage_times(s, n, ppn, p)
    comp = p.gamma * s * 2.0  # local stripe reduce + per-lane RS folds
    return t_rs + t_inter + t_ag + comp


def cost_mla_compressed(
    s: float, n: int, ppn: int, p: MachineParams, wire_ratio: float
) -> float:
    """Quantised two-level transport cost (the fused-kernel engine in
    :mod:`repro.core.grad_sync`) for a raw ``s``-byte payload.

    The intra-node pre-combine and rebuild stay exact f32 — they pay the
    raw width — while the inter-node exchange (the RS-half all_to_all
    and the AG-half all_gather) moves ``s * wire_ratio`` bytes
    (``wire_ratio`` = packed wire itemsize / raw itemsize: 1/4 for int8
    over f32, 1/8 for packed int4).  The compute port pays four fused
    kernel passes over the payload (quantize-pack, unpack+fold,
    requantize, unpack) instead of :func:`cost_mla`'s two reduce
    streams.  This is the cost the dispatcher/planner quote for
    compressed buckets — the same packed widths the executor moves.
    """
    t_rs, _, t_ag = _mla_stage_times(s, n, ppn, p)
    _, t_inter, _ = _mla_stage_times(s * wire_ratio, n, ppn, p)
    comp = p.gamma * s * 4.0
    return t_rs + t_inter + t_ag + comp


def _mla_stage_times(
    s_c: float, n: int, ppn: int, p: MachineParams
) -> tuple[float, float, float]:
    """(intra-RS, inter RS+AG, intra-AG) times for one ``s_c``-byte chunk.

    The single source of the MLA stage formulas: :func:`cost_mla` sums
    them serially and :func:`cost_mla_pipelined` pipelines them, so the
    two models cannot drift apart.
    """
    lanes = max(1, ppn)
    li = math.ceil(_log2(ppn)) if ppn > 1 else 0
    t_intra = li * p.alpha_l + p.beta_l * s_c * (lanes - 1) / lanes
    if n > 1:
        lo = math.ceil(_log2(n))
        lane_bytes = 2.0 * (s_c / lanes) * (n - 1) / n
        rate = min(p.R_b, p.R_N / lanes)
        t_inter = 2 * lo * p.alpha + lane_bytes / rate
    else:
        t_inter = 0.0
    return t_intra, t_inter, t_intra


def cost_mla_pipelined(
    s: float, n: int, ppn: int, p: MachineParams, chunks: int | None = None
) -> float:
    """Chunked, pipelined MLA cost under the max-rate model.

    The payload is split into ``chunks`` pieces; chunk ``c``'s inter-pod
    reduce-scatter/allgather overlaps chunk ``c±1``'s intra-pod phases
    (distinct networks: ICI vs DCI).  The makespan is the classic pipeline
    bound — whichever network domain is the bottleneck processes all
    ``chunks`` of its stages back to back, plus the fill/drain cost of the
    other domain's first and last chunk:

        T = max(C*t_inter + t_rs + t_ag,  C*(t_rs + t_ag) + t_inter) + comp

    ``chunks=1`` degenerates exactly to :func:`cost_mla`.  ``chunks=None``
    picks the model-optimal depth (:func:`optimal_pipeline_chunks`) — the
    bandwidth term is unchanged by chunking while the alpha term grows
    linearly in ``C``, so the optimum balances overlap savings against
    the ``C * 2*log2(n) * alpha`` latency bill.
    """
    if chunks is None:
        chunks = optimal_pipeline_chunks(s, n, ppn, p)
    c = max(1, int(chunks))
    t_rs, t_inter, t_ag = _mla_stage_times(s / c, n, ppn, p)
    span = max(c * t_inter + t_rs + t_ag, c * (t_rs + t_ag) + t_inter)
    return span + p.gamma * s * 2.0


def optimal_pipeline_chunks(
    s: float, n: int, ppn: int, p: MachineParams, max_chunks: int = 16
) -> int:
    """Model-optimal MLA pipeline depth (1 = don't pipeline).

    Evaluates the closed form over ``1..max_chunks`` — cheap enough to be
    exact rather than using the sqrt rule of thumb, and naturally returns
    1 whenever the alpha bill outweighs the overlap (small payloads,
    latency-dominated machines).
    """
    if n <= 1 or ppn <= 1:
        return 1  # no second domain to overlap with
    best_c, best_t = 1, None
    for c in range(1, max(1, max_chunks) + 1):
        t = cost_mla_pipelined(s, n, ppn, p, chunks=c)
        if best_t is None or t < best_t:
            best_c, best_t = c, t
    return best_c


def _cost_mla_pipelined_opt(
    s: float, n: int, ppn: int, p: MachineParams
) -> float:
    return cost_mla_pipelined(s, n, ppn, p, chunks=None)


def cost_psum(s: float, n: int, ppn: int, p: MachineParams) -> float:
    """Native single-level reduce over the joint grid — the fallback
    engine's price.  Modeled as node-agnostic recursive doubling over all
    ``n*ppn`` chips (what XLA's psum costs at worst on a flat ring/tree).
    """
    if n <= 1:
        return (p.alpha_l + p.beta_l * s + p.gamma * s) * _log2(ppn)
    return cost_rd(s, n, ppn, p)


def _striped_one_way_cost(
    s: float, n: int, ppn: int, p: MachineParams
) -> float:
    """Shared transport term of one striped RS *or* AG direction: intra
    stripe phase + per-lane inter phase (all ``ppn`` lanes inject at
    once).  The single source both directions price from — RS adds the
    fold pass on top."""
    lanes = max(1, ppn)
    li = math.ceil(_log2(ppn)) if ppn > 1 else 0
    t_intra = li * p.alpha_l + p.beta_l * s * (lanes - 1) / lanes
    if n > 1:
        lo = math.ceil(_log2(n))
        lane_bytes = (s / lanes) * (n - 1) / n
        rate = min(p.R_b, p.R_N / lanes)
        t_inter = lo * p.alpha + lane_bytes / rate
    else:
        t_inter = 0.0
    return t_intra + t_inter


def cost_reduce_scatter(s: float, n: int, ppn: int, p: MachineParams) -> float:
    """Node-aware striped reduce-scatter (the RS half of the MLA
    allreduce): intra stripe + per-lane inter RS, one fold pass."""
    return _striped_one_way_cost(s, n, ppn, p) + p.gamma * s


def cost_allgather(s: float, n: int, ppn: int, p: MachineParams) -> float:
    """Node-aware striped allgather (the AG half of the MLA allreduce):
    per-lane inter AG + intra AG, no reduction work."""
    return _striped_one_way_cost(s, n, ppn, p)


def _flat_one_way_cost(s: float, n: int, ppn: int, p: MachineParams) -> float:
    """Shared transport term of one flat (node-agnostic) RS or AG
    direction over all ``n*ppn`` chips: every chip's ``s*(p-1)/p`` bytes
    cross the slow domain injection-limited whenever ``n > 1``."""
    chips = max(1, n * ppn)
    steps = math.ceil(_log2(chips))
    bytes_moved = s * (chips - 1) / chips
    if n > 1:
        rate = min(p.R_b, p.R_N / max(1, ppn))
        return steps * p.alpha + bytes_moved / rate
    return steps * p.alpha_l + p.beta_l * bytes_moved


def cost_reduce_scatter_flat(
    s: float, n: int, ppn: int, p: MachineParams
) -> float:
    """Node-agnostic flat reduce-scatter — the baseline the striped
    engine beats whenever ``n > 1``."""
    return _flat_one_way_cost(s, n, ppn, p) + p.gamma * s


def cost_allgather_flat(
    s: float, n: int, ppn: int, p: MachineParams
) -> float:
    """Node-agnostic flat allgather — mirror of
    :func:`cost_reduce_scatter_flat` without the fold pass."""
    return _flat_one_way_cost(s, n, ppn, p)


# NOTE: the old module-local ``_LARGE_COSTS`` side table is gone — the
# engine registry (``repro.core.comm``) is the single place an engine
# declares its cost model, and ``crossover_bytes`` resolves the
# ``large`` contender there (a plain callable is also accepted, so the
# model layer stays usable standalone).
def _resolve_large_cost(large):
    if callable(large):
        return large
    from . import comm

    return comm.get_engine(large).cost


def crossover_bytes(
    n: int,
    ppn: int,
    p: MachineParams,
    lo: float = 8.0,
    hi: float = 1 << 22,
    large: str = "smp",
) -> float:
    """Smallest message size where the ``large``-regime algorithm becomes
    cheaper than NAP (the paper measured ~2048 B vs SMP at 32 768
    processes).  ``large="mla"`` yields the dispatcher's NAP↔MLA switch
    point.  ``large`` is a registered engine name (its declared cost
    model is used) or a bare cost callable.

    Returns ``math.inf`` when NAP is still cheaper at the search cap
    ``hi`` — there is no crossover in the searched range, and callers
    (``comm.Topology.crossover_bytes``, the grad-sync planner) treat
    the saturated result as "latency regime everywhere" instead of
    mistaking the cap for a real 4 MiB switch point.
    """
    cost_large = _resolve_large_cost(large)
    if cost_nap(lo, n, ppn, p) > cost_large(lo, n, ppn, p):
        return lo
    if cost_nap(hi, n, ppn, p) <= cost_large(hi, n, ppn, p):
        return math.inf
    while hi / lo > 1.01:
        mid = math.sqrt(lo * hi)
        if cost_nap(mid, n, ppn, p) <= cost_large(mid, n, ppn, p):
            lo = mid
        else:
            hi = mid
    return math.sqrt(lo * hi)


def dispatched_allreduce_cost(
    s: float, n: int, ppn: int, p: MachineParams
) -> float:
    """Modeled cost of one ``s``-byte allreduce under the auto dispatch.

    Mirrors ``collectives.select_algorithm``'s regime choice in pure
    closed form: NAP at or below the NAP↔MLA crossover, the best of
    plain/pipelined MLA above it, single-domain costs on degenerate
    grids.  This is the per-bucket cost term the bucket-size optimum
    integrates over, so the planner and the dispatcher price a bucket
    identically.
    """
    if n <= 1:
        # single-level: intra recursive doubling only
        return (p.alpha_l + p.beta_l * s + p.gamma * s) * _log2(ppn)
    if ppn <= 1:
        # degenerate lanes: RS+AG over the slow domain (the mla fallback)
        return cost_mla(s, n, 1, p)
    xo = crossover_bytes(n, ppn, p, large="mla")
    if s <= xo:
        return cost_nap(s, n, ppn, p)
    return cost_mla_pipelined(s, n, ppn, p, chunks=None)


@functools.lru_cache(maxsize=None)
def _optimal_bucket_count(
    total_bytes: float,
    n: int,
    ppn: int,
    p: MachineParams,
    compute_seconds: float | None,
    max_buckets: int,
) -> int:
    best_k, best_t = 1, math.inf
    t_one = dispatched_allreduce_cost(total_bytes, n, ppn, p)
    tc = compute_seconds if compute_seconds is not None else t_one
    for k in range(1, max(1, max_buckets) + 1):
        s = total_bytes / k
        t = dispatched_allreduce_cost(s, n, ppn, p)
        free = 0.0
        for i in range(k):
            ready = (i + 1) * tc / k
            free = max(free, ready) + t
        if free < best_t - 1e-15:
            best_k, best_t = k, free
    return best_k


def optimal_bucket_bytes(
    total_bytes: float,
    n: int,
    ppn: int,
    p: MachineParams,
    *,
    compute_seconds: float | None = None,
    max_buckets: int = 64,
) -> float:
    """Model-optimal grad-sync bucket size for backward/comm overlap.

    Backward is modeled as producing gradient bytes at a uniform rate
    over ``compute_seconds`` (default: the unbucketed sync time — the
    comm ≈ compute regime where bucketing matters most), and the network
    as one port executing bucket allreduces back to back.  With ``k``
    equal buckets, bucket ``i`` becomes ready at ``(i+1)/k * T_c`` and
    the makespan follows the serial-port recurrence

        free_i = max(free_{i-1}, ready_i) + T_allreduce(S/k)

    More buckets expose more overlap but pay the per-bucket alpha bill
    ``k`` times; fewer serialize the whole sync behind the last gradient.
    The optimum is found by evaluating ``k = 1..max_buckets`` exactly
    (each candidate is a closed-form sum — cheap) under the same
    dispatch costs the executor will incur per bucket.
    """
    if total_bytes <= 0:
        return float(total_bytes)
    k = _optimal_bucket_count(
        float(total_bytes), n, ppn, p, compute_seconds, max_buckets
    )
    return float(total_bytes) / k
