"""Beyond-paper extensions: NAP allgather and NAP reduce-scatter.

Paper §VI: "Natural extensions exist to the MPI_Allgather ... node-aware
extensions could be applied to larger MPI_Allreduce methods, optimizing
the reduce-scatter and allgather approach."  These implement exactly
that: the NAP exchange pattern applied to allgather (log_ppn(n)
inter-node steps instead of log2(n)) and to reduce-scatter (its mirror),
which together give a node-aware *large-message* allreduce whose
latency term is also log_ppn(n) — the missing piece the paper leaves as
future work.

Both require power-of-ppn node counts (the ragged donor machinery of the
allreduce does not transfer to value-carrying collectives); callers fall
back to XLA's native collectives otherwise via ``supported()``.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from . import napalg
from .. import compat
from .collectives import AxisNames, _as_tuple, _chip_index, _mask_lookup

__all__ = ["nap_allgather", "nap_reduce_scatter", "nap_allreduce_large", "supported"]


def _sizes(inter, intra):
    n = int(np.prod([compat.axis_size(a) for a in inter]))
    ppn = int(np.prod([compat.axis_size(a) for a in intra]))
    return n, ppn


def supported(n: int, ppn: int) -> bool:
    if n <= 1 or ppn < 2:
        return n > 0
    steps = napalg.nap_num_steps(n, ppn)
    return ppn**steps == n


def _step_masks(sched, n_chips):
    out = []
    for step in sched.steps:
        pairs = step.rounds[0]
        smask = np.zeros(n_chips, dtype=bool)
        for c in step.self_chips:
            smask[c] = True
        out.append((pairs, smask))
    return out


def nap_allgather(
    x: jax.Array, *, inter_axes: AxisNames, intra_axes: AxisNames
) -> jax.Array:
    """Node-aware allgather: returns (p, *x.shape) rows in chip order.

    log_ppn(n) inter-node exchange steps (payload growing ppn^i) versus
    log2(n) for recursive-doubling allgather.
    """
    inter, intra = _as_tuple(inter_axes), _as_tuple(intra_axes)
    n, ppn = _sizes(inter, intra)
    if not supported(n, ppn):
        raise ValueError(f"nap_allgather needs power-of-ppn nodes ({n},{ppn})")
    joint = inter + intra
    v = lax.all_gather(x, intra, axis=0)  # (ppn, ...)
    if n == 1:
        return v
    sched = napalg.build_nap_schedule(n, ppn)
    chip = _chip_index(inter, intra)
    for pairs, smask in _step_masks(sched, n * ppn):
        recv = lax.ppermute(v, joint, pairs)
        mine = _mask_lookup(smask, chip)
        recv = jnp.where(
            jnp.reshape(mine, (1,) * recv.ndim), v, recv
        )  # self-subgroup keeps its own block
        v = lax.all_gather(recv, intra, axis=0, tiled=True)
    return v


def nap_reduce_scatter(
    x: jax.Array, *, inter_axes: AxisNames, intra_axes: AxisNames
) -> jax.Array:
    """Node-aware reduce-scatter (sum): x is (p, ...) rows per chip;
    chip with flat id q returns the fully-reduced row q.

    Mirror of :func:`nap_allgather`: intra-node psum_scatter narrows the
    payload ppn-fold, one inter-node exchange per NAP level routes each
    block to the subgroup that owns it — log_ppn(n) inter-node steps.
    """
    inter, intra = _as_tuple(inter_axes), _as_tuple(intra_axes)
    n, ppn = _sizes(inter, intra)
    if not supported(n, ppn):
        raise ValueError(
            f"nap_reduce_scatter needs power-of-ppn nodes ({n},{ppn})"
        )
    joint = inter + intra
    chip = _chip_index(inter, intra)
    p = n * ppn
    if x.shape[0] != p:
        raise ValueError(f"leading dim {x.shape[0]} != total chips {p}")
    v = x
    if n > 1:
        sched = napalg.build_nap_schedule(n, ppn)
        for pairs, smask in reversed(_step_masks(sched, p)):
            v = lax.psum_scatter(v, intra, scatter_dimension=0, tiled=True)
            recv = lax.ppermute(v, joint, pairs)
            mine = _mask_lookup(smask, chip)
            v = jnp.where(jnp.reshape(mine, (1,) * recv.ndim), v, recv)
    v = lax.psum_scatter(v, intra, scatter_dimension=0, tiled=True)
    return v


def nap_allreduce_large(
    x: jax.Array, *, inter_axes: AxisNames, intra_axes: AxisNames
) -> jax.Array:
    """Node-aware large-message allreduce: NAP-RS + NAP-AG (§VI).

    Bandwidth-optimal data volume with only 2*log_ppn(n) inter-node
    message steps — the paper's proposed future-work algorithm.
    """
    inter, intra = _as_tuple(inter_axes), _as_tuple(intra_axes)
    n, ppn = _sizes(inter, intra)
    p = n * ppn
    orig_shape = x.shape
    flat = x.reshape(-1)
    pad = (-flat.size) % p
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    rows = flat.reshape(p, -1)
    mine = nap_reduce_scatter(rows, inter_axes=inter, intra_axes=intra)
    full = nap_allgather(
        mine[0], inter_axes=inter, intra_axes=intra
    )
    out = full.reshape(-1)
    if pad:
        out = out[: out.size - pad]
    return out.reshape(orig_shape)
