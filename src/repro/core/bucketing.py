"""Bucket scheduler planning for gradient synchronisation.

This module is the *planner* half of the grad-sync bucket scheduler
subsystem: pure host-side math (no jax tracing) that turns the static
metadata of a gradient pytree — leaf sizes, dtypes, transport widths —
into a :class:`BucketPlan` the executor (:mod:`repro.core.grad_sync`)
replays inside ``shard_map`` and the simulator
(:func:`repro.core.simulator.simulate_bucketed_sync`) replays under the
max-rate machine model.  Planning once, on the host, is what turns
grad_sync from "a loop over leaves" into a scheduling layer: every
dispatch decision (NAP vs MLA vs pipelined MLA, pipeline depth, fusion
grouping) is solved here from the §IV cost model and pinned into the
plan, so the traced program, the simulator replay and the cost
accounting all execute the *same* schedule.

Planning rules:

* **reverse-leaf issue order** — backward produces gradients for the
  last layers first, so buckets are packed and issued from the highest
  leaf index down (the Horovod/DDP convention; ChainerMN's
  double-buffered allreduce overlaps the same way).  Issuing a bucket as
  soon as its leaves are complete is what feeds XLA's latency-hiding
  scheduler independent collectives to overlap with remaining backward
  compute.
* **per-dtype fusion** — a fused bucket holds exactly one dtype.  Fusing
  bf16 leaves by casting them to f32 silently doubled transported bytes
  (and pushed buckets past the threshold that admitted their leaves);
  grouping by dtype keeps every leaf at its native transport width.
  Integer leaves never fuse (their overflow/rounding semantics are
  per-leaf) and ride in single-leaf buckets.
* **size-targeted buckets** — the packing target comes from
  :func:`perf_model.optimal_bucket_bytes`: the bucket count that best
  overlaps a uniform-rate backward with the serial network port under
  the same dispatch costs the executor will pay per bucket.
* **chunk-aligned boundaries** — when a bucket lands in the pipelined
  bandwidth regime, its close point is *snapped* so the ragged pipeline
  chunk grid (:func:`napalg.ragged_splits` — the exact offsets
  ``mla_allreduce`` splits at) coincides with leaf boundaries where
  possible (:func:`napalg.chunk_alignment`), instead of chunks
  straddling leaf fragments.  Per-chip inter-node bytes for every fused
  bucket stay at the uneven-block lower bound
  (:func:`napalg.mla_internode_lower_bound`) — asserted in tests.
* **transport-byte budgeting** — compressed (quantised) float leaves are
  budgeted and dispatched at their *packed wire* width, not the raw
  width, so compression genuinely moves the regime boundary.  The width
  may be fractional (0.5 B/elem for int4 nibble packing on the fused
  Pallas transport kernels — :mod:`repro.kernels.transport`); byte
  totals round up per leaf.
"""

from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import Sequence

from . import napalg

__all__ = [
    "LeafSpec",
    "Bucket",
    "BucketPlan",
    "plan_buckets",
    "leaf_specs_for",
]

# how many trailing leaves a snap may move to the next bucket, and the
# smallest bucket (as a fraction of the target) a snap may leave behind
_SNAP_WINDOW = 3
_SNAP_MIN_FRACTION = 0.5


@dataclass(frozen=True)
class LeafSpec:
    """Static metadata of one gradient leaf (host-side, hashable).

    ``transport_itemsize`` is the per-element byte width that actually
    crosses the network — the packed wire width for compressed float
    leaves (possibly fractional: 0.5 for two int4 nibbles per byte),
    the native width otherwise.  All budgeting and dispatch decisions
    use transport bytes (rounded up per leaf).
    """

    index: int
    elems: int
    itemsize: int
    dtype: str
    fusible: bool
    transport_itemsize: int | float | None = None

    @property
    def nbytes(self) -> int:
        return self.elems * self.itemsize

    @property
    def transport_bytes(self) -> int:
        it = self.transport_itemsize
        if it is None:
            return self.elems * self.itemsize
        return int(math.ceil(self.elems * it))


@dataclass(frozen=True)
class Bucket:
    """One fused bucket: which leaves, and the pinned dispatch decision.

    ``leaves`` lists original leaf indices in fusion/issue order
    (reverse-leaf).  ``algorithm``/``chunks`` are the planner's dispatch
    decision for the whole bucket — the executor passes them straight to
    ``hierarchical_allreduce`` so no second decision happens at trace
    time.
    """

    leaves: tuple[int, ...]
    elems: int
    nbytes: int
    transport_bytes: int
    dtype: str
    algorithm: str
    chunks: int = 1

    @property
    def chunk_splits(self) -> tuple[int, ...]:
        """Element count of each ragged pipeline chunk — the exact splits
        the MLA lowering executes and the simulator replays."""
        return napalg.ragged_splits(self.elems, max(1, self.chunks))

    @property
    def chunk_boundaries(self) -> tuple[int, ...]:
        return napalg.chunk_offsets(self.elems, max(1, self.chunks))


@dataclass(frozen=True)
class BucketPlan:
    """A full bucket schedule for one gradient pytree on one grid."""

    n: int
    ppn: int
    target_bytes: float
    crossover_bytes: float
    buckets: tuple[Bucket, ...]
    signature: tuple[tuple[int, str], ...]  # (elems, dtype) per leaf

    @property
    def num_buckets(self) -> int:
        return len(self.buckets)

    @property
    def total_transport_bytes(self) -> int:
        return sum(b.transport_bytes for b in self.buckets)

    def sim_rows(self) -> tuple[tuple[float, str, int, int], ...]:
        """(transport_bytes, algorithm, chunks, elems) per bucket, in
        issue order — the simulator's replay input."""
        return tuple(
            (float(b.transport_bytes), b.algorithm, b.chunks, b.elems)
            for b in self.buckets
        )


def leaf_specs_for(
    shaped_leaves: Sequence, *, transport_itemsize_fn=None
) -> tuple[LeafSpec, ...]:
    """LeafSpecs from anything with ``.size``/``.dtype`` (arrays or
    ShapeDtypeStructs), in leaf-index order."""
    import numpy as np

    specs = []
    for i, leaf in enumerate(shaped_leaves):
        dt = np.dtype(leaf.dtype)
        fusible = bool(np.issubdtype(dt, np.floating))
        tit = (
            transport_itemsize_fn(dt, fusible)
            if transport_itemsize_fn is not None
            else None
        )
        specs.append(
            LeafSpec(
                index=i,
                elems=int(np.prod(leaf.shape)) if leaf.shape else 1,
                itemsize=int(dt.itemsize),
                dtype=dt.name,
                fusible=fusible,
                transport_itemsize=tit,
            )
        )
    return tuple(specs)


def _decide(
    transport_bytes: int,
    topology,
    algorithm: str,
    op: str,
    small_threshold_bytes: int | None,
    pipeline_chunks: int | None,
) -> tuple[str, int]:
    """(engine, pipeline depth) for one bucket — the single dispatch
    decision, made at plan time through the engine registry
    (:func:`repro.core.comm.select_engine`), so the planner and the
    trace-time dispatcher cannot diverge."""
    from . import comm

    if algorithm != "auto":
        spec = comm.get_engine(algorithm)  # validates: listing on typos
        if spec.chunked:
            if pipeline_chunks is not None:
                return algorithm, max(1, int(pipeline_chunks))
            return algorithm, topology.optimal_pipeline_chunks(
                float(transport_bytes)
            )
        if spec.pipelined_variant is not None and pipeline_chunks is not None:
            return algorithm, max(1, int(pipeline_chunks))
        return algorithm, 1
    return tuple(
        comm.select_engine(
            topology,
            int(transport_bytes),
            op=op,
            small_threshold_bytes=small_threshold_bytes,
            pipeline_chunks=pipeline_chunks,
        )
    )


def plan_buckets(
    leaf_specs: tuple[LeafSpec, ...],
    topology,
    ppn: int | None = None,
    *,
    algorithm: str = "auto",
    op: str = "sum",
    small_threshold_bytes: int | None = None,
    pipeline_chunks: int | None = None,
    bucket_bytes: int | None = None,
    fuse: bool = True,
    params=None,
) -> BucketPlan:
    """Pack leaves into size-targeted, dtype-pure, chunk-aligned buckets.

    ``topology`` is a :class:`repro.core.comm.Topology` (preferred) or a
    legacy ``n`` node count with ``ppn`` as the third argument; ``params``
    overrides the topology's machine constants.  Pure in its (hashable)
    inputs and cached — planning runs once per (pytree structure x
    topology x config), off the trace path.  Buckets come back in
    reverse-leaf issue order; every leaf appears in exactly one bucket.
    """
    import dataclasses as _dc

    from . import comm

    if isinstance(topology, comm.Topology):
        topo = topology
        if params is not None:
            topo = _dc.replace(topo, params=params)
    else:
        topo = comm.Topology.of(int(topology), int(ppn or 1), params=params)
    return _plan_buckets_cached(
        leaf_specs,
        topo,
        algorithm,
        op,
        small_threshold_bytes,
        pipeline_chunks,
        bucket_bytes,
        fuse,
    )


@functools.lru_cache(maxsize=None)
def _plan_buckets_cached(
    leaf_specs: tuple[LeafSpec, ...],
    topo,
    algorithm: str,
    op: str,
    small_threshold_bytes: int | None,
    pipeline_chunks: int | None,
    bucket_bytes: int | None,
    fuse: bool,
) -> BucketPlan:
    n, ppn = topo.n_nodes, topo.ppn
    total_fusible = sum(
        ls.transport_bytes for ls in leaf_specs if ls.fusible
    )
    if bucket_bytes is not None:
        target = float(bucket_bytes)
    else:
        target = topo.optimal_bucket_bytes(float(max(total_fusible, 1)))
    xo = topo.crossover_bytes()

    buckets: list[Bucket] = []

    def decide(tbytes: int) -> tuple[str, int]:
        return _decide(
            tbytes, topo, algorithm, op,
            small_threshold_bytes, pipeline_chunks,
        )

    def close(run: list[LeafSpec]) -> None:
        if not run:
            return
        tbytes = sum(ls.transport_bytes for ls in run)
        algo, chunks = decide(tbytes)
        buckets.append(
            Bucket(
                leaves=tuple(ls.index for ls in run),
                elems=sum(ls.elems for ls in run),
                nbytes=sum(ls.nbytes for ls in run),
                transport_bytes=tbytes,
                dtype=run[0].dtype,
                algorithm=algo,
                chunks=chunks,
            )
        )

    def snap(run: list[LeafSpec]) -> list[LeafSpec]:
        """Close point snapped to the ragged chunk grid.

        Considers keeping the whole run or moving up to ``_SNAP_WINDOW``
        trailing leaves to the next bucket; scores each candidate by how
        well its pipeline chunk boundaries coincide with leaf boundaries
        (:func:`napalg.chunk_alignment`).  Returns the leaves deferred to
        the next bucket.
        """
        best_keep, best_score = len(run), -1.0
        for keep in range(len(run), max(len(run) - _SNAP_WINDOW, 1) - 1, -1):
            cand = run[:keep]
            tbytes = sum(ls.transport_bytes for ls in cand)
            if keep < len(run) and tbytes < _SNAP_MIN_FRACTION * target:
                break
            _, chunks = decide(tbytes)
            score = napalg.chunk_alignment(
                tuple(ls.elems for ls in cand), chunks
            )
            if score > best_score + 1e-12:
                best_keep, best_score = keep, score
            if score >= 1.0 and keep == len(run):
                break  # whole run already aligned: no need to shrink
        deferred = run[best_keep:]
        close(run[:best_keep])
        return deferred

    # one open fusion buffer per dtype (the Horovod/DDP idiom): a stray
    # f32 norm between bf16 matmul grads must not flush the bf16 run —
    # it accumulates in its own run instead, so dtype purity costs no
    # fragmentation.  A bucket is only issuable once its *last* leaf is
    # produced, so closing buffers as they fill (and flushing leftovers
    # at the end, most-recently-fed first) preserves readiness order.
    runs: dict[str, list[LeafSpec]] = {}
    touch: list[str] = []
    for ls in sorted(leaf_specs, key=lambda l: -l.index):
        if not fuse or not ls.fusible:
            close([ls])  # int / unfusible leaf: its own bucket, in place
            continue
        run = runs.setdefault(ls.dtype, [])
        if ls.dtype in touch:
            touch.remove(ls.dtype)
        touch.append(ls.dtype)
        run.append(ls)
        if sum(l.transport_bytes for l in run) >= target:
            runs[ls.dtype] = snap(run)
    for dt in touch:
        run = runs.get(dt) or []
        while run:
            run = snap(run)

    return BucketPlan(
        n=n,
        ppn=ppn,
        target_bytes=float(target),
        crossover_bytes=float(xo),
        buckets=tuple(buckets),
        signature=tuple((ls.elems, ls.dtype) for ls in leaf_specs),
    )
