"""shard_map implementations of the paper's allreduce algorithms.

Every function here is designed to be called *inside* a
``jax.shard_map``-traced function (or any context with named mesh axes).
The communication schedules are computed statically from the mesh axis
sizes (``jax.lax.axis_size``) by :mod:`repro.core.napalg`, then lowered to
``jax.lax.ppermute`` / ``psum`` calls — one ``collective-permute`` HLO per
inter-node step, which is exactly the quantity the paper minimizes.

TPU mapping (DESIGN.md §2): "node" = pod (ICI domain), "ppn" = chips per
pod, "inter-node network" = inter-pod DCI.  The same functions work for
any two-level mesh-axis hierarchy.

Algorithms:

* :func:`nap_allreduce` — the paper's contribution (§III): intra psum,
  ``ceil(log_ppn(n))`` joint-axis collective-permutes, intra psums.
* :func:`rd_allreduce` — node-agnostic recursive doubling (§II, Fig. 3).
* :func:`smp_allreduce` — MPICH's node-aware master-process algorithm
  (§II.A, Fig. 4).
* :func:`ring_allreduce` — bandwidth-optimal ring reduce-scatter +
  allgather (Patarasuk & Yuan, cited as [25]).
* :func:`rabenseifner_allreduce` — reduce-scatter + allgather via native
  XLA collectives (§II, [5], [8]); the "large message" baseline.
* :func:`mla_allreduce` — multi-lane node-aware allreduce: the pod partial
  is striped across local ranks (intra ``psum_scatter``), every lane runs
  reduce-scatter + allgather over the slow domain concurrently with
  ``s/ppn`` bytes, then an intra ``all_gather`` rebuilds the payload.  The
  bandwidth-regime engine (§VI future work, executed).  Supports
  ``op="sum"|"max"|"min"`` (dtype-aware pad identities) and
  ``pipeline_chunks=C``: the payload is split into ``C`` ragged chunks
  (``napalg.ragged_splits`` — no pad elements) whose independent
  collectives XLA can overlap, chunk ``c``'s inter-pod phase against
  chunk ``c+1``'s intra-pod phase.
* :func:`hierarchical_allreduce` — op-safe three-regime dispatcher: NAP
  for small payloads (latency regime), MLA for large ones (bandwidth
  regime, pipelined above the model's chunking threshold), plain psum
  when the mesh has no slow domain.  The NAP↔MLA switch point comes from
  the §IV cost model (:func:`perf_model.crossover_bytes`) for the actual
  grid shape, not a hardcoded constant; the MLA↔pipelined-MLA depth comes
  from :func:`perf_model.optimal_pipeline_chunks`.  Degenerate grids fall
  back identically in both threshold modes (fixed and modeled): ``psum``
  for ``n <= 1``, RS+AG for ``ppn == 1``.
"""

from __future__ import annotations

import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from . import napalg
from .. import compat

__all__ = [
    "nap_allreduce",
    "rd_allreduce",
    "smp_allreduce",
    "ring_allreduce",
    "rabenseifner_allreduce",
    "mla_allreduce",
    "mla_pipelined_allreduce",
    "mla_reduce_scatter",
    "mla_allgather",
    "flat_reduce_scatter",
    "flat_allgather",
    "hierarchical_allreduce",
    "select_algorithm",
    "auto_crossover_bytes",
    "ALGORITHMS",
]

AxisNames = str | tuple[str, ...]


def _as_tuple(axes: AxisNames) -> tuple[str, ...]:
    return (axes,) if isinstance(axes, str) else tuple(axes)


# op registry: (pairwise fold, named-axis reduce, identity)
_OPS: dict[str, tuple[Callable, Callable, float]] = {
    "sum": (jnp.add, lax.psum, 0.0),
    "max": (jnp.maximum, lax.pmax, -jnp.inf),
    "min": (jnp.minimum, lax.pmin, jnp.inf),
}

# ops each bandwidth-regime engine can execute; the dispatcher never
# routes an op to an engine outside its set (op-safe dispatch)
_MLA_OPS = frozenset({"sum", "max", "min"})

# axis-wise reducers for the explicit (non-psum_scatter) reduce-scatter
_AXIS_REDUCERS: dict[str, Callable] = {
    "sum": jnp.sum,
    "max": jnp.max,
    "min": jnp.min,
}


def _op_identity(op: str, dtype) -> jax.Array:
    """Dtype-correct reduction identity (used for ragged padding).

    ``sum`` pads with zeros of the payload dtype; ``max``/``min`` use the
    dtype's own extremes — ``jnp.iinfo`` bounds for integers (a float
    ``-inf`` would silently promote integer payloads to float) and
    ``±inf`` for floats (representable in f32/bf16/f16).
    """
    dtype = jnp.dtype(dtype)
    if op == "sum":
        return jnp.zeros((), dtype)
    if jnp.issubdtype(dtype, jnp.integer):
        info = jnp.iinfo(dtype)
        return jnp.asarray(info.min if op == "max" else info.max, dtype)
    return jnp.asarray(-jnp.inf if op == "max" else jnp.inf, dtype)


def _needs_f32_accum(dtype) -> bool:
    """Whether cross-node sums of this dtype must accumulate in f32."""
    dt = jnp.dtype(dtype)
    return jnp.issubdtype(dt, jnp.floating) and dt.itemsize < 4


def _f32_fold(fold: Callable, op: str, dtype) -> Callable:
    """Pairwise fold that accumulates sub-f32 float sums in float32.

    The wire payload keeps its native dtype — upcast happens *after*
    receive and the result is downcast *before* the next send — so
    transport bytes are unchanged; only the local accumulate runs wide.
    ``max``/``min`` lose nothing to low precision and keep the plain
    fold.  This is the executed counterpart of the spmd-lint
    numerics-flow rule: a bf16 payload must never feed a cross-node
    reduction directly.
    """
    if op != "sum" or not _needs_f32_accum(dtype):
        return fold
    dtype = jnp.dtype(dtype)

    def wide_fold(a: jax.Array, b: jax.Array) -> jax.Array:
        return fold(
            a.astype(jnp.float32), b.astype(jnp.float32)
        ).astype(dtype)

    return wide_fold


def _chip_index(inter_axes: tuple[str, ...], intra_axes: tuple[str, ...]):
    """SMP-style flat chip id: node-major, local-rank-minor."""
    node = 0
    for ax in inter_axes:
        node = node * compat.axis_size(ax) + lax.axis_index(ax)
    rank = 0
    for ax in intra_axes:
        rank = rank * compat.axis_size(ax) + lax.axis_index(ax)
    ppn = int(np.prod([compat.axis_size(ax) for ax in intra_axes]))
    return node * ppn + rank


def _mask_lookup(mask: np.ndarray, chip) -> jax.Array:
    """Per-chip boolean from a host-side mask table (tiny constant)."""
    return jnp.asarray(mask)[chip]


# ---------------------------------------------------------------------------
# NAP allreduce — the paper's algorithm
# ---------------------------------------------------------------------------


def nap_allreduce(
    x: jax.Array,
    *,
    inter_axes: AxisNames,
    intra_axes: AxisNames,
    op: str = "sum",
) -> jax.Array:
    """Node-Aware Parallel allreduce (paper §III, Algorithm 1).

    Reduces ``x`` over the combined ``inter_axes x intra_axes`` device
    grid.  Each inter-node step lowers to a single ``collective-permute``
    over the *joint* axes (plus rare donor rounds for ragged node counts),
    so a chip sends at most ``ceil(log_ppn(n))`` inter-node messages —
    versus ``log2(n)`` for recursive doubling.

    Args:
      x: per-chip value (any shape); identical reduction returned on every
        chip of the grid.
      inter_axes: mesh axis name(s) spanning the *slow* domain (pods).
      intra_axes: mesh axis name(s) spanning the *fast* domain (chips
        within a pod).
      op: "sum" | "max" | "min".
    """
    inter, intra = _as_tuple(inter_axes), _as_tuple(intra_axes)
    fold, named_reduce, _ = _OPS[op]
    fold = _f32_fold(fold, op, x.dtype)
    n = int(np.prod([compat.axis_size(ax) for ax in inter]))
    ppn = int(np.prod([compat.axis_size(ax) for ax in intra]))
    sched = napalg.build_nap_schedule(n, ppn)
    joint = inter + intra

    v = named_reduce(x, intra)
    if not sched.steps:
        return v
    chip = _chip_index(inter, intra)
    # dtype-correct identity for every op: integer max/min must use the
    # iinfo extremes (a float ±inf identity silently promoted integer
    # payloads to float), and sum must stay in the payload dtype.
    ident = _op_identity(op, v.dtype)
    # Host-constant mask tables (cached per (n, ppn)) + a single masked
    # accumulation per round: the accumulator starts from the self
    # contribution instead of an identity-filled temporary, so each
    # inter-node step lowers to one select per round rather than the
    # full_like + where + fold chain per mask.
    for step, (rmasks, smask) in zip(
        sched.steps, napalg.step_mask_tables(n, ppn)
    ):
        acc = jnp.where(_mask_lookup(smask, chip), v, ident)
        for rnd, rmask in zip(step.rounds, rmasks):
            recv = lax.ppermute(v, joint, rnd)
            acc = jnp.where(_mask_lookup(rmask, chip), fold(acc, recv), acc)
        v = named_reduce(acc, intra)
    return v


# ---------------------------------------------------------------------------
# point-to-point schedule executor (RD / SMP baselines)
# ---------------------------------------------------------------------------


def _run_p2p_schedule(
    x: jax.Array,
    sched: napalg.P2PSchedule,
    joint: tuple[str, ...],
    inter: tuple[str, ...],
    intra: tuple[str, ...],
    op: str,
) -> jax.Array:
    fold, _, _ = _OPS[op]
    fold = _f32_fold(fold, op, x.dtype)
    chip = _chip_index(inter, intra)
    v = x
    for step, rmask in zip(sched.steps, napalg.p2p_recv_masks(sched)):
        recv = lax.ppermute(v, joint, step.pairs)
        flag = _mask_lookup(rmask, chip)
        if step.combine:
            v = jnp.where(flag, fold(v, recv), v)
        else:
            v = jnp.where(flag, recv, v)
    return v


def rd_allreduce(
    x: jax.Array,
    *,
    inter_axes: AxisNames,
    intra_axes: AxisNames = (),
    op: str = "sum",
) -> jax.Array:
    """Node-agnostic recursive doubling over the flattened device grid.

    The classic butterfly (paper Fig. 3): ``log2(p)`` pairwise exchange
    steps, each lowering to one collective-permute.  Node-oblivious — at
    every inter-node step *all* chips of a node cross the slow domain with
    duplicate payloads, which is precisely the waste NAP removes.
    """
    inter, intra = _as_tuple(inter_axes), _as_tuple(intra_axes)
    joint = inter + intra
    n = int(np.prod([compat.axis_size(ax) for ax in inter]))
    ppn = int(np.prod([compat.axis_size(ax) for ax in intra])) if intra else 1
    sched = napalg.build_rd_schedule(n, ppn)
    return _run_p2p_schedule(x, sched, joint, inter, intra, op)


def smp_allreduce(
    x: jax.Array,
    *,
    inter_axes: AxisNames,
    intra_axes: AxisNames,
    op: str = "sum",
) -> jax.Array:
    """MPICH SMP allreduce (paper §II.A, Fig. 4).

    Local reduce to a master chip per pod, recursive doubling among the
    masters, local broadcast.  Same inter-node message *count* as RD but
    only one active chip per pod (no duplicate bytes, no injection
    pressure; all other chips idle — the imbalance NAP fixes).
    """
    inter, intra = _as_tuple(inter_axes), _as_tuple(intra_axes)
    joint = inter + intra
    n = int(np.prod([compat.axis_size(ax) for ax in inter]))
    ppn = int(np.prod([compat.axis_size(ax) for ax in intra]))
    sched = napalg.build_smp_schedule(n, ppn)
    return _run_p2p_schedule(x, sched, joint, inter, intra, op)


# ---------------------------------------------------------------------------
# bandwidth-regime baselines
# ---------------------------------------------------------------------------


def ring_allreduce(
    x: jax.Array, *, axes: AxisNames, op: str = "sum"
) -> jax.Array:
    """Bandwidth-optimal ring allreduce (reduce-scatter + allgather).

    ``2 (p-1)`` steps of neighbour exchange over the ring formed by the
    flattened ``axes``; each chip moves ``2 s (p-1)/p`` bytes — the data
    lower bound (paper §II, [25]).  Latency-poor for small ``s``.
    """
    fold, _, _ = _OPS[op]
    fold = _f32_fold(fold, op, x.dtype)
    ax = _as_tuple(axes)
    p = int(np.prod([compat.axis_size(a) for a in ax]))
    if p == 1:
        return x
    orig_shape, orig_dtype = x.shape, x.dtype
    flat = x.reshape(-1)
    pad = (-flat.size) % p
    if pad:
        # op-correct pad identity: zeros would corrupt max over
        # all-negative payloads (and min over all-positive ones)
        flat = jnp.concatenate(
            [flat, jnp.full((pad,), _op_identity(op, flat.dtype))]
        )
    chunks = flat.reshape(p, -1)
    idx = 0
    for a in ax:
        idx = idx * compat.axis_size(a) + lax.axis_index(a)
    fwd = [(i, (i + 1) % p) for i in range(p)]

    # reduce-scatter: after p-1 shifts, chip i owns the full sum of chunk
    # (i+1) mod p.
    def rs_body(k, carry):
        chunks, acc = carry
        send = lax.dynamic_index_in_dim(
            chunks, (idx - k) % p, axis=0, keepdims=False
        )
        payload = jnp.where(k == 0, send, acc)
        recv = lax.ppermute(payload, ax, fwd)
        own = lax.dynamic_index_in_dim(
            chunks, (idx - k - 1) % p, axis=0, keepdims=False
        )
        return chunks, fold(recv, own)

    _, acc = lax.fori_loop(0, p - 1, rs_body, (chunks, chunks[0]))

    # allgather ring: circulate the owned chunk p-1 times.
    def ag_body(k, carry):
        chunks, cur = carry
        recv = lax.ppermute(cur, ax, fwd)
        owner = (idx - k - 1) % p  # chunk id arriving at step k
        chunks = lax.dynamic_update_index_in_dim(
            chunks, recv, (owner + 1) % p, axis=0
        )
        return chunks, recv

    chunks = lax.dynamic_update_index_in_dim(
        chunks, acc, (idx + 1) % p, axis=0
    )
    chunks, _ = lax.fori_loop(0, p - 1, ag_body, (chunks, acc))
    out = chunks.reshape(-1)
    if pad:
        out = out[: out.size - pad]
    return out.reshape(orig_shape).astype(orig_dtype)


def rabenseifner_allreduce(
    x: jax.Array, *, axes: AxisNames, op: str = "sum"
) -> jax.Array:
    """Reduce-scatter + allgather via native XLA collectives ([5], [8]).

    Optimal data transport with ``2 log2(p)`` message steps; the paper's
    recommended regime for reductions above ~2 KiB.  For ``sum`` XLA
    emits ``reduce-scatter`` + ``all-gather`` directly, so on TPU this
    also enjoys ICI pipelining.  ``max``/``min`` (which
    ``lax.psum_scatter`` cannot express) realize the reduce-scatter as
    ``all_to_all`` + a local fold — identical byte transport
    (``(p-1)/p * s`` each way) — with dtype-correct pad identities.
    """
    if op not in _MLA_OPS:
        raise NotImplementedError(
            f"rabenseifner path supports {sorted(_MLA_OPS)}, got {op!r}"
        )
    ax = _as_tuple(axes)
    p = int(np.prod([compat.axis_size(a) for a in ax]))
    if p == 1:
        return x
    orig_shape, orig_dtype = x.shape, x.dtype
    flat = x.reshape(-1)
    pad = (-flat.size) % p
    if pad:
        flat = jnp.concatenate(
            [flat, jnp.full((pad,), _op_identity(op, flat.dtype))]
        )
    tiles = flat.reshape(p, -1)
    if op == "sum" and not _needs_f32_accum(flat.dtype):
        shard = lax.psum_scatter(tiles, ax, scatter_dimension=0, tiled=False)
    else:
        # reduce-scatter(max/min): every chip scatters tile j to chip j,
        # receives all chips' copies of its own tile, folds locally.
        # Sub-f32 float sums take the same route so the fold can run in
        # f32 (psum_scatter would accumulate on the wire dtype) — the
        # transport stays at native width either way.
        gathered = lax.all_to_all(
            tiles[:, None, :], ax, split_axis=0, concat_axis=1, tiled=False
        )
        if op == "sum":
            shard = (
                gathered[0].astype(jnp.float32).sum(axis=0)
            ).astype(flat.dtype)
        else:
            shard = _AXIS_REDUCERS[op](gathered[0], axis=0)
    out = lax.all_gather(shard, ax, axis=0, tiled=False).reshape(-1)
    if pad:
        out = out[: out.size - pad]
    return out.reshape(orig_shape).astype(orig_dtype)


# ---------------------------------------------------------------------------
# MLA allreduce — multi-lane node-aware bandwidth path
# ---------------------------------------------------------------------------


def _mla_one_chunk(
    flat: jax.Array,
    inter: tuple[str, ...],
    intra: tuple[str, ...],
    n: int,
    ppn: int,
    op: str,
) -> jax.Array:
    """One chunk of the MLA allreduce (flat 1-D payload in, same out)."""
    size = flat.size
    pad = (-size) % ppn
    if pad:
        # the pad identity never crosses the slow domain logically: the
        # ragged schedule/accounting (napalg.mla_stripe_geometry) charges
        # only real elements, and the identity is op/dtype-correct so the
        # result is exact either way
        flat = jnp.concatenate(
            [flat, jnp.full((pad,), _op_identity(op, flat.dtype))]
        )
    tiles = flat.reshape(ppn, -1)
    if op == "sum":
        # phase 1: stripe the pod partial across local ranks
        stripe = lax.psum_scatter(tiles, intra, scatter_dimension=0, tiled=False)
    else:
        gathered = lax.all_to_all(
            tiles[:, None, :], intra, split_axis=0, concat_axis=1, tiled=False
        )
        stripe = _AXIS_REDUCERS[op](gathered[0], axis=0)
    # phase 2: per-lane RS+AG across the slow domain (ppn parallel lanes)
    if n > 1:
        stripe = rabenseifner_allreduce(stripe, axes=inter, op=op)
    # phase 3: rebuild the full payload inside the pod
    out = lax.all_gather(stripe, intra, axis=0, tiled=False).reshape(-1)
    if pad:
        out = out[: out.size - pad]
    return out


def mla_allreduce(
    x: jax.Array,
    *,
    inter_axes: AxisNames,
    intra_axes: AxisNames,
    op: str = "sum",
    pipeline_chunks: int = 1,
) -> jax.Array:
    """Multi-lane node-aware allreduce (the bandwidth-regime engine).

    Three phases, mirroring :func:`napalg.build_mla_schedule`:

      1. intra-pod reduce-scatter stripes the pod-local partial across
         the ``ppn`` local ranks — rank ``r`` owns stripe ``r`` of
         ``s/ppn`` bytes (``psum_scatter`` for sum; ``all_to_all`` + a
         local fold for max/min, same byte transport);
      2. every lane ``r`` runs an independent reduce-scatter + allgather
         over ``inter_axes`` — all ``ppn`` lanes cross the slow domain
         concurrently with ``s/ppn`` bytes each, instead of every chip
         carrying the full ``s`` (the §II duplicate-traffic waste) or a
         single master serialising the node's bandwidth (SMP);
      3. intra-pod ``all_gather`` rebuilds the full reduced payload.

    Per-chip inter-node traffic is ``~2*(s/ppn)*(n-1)/n`` — the data lower
    bound divided across all local ranks — which is why this wins the
    large-message regime the paper's §VI leaves as future work.

    ``pipeline_chunks=C > 1`` splits the payload into ``C`` *ragged*
    chunks (:func:`napalg.ragged_splits` — uneven sizes, no pad elements
    at the chunk level) and runs the three phases per chunk.  The chunks
    carry no data dependencies on each other, so XLA's async collectives
    can overlap chunk ``c``'s inter-pod phase with chunk ``c±1``'s
    intra-pod phases (ICI vs DCI — distinct networks), the chunk-level
    overlap of Träff's doubly-pipelined scheme.  The model-optimal depth
    comes from :func:`perf_model.optimal_pipeline_chunks`; the ``auto``
    dispatcher applies it for payloads past the chunking threshold.
    """
    if op not in _MLA_OPS:
        raise NotImplementedError(
            f"mla path supports {sorted(_MLA_OPS)}, got {op!r}"
        )
    inter, intra = _as_tuple(inter_axes), _as_tuple(intra_axes)
    ppn = int(np.prod([compat.axis_size(ax) for ax in intra]))
    n = int(np.prod([compat.axis_size(ax) for ax in inter]))
    if ppn == 1:
        return rabenseifner_allreduce(x, axes=inter, op=op)
    orig_shape, orig_dtype = x.shape, x.dtype
    flat = x.reshape(-1)
    chunks = max(1, min(int(pipeline_chunks), flat.size))
    if chunks == 1:
        out = _mla_one_chunk(flat, inter, intra, n, ppn, op)
        return out.reshape(orig_shape).astype(orig_dtype)
    parts = []
    off = 0
    for ce in napalg.ragged_splits(flat.size, chunks):
        if ce == 0:
            continue
        parts.append(
            _mla_one_chunk(flat[off : off + ce], inter, intra, n, ppn, op)
        )
        off += ce
    out = jnp.concatenate(parts)
    return out.reshape(orig_shape).astype(orig_dtype)


def mla_pipelined_allreduce(
    x: jax.Array,
    *,
    inter_axes: AxisNames,
    intra_axes: AxisNames,
    op: str = "sum",
    pipeline_chunks: int | None = None,
    params=None,
) -> jax.Array:
    """MLA with the pipeline depth solved from the §IV cost model.

    ``pipeline_chunks=None`` asks :func:`perf_model.optimal_pipeline_chunks`
    for the depth that balances the extra per-chunk alpha steps against
    the intra/inter overlap for this payload and grid — the same decision
    the simulator replays and ``select_algorithm`` dispatches on.  Pass
    the same ``params`` (MachineParams) given to ``select_algorithm`` so
    the dispatch decision and the executed depth are solved under one
    machine model (default: TPU_V5E_POD, matching the dispatcher).
    """
    if pipeline_chunks is None:
        from . import perf_model as pm

        inter, intra = _as_tuple(inter_axes), _as_tuple(intra_axes)
        n = int(np.prod([compat.axis_size(ax) for ax in inter]))
        ppn = int(np.prod([compat.axis_size(ax) for ax in intra]))
        nbytes = float(int(np.prod(x.shape)) * x.dtype.itemsize)
        pipeline_chunks = pm.optimal_pipeline_chunks(
            nbytes, n, ppn, params or pm.TPU_V5E_POD
        )
    return mla_allreduce(
        x,
        inter_axes=inter_axes,
        intra_axes=intra_axes,
        op=op,
        pipeline_chunks=pipeline_chunks,
    )


# ---------------------------------------------------------------------------
# reduce-scatter / allgather — first-class striped collectives
# ---------------------------------------------------------------------------


def _level_reduce_scatter(
    flat: jax.Array, axes, k: int, op: str, *, f32_accum: bool = False
) -> jax.Array:
    """One reduce-scatter level: pad to ``k``, scatter tile ``i`` to the
    rank of index ``i`` along ``axes`` (psum_scatter for sum, all_to_all
    + fold for max/min — same byte transport).

    ``f32_accum=True`` marks a level that crosses the slow domain: a
    sub-f32 float sum then routes through ``all_to_all`` + an f32 fold
    (native wire width, wide accumulate) instead of letting
    ``psum_scatter`` accumulate on the wire dtype.
    """
    if k <= 1:
        return flat
    pad = (-flat.size) % k
    if pad:
        flat = jnp.concatenate(
            [flat, jnp.full((pad,), _op_identity(op, flat.dtype))]
        )
    tiles = flat.reshape(k, -1)
    wide = f32_accum and op == "sum" and _needs_f32_accum(flat.dtype)
    if op == "sum" and not wide:
        return lax.psum_scatter(tiles, axes, scatter_dimension=0, tiled=False)
    gathered = lax.all_to_all(
        tiles[:, None, :], axes, split_axis=0, concat_axis=1, tiled=False
    )
    if wide:
        return (
            gathered[0].astype(jnp.float32).sum(axis=0)
        ).astype(flat.dtype)
    return _AXIS_REDUCERS[op](gathered[0], axis=0)


def mla_reduce_scatter(
    x: jax.Array,
    *,
    inter_axes: AxisNames,
    intra_axes: AxisNames,
    op: str = "sum",
) -> jax.Array:
    """Node-aware striped reduce-scatter — the RS half of the MLA
    allreduce, promoted to a public collective.

    Two levels: the pod partial is striped across the ``ppn`` local
    lanes (intra reduce-scatter), then every lane reduce-scatters its
    stripe over the slow domain — chip ``(node j, lane r)`` ends up
    owning the fully reduced block ``(r, j)`` of the MLA stripe layout
    (:func:`napalg.mla_stripe_geometry`, uniform-padded for SPMD shape
    agreement like the MLA lowering).  Per-chip inter-node bytes are
    half the allreduce round trip — the ZeRO-style sharded-optimizer
    sync primitive.  Inverse: :func:`mla_allgather`.
    """
    if op not in _MLA_OPS:
        raise NotImplementedError(
            f"mla_reduce_scatter supports {sorted(_MLA_OPS)}, got {op!r}"
        )
    inter, intra = _as_tuple(inter_axes), _as_tuple(intra_axes)
    ppn = int(np.prod([compat.axis_size(ax) for ax in intra])) if intra else 1
    n = int(np.prod([compat.axis_size(ax) for ax in inter])) if inter else 1
    flat = x.reshape(-1)
    stripe = _level_reduce_scatter(flat, intra, ppn, op)
    return _level_reduce_scatter(stripe, inter, n, op, f32_accum=True)


def mla_allgather(
    x: jax.Array,
    *,
    inter_axes: AxisNames,
    intra_axes: AxisNames,
    elems: int | None = None,
) -> jax.Array:
    """Node-aware striped allgather — the AG half of the MLA allreduce.

    Exact inverse of :func:`mla_reduce_scatter` on the same topology:
    every lane allgathers its blocks over the slow domain (rebuilding
    its stripe), then an intra-pod allgather rebuilds the flat payload.
    ``elems`` is the original payload size, needed to strip the
    uniform-shape padding (default: assume no padding was required).
    """
    inter, intra = _as_tuple(inter_axes), _as_tuple(intra_axes)
    ppn = int(np.prod([compat.axis_size(ax) for ax in intra])) if intra else 1
    n = int(np.prod([compat.axis_size(ax) for ax in inter])) if inter else 1
    shard = x.reshape(-1)
    if elems is None:
        elems = shard.size * n * ppn
    stripe_len = -(-int(elems) // ppn)  # ceil: the intra-RS stripe size
    if n > 1:
        stripe = lax.all_gather(shard, inter, axis=0, tiled=False).reshape(-1)
        stripe = stripe[:stripe_len]
    else:
        stripe = shard[:stripe_len]
    if ppn > 1:
        full = lax.all_gather(stripe, intra, axis=0, tiled=False).reshape(-1)
    else:
        full = stripe
    return full[: int(elems)]


def flat_reduce_scatter(
    x: jax.Array, *, axes: AxisNames, op: str = "sum", f32_accum: bool = False
) -> jax.Array:
    """Single-level (node-agnostic) reduce-scatter over the flattened
    ``axes`` grid — the fallback engine when there is no slow domain.
    ``f32_accum=True`` (set by the dispatcher when the flattened grid
    does cross nodes) keeps sub-f32 sums accumulating in f32."""
    if op not in _MLA_OPS:
        raise NotImplementedError(
            f"flat_reduce_scatter supports {sorted(_MLA_OPS)}, got {op!r}"
        )
    ax = _as_tuple(axes)
    p = int(np.prod([compat.axis_size(a) for a in ax])) if ax else 1
    return _level_reduce_scatter(x.reshape(-1), ax, p, op, f32_accum=f32_accum)


def flat_allgather(
    x: jax.Array, *, axes: AxisNames, elems: int | None = None
) -> jax.Array:
    """Single-level allgather over the flattened ``axes`` grid — inverse
    of :func:`flat_reduce_scatter` (chip-order tile layout)."""
    ax = _as_tuple(axes)
    p = int(np.prod([compat.axis_size(a) for a in ax])) if ax else 1
    shard = x.reshape(-1)
    if p <= 1:
        out = shard
    else:
        out = lax.all_gather(shard, ax, axis=0, tiled=False).reshape(-1)
    if elems is None:
        elems = shard.size * p
    return out[: int(elems)]


# ---------------------------------------------------------------------------
# dispatcher — thin delegates over the engine registry (repro.core.comm)
# ---------------------------------------------------------------------------


def _psum_allreduce(x, *, inter_axes, intra_axes=(), op="sum", **_):
    _, named_reduce, _ = _OPS[op]
    inter = _as_tuple(inter_axes)
    joint = inter + _as_tuple(intra_axes)
    if op == "sum" and inter and _needs_f32_accum(x.dtype):
        # the native psum accumulates on the wire dtype; a cross-node
        # bf16 sum must run in f32 (spmd-lint numerics-flow rule)
        return named_reduce(x.astype(jnp.float32), joint).astype(x.dtype)
    return named_reduce(x, joint)


def __getattr__(name: str):
    # ``ALGORITHMS`` is a *view* of the engine registry now — the
    # registry (repro.core.comm) is the single source of truth, and this
    # legacy alias stays importable for existing callers.
    if name == "ALGORITHMS":
        from . import comm

        return comm.legacy_execute_table()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


@functools.lru_cache(maxsize=None)
def auto_crossover_bytes(n: int, ppn: int, params=None) -> float:
    """Model-driven NAP↔MLA crossover for an (n, ppn) grid (cached).

    Legacy alias of :meth:`repro.core.comm.Topology.crossover_bytes` —
    solved from the §IV max-rate cost model for the actual grid shape
    and machine constants, never a hardcoded byte count.

    Returns ``math.inf`` when NAP never loses within the model's search
    range (saturated crossover — machines whose alpha bill dwarfs the
    bandwidth term).  Callers must treat infinity as "latency regime for
    every payload", not clamp it to a byte count: the dispatch then
    routes everything to NAP, and the grad-sync planner keeps its
    *fusion* bucket target on the separate
    :func:`perf_model.optimal_bucket_bytes` optimum, which stays finite.
    """
    from . import comm

    return comm.Topology.of(n, ppn, params=params).crossover_bytes()


def select_algorithm(
    nbytes: int,
    n: int,
    ppn: int,
    params=None,
    op: str = "sum",
    small_threshold_bytes: int | None = None,
) -> str:
    """The op-safe three-regime dispatch decision (host-side, static).

    Legacy wrapper over :func:`repro.core.comm.select_engine` — the
    capability-filtered cost tournament over the registered engines:

    * no slow domain (``n <= 1``) — "psum": single-level native reduce;
    * ``ppn == 1`` — "mla" (degenerates to RS+AG over the slow domain):
      NAP needs ``ppn >= 2`` to trade steps for lanes, in *both*
      threshold modes;
    * ``nbytes`` at or below the crossover — "nap": latency regime,
      ``log_ppn(n)`` inter-node steps;
    * above it — the bandwidth tournament: "mla_pipelined" when chunked
      intra/inter overlap strictly beats plain MLA under the declared
      cost models, plain "mla" otherwise.

    ``op`` guards the decision through the engines' declared capability
    sets — dispatch cannot route a payload to an engine that would raise
    at trace time.  ``small_threshold_bytes`` overrides the modeled
    crossover with a fixed byte threshold; the degenerate-grid fallbacks
    above apply identically.
    """
    from . import comm

    return comm.select_engine(
        comm.Topology.of(n, ppn, params=params),
        int(nbytes),
        op=op,
        small_threshold_bytes=small_threshold_bytes,
    ).engine


def hierarchical_allreduce(
    x: jax.Array,
    *,
    inter_axes: AxisNames,
    intra_axes: AxisNames,
    algorithm: str = "auto",
    op: str = "sum",
    small_threshold_bytes: int | None = None,
    pipeline_chunks: int | None = None,
) -> jax.Array:
    """Allreduce over a two-level hierarchy with a model-driven switch.

    .. deprecated::
        Thin shim over the topology-first API: builds a
        :class:`repro.core.comm.Topology` from the axis names and a
        default policy, then calls
        :meth:`repro.core.comm.CommContext.allreduce`.  Warns once.

    ``algorithm="auto"`` runs the engine-registry dispatch (NAP below
    the modeled NAP↔MLA crossover, the striped multi-lane MLA path above
    it — chunk-pipelined when the cost tournament says the payload
    amortises the extra latency steps — plain psum when there is no slow
    domain), op-aware through the engines' declared capability sets.
    ``small_threshold_bytes`` overrides the modeled crossover;
    ``pipeline_chunks`` pins the MLA pipeline depth.
    """
    from . import comm

    comm.warn_deprecated_once(
        "collectives.hierarchical_allreduce", "CommContext.allreduce"
    )
    ctx = comm.CommContext(
        comm.Topology.from_axes(inter_axes, intra_axes),
        comm.CommPolicy(
            algorithm=algorithm,
            small_threshold_bytes=small_threshold_bytes,
            pipeline_chunks=pipeline_chunks,
        ),
    )
    return ctx.allreduce(x, op=op)
