from . import (
    bucketing,
    collectives,
    comm,
    extensions,
    grad_sync,
    napalg,
    perf_model,
    simulator,
)
from .collectives import (
    hierarchical_allreduce,
    nap_allreduce,
    rd_allreduce,
    ring_allreduce,
    smp_allreduce,
)
from .comm import CommContext, CommPolicy, Topology
from .napalg import build_nap_schedule, nap_num_steps

__all__ = [
    "CommContext",
    "CommPolicy",
    "Topology",
    "bucketing",
    "build_nap_schedule",
    "collectives",
    "comm",
    "extensions",
    "grad_sync",
    "hierarchical_allreduce",
    "nap_allreduce",
    "nap_num_steps",
    "napalg",
    "perf_model",
    "rd_allreduce",
    "ring_allreduce",
    "simulator",
    "smp_allreduce",
]
