"""NAP (Node-Aware Parallel) allreduce schedule construction.

This module is the pure-math heart of the paper

    "Node-Aware Improvements to Allreduce", Bienz, Olson, Gropp (2019).

It builds, entirely in Python/NumPy (no jax), the static communication
schedule of the NAP allreduce over a logical grid of ``n_nodes`` nodes with
``ppn`` processes ("chips" in the TPU mapping) each:

  1. an intra-node allreduce so every chip holds its node's partial;
  2. ``ceil(log_ppn(n_nodes))`` *inter-node* steps.  At step ``i`` the nodes
     are partitioned into groups of up to ``ppn`` subgroups, each subgroup
     being a group of the previous step (size ``~ppn^i``).  The chip with
     local rank ``r`` on the node at position ``q`` of subgroup ``m``
     exchanges its (subgroup-``m``) partial with the chip of local rank
     ``m`` on the node at position ``q`` of subgroup ``r``;
  3. after the exchange, an intra-node allreduce over the received
     contributions leaves every chip of every node of the group holding the
     identical group partial — the invariant of paper §III.

Non-power-of-``ppn`` node counts (paper §III.A) use *balanced* subgroup
sizes ("groups of nearly equal size", Fig. 9).  When a chip's partner node
does not exist (its target subgroup is smaller), the otherwise-idle chip of
the target subgroup — the one with ``local rank == its own subgroup index``
— *donates* its partial to the orphaned chip ("... will instead send data
to the idle process"; the Fig. 9 example P14 <- P34 is reproduced in the
unit tests).  The donor does not need to receive anything back.

Beyond the paper, this module also builds the *bandwidth-regime* MLA
schedules: ``build_mla_schedule`` (striped multi-lane RS+AG; with an
``elems`` payload size the stripes are **ragged** — uneven blocks from
``ragged_splits``/``mla_stripe_geometry``, so per-chip inter-node bytes
equal the uneven-block lower bound ``mla_internode_lower_bound`` and no
padded bytes cross the slow domain) and ``build_mla_pipelined_schedule``
(the payload split into ``C`` ragged chunks whose ``P2PStep``s carry
per-chunk fractions, chunk tags and ``dep`` chains so chunk ``c``'s
inter-pod phases overlap chunk ``c±1``'s intra-pod phases in the
simulator's port-contention replay).

The schedules are consumed by three independent clients:

* ``repro.core.collectives`` lowers each step to one (or more)
  ``jax.lax.ppermute`` calls over the joint device mesh axes (the MLA
  flavours lower to native RS/AG collectives, taking their *chunk*
  boundaries from the same ``ragged_splits``; within a chunk the SPMD
  lowering still pads stripes to uniform shapes — the zero-padded-bytes
  guarantee is a property of this schedule/accounting layer, which is
  what the dispatcher's cost decisions consume);
* ``repro.core.simulator`` replays the message lists under the max-rate
  performance model to produce the paper's "measured" figures;
* the test-suite executes the schedules with NumPy interpreters
  (``simulate_allreduce`` / ``simulate_mla_allreduce``) and checks them
  against ``np.sum``/``max``/... for a wide (n_nodes, ppn) sweep.

Chip numbering is SMP-style (paper §III): ``chip = node * ppn + rank``.
"""

from __future__ import annotations

import functools
import math
from dataclasses import dataclass, field, replace as dataclass_replace
from typing import Callable, Sequence

import numpy as np

__all__ = [
    "NapStep",
    "NapSchedule",
    "build_nap_schedule",
    "build_rd_schedule",
    "build_smp_schedule",
    "build_mla_schedule",
    "build_mla_pipelined_schedule",
    "build_mla_rs_schedule",
    "build_mla_ag_schedule",
    "ScheduleMessage",
    "iter_messages",
    "ragged_splits",
    "chunk_offsets",
    "chunk_alignment",
    "mla_stripe_geometry",
    "mla_internode_lower_bound",
    "rs_internode_lower_bound",
    "ag_internode_lower_bound",
    "step_mask_tables",
    "p2p_recv_masks",
    "simulate_allreduce",
    "simulate_mla_allreduce",
    "nap_num_steps",
    "message_counts",
]


# ---------------------------------------------------------------------------
# schedule data structures
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class NapStep:
    """One inter-node step of the NAP allreduce.

    Attributes:
      rounds: tuple of ppermute rounds; each round is a tuple of
        ``(src_chip, dst_chip)`` pairs forming a partial permutation (each
        chip appears at most once as a source and at most once as a
        destination per round).  Round 0 carries the main pairwise
        exchange; later rounds exist only when ragged subgroups make one
        donor chip serve several orphaned receivers.
      recv_chips: chips that receive a partial this step (any round).
      self_chips: idle chips whose *own* value participates in the
        following intra-node allreduce (local rank == own subgroup index).
      groups: the node grouping this step reduces over — a tuple of groups,
        each a tuple of subgroups, each a tuple of node ids.  Kept for
        introspection, simulation and tests.
    """

    rounds: tuple[tuple[tuple[int, int], ...], ...]
    recv_chips: tuple[int, ...]
    self_chips: tuple[int, ...]
    groups: tuple[tuple[tuple[int, ...], ...], ...]

    @property
    def messages(self) -> list[tuple[int, int]]:
        """All (src, dst) messages of this step, across rounds."""
        return [pair for rnd in self.rounds for pair in rnd]


@dataclass(frozen=True)
class NapSchedule:
    """A full NAP allreduce schedule over ``n_nodes`` x ``ppn`` chips."""

    n_nodes: int
    ppn: int
    steps: tuple[NapStep, ...]

    @property
    def n_chips(self) -> int:
        return self.n_nodes * self.ppn

    @property
    def num_internode_steps(self) -> int:
        return len(self.steps)

    def max_messages_per_chip(self) -> int:
        """Maximum number of inter-node messages *sent* by any chip."""
        sends = np.zeros(self.n_chips, dtype=np.int64)
        for step in self.steps:
            for src, dst in step.messages:
                if src != dst:
                    sends[src] += 1
        return int(sends.max(initial=0))

    def total_internode_messages(self) -> int:
        return sum(
            sum(1 for s, d in step.messages if s != d) for step in self.steps
        )

    def max_internode_bytes_per_chip(self, s: float) -> float:
        """Every NAP message carries the full payload."""
        return float(self.max_messages_per_chip() * s)


# ---------------------------------------------------------------------------
# grouping: balanced, top-down
# ---------------------------------------------------------------------------


def nap_num_steps(n_nodes: int, ppn: int) -> int:
    """ceil(log_ppn(n_nodes)); 0 for a single node."""
    if n_nodes <= 1:
        return 0
    if ppn < 2:
        raise ValueError("NAP requires ppn >= 2 for multi-node reductions")
    return max(1, math.ceil(math.log(n_nodes) / math.log(ppn) - 1e-12))


def _balanced_split(nodes: Sequence[int], k: int) -> list[list[int]]:
    """Split ``nodes`` into ``k`` contiguous parts with sizes differing <=1.

    Larger parts come first, so ragged "extra" positions live in the
    leading subgroups — matching the paper's "subgroups with extra nodes".
    """
    n = len(nodes)
    base, rem = divmod(n, k)
    out, start = [], 0
    for i in range(k):
        size = base + (1 if i < rem else 0)
        out.append(list(nodes[start : start + size]))
        start += size
    return [p for p in out if p]


def _build_levels(
    nodes: list[int], n_steps: int, ppn: int
) -> list[list[list[list[int]]]]:
    """Recursive balanced grouping.

    Returns ``levels`` where ``levels[i]`` is the list of *groups* reduced
    at step ``i`` (0 = first inter-node step), each group being a list of
    subgroups (node-id lists).  Step ``i``'s subgroups are exactly step
    ``i-1``'s groups, so the §III invariant (all chips of a subgroup hold
    the identical partial) holds by construction.
    """
    levels: list[list[list[list[int]]]] = [[] for _ in range(n_steps)]
    if n_steps == 0 or len(nodes) <= 1:
        return levels

    # Number of subgroups of the (final) top-level step.  Each subgroup must
    # be reducible within the remaining n_steps - 1 steps, i.e. its size
    # must not exceed ppn ** (n_steps - 1).
    cap = ppn ** (n_steps - 1)
    k = min(ppn, math.ceil(len(nodes) / cap))
    subgroups = _balanced_split(nodes, k)
    levels[n_steps - 1] = [subgroups]

    for sg in subgroups:
        sub_levels = _build_levels(sg, n_steps - 1, ppn)
        for i in range(n_steps - 1):
            levels[i].extend(sub_levels[i])
    return levels


# ---------------------------------------------------------------------------
# schedule construction
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def build_nap_schedule(n_nodes: int, ppn: int) -> NapSchedule:
    """Build the full NAP schedule (paper Algorithm 1 + §III.A extension).

    Cached: schedule construction is pure in ``(n_nodes, ppn)`` and sits on
    the trace-time hot path of every ``nap_allreduce`` call, so repeated
    traces at the same grid shape hit ``lru_cache`` instead of re-running
    the recursive grouping.
    """
    if n_nodes < 1 or ppn < 1:
        raise ValueError("n_nodes and ppn must be positive")
    n_steps = nap_num_steps(n_nodes, ppn) if n_nodes > 1 else 0
    levels = _build_levels(list(range(n_nodes)), n_steps, ppn)

    steps: list[NapStep] = []
    for level in levels:
        rounds: list[list[tuple[int, int]]] = [[]]
        # per-round source occupancy to keep each round a valid permutation
        used_src: list[set[int]] = [set()]
        used_dst: list[set[int]] = [set()]
        recv: set[int] = set()
        selfc: set[int] = set()

        def emit(src: int, dst: int) -> None:
            """Place (src, dst) in the earliest round where both are free."""
            for i in range(len(rounds)):
                if src not in used_src[i] and dst not in used_dst[i]:
                    rounds[i].append((src, dst))
                    used_src[i].add(src)
                    used_dst[i].add(dst)
                    return
            rounds.append([(src, dst)])
            used_src.append({src})
            used_dst.append({dst})

        covered: set[int] = set()
        for group in level:
            k = len(group)
            for sg in group:
                covered.update(sg)
            if k <= 1:
                # degenerate group: its single subgroup already holds the
                # partial.  Exactly ONE rank per node re-contributes it so
                # the closing intra-node allreduce is value-preserving for
                # non-idempotent ops (sum/prod).
                for sg in group:
                    for node in sg:
                        selfc.add(node * ppn)
                continue
            sizes = [len(sg) for sg in group]
            # round-robin donor cursor per target subgroup
            donor_cursor = [0] * k
            for m, sg in enumerate(group):
                for q, node in enumerate(sg):
                    for r in range(ppn):
                        chip = node * ppn + r
                        if r == m:
                            # idle/self chip: own value feeds the local
                            # reduction (and may donate, handled below).
                            selfc.add(chip)
                            continue
                        if r >= k:
                            continue  # inactive rank: contributes identity
                        if q < sizes[r]:
                            partner_node = group[r][q]
                            partner = partner_node * ppn + m
                            emit(chip, partner)  # deliver subgroup m partial
                            recv.add(partner)
                        # else: our partner node does not exist; subgroup
                        # m's partial still reaches subgroup r through the
                        # positions that do exist.  Our own *receive* is
                        # repaired by a donor below.
            # donor repair: chip (m, q, r) with q >= sizes[r] receives the
            # subgroup-r partial from subgroup r's idle chip (paper §III.A,
            # Fig. 9: P14 <- P34).
            for m, sg in enumerate(group):
                for q, node in enumerate(sg):
                    for r in range(k):
                        if r == m or q < sizes[r]:
                            continue
                        orphan = node * ppn + r
                        donor_node = group[r][donor_cursor[r] % sizes[r]]
                        donor_cursor[r] += 1
                        donor = donor_node * ppn + r  # idle chip of sg r
                        emit(donor, orphan)
                        recv.add(orphan)

        # Nodes untouched by any group this step (singleton subtrees of the
        # ragged recursion) keep their value: one rank re-contributes it.
        for node in range(n_nodes):
            if node not in covered:
                selfc.add(node * ppn)

        steps.append(
            NapStep(
                rounds=tuple(tuple(rnd) for rnd in rounds if rnd),
                recv_chips=tuple(sorted(recv)),
                self_chips=tuple(sorted(selfc)),
                groups=tuple(
                    tuple(tuple(sg) for sg in group) for group in level
                ),
            )
        )
    return NapSchedule(n_nodes=n_nodes, ppn=ppn, steps=tuple(steps))


# ---------------------------------------------------------------------------
# baseline schedules (for the simulator / message-count comparisons)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class P2PStep:
    """One step of a point-to-point baseline schedule.

    ``pairs`` is a list of (src, dst) messages issued concurrently;
    ``combine`` marks whether receivers fold the payload into their value;
    ``frac`` is the fraction of the full reduction payload each message of
    this step carries (1.0 for whole-payload exchanges; striped schedules
    like MLA move ``1/ppn`` or ``1/(n*ppn)`` of the bytes per message).

    Ragged / pipelined extensions:

    ``fracs`` (optional) gives a *per-pair* payload fraction, overriding
    the scalar ``frac`` — uneven-block (ragged) stripes make messages of
    one step carry different byte counts.  ``chunk`` tags the pipeline
    chunk this step belongs to, and ``dep`` is the index (into the owning
    schedule's ``steps``) of the same-chunk predecessor that must complete
    before this step may start (``-1`` for none).  Steps of *different*
    chunks carry no data dependency — only per-chip, per-domain port
    contention serialises them, which is exactly the overlap the
    pipelined MLA engine exploits.
    """

    pairs: tuple[tuple[int, int], ...]
    combine: bool = True
    frac: float = 1.0
    fracs: tuple[float, ...] | None = None
    chunk: int = 0
    dep: int = -1

    def pair_fracs(self) -> tuple[float, ...]:
        """Per-pair payload fractions (scalar ``frac`` broadcast)."""
        if self.fracs is not None:
            return self.fracs
        return (self.frac,) * len(self.pairs)


@dataclass(frozen=True)
class P2PSchedule:
    """A flat schedule of point-to-point steps plus metadata."""

    n_nodes: int
    ppn: int
    steps: tuple[P2PStep, ...]
    kind: str = "generic"
    chunks: int = 1

    @property
    def n_chips(self) -> int:
        return self.n_nodes * self.ppn

    def max_internode_messages_per_chip(self) -> int:
        sends = np.zeros(self.n_chips, dtype=np.int64)
        for step in self.steps:
            for src, dst in step.pairs:
                if src // self.ppn != dst // self.ppn:
                    sends[src] += 1
        return int(sends.max(initial=0))

    def max_internode_bytes_per_chip(self, s: float) -> float:
        """Max over chips of inter-node bytes *sent* for an ``s``-byte
        reduction — the quantity the striped MLA path divides by ppn."""
        sends = np.zeros(self.n_chips, dtype=np.float64)
        for step in self.steps:
            for (src, dst), f in zip(step.pairs, step.pair_fracs()):
                if src // self.ppn != dst // self.ppn:
                    sends[src] += f * s
        return float(sends.max(initial=0.0))


@dataclass(frozen=True)
class ScheduleMessage:
    """One send/recv endpoint pair of any schedule, in a uniform shape.

    The normal form the static analyses (:mod:`repro.analysis`) iterate:
    NAP steps flatten their donor rounds into ``(step, round)`` positions
    with ``frac=1.0`` (every NAP message carries the full payload);
    P2P steps broadcast their scalar/ragged fractions per pair.  ``inter``
    is the slow-domain flag (``src`` and ``dst`` live on different
    nodes), derived once here so every consumer shares one definition.
    """

    step: int
    round: int
    src: int
    dst: int
    frac: float
    chunk: int
    combine: bool
    inter: bool


def iter_messages(schedule):
    """Yield every message of a :class:`NapSchedule` or
    :class:`P2PSchedule` as a :class:`ScheduleMessage`.

    The single endpoint-iteration point for schedule-shape consumers
    that must not trust the schedules' own accounting helpers (the
    verifier recomputes byte totals from these records and *checks* the
    helpers against them).
    """
    ppn = schedule.ppn
    if isinstance(schedule, NapSchedule):
        for i, step in enumerate(schedule.steps):
            for rnd_idx, rnd in enumerate(step.rounds):
                for src, dst in rnd:
                    yield ScheduleMessage(
                        step=i, round=rnd_idx, src=src, dst=dst,
                        frac=1.0, chunk=0, combine=True,
                        inter=src // ppn != dst // ppn,
                    )
        return
    for i, step in enumerate(schedule.steps):
        for (src, dst), frac in zip(step.pairs, step.pair_fracs()):
            yield ScheduleMessage(
                step=i, round=0, src=src, dst=dst, frac=float(frac),
                chunk=step.chunk, combine=step.combine,
                inter=src // ppn != dst // ppn,
            )


@functools.lru_cache(maxsize=None)
def build_rd_schedule(n_nodes: int, ppn: int) -> P2PSchedule:
    """Node-agnostic recursive doubling over all p = n*ppn chips.

    Non-power-of-two counts use the standard MPICH fold: the first
    ``2*rem`` chips pre-combine into ``rem`` survivors, a power-of-two core
    runs the butterfly, and results are returned to the folded chips.
    """
    p = n_nodes * ppn
    steps: list[P2PStep] = []
    pow2 = 1 << (p.bit_length() - 1)
    rem = p - pow2
    # fold: odd chips of the first 2*rem send to their even neighbour
    if rem:
        steps.append(
            P2PStep(tuple((2 * i + 1, 2 * i) for i in range(rem)))
        )
    core = [2 * i for i in range(rem)] + list(range(2 * rem, p))
    for bit in range(int(math.log2(pow2)) if pow2 > 1 else 0):
        pairs = []
        for idx, chip in enumerate(core):
            partner = core[idx ^ (1 << bit)]
            pairs.append((chip, partner))
        steps.append(P2PStep(tuple(pairs)))
    if rem:
        steps.append(
            P2PStep(
                tuple((2 * i, 2 * i + 1) for i in range(rem)), combine=False
            )
        )
    return P2PSchedule(n_nodes, ppn, tuple(steps), kind="rd")


@functools.lru_cache(maxsize=None)
def build_smp_schedule(n_nodes: int, ppn: int) -> P2PSchedule:
    """MPICH SMP allreduce: local tree reduce -> RD among masters -> bcast."""
    steps: list[P2PStep] = []

    # intra-node binomial-tree reduction to local rank 0
    span = 1
    while span < ppn:
        pairs = []
        for node in range(n_nodes):
            base = node * ppn
            for r in range(0, ppn, 2 * span):
                if r + span < ppn:
                    pairs.append((base + r + span, base + r))
        if pairs:
            steps.append(P2PStep(tuple(pairs)))
        span *= 2
    # recursive doubling among masters (chip = node*ppn)
    masters = [node * ppn for node in range(n_nodes)]
    pow2 = 1 << (n_nodes.bit_length() - 1)
    rem = n_nodes - pow2
    if rem:
        steps.append(
            P2PStep(tuple((masters[2 * i + 1], masters[2 * i]) for i in range(rem)))
        )
    core = [masters[2 * i] for i in range(rem)] + masters[2 * rem :]
    for bit in range(int(math.log2(pow2)) if pow2 > 1 else 0):
        pairs = []
        for idx, chip in enumerate(core):
            partner = core[idx ^ (1 << bit)]
            pairs.append((chip, partner))
        steps.append(P2PStep(tuple(pairs)))
    if rem:
        steps.append(
            P2PStep(
                tuple((masters[2 * i], masters[2 * i + 1]) for i in range(rem)),
                combine=False,
            )
        )
    # intra-node binomial-tree broadcast from rank 0
    span = 1 << max(0, (ppn - 1).bit_length() - 1)
    bcast_steps = []
    while span >= 1:
        pairs = []
        for node in range(n_nodes):
            base = node * ppn
            for r in range(0, ppn, 2 * span):
                if r + span < ppn:
                    pairs.append((base + r, base + r + span))
        if pairs:
            bcast_steps.append(P2PStep(tuple(pairs), combine=False))
        span //= 2
    steps.extend(bcast_steps)
    return P2PSchedule(n_nodes, ppn, tuple(steps), kind="smp")


def ragged_splits(total: int, k: int) -> tuple[int, ...]:
    """Split ``total`` items into ``k`` blocks with sizes differing <= 1.

    Larger blocks come first (matching :func:`_balanced_split`).  This is
    the single source of truth for the *ragged* (uneven-block) stripe and
    chunk geometry: the schedule builders, the executed
    ``collectives.mla_allreduce`` lowering and the NumPy oracle all derive
    their offsets from it, so no zero padding is ever introduced.
    """
    if k < 1:
        raise ValueError("k must be positive")
    base, rem = divmod(total, k)
    return tuple(base + 1 if i < rem else base for i in range(k))


def chunk_offsets(total: int, k: int) -> tuple[int, ...]:
    """Interior boundaries of the ragged ``k``-way chunk grid.

    The cumulative offsets of :func:`ragged_splits` (excluding 0 and
    ``total``) — the exact positions at which the chunk-pipelined MLA
    lowering splits a flat payload.  The bucket planner snaps fused-bucket
    boundaries to this grid so a bucket's pipeline chunks align with leaf
    boundaries instead of straddling leaf fragments.
    """
    out, off = [], 0
    for ce in ragged_splits(total, k)[:-1]:
        off += ce
        out.append(off)
    return tuple(out)


def chunk_alignment(part_sizes: Sequence[int], k: int) -> float:
    """Fraction of the ragged ``k``-chunk grid's interior boundaries that
    coincide with part (leaf) boundaries of a fused payload.

    ``part_sizes`` are the element counts of the payload's constituent
    parts, in fusion order.  1.0 means every pipeline chunk is a whole
    number of leaves (no chunk straddles a leaf fragment); ``k <= 1`` is
    trivially aligned.  Used by the bucket planner to score candidate
    bucket close points.
    """
    total = int(sum(part_sizes))
    if k <= 1 or total == 0:
        return 1.0
    bounds = chunk_offsets(total, k)
    if not bounds:
        return 1.0
    leaf_bounds, off = set(), 0
    for sz in part_sizes:
        off += int(sz)
        leaf_bounds.add(off)
    hit = sum(1 for b in bounds if b in leaf_bounds)
    return hit / len(bounds)


def mla_stripe_geometry(
    n_nodes: int, ppn: int, elems: int
) -> tuple[tuple[int, ...], tuple[tuple[int, ...], ...]]:
    """Ragged MLA stripe geometry for an ``elems``-element payload.

    Returns ``(stripes, blocks)`` where ``stripes[r]`` is the element
    count of lane ``r``'s stripe (the intra reduce-scatter output) and
    ``blocks[r][j]`` is the element count of node ``j``'s sub-block of
    stripe ``r`` (the per-lane inter-node reduce-scatter output).  All
    sizes differ by at most one — no padded elements exist, so none can
    cross the slow domain.
    """
    stripes = ragged_splits(elems, ppn)
    blocks = tuple(ragged_splits(sr, n_nodes) for sr in stripes)
    return stripes, blocks


def _one_way_internode_lower_bound(n_nodes: int, ppn: int, elems: int) -> int:
    """Worst-chip inter-node *elements* for one direction (RS or AG).

    The chip of lane ``r`` on node ``j`` must push its contributions to
    every sub-block it does not own across the slow domain
    (``stripes[r] - blocks[r][j]`` elements).  The binding chip is the one
    owning the smallest sub-block of the largest stripe.
    """
    if n_nodes <= 1:
        return 0
    stripes, blocks = mla_stripe_geometry(n_nodes, ppn, elems)
    return max(
        (sr - min(bl) for sr, bl in zip(stripes, blocks) if sr > 0),
        default=0,
    )


def rs_internode_lower_bound(n_nodes: int, ppn: int, elems: int) -> int:
    """Uneven-block lower bound on per-chip inter-node elements sent by
    the striped *reduce-scatter* (the RS half of the MLA allreduce)."""
    return _one_way_internode_lower_bound(n_nodes, ppn, elems)


def ag_internode_lower_bound(n_nodes: int, ppn: int, elems: int) -> int:
    """Uneven-block lower bound on per-chip inter-node elements sent by
    the striped *allgather* (the AG half of the MLA allreduce)."""
    return _one_way_internode_lower_bound(n_nodes, ppn, elems)


def mla_internode_lower_bound(n_nodes: int, ppn: int, elems: int) -> int:
    """Uneven-block lower bound on per-chip inter-node *elements* sent.

    The chip of lane ``r`` on node ``j`` must push its contributions to
    every sub-block it does not own across the slow domain during the
    reduce-scatter (``stripes[r] - blocks[r][j]`` elements) and the same
    amount back during the allgather — the sum of the
    :func:`rs_internode_lower_bound` and :func:`ag_internode_lower_bound`
    one-way bounds.
    """
    return rs_internode_lower_bound(
        n_nodes, ppn, elems
    ) + ag_internode_lower_bound(n_nodes, ppn, elems)


def _phase_weights(k: int) -> list[float]:
    """Normalised per-step weights of a k-way halving RS (sum to 1)."""
    if k <= 1:
        return []
    n_steps = math.ceil(math.log2(k))
    raw = [2.0 ** -(i + 1) for i in range(n_steps)]
    tot = sum(raw)
    return [f / tot for f in raw]


def _mla_phase_steps(
    n_nodes: int,
    ppn: int,
    elems: int | None,
    scale: float,
    chunk: int,
) -> tuple[list[P2PStep], list[P2PStep], list[P2PStep], list[P2PStep]]:
    """The four MLA phases as step lists (intra-RS, inter-RS, inter-AG,
    intra-AG).

    ``elems=None`` produces the even (divisibility-assumed) fractions of
    the original builder; an integer ``elems`` produces *ragged* per-pair
    fractions from :func:`mla_stripe_geometry` — each chip's sent bytes
    across a phase total exactly its uneven-block share, with zero padded
    bytes.  ``scale`` multiplies every fraction (chunked schedules pass
    the chunk's share of the payload); ``chunk`` tags the emitted steps.
    """
    intra_w = _phase_weights(ppn)
    inter_w = _phase_weights(n_nodes)
    li, lo = len(intra_w), len(inter_w)

    if elems is None:
        # even fractions, rescaled so phase byte totals are exactly
        # (k-1)/k of the phase payload (the divisible-stripe ideal)
        intra_tot = [(ppn - 1) / ppn] * (n_nodes * ppn)
        inter_tot = [(1.0 / ppn) * (n_nodes - 1) / n_nodes] * (
            n_nodes * ppn
        )
    else:
        stripes, blocks = mla_stripe_geometry(n_nodes, ppn, elems)
        e = float(max(elems, 1))
        intra_tot = [
            (elems - stripes[r]) / e
            for _ in range(n_nodes)
            for r in range(ppn)
        ]
        inter_tot = [
            (stripes[r] - blocks[r][node]) / e
            for node in range(n_nodes)
            for r in range(ppn)
        ]

    def _wsum(k: int, bits: Sequence[int], weights: Sequence[float]):
        """Per-position sum of the weights of the steps it takes part in.

        Non-power counts skip a position in steps where its partner does
        not exist; normalising by this sum keeps each chip's *phase*
        byte total exact (ragged accounting) instead of losing the
        skipped steps' weight mass.
        """
        out = [0.0] * k
        for bit, w in zip(bits, weights):
            for j in range(k):
                if (j ^ bit) < k:
                    out[j] += w
        return out

    intra_bits = [1 << (li - 1 - i) for i in range(li)]
    inter_bits = [1 << (lo - 1 - i) for i in range(lo)]
    intra_wsum = _wsum(ppn, intra_bits, intra_w)
    inter_wsum = _wsum(n_nodes, inter_bits, inter_w)

    def step(bit: int, w: float, combine: bool, inter: bool) -> P2PStep:
        pairs: list[tuple[int, int]] = []
        fr: list[float] = []
        for node in range(n_nodes):
            for r in range(ppn):
                if inter:
                    if (node ^ bit) >= n_nodes:
                        continue
                    pair = (node * ppn + r, (node ^ bit) * ppn + r)
                    wn = w if elems is None else w / inter_wsum[node]
                else:
                    if (r ^ bit) >= ppn:
                        continue
                    pair = (node * ppn + r, node * ppn + (r ^ bit))
                    wn = w if elems is None else w / intra_wsum[r]
                tot = (inter_tot if inter else intra_tot)[pair[0]]
                f = wn * tot * scale
                if f <= 0.0:
                    continue  # ragged zero-size message: never sent
                pairs.append(pair)
                fr.append(f)
        if elems is None and pairs and len(set(fr)) == 1:
            # even, uniform fractions: keep the scalar-``frac`` form
            return P2PStep(
                tuple(pairs), combine=combine, frac=fr[0], chunk=chunk
            )
        return P2PStep(
            tuple(pairs), combine=combine, fracs=tuple(fr), chunk=chunk
        )

    intra_rs = [
        step(intra_bits[i], intra_w[i], True, False) for i in range(li)
    ]
    inter_rs = [
        step(inter_bits[i], inter_w[i], True, True) for i in range(lo)
    ]
    rev_inter = list(reversed(inter_w))
    inter_ag = [
        step(1 << i, rev_inter[i], False, True) for i in range(lo)
    ]
    rev_intra = list(reversed(intra_w))
    intra_ag = [
        step(1 << i, rev_intra[i], False, False) for i in range(li)
    ]
    drop_empty = lambda steps: [st for st in steps if st.pairs]
    return (
        drop_empty(intra_rs),
        drop_empty(inter_rs),
        drop_empty(inter_ag),
        drop_empty(intra_ag),
    )


@functools.lru_cache(maxsize=None)
def build_mla_schedule(
    n_nodes: int, ppn: int, elems: int | None = None
) -> P2PSchedule:
    """Multi-lane node-aware (MLA) allreduce message schedule.

    The bandwidth-regime mirror of NAP: instead of each chip carrying the
    *full* payload across the slow domain, the pod-local partial is striped
    across the ``ppn`` local ranks (intra reduce-scatter), every lane ``r``
    then runs an independent reduce-scatter + allgather over the
    ``n_nodes`` nodes with its ``s/ppn``-byte stripe, and an intra
    allgather rebuilds the full payload.  Per-chip inter-node traffic
    drops from ``~2s`` (node-agnostic RS+AG) to ``~2*(s/ppn)*(n-1)/n`` —
    the paper's §VI "future work" regime, executed as ppn concurrent
    lanes.

    Both RS/AG phases are realized as recursive halving/doubling
    butterflies — ``ceil(log2(k))`` latency steps with message sizes
    halving per step — matching what ``cost_mla`` models and what the
    executed ``mla_allreduce`` lowers to, so the simulator's replay, the
    closed-form model and the real path agree on both the latency-step
    count and the byte totals.  (A ring realization would charge ``k-1``
    alpha-steps and materialize O(k^2) pairs, which is neither.)

    ``elems=None`` keeps the even-fraction accounting (per-chip bytes
    exactly ``(k-1)/k`` of each phase payload).  Passing the payload's
    element count instead builds the *ragged-stripe* schedule: per-pair
    fractions follow :func:`mla_stripe_geometry`'s uneven blocks, so
    ``max_internode_bytes_per_chip`` equals the uneven-block lower bound
    (:func:`mla_internode_lower_bound`) — no zero-padded bytes ever cross
    the slow domain, unlike pad-to-power striping.

    Message sizes are carried as payload *fractions* (of the full ``s``)
    in ``P2PStep.frac``/``fracs`` so the event-driven simulator can replay
    the striped schedule exactly.
    """
    if n_nodes < 1 or ppn < 1:
        raise ValueError("n_nodes and ppn must be positive")
    phases = _mla_phase_steps(n_nodes, ppn, elems, 1.0, 0)
    steps = [st for phase in phases for st in phase]
    return P2PSchedule(n_nodes, ppn, tuple(steps), kind="mla")


@functools.lru_cache(maxsize=None)
def build_mla_rs_schedule(
    n_nodes: int, ppn: int, elems: int | None = None
) -> P2PSchedule:
    """Striped *reduce-scatter* schedule: the first two MLA phases.

    Intra-pod reduce-scatter stripes the pod partial across the ``ppn``
    lanes, then every lane runs an independent reduce-scatter over the
    slow domain — chip ``(j, r)`` ends up owning the fully reduced block
    ``(r, j)`` of :func:`mla_stripe_geometry`.  With ``elems`` the
    per-pair fractions are ragged, so
    ``max_internode_bytes_per_chip`` equals the one-way lower bound
    (:func:`rs_internode_lower_bound`) — half the allreduce's round trip.
    """
    if n_nodes < 1 or ppn < 1:
        raise ValueError("n_nodes and ppn must be positive")
    intra_rs, inter_rs, _, _ = _mla_phase_steps(n_nodes, ppn, elems, 1.0, 0)
    return P2PSchedule(
        n_nodes, ppn, tuple(intra_rs + inter_rs), kind="mla_rs"
    )


@functools.lru_cache(maxsize=None)
def build_mla_ag_schedule(
    n_nodes: int, ppn: int, elems: int | None = None
) -> P2PSchedule:
    """Striped *allgather* schedule: the last two MLA phases.

    The exact mirror of :func:`build_mla_rs_schedule`: every lane
    allgathers its blocks over the slow domain, then an intra-pod
    allgather rebuilds the payload — per-chip inter-node bytes equal the
    one-way lower bound (:func:`ag_internode_lower_bound`).
    """
    if n_nodes < 1 or ppn < 1:
        raise ValueError("n_nodes and ppn must be positive")
    _, _, inter_ag, intra_ag = _mla_phase_steps(n_nodes, ppn, elems, 1.0, 0)
    return P2PSchedule(
        n_nodes, ppn, tuple(inter_ag + intra_ag), kind="mla_ag"
    )


@functools.lru_cache(maxsize=None)
def build_mla_pipelined_schedule(
    n_nodes: int, ppn: int, chunks: int, elems: int | None = None
) -> P2PSchedule:
    """Chunked, pipelined MLA schedule (doubly-pipelined reduction-to-all).

    The payload is split into ``chunks`` ragged chunks; each chunk runs
    the four MLA phases, and chunk ``c``'s inter-pod phases overlap chunk
    ``c+1``'s intra-pod phases because they occupy *different* network
    domains (ICI vs DCI) — the chunk-level overlap of Träff's
    doubly-pipelined allreduce (arXiv:2109.12626) applied to the
    multi-lane engine.

    Steps are emitted in wavefront order (chunk ``c`` phase ``p`` before
    chunk ``c+1`` phase ``p``), each tagged with its ``chunk`` and chained
    to its same-chunk predecessor through ``dep``; cross-chunk order is
    constrained only by per-chip, per-domain port availability, which is
    how the simulator's replay exhibits the overlap win.  Total bytes are
    identical to the unpipelined schedule — pipelining trades extra alpha
    steps (``chunks`` x the latency) for intra/inter overlap, which is why
    the dispatcher only selects it when the §IV model says the payload
    amortises the latency.
    """
    if chunks < 1:
        raise ValueError("chunks must be positive")
    if elems is not None:
        chunk_elems = ragged_splits(elems, chunks)
        scales = [ce / float(max(elems, 1)) for ce in chunk_elems]
        per_chunk = [
            _mla_phase_steps(n_nodes, ppn, ce, sc, c) if ce else ([], [], [], [])
            for c, (ce, sc) in enumerate(zip(chunk_elems, scales))
        ]
    else:
        per_chunk = [
            _mla_phase_steps(n_nodes, ppn, None, 1.0 / chunks, c)
            for c in range(chunks)
        ]

    steps: list[P2PStep] = []
    last_idx = [-1] * chunks  # index of each chunk's last emitted step
    n_phases = 4
    for wave in range(chunks + n_phases - 1):
        for c in range(chunks):
            ph = wave - c
            if not 0 <= ph < n_phases:
                continue
            for st in per_chunk[c][ph]:
                steps.append(
                    dataclass_replace(st, dep=last_idx[c])
                )
                last_idx[c] = len(steps) - 1
    return P2PSchedule(
        n_nodes, ppn, tuple(steps), kind="mla_pipelined", chunks=chunks
    )


# ---------------------------------------------------------------------------
# host-constant mask tables (trace-time hot path)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def step_mask_tables(
    n_nodes: int, ppn: int
) -> tuple[tuple[tuple[np.ndarray, ...], np.ndarray], ...]:
    """Per-step (receive-mask-per-round, self-mask) boolean tables.

    Computed once per (n_nodes, ppn) on the host and embedded as tiny
    constants by the collective lowering, replacing the per-trace Python
    loops that previously rebuilt each mask on every ``nap_allreduce``
    trace.  Entry ``i`` pairs with ``build_nap_schedule(...).steps[i]``.
    """
    sched = build_nap_schedule(n_nodes, ppn)
    n_chips = sched.n_chips
    tables = []
    for step in sched.steps:
        rmasks = []
        for rnd in step.rounds:
            m = np.zeros(n_chips, dtype=bool)
            for _, dst in rnd:
                m[dst] = True
            m.setflags(write=False)
            rmasks.append(m)
        smask = np.zeros(n_chips, dtype=bool)
        for c in step.self_chips:
            smask[c] = True
        smask.setflags(write=False)
        tables.append((tuple(rmasks), smask))
    return tuple(tables)


@functools.lru_cache(maxsize=None)
def p2p_recv_masks(sched: P2PSchedule) -> tuple[np.ndarray, ...]:
    """Per-step receive masks for a P2P schedule (host constants)."""
    out = []
    for step in sched.steps:
        m = np.zeros(sched.n_chips, dtype=bool)
        for _, dst in step.pairs:
            m[dst] = True
        m.setflags(write=False)
        out.append(m)
    return tuple(out)


# ---------------------------------------------------------------------------
# NumPy interpreter (test oracle + simulator substrate)
# ---------------------------------------------------------------------------

_OPS: dict[str, tuple[Callable[[np.ndarray, np.ndarray], np.ndarray], float]] = {
    "sum": (np.add, 0.0),
    "max": (np.maximum, -np.inf),
    "min": (np.minimum, np.inf),
    "prod": (np.multiply, 1.0),
}


def simulate_allreduce(
    schedule: NapSchedule, values: np.ndarray, op: str = "sum"
) -> np.ndarray:
    """Execute a NAP schedule on host, returning per-chip results.

    ``values`` has shape (n_chips, ...).  This is the correctness oracle
    used by the tests: the result must equal the op-reduction of ``values``
    along axis 0, replicated to every chip.
    """
    fold, ident = _OPS[op]
    n, ppn = schedule.n_nodes, schedule.ppn
    v = np.array(values, dtype=np.float64, copy=True)
    if v.shape[0] != n * ppn:
        raise ValueError("values must have one leading row per chip")

    def local_allreduce(x: np.ndarray) -> np.ndarray:
        out = np.empty_like(x)
        for node in range(n):
            sl = slice(node * ppn, (node + 1) * ppn)
            red = x[sl][0]
            for row in x[sl][1:]:
                red = fold(red, row)
            out[sl] = red
        return out

    v = local_allreduce(v)
    for step in schedule.steps:
        snapshot = v.copy()
        contrib = np.full_like(v, ident)
        for src, dst in step.messages:
            contrib[dst] = fold(contrib[dst], snapshot[src])
        for chip in step.self_chips:
            contrib[chip] = fold(contrib[chip], snapshot[chip])
        v = local_allreduce(contrib)
    return v


def simulate_mla_allreduce(
    n_nodes: int,
    ppn: int,
    values: np.ndarray,
    op: str = "sum",
    chunks: int = 1,
) -> np.ndarray:
    """Execute the ragged (optionally chunked) MLA algorithm on host.

    Walks the exact uneven-block geometry the schedule builders and the
    ``collectives.mla_allreduce`` lowering share — chunk split, per-lane
    stripes, per-node sub-blocks — reducing each sub-block only along the
    path the real algorithm uses.  The test oracle: the result must equal
    the op-reduction of ``values`` along axis 0 on every chip, proving
    the ragged offsets partition the payload exactly (no element dropped,
    none double-counted, no padding needed).
    """
    fold, _ = _OPS[op]
    n_chips = n_nodes * ppn
    v = np.asarray(values, dtype=np.float64)
    if v.ndim != 2 or v.shape[0] != n_chips:
        raise ValueError("values must have shape (n_chips, elems)")
    elems = v.shape[1]
    result = np.empty(elems, dtype=np.float64)
    c_off = 0
    for ce in ragged_splits(elems, chunks):
        if ce == 0:
            continue
        sub = v[:, c_off : c_off + ce]
        stripes, blocks = mla_stripe_geometry(n_nodes, ppn, ce)
        s_off = 0
        for r, sr in enumerate(stripes):
            if sr == 0:
                continue
            stripe_vals = sub[:, s_off : s_off + sr]
            # phase 1 (intra RS): lane-r chip of node j holds node j's
            # partial of stripe r
            node_part = np.empty((n_nodes, sr))
            for j in range(n_nodes):
                acc = stripe_vals[j * ppn]
                for row in stripe_vals[j * ppn + 1 : (j + 1) * ppn]:
                    acc = fold(acc, row)
                node_part[j] = acc
            # phase 2 (per-lane inter RS): node j reduces its sub-block
            b_off = 0
            reduced = np.empty(sr)
            for j, bj in enumerate(blocks[r]):
                if bj == 0:
                    continue
                blk = node_part[0, b_off : b_off + bj]
                for row in node_part[1:, b_off : b_off + bj]:
                    blk = fold(blk, row)
                reduced[b_off : b_off + bj] = blk
                b_off += bj
            # phases 2b/3 (inter AG + intra AG): everyone gets the stripe
            result[c_off + s_off : c_off + s_off + sr] = reduced
            s_off += sr
        c_off += ce
    return np.broadcast_to(result, v.shape).copy()


def message_counts(schedule: NapSchedule) -> dict[str, int]:
    """Inter-node message statistics for comparisons/figures."""
    per_chip = np.zeros(schedule.n_chips, dtype=np.int64)
    total = 0
    for step in schedule.steps:
        for src, dst in step.messages:
            if src // schedule.ppn != dst // schedule.ppn:
                per_chip[src] += 1
                total += 1
    return {
        "steps": schedule.num_internode_steps,
        "max_per_chip": int(per_chip.max(initial=0)),
        "total": total,
    }
