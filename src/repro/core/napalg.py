"""NAP (Node-Aware Parallel) allreduce schedule construction.

This module is the pure-math heart of the paper

    "Node-Aware Improvements to Allreduce", Bienz, Olson, Gropp (2019).

It builds, entirely in Python/NumPy (no jax), the static communication
schedule of the NAP allreduce over a logical grid of ``n_nodes`` nodes with
``ppn`` processes ("chips" in the TPU mapping) each:

  1. an intra-node allreduce so every chip holds its node's partial;
  2. ``ceil(log_ppn(n_nodes))`` *inter-node* steps.  At step ``i`` the nodes
     are partitioned into groups of up to ``ppn`` subgroups, each subgroup
     being a group of the previous step (size ``~ppn^i``).  The chip with
     local rank ``r`` on the node at position ``q`` of subgroup ``m``
     exchanges its (subgroup-``m``) partial with the chip of local rank
     ``m`` on the node at position ``q`` of subgroup ``r``;
  3. after the exchange, an intra-node allreduce over the received
     contributions leaves every chip of every node of the group holding the
     identical group partial — the invariant of paper §III.

Non-power-of-``ppn`` node counts (paper §III.A) use *balanced* subgroup
sizes ("groups of nearly equal size", Fig. 9).  When a chip's partner node
does not exist (its target subgroup is smaller), the otherwise-idle chip of
the target subgroup — the one with ``local rank == its own subgroup index``
— *donates* its partial to the orphaned chip ("... will instead send data
to the idle process"; the Fig. 9 example P14 <- P34 is reproduced in the
unit tests).  The donor does not need to receive anything back.

The schedule is consumed by three independent clients:

* ``repro.core.collectives`` lowers each step to one (or more)
  ``jax.lax.ppermute`` calls over the joint device mesh axes;
* ``repro.core.simulator`` replays the message lists under the max-rate
  performance model to produce the paper's "measured" figures;
* the test-suite executes the schedule with a NumPy interpreter
  (``simulate_allreduce``) and checks it against ``np.sum``/``max``/... for
  a wide (n_nodes, ppn) sweep.

Chip numbering is SMP-style (paper §III): ``chip = node * ppn + rank``.
"""

from __future__ import annotations

import functools
import math
from dataclasses import dataclass, field
from typing import Callable, Sequence

import numpy as np

__all__ = [
    "NapStep",
    "NapSchedule",
    "build_nap_schedule",
    "build_rd_schedule",
    "build_smp_schedule",
    "build_mla_schedule",
    "step_mask_tables",
    "p2p_recv_masks",
    "simulate_allreduce",
    "nap_num_steps",
    "message_counts",
]


# ---------------------------------------------------------------------------
# schedule data structures
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class NapStep:
    """One inter-node step of the NAP allreduce.

    Attributes:
      rounds: tuple of ppermute rounds; each round is a tuple of
        ``(src_chip, dst_chip)`` pairs forming a partial permutation (each
        chip appears at most once as a source and at most once as a
        destination per round).  Round 0 carries the main pairwise
        exchange; later rounds exist only when ragged subgroups make one
        donor chip serve several orphaned receivers.
      recv_chips: chips that receive a partial this step (any round).
      self_chips: idle chips whose *own* value participates in the
        following intra-node allreduce (local rank == own subgroup index).
      groups: the node grouping this step reduces over — a tuple of groups,
        each a tuple of subgroups, each a tuple of node ids.  Kept for
        introspection, simulation and tests.
    """

    rounds: tuple[tuple[tuple[int, int], ...], ...]
    recv_chips: tuple[int, ...]
    self_chips: tuple[int, ...]
    groups: tuple[tuple[tuple[int, ...], ...], ...]

    @property
    def messages(self) -> list[tuple[int, int]]:
        """All (src, dst) messages of this step, across rounds."""
        return [pair for rnd in self.rounds for pair in rnd]


@dataclass(frozen=True)
class NapSchedule:
    """A full NAP allreduce schedule over ``n_nodes`` x ``ppn`` chips."""

    n_nodes: int
    ppn: int
    steps: tuple[NapStep, ...]

    @property
    def n_chips(self) -> int:
        return self.n_nodes * self.ppn

    @property
    def num_internode_steps(self) -> int:
        return len(self.steps)

    def max_messages_per_chip(self) -> int:
        """Maximum number of inter-node messages *sent* by any chip."""
        sends = np.zeros(self.n_chips, dtype=np.int64)
        for step in self.steps:
            for src, dst in step.messages:
                if src != dst:
                    sends[src] += 1
        return int(sends.max(initial=0))

    def total_internode_messages(self) -> int:
        return sum(
            sum(1 for s, d in step.messages if s != d) for step in self.steps
        )

    def max_internode_bytes_per_chip(self, s: float) -> float:
        """Every NAP message carries the full payload."""
        return float(self.max_messages_per_chip() * s)


# ---------------------------------------------------------------------------
# grouping: balanced, top-down
# ---------------------------------------------------------------------------


def nap_num_steps(n_nodes: int, ppn: int) -> int:
    """ceil(log_ppn(n_nodes)); 0 for a single node."""
    if n_nodes <= 1:
        return 0
    if ppn < 2:
        raise ValueError("NAP requires ppn >= 2 for multi-node reductions")
    return max(1, math.ceil(math.log(n_nodes) / math.log(ppn) - 1e-12))


def _balanced_split(nodes: Sequence[int], k: int) -> list[list[int]]:
    """Split ``nodes`` into ``k`` contiguous parts with sizes differing <=1.

    Larger parts come first, so ragged "extra" positions live in the
    leading subgroups — matching the paper's "subgroups with extra nodes".
    """
    n = len(nodes)
    base, rem = divmod(n, k)
    out, start = [], 0
    for i in range(k):
        size = base + (1 if i < rem else 0)
        out.append(list(nodes[start : start + size]))
        start += size
    return [p for p in out if p]


def _build_levels(
    nodes: list[int], n_steps: int, ppn: int
) -> list[list[list[list[int]]]]:
    """Recursive balanced grouping.

    Returns ``levels`` where ``levels[i]`` is the list of *groups* reduced
    at step ``i`` (0 = first inter-node step), each group being a list of
    subgroups (node-id lists).  Step ``i``'s subgroups are exactly step
    ``i-1``'s groups, so the §III invariant (all chips of a subgroup hold
    the identical partial) holds by construction.
    """
    levels: list[list[list[list[int]]]] = [[] for _ in range(n_steps)]
    if n_steps == 0 or len(nodes) <= 1:
        return levels

    # Number of subgroups of the (final) top-level step.  Each subgroup must
    # be reducible within the remaining n_steps - 1 steps, i.e. its size
    # must not exceed ppn ** (n_steps - 1).
    cap = ppn ** (n_steps - 1)
    k = min(ppn, math.ceil(len(nodes) / cap))
    subgroups = _balanced_split(nodes, k)
    levels[n_steps - 1] = [subgroups]

    for sg in subgroups:
        sub_levels = _build_levels(sg, n_steps - 1, ppn)
        for i in range(n_steps - 1):
            levels[i].extend(sub_levels[i])
    return levels


# ---------------------------------------------------------------------------
# schedule construction
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def build_nap_schedule(n_nodes: int, ppn: int) -> NapSchedule:
    """Build the full NAP schedule (paper Algorithm 1 + §III.A extension).

    Cached: schedule construction is pure in ``(n_nodes, ppn)`` and sits on
    the trace-time hot path of every ``nap_allreduce`` call, so repeated
    traces at the same grid shape hit ``lru_cache`` instead of re-running
    the recursive grouping.
    """
    if n_nodes < 1 or ppn < 1:
        raise ValueError("n_nodes and ppn must be positive")
    n_steps = nap_num_steps(n_nodes, ppn) if n_nodes > 1 else 0
    levels = _build_levels(list(range(n_nodes)), n_steps, ppn)

    steps: list[NapStep] = []
    for level in levels:
        rounds: list[list[tuple[int, int]]] = [[]]
        # per-round source occupancy to keep each round a valid permutation
        used_src: list[set[int]] = [set()]
        used_dst: list[set[int]] = [set()]
        recv: set[int] = set()
        selfc: set[int] = set()

        def emit(src: int, dst: int) -> None:
            """Place (src, dst) in the earliest round where both are free."""
            for i in range(len(rounds)):
                if src not in used_src[i] and dst not in used_dst[i]:
                    rounds[i].append((src, dst))
                    used_src[i].add(src)
                    used_dst[i].add(dst)
                    return
            rounds.append([(src, dst)])
            used_src.append({src})
            used_dst.append({dst})

        covered: set[int] = set()
        for group in level:
            k = len(group)
            for sg in group:
                covered.update(sg)
            if k <= 1:
                # degenerate group: its single subgroup already holds the
                # partial.  Exactly ONE rank per node re-contributes it so
                # the closing intra-node allreduce is value-preserving for
                # non-idempotent ops (sum/prod).
                for sg in group:
                    for node in sg:
                        selfc.add(node * ppn)
                continue
            sizes = [len(sg) for sg in group]
            # round-robin donor cursor per target subgroup
            donor_cursor = [0] * k
            for m, sg in enumerate(group):
                for q, node in enumerate(sg):
                    for r in range(ppn):
                        chip = node * ppn + r
                        if r == m:
                            # idle/self chip: own value feeds the local
                            # reduction (and may donate, handled below).
                            selfc.add(chip)
                            continue
                        if r >= k:
                            continue  # inactive rank: contributes identity
                        if q < sizes[r]:
                            partner_node = group[r][q]
                            partner = partner_node * ppn + m
                            emit(chip, partner)  # deliver subgroup m partial
                            recv.add(partner)
                        # else: our partner node does not exist; subgroup
                        # m's partial still reaches subgroup r through the
                        # positions that do exist.  Our own *receive* is
                        # repaired by a donor below.
            # donor repair: chip (m, q, r) with q >= sizes[r] receives the
            # subgroup-r partial from subgroup r's idle chip (paper §III.A,
            # Fig. 9: P14 <- P34).
            for m, sg in enumerate(group):
                for q, node in enumerate(sg):
                    for r in range(k):
                        if r == m or q < sizes[r]:
                            continue
                        orphan = node * ppn + r
                        donor_node = group[r][donor_cursor[r] % sizes[r]]
                        donor_cursor[r] += 1
                        donor = donor_node * ppn + r  # idle chip of sg r
                        emit(donor, orphan)
                        recv.add(orphan)

        # Nodes untouched by any group this step (singleton subtrees of the
        # ragged recursion) keep their value: one rank re-contributes it.
        for node in range(n_nodes):
            if node not in covered:
                selfc.add(node * ppn)

        steps.append(
            NapStep(
                rounds=tuple(tuple(rnd) for rnd in rounds if rnd),
                recv_chips=tuple(sorted(recv)),
                self_chips=tuple(sorted(selfc)),
                groups=tuple(
                    tuple(tuple(sg) for sg in group) for group in level
                ),
            )
        )
    return NapSchedule(n_nodes=n_nodes, ppn=ppn, steps=tuple(steps))


# ---------------------------------------------------------------------------
# baseline schedules (for the simulator / message-count comparisons)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class P2PStep:
    """One step of a point-to-point baseline schedule.

    ``pairs`` is a list of (src, dst) messages issued concurrently;
    ``combine`` marks whether receivers fold the payload into their value;
    ``frac`` is the fraction of the full reduction payload each message of
    this step carries (1.0 for whole-payload exchanges; striped schedules
    like MLA move ``1/ppn`` or ``1/(n*ppn)`` of the bytes per message).
    """

    pairs: tuple[tuple[int, int], ...]
    combine: bool = True
    frac: float = 1.0


@dataclass(frozen=True)
class P2PSchedule:
    """A flat schedule of point-to-point steps plus metadata."""

    n_nodes: int
    ppn: int
    steps: tuple[P2PStep, ...]
    kind: str = "generic"

    @property
    def n_chips(self) -> int:
        return self.n_nodes * self.ppn

    def max_internode_messages_per_chip(self) -> int:
        sends = np.zeros(self.n_chips, dtype=np.int64)
        for step in self.steps:
            for src, dst in step.pairs:
                if src // self.ppn != dst // self.ppn:
                    sends[src] += 1
        return int(sends.max(initial=0))

    def max_internode_bytes_per_chip(self, s: float) -> float:
        """Max over chips of inter-node bytes *sent* for an ``s``-byte
        reduction — the quantity the striped MLA path divides by ppn."""
        sends = np.zeros(self.n_chips, dtype=np.float64)
        for step in self.steps:
            for src, dst in step.pairs:
                if src // self.ppn != dst // self.ppn:
                    sends[src] += step.frac * s
        return float(sends.max(initial=0.0))


@functools.lru_cache(maxsize=None)
def build_rd_schedule(n_nodes: int, ppn: int) -> P2PSchedule:
    """Node-agnostic recursive doubling over all p = n*ppn chips.

    Non-power-of-two counts use the standard MPICH fold: the first
    ``2*rem`` chips pre-combine into ``rem`` survivors, a power-of-two core
    runs the butterfly, and results are returned to the folded chips.
    """
    p = n_nodes * ppn
    steps: list[P2PStep] = []
    pow2 = 1 << (p.bit_length() - 1)
    rem = p - pow2
    # fold: odd chips of the first 2*rem send to their even neighbour
    if rem:
        steps.append(
            P2PStep(tuple((2 * i + 1, 2 * i) for i in range(rem)))
        )
    core = [2 * i for i in range(rem)] + list(range(2 * rem, p))
    for bit in range(int(math.log2(pow2)) if pow2 > 1 else 0):
        pairs = []
        for idx, chip in enumerate(core):
            partner = core[idx ^ (1 << bit)]
            pairs.append((chip, partner))
        steps.append(P2PStep(tuple(pairs)))
    if rem:
        steps.append(
            P2PStep(
                tuple((2 * i, 2 * i + 1) for i in range(rem)), combine=False
            )
        )
    return P2PSchedule(n_nodes, ppn, tuple(steps), kind="rd")


@functools.lru_cache(maxsize=None)
def build_smp_schedule(n_nodes: int, ppn: int) -> P2PSchedule:
    """MPICH SMP allreduce: local tree reduce -> RD among masters -> bcast."""
    steps: list[P2PStep] = []

    # intra-node binomial-tree reduction to local rank 0
    span = 1
    while span < ppn:
        pairs = []
        for node in range(n_nodes):
            base = node * ppn
            for r in range(0, ppn, 2 * span):
                if r + span < ppn:
                    pairs.append((base + r + span, base + r))
        if pairs:
            steps.append(P2PStep(tuple(pairs)))
        span *= 2
    # recursive doubling among masters (chip = node*ppn)
    masters = [node * ppn for node in range(n_nodes)]
    pow2 = 1 << (n_nodes.bit_length() - 1)
    rem = n_nodes - pow2
    if rem:
        steps.append(
            P2PStep(tuple((masters[2 * i + 1], masters[2 * i]) for i in range(rem)))
        )
    core = [masters[2 * i] for i in range(rem)] + masters[2 * rem :]
    for bit in range(int(math.log2(pow2)) if pow2 > 1 else 0):
        pairs = []
        for idx, chip in enumerate(core):
            partner = core[idx ^ (1 << bit)]
            pairs.append((chip, partner))
        steps.append(P2PStep(tuple(pairs)))
    if rem:
        steps.append(
            P2PStep(
                tuple((masters[2 * i], masters[2 * i + 1]) for i in range(rem)),
                combine=False,
            )
        )
    # intra-node binomial-tree broadcast from rank 0
    span = 1 << max(0, (ppn - 1).bit_length() - 1)
    bcast_steps = []
    while span >= 1:
        pairs = []
        for node in range(n_nodes):
            base = node * ppn
            for r in range(0, ppn, 2 * span):
                if r + span < ppn:
                    pairs.append((base + r, base + r + span))
        if pairs:
            bcast_steps.append(P2PStep(tuple(pairs), combine=False))
        span //= 2
    steps.extend(bcast_steps)
    return P2PSchedule(n_nodes, ppn, tuple(steps), kind="smp")


@functools.lru_cache(maxsize=None)
def build_mla_schedule(n_nodes: int, ppn: int) -> P2PSchedule:
    """Multi-lane node-aware (MLA) allreduce message schedule.

    The bandwidth-regime mirror of NAP: instead of each chip carrying the
    *full* payload across the slow domain, the pod-local partial is striped
    across the ``ppn`` local ranks (intra reduce-scatter), every lane ``r``
    then runs an independent reduce-scatter + allgather over the
    ``n_nodes`` nodes with its ``s/ppn``-byte stripe, and an intra
    allgather rebuilds the full payload.  Per-chip inter-node traffic
    drops from ``~2s`` (node-agnostic RS+AG) to ``~2*(s/ppn)*(n-1)/n`` —
    the paper's §VI "future work" regime, executed as ppn concurrent
    lanes.

    Both RS/AG phases are realized as recursive halving/doubling
    butterflies — ``ceil(log2(k))`` latency steps with message sizes
    halving per step — matching what ``cost_mla`` models and what the
    executed ``mla_allreduce`` lowers to, so the simulator's replay, the
    closed-form model and the real path agree on both the latency-step
    count and the byte totals.  (A ring realization would charge ``k-1``
    alpha-steps and materialize O(k^2) pairs, which is neither.)  For
    non-power counts the step fractions are rescaled so per-chip bytes
    stay exactly ``(k-1)/k`` of the phase payload.

    Message sizes are carried as payload *fractions* (of the full ``s``)
    in ``P2PStep.frac`` so the event-driven simulator can replay the
    striped schedule exactly.
    """
    if n_nodes < 1 or ppn < 1:
        raise ValueError("n_nodes and ppn must be positive")

    def halving_fracs(k: int, scale: float) -> list[float]:
        """Per-step payload fractions of a k-way recursive-halving RS."""
        if k <= 1:
            return []
        n_steps = math.ceil(math.log2(k))
        raw = [2.0 ** -(i + 1) for i in range(n_steps)]
        return [f * ((k - 1) / k) / sum(raw) * scale for f in raw]

    def intra_pairs(bit: int) -> tuple[tuple[int, int], ...]:
        return tuple(
            (node * ppn + r, node * ppn + (r ^ bit))
            for node in range(n_nodes)
            for r in range(ppn)
            if (r ^ bit) < ppn
        )

    def inter_pairs(bit: int) -> tuple[tuple[int, int], ...]:
        return tuple(
            (node * ppn + r, (node ^ bit) * ppn + r)
            for node in range(n_nodes)
            for r in range(ppn)
            if (node ^ bit) < n_nodes
        )

    intra_fracs = halving_fracs(ppn, 1.0)
    inter_fracs = halving_fracs(n_nodes, 1.0 / ppn)  # per-lane stripes
    li, lo = len(intra_fracs), len(inter_fracs)

    steps: list[P2PStep] = []
    # stripe the pod partial: halving RS, farthest partner first
    for i, f in enumerate(intra_fracs):
        steps.append(
            P2PStep(intra_pairs(1 << (li - 1 - i)), combine=True, frac=f)
        )
    # per-lane RS across the slow domain
    for i, f in enumerate(inter_fracs):
        steps.append(
            P2PStep(inter_pairs(1 << (lo - 1 - i)), combine=True, frac=f)
        )
    # per-lane AG: doubling, smallest chunk first
    for i, f in enumerate(reversed(inter_fracs)):
        steps.append(P2PStep(inter_pairs(1 << i), combine=False, frac=f))
    # rebuild the full payload inside the pod
    for i, f in enumerate(reversed(intra_fracs)):
        steps.append(P2PStep(intra_pairs(1 << i), combine=False, frac=f))
    return P2PSchedule(n_nodes, ppn, tuple(steps), kind="mla")


# ---------------------------------------------------------------------------
# host-constant mask tables (trace-time hot path)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def step_mask_tables(
    n_nodes: int, ppn: int
) -> tuple[tuple[tuple[np.ndarray, ...], np.ndarray], ...]:
    """Per-step (receive-mask-per-round, self-mask) boolean tables.

    Computed once per (n_nodes, ppn) on the host and embedded as tiny
    constants by the collective lowering, replacing the per-trace Python
    loops that previously rebuilt each mask on every ``nap_allreduce``
    trace.  Entry ``i`` pairs with ``build_nap_schedule(...).steps[i]``.
    """
    sched = build_nap_schedule(n_nodes, ppn)
    n_chips = sched.n_chips
    tables = []
    for step in sched.steps:
        rmasks = []
        for rnd in step.rounds:
            m = np.zeros(n_chips, dtype=bool)
            for _, dst in rnd:
                m[dst] = True
            m.setflags(write=False)
            rmasks.append(m)
        smask = np.zeros(n_chips, dtype=bool)
        for c in step.self_chips:
            smask[c] = True
        smask.setflags(write=False)
        tables.append((tuple(rmasks), smask))
    return tuple(tables)


@functools.lru_cache(maxsize=None)
def p2p_recv_masks(sched: P2PSchedule) -> tuple[np.ndarray, ...]:
    """Per-step receive masks for a P2P schedule (host constants)."""
    out = []
    for step in sched.steps:
        m = np.zeros(sched.n_chips, dtype=bool)
        for _, dst in step.pairs:
            m[dst] = True
        m.setflags(write=False)
        out.append(m)
    return tuple(out)


# ---------------------------------------------------------------------------
# NumPy interpreter (test oracle + simulator substrate)
# ---------------------------------------------------------------------------

_OPS: dict[str, tuple[Callable[[np.ndarray, np.ndarray], np.ndarray], float]] = {
    "sum": (np.add, 0.0),
    "max": (np.maximum, -np.inf),
    "min": (np.minimum, np.inf),
    "prod": (np.multiply, 1.0),
}


def simulate_allreduce(
    schedule: NapSchedule, values: np.ndarray, op: str = "sum"
) -> np.ndarray:
    """Execute a NAP schedule on host, returning per-chip results.

    ``values`` has shape (n_chips, ...).  This is the correctness oracle
    used by the tests: the result must equal the op-reduction of ``values``
    along axis 0, replicated to every chip.
    """
    fold, ident = _OPS[op]
    n, ppn = schedule.n_nodes, schedule.ppn
    v = np.array(values, dtype=np.float64, copy=True)
    if v.shape[0] != n * ppn:
        raise ValueError("values must have one leading row per chip")

    def local_allreduce(x: np.ndarray) -> np.ndarray:
        out = np.empty_like(x)
        for node in range(n):
            sl = slice(node * ppn, (node + 1) * ppn)
            red = x[sl][0]
            for row in x[sl][1:]:
                red = fold(red, row)
            out[sl] = red
        return out

    v = local_allreduce(v)
    for step in schedule.steps:
        snapshot = v.copy()
        contrib = np.full_like(v, ident)
        for src, dst in step.messages:
            contrib[dst] = fold(contrib[dst], snapshot[src])
        for chip in step.self_chips:
            contrib[chip] = fold(contrib[chip], snapshot[chip])
        v = local_allreduce(contrib)
    return v


def message_counts(schedule: NapSchedule) -> dict[str, int]:
    """Inter-node message statistics for comparisons/figures."""
    per_chip = np.zeros(schedule.n_chips, dtype=np.int64)
    total = 0
    for step in schedule.steps:
        for src, dst in step.messages:
            if src // schedule.ppn != dst // schedule.ppn:
                per_chip[src] += 1
                total += 1
    return {
        "steps": schedule.num_internode_steps,
        "max_per_chip": int(per_chip.max(initial=0)),
        "total": total,
    }
