"""Event-driven schedule simulator — the paper's "measured" analogue.

This container has one CPU, so the paper's Blue Waters measurements
(Figs 12-17) cannot be re-run on hardware.  Instead we *execute the real
schedules* produced by :mod:`repro.core.napalg` on a virtual cluster under
the max-rate model: per-chip clocks advance through every message with
node-aware costs, injection-bandwidth penalties are derived from the
actual number of concurrent inter-node senders per node at each step (not
assumed), and idle/donor imbalance shows up naturally as clock skew.

This is strictly more faithful than evaluating the closed forms (Eq 4-6):
ragged node counts, donor rounds, the SMP master bottleneck and the fold
steps of non-power recursive doubling all shape the simulated time.

Chunked (pipelined MLA) schedules are replayed with *per-domain ports*:
each chip owns independent intra-pod (ICI) and inter-pod (DCI) ports, a
chunk's phases serialize through their ``dep``/data-readiness chain, and
different chunks contend only for ports — so chunk ``c+1``'s intra
phases genuinely overlap chunk ``c``'s inter phases and the overlap win
appears as reduced clock skew, not as an assumed formula.  Ragged
stripes replay with their exact per-pair (uneven-block) message sizes.

Bucketed grad-sync plans are replayed with a *compute port*
(:func:`simulate_bucketed_sync`): backward produces each bucket's
gradients at a given clock and the async executor overlaps earlier
buckets' transfers with later buckets' compute, so the bucket-overlap
win of the grad_sync scheduler is measurable as wall-clock.

Vectorised with NumPy: each step processes all messages at once (each chip
receives at most one message per round by schedule construction).
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from . import napalg
from .perf_model import MachineParams

__all__ = [
    "simulate_time",
    "simulate_algorithm",
    "simulate_collective",
    "simulate_bucketed_sync",
    "internode_bytes_per_chip",
    "replay_internode_bytes",
]


def _local_allreduce_time(
    t: np.ndarray, n_nodes: int, ppn: int, s: float, p: MachineParams
) -> np.ndarray:
    """Advance clocks through a recursive-doubling intra-node allreduce."""
    if ppn <= 1:
        return t
    t = t.reshape(n_nodes, ppn)
    steps = math.ceil(math.log2(ppn))
    pow2 = 1 << steps
    cost = p.alpha_l + p.beta_l * s + p.gamma * s
    if pow2 == ppn:
        for bit in range(steps):
            partner = np.arange(ppn) ^ (1 << bit)
            t = np.maximum(t, t[:, partner]) + cost
    else:
        # non-power ppn: everyone synchronises on the node's max clock for
        # each tree level (fold + butterfly approximation).
        for _ in range(steps + 1):
            t = np.broadcast_to(
                t.max(axis=1, keepdims=True), t.shape
            ).copy()
            t = t + cost
    return t.reshape(-1)


def _pair_costs(
    pairs: np.ndarray,
    ppn: int,
    s,
    p: MachineParams,
    combine: bool,
    n_nodes: int,
) -> tuple[np.ndarray, np.ndarray]:
    """(inter-mask, per-message cost) for one round of messages.

    ``s`` may be a scalar (every message the same size) or a per-pair
    byte array (ragged stripes).  The injection penalty counts the
    concurrent inter-node senders per node *within this round*.
    """
    src, dst = pairs[:, 0], pairs[:, 1]
    inter = (src // ppn) != (dst // ppn)
    s = np.broadcast_to(np.asarray(s, dtype=np.float64), src.shape)
    senders = src[inter] // ppn
    if senders.size:
        counts = np.bincount(senders, minlength=n_nodes)
        k = counts[src // ppn]
    else:
        k = np.zeros_like(src)
    k = np.maximum(k, 1)
    cost = np.where(
        inter,
        p.alpha + (k * s) / np.minimum(p.R_N, k * p.R_b),
        p.alpha_l + p.beta_l * s,
    )
    if combine:
        cost = cost + p.gamma * s
    return inter, cost


def _message_step_time(
    t: np.ndarray,
    pairs: np.ndarray,
    ppn: int,
    s,
    p: MachineParams,
    combine: bool,
) -> np.ndarray:
    """Advance clocks through one round of point-to-point messages."""
    if pairs.size == 0:
        return t
    src, dst = pairs[:, 0], pairs[:, 1]
    inter, cost = _pair_costs(
        pairs, ppn, s, p, combine, int(t.size // ppn)
    )
    t_new = t.copy()
    np.maximum.at(t_new, dst, np.maximum(t[src], t[dst]) + cost)
    # senders are busy until their message is injected (latency portion)
    np.maximum.at(t_new, src, t[src] + np.where(inter, p.alpha, p.alpha_l))
    return t_new


def _simulate_chunked(schedule, s: float, p: MachineParams) -> float:
    """Replay a chunked (pipelined MLA) schedule with per-domain ports.

    Each chip owns two independent network ports — intra-pod (ICI) and
    inter-pod (DCI).  A step's start time on a pair is the max of (a) the
    endpoints' *data* readiness within the step's chunk (the ``dep``
    chain: phases of one chunk serialize) and (b) the endpoints' port
    availability in the step's domain (steps of *different* chunks
    contend only for ports).  Chunk ``c+1``'s intra phases therefore
    overlap chunk ``c``'s inter phases — the pipelined win — while two
    inter phases can never overlap on one chip, so the DCI is never
    oversubscribed.  Per-chip clock skew (ragged stripes, non-power
    grids) emerges naturally, exactly as in the unchunked replay.
    """
    n, ppn = schedule.n_nodes, schedule.ppn
    n_chips = n * ppn
    zeros = np.zeros(n_chips)
    # cumulative per-chip data-readiness *after* each step; a step's
    # baseline readiness comes from its declared ``dep`` predecessor
    ready_after: dict[int, np.ndarray] = {}
    avail = {
        False: np.zeros(n_chips),  # intra (ICI) port free time
        True: np.zeros(n_chips),  # inter (DCI) port free time
    }
    for idx, step in enumerate(schedule.steps):
        rc = ready_after[step.dep] if step.dep >= 0 else zeros
        pairs = np.asarray(step.pairs, dtype=np.int64).reshape(-1, 2)
        if pairs.size == 0:
            ready_after[idx] = rc
            continue
        src, dst = pairs[:, 0], pairs[:, 1]
        msg_bytes = np.asarray(step.pair_fracs(), dtype=np.float64) * s
        inter, cost = _pair_costs(
            pairs, ppn, msg_bytes, p, step.combine, n
        )
        av_src = np.where(inter, avail[True][src], avail[False][src])
        av_dst = np.where(inter, avail[True][dst], avail[False][dst])
        start = np.maximum(
            np.maximum(rc[src], rc[dst]), np.maximum(av_src, av_dst)
        )
        finish = start + cost
        alpha_dom = np.where(inter, p.alpha, p.alpha_l)
        # data readiness: receivers wait for the payload, senders are busy
        # only through injection
        rc_new = rc.copy()
        np.maximum.at(rc_new, dst, finish)
        np.maximum.at(rc_new, src, start + alpha_dom)
        ready_after[idx] = rc_new
        # port occupancy per domain
        for dom in (False, True):
            m = inter == dom
            if not m.any():
                continue
            np.maximum.at(avail[dom], dst[m], finish[m])
            np.maximum.at(avail[dom], src[m], start[m] + alpha_dom[m])
    if not ready_after:
        return 0.0
    return float(max(r.max() for r in ready_after.values()))


def simulate_time(
    schedule, s: float, p: MachineParams
) -> float:
    """Simulated wall-time (max chip clock) of one allreduce of ``s`` bytes."""
    n, ppn = schedule.n_nodes, schedule.ppn
    t = np.zeros(n * ppn)
    if isinstance(schedule, napalg.NapSchedule):
        t = _local_allreduce_time(t, n, ppn, s, p)
        for step in schedule.steps:
            for rnd in step.rounds:
                t = _message_step_time(
                    t, np.asarray(rnd, dtype=np.int64).reshape(-1, 2),
                    ppn, s, p, combine=True,
                )
            t = _local_allreduce_time(t, n, ppn, s, p)
        return float(t.max())
    if getattr(schedule, "kind", "") == "mla_pipelined":
        # chunked schedules: per-domain ports let chunks overlap
        return _simulate_chunked(schedule, s, p)
    # P2P schedules (RD / SMP / MLA).  Striped schedules carry a payload
    # fraction per step (per-pair for ragged stripes), so the striped MLA
    # path is replayed with the real uneven message sizes.
    for step in schedule.steps:
        fracs = (
            np.asarray(step.fracs, dtype=np.float64)
            if getattr(step, "fracs", None) is not None
            else getattr(step, "frac", 1.0)
        )
        t = _message_step_time(
            t,
            np.asarray(step.pairs, dtype=np.int64).reshape(-1, 2),
            ppn,
            s * fracs,
            p,
            combine=step.combine,
        )
    return float(t.max())


def _build(algo, n_nodes, ppn, s, p, chunks=None, elems=None):
    """Resolve an engine's schedule through the registry — no local
    per-engine name tables to fall out of sync with registrations."""
    from . import comm

    if chunks is None and comm.find_engine(algo).chunked:
        from . import perf_model as pm

        # chunked engines replay at the model-optimal depth (so the
        # dispatcher's decision and the replay agree)
        chunks = pm.optimal_pipeline_chunks(s, n_nodes, ppn, p)
    return comm.engine_schedule(
        algo, n_nodes, ppn, chunks=chunks or 1, elems=elems
    )


def simulate_algorithm(
    algo: str,
    n_nodes: int,
    ppn: int,
    s: float,
    p: MachineParams,
    *,
    chunks: int | None = None,
    elems: int | None = None,
) -> float:
    """Simulated wall-time of one ``s``-byte allreduce.

    ``algo="mla_pipelined"`` replays the chunked schedule; ``chunks=None``
    takes the model-optimal depth (so the dispatcher's decision and the
    replay agree).  ``elems`` switches MLA flavours to exact ragged-stripe
    message sizes instead of the even ideal.  ``algo="mla_rs"`` /
    ``"mla_ag"`` replay the striped reduce-scatter / allgather halves —
    the first-class RS/AG collectives of :mod:`repro.core.comm`.
    """
    # the schedule builders are lru_cached, so no cache layer needed here
    return simulate_time(_build(algo, n_nodes, ppn, s, p, chunks, elems), s, p)


def simulate_collective(
    topology,
    algo: str,
    s: float,
    *,
    chunks: int | None = None,
    elems: int | None = None,
) -> float:
    """Topology-first wrapper of :func:`simulate_algorithm`: the grid
    shape and machine constants come from one
    :class:`repro.core.comm.Topology` instead of loose kwargs."""
    return simulate_algorithm(
        algo, topology.n_nodes, topology.ppn, s, topology.params,
        chunks=chunks, elems=elems,
    )


def _bucket_duration(
    nbytes: float,
    algo: str,
    n_nodes: int,
    ppn: int,
    p: MachineParams,
    chunks: int | None,
    elems: int | None,
) -> float:
    """Replayed wall-time of one bucket's collective."""
    if algo == "psum" or n_nodes <= 1:
        # single-level native reduce: intra RD rounds only
        rounds = math.ceil(math.log2(max(2, n_nodes * ppn)))
        return rounds * (p.alpha_l + p.beta_l * nbytes + p.gamma * nbytes)
    return simulate_time(
        _build(algo, n_nodes, ppn, nbytes, p, chunks, elems), nbytes, p
    )


def simulate_bucketed_sync(
    buckets,
    n_nodes: int,
    ppn: int,
    p: MachineParams,
    *,
    compute_times=None,
    overlap: bool = True,
) -> float:
    """Wall-clock of a bucketed grad sync replayed with a compute port.

    ``buckets`` is a sequence of ``(nbytes, algorithm, chunks, elems)``
    rows in issue order — exactly what ``BucketPlan.sim_rows()`` emits.
    A row may carry an optional fifth element ``raw_bytes`` for
    compressed buckets (``nbytes`` = packed wire bytes < ``raw_bytes``):
    such rows are priced with
    :func:`repro.core.perf_model.cost_mla_compressed` — f32 intra
    stages at the raw width, inter exchange at the wire width, four
    fused kernel passes on the compute side.  ``compute_times[i]`` is
    the clock at which backward has produced
    bucket ``i``'s gradients (the compute port; defaults to all zero).
    Each bucket's collective is replayed through the event-driven
    schedule simulator (ragged stripes, pipelined chunks, donor rounds
    and all) to get its duration; the network port then executes buckets
    back to back:

    * ``overlap=True`` (the async executor): bucket ``i`` starts at
      ``max(network free, compute_times[i])`` — transfers hide behind
      the compute that produces later buckets;
    * ``overlap=False`` (the old serial sync): nothing starts until the
      *last* gradient exists, then every bucket runs in sequence.

    The async wall-clock is never worse than the serial one (asserted in
    tests on a 16x16 grid) — the measurable form of the bucket-overlap
    claim rather than an assumed formula.
    """
    rows = list(buckets)
    if not rows:
        return 0.0
    if compute_times is None:
        compute_times = [0.0] * len(rows)
    if len(compute_times) != len(rows):
        raise ValueError("compute_times must have one entry per bucket")
    durations = []
    for row in rows:
        nb, algo, ch, el = row[:4]
        raw = float(row[4]) if len(row) > 4 else float(nb)
        if raw > float(nb) and n_nodes > 1:
            from . import perf_model as pm

            durations.append(
                pm.cost_mla_compressed(raw, n_nodes, ppn, p, float(nb) / raw)
            )
            continue
        durations.append(
            _bucket_duration(float(nb), algo, n_nodes, ppn, p, ch, el)
        )
    if overlap:
        free = 0.0
        for ready, dur in zip(compute_times, durations):
            free = max(free, float(ready)) + dur
        return free
    return float(max(compute_times)) + sum(durations)


def replay_internode_bytes(schedule, s: float) -> np.ndarray:
    """Per-chip inter-node bytes *sent*, from replaying the schedule.

    Vectorised per-step accumulation over the same message stream the
    timing replay walks — an accounting path independent of both the
    schedules' own ``max_internode_bytes_per_chip`` helpers and the
    verifier's per-endpoint iteration
    (:func:`repro.core.napalg.iter_messages`).  The schedule verifier
    cross-checks all three against each other, so a bug in any one of
    them surfaces as a byte-accounting violation instead of silently
    shifting every figure built on the accounting.
    """
    ppn = schedule.ppn
    sends = np.zeros(schedule.n_chips, dtype=np.float64)
    if isinstance(schedule, napalg.NapSchedule):
        for step in schedule.steps:
            for rnd in step.rounds:
                if not rnd:
                    continue
                pairs = np.asarray(rnd, dtype=np.int64).reshape(-1, 2)
                inter = (pairs[:, 0] // ppn) != (pairs[:, 1] // ppn)
                np.add.at(sends, pairs[inter, 0], float(s))
        return sends
    for step in schedule.steps:
        if not step.pairs:
            continue
        pairs = np.asarray(step.pairs, dtype=np.int64).reshape(-1, 2)
        fracs = np.asarray(step.pair_fracs(), dtype=np.float64)
        inter = (pairs[:, 0] // ppn) != (pairs[:, 1] // ppn)
        np.add.at(sends, pairs[inter, 0], fracs[inter] * float(s))
    return sends


def internode_bytes_per_chip(
    algo: str,
    n_nodes: int,
    ppn: int,
    s: float,
    *,
    chunks: int | None = None,
    elems: int | None = None,
) -> float:
    """Max inter-node bytes any chip sends for an ``s``-byte reduction.

    The quantity the MLA stripe divides by ppn: replaying the schedules
    shows ``~2s`` for node-agnostic RS+AG lowerings, ``steps*s`` for NAP,
    and ``~2*(s/ppn)*(n-1)/n`` for MLA.  With ``elems`` the MLA flavours
    account ragged stripes exactly (the uneven-block lower bound — no
    padded bytes cross the slow domain).
    """
    from .perf_model import TPU_V5E_POD

    sched = _build(algo, n_nodes, ppn, s, TPU_V5E_POD, chunks, elems)
    return sched.max_internode_bytes_per_chip(s)
