"""Event-driven schedule simulator — the paper's "measured" analogue.

This container has one CPU, so the paper's Blue Waters measurements
(Figs 12-17) cannot be re-run on hardware.  Instead we *execute the real
schedules* produced by :mod:`repro.core.napalg` on a virtual cluster under
the max-rate model: per-chip clocks advance through every message with
node-aware costs, injection-bandwidth penalties are derived from the
actual number of concurrent inter-node senders per node at each step (not
assumed), and idle/donor imbalance shows up naturally as clock skew.

This is strictly more faithful than evaluating the closed forms (Eq 4-6):
ragged node counts, donor rounds, the SMP master bottleneck and the fold
steps of non-power recursive doubling all shape the simulated time.

Vectorised with NumPy: each step processes all messages at once (each chip
receives at most one message per round by schedule construction).
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from . import napalg
from .perf_model import MachineParams

__all__ = ["simulate_time", "simulate_algorithm", "internode_bytes_per_chip"]


def _local_allreduce_time(
    t: np.ndarray, n_nodes: int, ppn: int, s: float, p: MachineParams
) -> np.ndarray:
    """Advance clocks through a recursive-doubling intra-node allreduce."""
    if ppn <= 1:
        return t
    t = t.reshape(n_nodes, ppn)
    steps = math.ceil(math.log2(ppn))
    pow2 = 1 << steps
    cost = p.alpha_l + p.beta_l * s + p.gamma * s
    if pow2 == ppn:
        for bit in range(steps):
            partner = np.arange(ppn) ^ (1 << bit)
            t = np.maximum(t, t[:, partner]) + cost
    else:
        # non-power ppn: everyone synchronises on the node's max clock for
        # each tree level (fold + butterfly approximation).
        for _ in range(steps + 1):
            t = np.broadcast_to(
                t.max(axis=1, keepdims=True), t.shape
            ).copy()
            t = t + cost
    return t.reshape(-1)


def _message_step_time(
    t: np.ndarray,
    pairs: np.ndarray,
    ppn: int,
    s: float,
    p: MachineParams,
    combine: bool,
) -> np.ndarray:
    """Advance clocks through one round of point-to-point messages."""
    if pairs.size == 0:
        return t
    src, dst = pairs[:, 0], pairs[:, 1]
    inter = (src // ppn) != (dst // ppn)
    # per-node concurrent inter-node senders -> max-rate injection penalty
    senders = src[inter] // ppn
    if senders.size:
        counts = np.bincount(senders, minlength=int(t.size // ppn))
        k = counts[src // ppn]
    else:
        k = np.zeros_like(src)
    k = np.maximum(k, 1)
    cost = np.where(
        inter,
        p.alpha + (k * s) / np.minimum(p.R_N, k * p.R_b),
        p.alpha_l + p.beta_l * s,
    )
    if combine:
        cost = cost + p.gamma * s
    t_new = t.copy()
    np.maximum.at(t_new, dst, np.maximum(t[src], t[dst]) + cost)
    # senders are busy until their message is injected (latency portion)
    np.maximum.at(t_new, src, t[src] + np.where(inter, p.alpha, p.alpha_l))
    return t_new


def simulate_time(
    schedule, s: float, p: MachineParams
) -> float:
    """Simulated wall-time (max chip clock) of one allreduce of ``s`` bytes."""
    n, ppn = schedule.n_nodes, schedule.ppn
    t = np.zeros(n * ppn)
    if isinstance(schedule, napalg.NapSchedule):
        t = _local_allreduce_time(t, n, ppn, s, p)
        for step in schedule.steps:
            for rnd in step.rounds:
                t = _message_step_time(
                    t, np.asarray(rnd, dtype=np.int64).reshape(-1, 2),
                    ppn, s, p, combine=True,
                )
            t = _local_allreduce_time(t, n, ppn, s, p)
        return float(t.max())
    # P2P schedules (RD / SMP / MLA).  Striped schedules carry a payload
    # fraction per step, so the striped MLA path is replayed with the real
    # s/ppn (intra) and s/(n*ppn) (inter-lane) message sizes.
    for step in schedule.steps:
        t = _message_step_time(
            t,
            np.asarray(step.pairs, dtype=np.int64).reshape(-1, 2),
            ppn,
            s * getattr(step, "frac", 1.0),
            p,
            combine=step.combine,
        )
    return float(t.max())


_BUILDERS = {
    "nap": napalg.build_nap_schedule,
    "rd": napalg.build_rd_schedule,
    "smp": napalg.build_smp_schedule,
    "mla": napalg.build_mla_schedule,
}


def simulate_algorithm(
    algo: str, n_nodes: int, ppn: int, s: float, p: MachineParams
) -> float:
    # the schedule builders are lru_cached, so no cache layer needed here
    return simulate_time(_BUILDERS[algo](n_nodes, ppn), s, p)


def internode_bytes_per_chip(algo: str, n_nodes: int, ppn: int, s: float) -> float:
    """Max inter-node bytes any chip sends for an ``s``-byte reduction.

    The quantity the MLA stripe divides by ppn: replaying the schedules
    shows ``~2s`` for node-agnostic RS+AG lowerings, ``steps*s`` for NAP,
    and ``~2*(s/ppn)*(n-1)/n`` for MLA.
    """
    return _BUILDERS[algo](n_nodes, ppn).max_internode_bytes_per_chip(s)
