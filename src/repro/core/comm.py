"""Topology-first collective API: ``Topology`` + engine registry + ``CommContext``.

This module is the public face of the allreduce stack.  The paper's whole
point is that collective dispatch is a function of *machine topology* —
node count, lanes per node, intra/inter link rates — so topology is a
first-class, frozen, hashable object here instead of loose
``(inter_axes, intra_axes, n, ppn, params)`` keyword soup:

* :class:`Topology` owns the grid shape, the mesh axis names and the
  :class:`~repro.core.perf_model.MachineParams`, and memoises every
  derived quantity (NAP↔MLA crossover, schedules, ragged chunk geometry,
  inter-node lower bounds) so no module ever re-derives or re-defaults
  them;
* the **engine registry** (:func:`register_engine` /
  :func:`select_engine`) replaces the old ``ALGORITHMS`` dict and the
  ``_MLA_OPS`` / ``_LARGE_COSTS`` side tables: an engine is one
  declaration carrying its capabilities (ops, grid constraints), its
  cost model and its executable lowering, and dispatch is a
  capability-filtered cost tournament over the registered engines;
* :class:`CommContext` is the facade: ``allreduce``, ``reduce_scatter``
  and ``allgather`` are peer public collectives (RS/AG promoted from MLA
  internals — ZeRO-style sharded-optimizer sync is expressible), plus
  bucket-scheduled gradient sync.

Quickstart — mesh to collective in a few lines::

    from repro import compat
    from repro.core import comm
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=True)   # 2 pods x 16 x 16
    topo = comm.Topology.from_mesh(mesh)          # n=2, ppn=256, params
    ctx = comm.CommContext(topo)                  # default auto policy
    sync = compat.shard_map(
        lambda g: ctx.allreduce(g), mesh=mesh,
        in_specs=P(("pod", "data")), out_specs=P(("pod", "data")),
    )                                             # model-driven dispatch

The deprecated entry points (``collectives.hierarchical_allreduce``,
``grad_sync.GradSyncConfig``) are thin shims over this module: they build
a ``Topology`` + default policy internally and warn (once) on first use.
"""

from __future__ import annotations

import dataclasses
import functools
import math
import os
import types
import warnings
from typing import Callable, NamedTuple

import numpy as np

from . import collectives, napalg, perf_model as pm
from .. import compat

__all__ = [
    "Topology",
    "EngineSpec",
    "Decision",
    "register_engine",
    "get_engine",
    "registered_engines",
    "find_engine",
    "engine_schedule",
    "verify_engine",
    "lint_lowering",
    "select_engine",
    "CommPolicy",
    "CommContext",
    "COLLECTIVES",
    "warn_deprecated_once",
]

#: the collective families the registry dispatches over
COLLECTIVES = ("allreduce", "reduce_scatter", "allgather")


# ---------------------------------------------------------------------------
# Topology
# ---------------------------------------------------------------------------


def _axes_tuple(axes) -> tuple[str, ...]:
    if axes is None:
        return ()
    return (axes,) if isinstance(axes, str) else tuple(axes)


@dataclasses.dataclass(frozen=True)
class Topology:
    """Frozen, hashable description of a two-level device grid.

    ``n_nodes`` nodes (pods — the slow domain) of ``ppn`` chips each,
    optionally bound to mesh axis names so collectives can execute, plus
    the machine constants every cost decision is solved under.  Being
    hashable, a Topology keys every ``lru_cache`` in the stack — equal
    topologies share schedules, crossovers and bucket plans.
    """

    n_nodes: int
    ppn: int
    inter_axes: tuple[str, ...] = ()
    intra_axes: tuple[str, ...] = ()
    params: pm.MachineParams = pm.TPU_V5E_POD

    def __post_init__(self):
        object.__setattr__(self, "inter_axes", _axes_tuple(self.inter_axes))
        object.__setattr__(self, "intra_axes", _axes_tuple(self.intra_axes))
        if self.n_nodes < 1 or self.ppn < 1:
            raise ValueError(
                f"topology needs n_nodes >= 1 and ppn >= 1, got "
                f"({self.n_nodes}, {self.ppn})"
            )

    # -- constructors ------------------------------------------------------

    @classmethod
    def of(
        cls, n_nodes: int, ppn: int, *, params: pm.MachineParams | None = None
    ) -> "Topology":
        """Explicit grid shape, no axis binding (planning/analysis use)."""
        return cls(
            int(n_nodes), int(ppn), params=params or pm.TPU_V5E_POD
        )

    @classmethod
    def from_mesh(
        cls,
        mesh,
        *,
        inter_axes=None,
        intra_axes=None,
        params: pm.MachineParams | None = None,
    ) -> "Topology":
        """Topology of a jax mesh (host-side; no traced context needed).

        Axis defaults follow :func:`repro.launch.mesh.hierarchy_axes`:
        a ``"pod"`` axis is the slow domain, everything else data-local.
        """
        if inter_axes is None or intra_axes is None:
            from ..launch.mesh import hierarchy_axes

            d_inter, d_intra = hierarchy_axes(mesh)
            if inter_axes is None:
                inter_axes = d_inter
            if intra_axes is None:
                intra_axes = d_intra
        inter = _axes_tuple(inter_axes)
        intra = _axes_tuple(intra_axes)
        overlap = set(inter) & set(intra)
        if overlap:
            raise ValueError(
                f"axes {sorted(overlap)} appear in both inter_axes "
                f"{inter} and intra_axes {intra}"
            )
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        for ax in inter + intra:
            if ax not in sizes:
                raise ValueError(
                    f"axis {ax!r} not in mesh axes {tuple(sizes)}"
                )
        n = int(np.prod([sizes[a] for a in inter])) if inter else 1
        ppn = int(np.prod([sizes[a] for a in intra])) if intra else 1
        return cls(
            n, ppn, inter_axes=inter, intra_axes=intra,
            params=params or pm.TPU_V5E_POD,
        )

    @classmethod
    def from_axes(
        cls,
        inter_axes,
        intra_axes,
        *,
        params: pm.MachineParams | None = None,
    ) -> "Topology":
        """Topology from named mesh axes, *inside* a traced context
        (axis sizes come from ``jax.lax``/shard_map)."""
        inter = _axes_tuple(inter_axes)
        intra = _axes_tuple(intra_axes)
        n = int(np.prod([compat.axis_size(a) for a in inter])) if inter else 1
        ppn = (
            int(np.prod([compat.axis_size(a) for a in intra])) if intra else 1
        )
        return cls(
            n, ppn, inter_axes=inter, intra_axes=intra,
            params=params or pm.TPU_V5E_POD,
        )

    # -- basic shape -------------------------------------------------------

    @property
    def group(self) -> int:
        """Total chips — the reduction group size."""
        return self.n_nodes * self.ppn

    @property
    def has_slow_domain(self) -> bool:
        return self.n_nodes > 1

    @property
    def axes(self) -> tuple[str, ...]:
        """Joint (inter + intra) axis names, slow-domain-major."""
        return self.inter_axes + self.intra_axes

    def require_axes(self) -> "Topology":
        """Guard for execution entry points (returns ``self``).

        A multi-chip topology without mesh axis names (``Topology.of``
        — the planning/analysis constructor) cannot execute: the
        collectives would silently reduce over nothing and return each
        chip's local value.  Raise here instead of corrupting results.
        """
        if self.group > 1 and not self.axes:
            raise ValueError(
                f"topology ({self.n_nodes} nodes x {self.ppn} lanes) "
                "carries no mesh axis names, so collectives cannot "
                "execute on it; build it with Topology.from_mesh / "
                "Topology.from_axes (Topology.of is planning-only)"
            )
        return self

    # -- cached model-derived state ---------------------------------------

    def crossover_bytes(self) -> float:
        """Model-driven NAP↔MLA crossover for this grid (memoised).

        ``math.inf`` when NAP never loses in the model's search range
        (latency regime everywhere), ``0.0`` for degenerate lanes
        (``ppn == 1`` — NAP needs two lanes to trade steps for lanes).
        The large-message contender is the registry's *primary*
        (first-registered) bandwidth engine, not a hardcoded name.
        """
        return _crossover_bytes(
            self.n_nodes, self.ppn, self.params,
            _primary_bandwidth_engine(),
        )

    def optimal_pipeline_chunks(self, nbytes: float) -> int:
        """Model-optimal MLA pipeline depth for an ``nbytes`` payload."""
        return pm.optimal_pipeline_chunks(
            float(nbytes), self.n_nodes, self.ppn, self.params
        )

    def optimal_bucket_bytes(
        self,
        total_bytes: float,
        *,
        compute_seconds: float | None = None,
        max_buckets: int = 64,
    ) -> float:
        """Grad-sync fusion bucket target (overlap optimum, always finite)."""
        return pm.optimal_bucket_bytes(
            float(total_bytes), self.n_nodes, self.ppn, self.params,
            compute_seconds=compute_seconds, max_buckets=max_buckets,
        )

    def dispatched_cost(self, nbytes: float) -> float:
        """Modeled cost of one auto-dispatched allreduce of ``nbytes``."""
        return pm.dispatched_allreduce_cost(
            float(nbytes), self.n_nodes, self.ppn, self.params
        )

    # -- cached schedules / geometry --------------------------------------

    def schedule(self, engine: str, *, chunks: int = 1, elems: int | None = None):
        """The message schedule a registered engine would execute here."""
        return engine_schedule(
            engine, self.n_nodes, self.ppn, chunks=chunks, elems=elems
        )

    def chunk_splits(self, elems: int, chunks: int) -> tuple[int, ...]:
        """Ragged pipeline-chunk sizes (the exact executed splits)."""
        return napalg.ragged_splits(elems, max(1, chunks))

    def chunk_offsets(self, elems: int, chunks: int) -> tuple[int, ...]:
        return napalg.chunk_offsets(elems, max(1, chunks))

    def stripe_geometry(self, elems: int):
        """Ragged MLA stripe/block geometry ``(stripes, blocks)``."""
        return napalg.mla_stripe_geometry(self.n_nodes, self.ppn, elems)

    def internode_lower_bound(
        self, elems: int, collective: str = "allreduce"
    ) -> int:
        """Uneven-block lower bound on per-chip inter-node *elements*.

        The quantity the striped engines achieve exactly at the
        schedule/accounting layer: the full round trip for allreduce,
        the one-way halves for reduce_scatter / allgather.
        """
        if collective == "allreduce":
            return napalg.mla_internode_lower_bound(
                self.n_nodes, self.ppn, elems
            )
        if collective == "reduce_scatter":
            return napalg.rs_internode_lower_bound(
                self.n_nodes, self.ppn, elems
            )
        if collective == "allgather":
            return napalg.ag_internode_lower_bound(
                self.n_nodes, self.ppn, elems
            )
        raise ValueError(
            f"unknown collective {collective!r}; one of {COLLECTIVES}"
        )


def _primary_bandwidth_engine(collective: str = "allreduce") -> str:
    """The crossover's large-message contender: the first-registered
    bandwidth engine with a cost model — the same engine the tournament's
    registration-order tie-break prefers, so the regime split and the
    tournament agree on who anchors the bandwidth side."""
    for spec in _REGISTRY[collective].values():
        if spec.regime == "bandwidth" and spec.cost is not None:
            return spec.name
    raise ValueError(
        f"no bandwidth {collective} engine with a cost model is "
        "registered; cannot solve a latency/bandwidth crossover"
    )


@functools.lru_cache(maxsize=None)
def _crossover_bytes(
    n: int, ppn: int, params: pm.MachineParams, large: str
) -> float:
    if n <= 1:
        return math.inf  # no slow domain: NAP degenerates to psum
    if ppn <= 1:
        # NAP needs ppn >= 2 to trade steps for lanes; the striped path
        # degenerates to RS+AG over the slow domain, always valid here.
        return 0.0
    return pm.crossover_bytes(n, ppn, params, large=large)


# ---------------------------------------------------------------------------
# engine registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """One registered collective engine: capabilities + cost + lowering.

    ``execute`` is the shard_map-level lowering (signature per
    collective, see :class:`CommContext`); ``cost`` prices an ``s``-byte
    payload as ``cost(s, n, ppn, params)`` for the dispatch tournament
    and the crossover solver; ``build_schedule`` produces the message
    schedule the simulator replays.  ``regime`` structures the
    tournament: a ``latency`` engine wins below the memoised crossover,
    ``bandwidth`` engines fight a cost tournament above it, a
    ``fallback`` engine catches grids/ops nothing else supports, and
    ``baseline`` engines never auto-dispatch (explicit pin only).
    ``ops=None`` means op-independent (allgather moves bytes, no fold).
    ``ragged`` marks a ``build_schedule`` taking the payload element
    count for uneven-block accounting (``builder(n, ppn, elems)``);
    ``chunked`` marks a pipelined builder (``builder(n, ppn, chunks,
    elems)``) — :func:`engine_schedule` resolves the calling convention
    from these flags, so no caller keeps per-engine name tables.
    """

    name: str
    collective: str
    execute: Callable
    cost: Callable | None = None
    build_schedule: Callable | None = None
    ops: frozenset[str] | None = frozenset({"sum"})
    regime: str = "baseline"
    min_nodes: int = 1
    min_ppn: int = 1
    chunked: bool = False
    ragged: bool = False
    pipelined_variant: str | None = None
    legacy: Callable | None = None

    def supports(self, topology: Topology, op: str) -> bool:
        """Capability check: op + grid constraints."""
        if self.ops is not None and op not in self.ops:
            return False
        return (
            topology.n_nodes >= self.min_nodes
            and topology.ppn >= self.min_ppn
        )

    def describe(self) -> dict:
        """JSON-safe capability row (benchmark/CI listing)."""
        return {
            "name": self.name,
            "collective": self.collective,
            "regime": self.regime,
            "ops": sorted(self.ops) if self.ops is not None else "any",
            "min_nodes": self.min_nodes,
            "min_ppn": self.min_ppn,
            "chunked": self.chunked,
            "has_cost_model": self.cost is not None,
            "has_schedule": self.build_schedule is not None,
        }


_REGISTRY: dict[str, dict[str, EngineSpec]] = {c: {} for c in COLLECTIVES}


def register_engine(
    name: str,
    *,
    collective: str = "allreduce",
    ops: frozenset[str] | set[str] | None = frozenset({"sum"}),
    execute: Callable | None = None,
    cost: Callable | None = None,
    build_schedule: Callable | None = None,
    regime: str = "baseline",
    min_nodes: int = 1,
    min_ppn: int = 1,
    chunked: bool = False,
    ragged: bool = False,
    pipelined_variant: str | None = None,
    legacy: Callable | None = None,
    override: bool = False,
    verify: bool = True,
):
    """Register a collective engine (usable directly or as a decorator).

    A new engine — or a whole new backend — is one declaration::

        @register_engine(
            "mla_pipelined", ops={"sum", "max", "min"},
            cost=pm.cost_mla_pipelined_opt,
            build_schedule=napalg.build_mla_pipelined_schedule,
            regime="bandwidth", min_nodes=2, min_ppn=2, chunked=True,
        )
        def _execute(x, *, topology, op, pipeline_chunks):
            ...

    replacing the former edits across four files (``ALGORITHMS``,
    ``_MLA_OPS``, ``_LARGE_COSTS``, ``select_algorithm``).

    **Verify-on-register.**  When ``REPRO_VERIFY_ON_REGISTER`` is set in
    the environment (the test suite sets it), every registration with a
    schedule builder is statically verified by
    :mod:`repro.analysis.schedule_verifier` over a small grid matrix —
    match-completeness, deadlock-freedom, exactly-once reduction and
    byte accounting — before it becomes visible; a failing engine is
    rolled back out of the registry and the registration raises with the
    violation list.  ``verify=False`` opts a registration out of the
    *schedule* checks (for deliberately exotic schedules carrying their
    own proofs, and for native lowerings that have no schedule object).

    **Lint-on-register.**  Under the same environment flag every
    registration — ``verify=False`` included — is additionally traced
    to a jaxpr and run through :func:`lint_lowering`
    (:mod:`repro.analysis.spmd_lint`): collective uniformity, axis
    discipline, numerics flow and schedule-vs-jaxpr byte equality.
    There is no opt-out: an engine that cannot be traced and proven
    hang-free does not enter the tournament.
    """
    if collective not in _REGISTRY:
        raise ValueError(
            f"unknown collective {collective!r}; one of {COLLECTIVES}"
        )

    def _register(execute_fn: Callable) -> Callable:
        if name in _REGISTRY[collective] and not override:
            raise ValueError(
                f"{collective} engine {name!r} is already registered; "
                "pass override=True to replace it deliberately"
            )
        spec = EngineSpec(
            name=name,
            collective=collective,
            execute=execute_fn,
            cost=cost,
            build_schedule=build_schedule,
            ops=frozenset(ops) if ops is not None else None,
            regime=regime,
            min_nodes=min_nodes,
            min_ppn=min_ppn,
            chunked=chunked,
            ragged=ragged,
            pipelined_variant=pipelined_variant,
            legacy=legacy,
        )
        _REGISTRY[collective][name] = spec
        if _verify_on_register_enabled():
            try:
                if verify:
                    _verify_spec_quick(spec)
                # the jaxpr lint is NOT gated on ``verify``: engines
                # without a schedule to verify (the native lowerings)
                # still have an executed lowering to prove
                _lint_spec_quick(spec)
            except Exception:
                _REGISTRY[collective].pop(name, None)
                raise
        if legacy is not None and collective == "allreduce":
            _LEGACY_TABLE[name] = legacy
        return execute_fn

    if execute is not None:
        _register(execute)
        return _REGISTRY[collective][name]
    return _register


# registry-maintained backing store of the legacy ``ALGORITHMS`` view
_LEGACY_TABLE: dict[str, Callable] = {}
_LEGACY_VIEW = types.MappingProxyType(_LEGACY_TABLE)


def registered_engines(
    collective: str | None = None,
) -> dict[str, EngineSpec]:
    """The registry (one collective family, or all of them flattened)."""
    if collective is not None:
        if collective not in _REGISTRY:
            raise ValueError(
                f"unknown collective {collective!r}; one of {COLLECTIVES}"
            )
        return dict(_REGISTRY[collective])
    return {
        f"{c}:{n}": s for c, tab in _REGISTRY.items() for n, s in tab.items()
    }


def get_engine(name: str, collective: str = "allreduce") -> EngineSpec:
    """Resolve an engine by name, with a listing error on typos.

    This is the config/context build-time validation: a mistyped
    ``algorithm`` raises here — naming every registered engine — instead
    of surfacing as a bare ``KeyError`` deep inside tracing.
    """
    table = _REGISTRY[collective]
    spec = table.get(name)
    if spec is None:
        raise ValueError(
            f"unknown {collective} engine {name!r}; registered engines: "
            f"{sorted(table)} (or 'auto' for the model-driven dispatch)"
        )
    return spec


def _engine_collective(name: str) -> str:
    for coll, table in _REGISTRY.items():
        if name in table:
            return coll
    raise ValueError(
        f"unknown engine {name!r}; registered: "
        f"{sorted(registered_engines())}"
    )


def find_engine(name: str) -> EngineSpec:
    """Resolve an engine by name across all collective families."""
    return get_engine(name, _engine_collective(name))


def engine_schedule(
    name: str,
    n_nodes: int,
    ppn: int,
    *,
    chunks: int = 1,
    elems: int | None = None,
):
    """The message schedule a registered engine executes on an
    ``(n_nodes, ppn)`` grid — the single schedule-resolution point.

    The calling convention comes from the engine's declared flags
    (``chunked`` → ``builder(n, ppn, chunks, elems)``, ``ragged`` →
    ``builder(n, ppn, elems)``), so the simulator and Topology don't
    keep per-engine name tables that a new registration would miss.
    """
    spec = find_engine(name)
    if spec.build_schedule is None:
        raise ValueError(f"engine {spec.name!r} has no schedule builder")
    if spec.chunked:
        return spec.build_schedule(n_nodes, ppn, max(1, chunks), elems)
    if spec.ragged:
        return spec.build_schedule(n_nodes, ppn, elems)
    return spec.build_schedule(n_nodes, ppn)


def _verify_on_register_enabled() -> bool:
    return os.environ.get("REPRO_VERIFY_ON_REGISTER", "").lower() in (
        "1", "true", "yes",
    )


def _verify_spec_quick(spec: EngineSpec) -> None:
    """The verify-on-register gate: sweep the registration grids and
    raise (so the caller rolls the registry back) on any violation."""
    from ..analysis import schedule_verifier as _sv

    bad = []
    for n, ppn in _sv.REGISTER_GRIDS:
        for elems in (None, 19):
            r = _sv.verify_spec(
                spec, n, ppn, elems=elems, chunks=2 if spec.chunked else 1
            )
            if not r.ok:
                bad.append(r)
    if bad:
        lines = [
            f"  ({r.n_nodes}x{r.ppn}, elems={r.elems}) "
            f"[{v.rule}] {v.message}"
            for r in bad
            for v in r.violations
        ]
        raise ValueError(
            f"{spec.collective} engine {spec.name!r} failed static "
            "verification on registration:\n" + "\n".join(lines)
        )


def verify_engine(
    name: str,
    topology: Topology | None = None,
    *,
    n_nodes: int | None = None,
    ppn: int | None = None,
    elems: int | None = None,
    chunks: int = 1,
    grids=None,
    raise_on_violation: bool = True,
):
    """Statically verify a registered engine's schedules.

    The four passes of :mod:`repro.analysis.schedule_verifier` — match
    completeness, deadlock-freedom, exactly-once reduction correctness
    and byte-accounting equality against the engine's declared bound —
    run over one grid (a ``topology`` or ``n_nodes``/``ppn``) or a grid
    matrix (``grids``; defaults to the registration grids).  Returns the
    list of :class:`repro.analysis.VerificationReport` rows; raises
    ``ValueError`` listing every violation unless
    ``raise_on_violation=False``.

    New engines (ROADMAP open item 2) must pass this before entering
    the tournament — the test suite enforces it via verify-on-register.
    """
    from ..analysis import schedule_verifier as _sv

    spec = find_engine(name)
    if topology is not None:
        grid_list = [(topology.n_nodes, topology.ppn)]
    elif n_nodes is not None and ppn is not None:
        grid_list = [(n_nodes, ppn)]
    elif grids is not None:
        grid_list = list(grids)
    else:
        grid_list = list(_sv.REGISTER_GRIDS)

    reports = [
        _sv.verify_spec(
            spec, n, p, elems=elems,
            chunks=chunks if chunks > 1 else (2 if spec.chunked else 1),
        )
        for n, p in grid_list
    ]
    bad = [r for r in reports if not r.ok]
    if bad and raise_on_violation:
        lines = [
            f"  ({r.n_nodes}x{r.ppn}, elems={r.elems}) "
            f"[{v.rule}] {v.message}"
            for r in bad
            for v in r.violations
        ]
        raise ValueError(
            f"engine {name!r} failed static verification:\n"
            + "\n".join(lines)
        )
    return reports


#: grids the registration-time jaxpr lint sweeps (kept smaller than the
#: schedule verifier's REGISTER_GRIDS — tracing is costlier than graph
#: checks, and the jaxpr rules are grid-shape-generic)
_LINT_GRIDS = ((2, 2), (3, 2))


def lint_lowering(
    name: str,
    topology: Topology | None = None,
    *,
    n_nodes: int | None = None,
    ppn: int | None = None,
    elems: int | None = None,
    dtype="float32",
    op: str = "sum",
    chunks: int = 1,
    raise_on_violation: bool = True,
):
    """Statically lint a registered engine's *executed* lowering.

    Traces the engine's ``execute`` to a jaxpr under an abstract axis
    environment (no devices or mesh needed) and runs
    :func:`repro.analysis.spmd_lint.lint_jaxpr` over it: collective
    uniformity (the static hang detector), axis discipline, numerics
    flow, and byte accounting — the jaxpr-recomputed inter-node bytes
    per chip must equal the bound the engine's *schedule* declares,
    closing the schedule → jaxpr link of the three-layer proof chain
    (:mod:`repro.analysis`).

    The byte bound is resolved from the engine's declared flags: a
    non-ragged schedule builder gives the exact
    ``max_internode_bytes_per_chip`` at any payload; ragged/chunked
    engines are held to ``Topology.internode_lower_bound`` (exact when
    ``elems`` divides evenly, which the default payload does); native
    engines without a schedule are byte-audited report-only.

    Returns the :class:`repro.analysis.spmd_lint.SpmdLintReport`;
    raises ``ValueError`` listing every violation unless
    ``raise_on_violation=False``.  Like :func:`verify_engine` this is
    part of the registration gate — including for engines registered
    with ``verify=False``, which have no schedule to verify but still
    have a lowering to prove.
    """
    import jax
    import jax.numpy as jnp

    from ..analysis import spmd_lint as _sl

    spec = find_engine(name)
    if topology is not None:
        n, p = topology.n_nodes, topology.ppn
    elif n_nodes is not None and ppn is not None:
        n, p = int(n_nodes), int(ppn)
    else:
        n, p = _LINT_GRIDS[0]
    if n < spec.min_nodes or p < spec.min_ppn:
        raise ValueError(
            f"engine {name!r} needs at least "
            f"{spec.min_nodes}x{spec.min_ppn}, got {n}x{p}"
        )
    eff_chunks = chunks if chunks > 1 else (2 if spec.chunked else 1)
    # bind single mesh axis names; a caller topology with exactly one
    # axis per level keeps its names, anything else (unbound, or
    # multi-axis levels whose per-axis sizes a Topology doesn't carry)
    # falls back to synthetic names — the lint rules only care that the
    # axis *sizes* multiply out to the grid
    inter = ("pod",)
    intra = ("data",) if p > 1 else ()
    if topology is not None:
        if len(topology.inter_axes) == 1:
            inter = topology.inter_axes
        if len(topology.intra_axes) == 1 and p > 1:
            intra = topology.intra_axes
    topo = dataclasses.replace(
        topology if topology is not None else Topology.of(n, p),
        inter_axes=inter, intra_axes=intra,
    )
    dt = jnp.dtype(dtype)
    if elems is None:
        elems = n * p * eff_chunks * 4
    elems = int(elems)

    if spec.collective == "allgather":
        shard = -(-(-(-elems // p)) // n)  # ceil(ceil(e/ppn)/n)
        x = jax.ShapeDtypeStruct((shard,), dt)
        fn = functools.partial(spec.execute, topology=topo, elems=elems)
    else:
        x = jax.ShapeDtypeStruct((elems,), dt)
        if spec.collective == "reduce_scatter":
            fn = functools.partial(spec.execute, topology=topo, op=op)
        else:
            fn = functools.partial(
                spec.execute, topology=topo, op=op,
                pipeline_chunks=eff_chunks,
            )

    declared = None
    if spec.ragged or spec.chunked:
        if elems % (n * p * eff_chunks) == 0:
            declared = (
                topo.internode_lower_bound(elems, spec.collective)
                * dt.itemsize
            )
    elif spec.build_schedule is not None:
        declared = engine_schedule(
            name, n, p
        ).max_internode_bytes_per_chip(elems * dt.itemsize)

    axis_env = [(ax, n) for ax in inter] + [(ax, p) for ax in intra]
    closed = jax.make_jaxpr(fn, axis_env=axis_env)(x)
    report = _sl.lint_jaxpr(
        closed,
        axis_sizes=dict(axis_env),
        inter_axes=inter,
        intra_axes=intra,
        declared_internode_bytes=declared,
        label=f"{spec.collective}:{name}@{n}x{p}/{dt.name}",
    )
    if not report.ok and raise_on_violation:
        lines = [
            f"  [{v.rule}] {v.message}" for v in report.violations
        ]
        raise ValueError(
            f"engine {name!r} lowering failed the spmd lint on "
            f"{n}x{p} ({dt.name}):\n" + "\n".join(lines)
        )
    return report


def _lint_spec_quick(spec: EngineSpec) -> None:
    """The lint-on-register gate: trace and lint the engine's lowering
    over the lint grids, raising (so the caller rolls the registry
    back) on any violation.  Runs for *every* registration — the
    ``verify=False`` natives have no schedule but do have a lowering."""
    bad = []
    for n, p in _LINT_GRIDS:
        if n < spec.min_nodes or p < spec.min_ppn:
            continue
        r = lint_lowering(
            spec.name, n_nodes=n, ppn=p, raise_on_violation=False
        )
        if not r.ok:
            bad.append((n, p, r))
    if bad:
        lines = [
            f"  ({n}x{p}) [{v.rule}] {v.message}"
            for n, p, r in bad
            for v in r.violations
        ]
        raise ValueError(
            f"{spec.collective} engine {spec.name!r} lowering failed "
            "the spmd lint on registration:\n" + "\n".join(lines)
        )


class Decision(NamedTuple):
    """One dispatch decision: the engine and its pipeline depth."""

    engine: str
    chunks: int


def select_engine(
    topology: Topology,
    nbytes: int,
    op: str = "sum",
    *,
    collective: str = "allreduce",
    small_threshold_bytes: int | None = None,
    pipeline_chunks: int | None = None,
) -> Decision:
    """Capability-filtered cost tournament over the registered engines.

    1. **filter** — engines whose declared capabilities (ops, grid
       constraints) match this topology and op.  ``baseline`` engines
       never enter auto dispatch.
    2. **regime split** — when both a latency and a bandwidth engine are
       eligible, the switch point is ``small_threshold_bytes`` if given,
       else the memoised crossover of their declared cost models
       (:meth:`Topology.crossover_bytes`); at or below it the latency
       engine wins outright.
    3. **tournament** — above it the bandwidth engines compete on their
       declared ``cost`` at this payload size; earlier registration wins
       ties (so plain MLA beats pipelined MLA unless chunking strictly
       pays for its extra alpha steps — exactly
       :func:`perf_model.optimal_pipeline_chunks`' rule).
    4. **fallback** — grids/ops no latency or bandwidth engine supports
       (no slow domain; exotic ops) go to the fallback engine.

    ``pipeline_chunks`` pins the depth of a chunked winner (and promotes
    a plain bandwidth winner to its declared ``pipelined_variant`` when
    the pin exceeds 1).  Raises ``NotImplementedError`` listing every
    registered engine and its op set when nothing is eligible.
    """
    table = _REGISTRY[collective]
    eligible = [
        s
        for s in table.values()
        if s.regime in ("latency", "bandwidth", "fallback")
        and s.supports(topology, op)
    ]
    latency = [s for s in eligible if s.regime == "latency"]
    bandwidth = [s for s in eligible if s.regime == "bandwidth"]
    fallback = [s for s in eligible if s.regime == "fallback"]

    if not latency and not bandwidth:
        if not fallback:
            raise NotImplementedError(
                f"no registered {collective} engine supports op={op!r} on "
                f"grid (n={topology.n_nodes}, ppn={topology.ppn}); "
                f"registered engines: "
                + ", ".join(
                    f"{s.name}(ops="
                    f"{sorted(s.ops) if s.ops is not None else 'any'})"
                    for s in table.values()
                )
            )
        return Decision(fallback[0].name, 1)

    if latency and bandwidth:
        threshold = (
            float(small_threshold_bytes)
            if small_threshold_bytes is not None
            else topology.crossover_bytes()
        )
        if nbytes <= threshold:
            return Decision(latency[0].name, 1)
    if not bandwidth:
        return Decision(latency[0].name, 1)

    n, ppn, mp = topology.n_nodes, topology.ppn, topology.params
    best = bandwidth[0]
    best_cost = (
        best.cost(float(nbytes), n, ppn, mp) if best.cost else math.inf
    )
    for s in bandwidth[1:]:
        c = s.cost(float(nbytes), n, ppn, mp) if s.cost else math.inf
        if c < best_cost:
            best, best_cost = s, c

    if best.chunked:
        chunks = (
            max(1, int(pipeline_chunks))
            if pipeline_chunks is not None
            else topology.optimal_pipeline_chunks(nbytes)
        )
        return Decision(best.name, chunks)
    if pipeline_chunks is not None and best.pipelined_variant is not None:
        c = max(1, int(pipeline_chunks))
        return Decision(best.pipelined_variant if c > 1 else best.name, c)
    return Decision(best.name, 1)


# ---------------------------------------------------------------------------
# engine registrations
# ---------------------------------------------------------------------------

_ALL_OPS = frozenset(collectives._OPS)
_STRIPED_OPS = collectives._MLA_OPS


def _exec_psum(x, *, topology, op="sum", pipeline_chunks=None):
    return collectives._psum_allreduce(
        x, inter_axes=topology.inter_axes, intra_axes=topology.intra_axes,
        op=op,
    )


def _exec_nap(x, *, topology, op="sum", pipeline_chunks=None):
    return collectives.nap_allreduce(
        x, inter_axes=topology.inter_axes, intra_axes=topology.intra_axes,
        op=op,
    )


def _exec_rd(x, *, topology, op="sum", pipeline_chunks=None):
    return collectives.rd_allreduce(
        x, inter_axes=topology.inter_axes, intra_axes=topology.intra_axes,
        op=op,
    )


def _exec_smp(x, *, topology, op="sum", pipeline_chunks=None):
    return collectives.smp_allreduce(
        x, inter_axes=topology.inter_axes, intra_axes=topology.intra_axes,
        op=op,
    )


def _exec_mla(x, *, topology, op="sum", pipeline_chunks=None):
    return collectives.mla_allreduce(
        x, inter_axes=topology.inter_axes, intra_axes=topology.intra_axes,
        op=op, pipeline_chunks=pipeline_chunks or 1,
    )


def _exec_mla_pipelined(x, *, topology, op="sum", pipeline_chunks=None):
    return collectives.mla_pipelined_allreduce(
        x, inter_axes=topology.inter_axes, intra_axes=topology.intra_axes,
        op=op, pipeline_chunks=pipeline_chunks, params=topology.params,
    )


def _exec_ring(x, *, topology, op="sum", pipeline_chunks=None):
    return collectives.ring_allreduce(x, axes=topology.axes, op=op)


def _exec_rabenseifner(x, *, topology, op="sum", pipeline_chunks=None):
    # SMP-style large-message baseline: reduce inside the pod first so a
    # single de-duplicated payload crosses the slow domain, then RS+AG.
    v = x
    if topology.intra_axes:
        _, named_reduce, _ = collectives._OPS[op]
        v = named_reduce(v, topology.intra_axes)
    if not topology.inter_axes:
        return v
    return collectives.rabenseifner_allreduce(
        v, axes=topology.inter_axes, op=op
    )


def _cost_mla_pipelined_opt(s, n, ppn, p):
    return pm.cost_mla_pipelined(s, n, ppn, p, chunks=None)


register_engine(
    "nap", ops=_ALL_OPS, regime="latency", min_nodes=2, min_ppn=2,
    cost=pm.cost_nap, build_schedule=napalg.build_nap_schedule,
    execute=_exec_nap, legacy=collectives.nap_allreduce,
)
register_engine(
    "mla", ops=_STRIPED_OPS, regime="bandwidth", min_nodes=2,
    cost=pm.cost_mla, build_schedule=napalg.build_mla_schedule,
    ragged=True, execute=_exec_mla, legacy=collectives.mla_allreduce,
    pipelined_variant="mla_pipelined",
)
register_engine(
    "mla_pipelined", ops=_STRIPED_OPS, regime="bandwidth",
    min_nodes=2, min_ppn=2, cost=_cost_mla_pipelined_opt,
    build_schedule=napalg.build_mla_pipelined_schedule, chunked=True,
    execute=_exec_mla_pipelined, legacy=collectives.mla_pipelined_allreduce,
)
register_engine(
    "psum", ops=_ALL_OPS, regime="fallback", cost=pm.cost_psum,
    execute=_exec_psum, legacy=collectives._psum_allreduce,
)
register_engine(
    "rd", ops=_ALL_OPS, regime="baseline", cost=pm.cost_rd,
    build_schedule=napalg.build_rd_schedule, execute=_exec_rd,
    legacy=collectives.rd_allreduce,
)
register_engine(
    "smp", ops=_ALL_OPS, regime="baseline", cost=pm.cost_smp,
    build_schedule=napalg.build_smp_schedule, execute=_exec_smp,
    legacy=collectives.smp_allreduce,
)
register_engine(
    "ring", ops=_STRIPED_OPS, regime="baseline", execute=_exec_ring,
)
register_engine(
    "rabenseifner", ops=_STRIPED_OPS, regime="baseline",
    execute=_exec_rabenseifner,
)


def _exec_mla_rs(x, *, topology, op="sum"):
    return collectives.mla_reduce_scatter(
        x, inter_axes=topology.inter_axes, intra_axes=topology.intra_axes,
        op=op,
    )


def _exec_flat_rs(x, *, topology, op="sum"):
    return collectives.flat_reduce_scatter(
        x, axes=topology.axes, op=op,
        f32_accum=topology.n_nodes > 1,
    )


def _exec_mla_ag(x, *, topology, elems=None):
    return collectives.mla_allgather(
        x, inter_axes=topology.inter_axes, intra_axes=topology.intra_axes,
        elems=elems,
    )


def _exec_flat_ag(x, *, topology, elems=None):
    return collectives.flat_allgather(x, axes=topology.axes, elems=elems)


register_engine(
    "mla_rs", collective="reduce_scatter", ops=_STRIPED_OPS,
    regime="bandwidth", min_nodes=2, cost=pm.cost_reduce_scatter,
    build_schedule=napalg.build_mla_rs_schedule, ragged=True,
    execute=_exec_mla_rs,
)
register_engine(
    "psum_scatter", collective="reduce_scatter", ops=_STRIPED_OPS,
    regime="fallback", cost=pm.cost_reduce_scatter_flat,
    execute=_exec_flat_rs,
)
register_engine(
    "mla_ag", collective="allgather", ops=None, regime="bandwidth",
    min_nodes=2, cost=pm.cost_allgather,
    build_schedule=napalg.build_mla_ag_schedule, ragged=True,
    execute=_exec_mla_ag,
)
register_engine(
    "all_gather", collective="allgather", ops=None, regime="fallback",
    cost=pm.cost_allgather_flat, execute=_exec_flat_ag,
)


def legacy_execute_table():
    """The old ``collectives.ALGORITHMS`` view, derived from the registry:
    allreduce engines that still expose an axis-kwargs lowering.

    Read-only (a ``MappingProxyType`` over a registry-maintained dict):
    the old extension idiom ``ALGORITHMS["custom"] = fn`` would mutate a
    view the dispatcher never consults, so it now fails loudly — new
    engines register through :func:`register_engine` instead.
    """
    return _LEGACY_VIEW


# ---------------------------------------------------------------------------
# policy + context facade
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CommPolicy:
    """How a :class:`CommContext` dispatches and syncs.

    algorithm: allreduce engine name or ``"auto"`` (capability-filtered
      cost tournament; see :func:`select_engine`).  Validated here at
      build time against the registry — a typo raises immediately with
      the list of registered engines.
    mean: grad-sync only — divide by the group size (data-parallel
      averaging), with integer leaves rounded rather than silently left
      as sums.
    compress_bits: None (off) or 2..8 — quantised grad transport on the
      fused Pallas kernels (:mod:`repro.kernels.transport`) with
      per-leaf max-abs scales.  8 moves ``s8`` wire bytes (1/4 of the
      uncompressed f32 inter-node traffic); 4 packs two int4 nibbles
      per ``u8`` byte (1/8).  The node-aware shape (exact f32
      intra-node pre-combine, packed inter-node exchange) is documented
      in :mod:`repro.core.grad_sync`.
    error_feedback: carry per-chip EF residuals
      (:mod:`repro.optim.error_feedback`) so low-bit transport
      converges: each sync transports ``g + r`` and stores back what the
      wire quantizer dropped.  Requires ``compress_bits``; the caller
      threads the residual tree through
      :meth:`CommContext.sync_grads(ef_state=...) <CommContext.sync_grads>`.
    small_threshold_bytes: fixed latency/bandwidth switch override;
      ``None`` uses the memoised model crossover (possibly ``inf``).
    fuse_small_buckets: let the bucket planner fuse same-dtype float
      leaves (False = one bucket per leaf).
    bucket_bytes: fusion bucket target; ``None`` = overlap optimum from
      :meth:`Topology.optimal_bucket_bytes`.
    pipeline_chunks: MLA pipeline depth; ``None`` = model-optimal per
      payload.
    """

    algorithm: str = "auto"
    mean: bool = True
    compress_bits: int | None = None
    small_threshold_bytes: int | None = None
    fuse_small_buckets: bool = True
    bucket_bytes: int | None = None
    pipeline_chunks: int | None = None
    error_feedback: bool = False

    def __post_init__(self):
        if self.algorithm != "auto":
            get_engine(self.algorithm)  # raises with the engine listing
        if self.compress_bits is not None and not (
            2 <= int(self.compress_bits) <= 8
        ):
            raise ValueError(
                f"compress_bits must be None or 2..8, got "
                f"{self.compress_bits!r}"
            )
        if self.error_feedback and self.compress_bits is None:
            raise ValueError(
                "error_feedback=True requires compress_bits (residuals "
                "of an exact sync are identically zero)"
            )


@dataclasses.dataclass(frozen=True)
class CommContext:
    """Facade binding a :class:`Topology` to a dispatch policy.

    The collective methods execute inside a ``shard_map`` whose mesh
    carries the topology's axis names; dispatch decisions are host-side
    and static (payload sizes are trace constants), so the traced
    program contains exactly the schedule the model picked — the same
    decision the simulator replays and the planner prices.
    """

    topology: Topology
    policy: CommPolicy = CommPolicy()

    # -- dispatch (host-side, static) -------------------------------------

    def dispatch(
        self,
        nbytes: int,
        op: str = "sum",
        *,
        collective: str = "allreduce",
        algorithm: str | None = None,
        pipeline_chunks: int | None = None,
    ) -> Decision:
        """The (engine, chunks) decision for an ``nbytes`` payload."""
        algo = algorithm if algorithm is not None else (
            self.policy.algorithm if collective == "allreduce" else "auto"
        )
        pin = (
            pipeline_chunks
            if pipeline_chunks is not None
            else self.policy.pipeline_chunks
        )
        if algo != "auto":
            spec = get_engine(algo, collective)
            if spec.chunked:
                chunks = (
                    max(1, int(pin))
                    if pin is not None
                    else self.topology.optimal_pipeline_chunks(nbytes)
                )
                return Decision(spec.name, chunks)
            if spec.pipelined_variant is not None and pin is not None:
                return Decision(spec.name, max(1, int(pin)))
            return Decision(spec.name, 1)
        return select_engine(
            self.topology,
            nbytes,
            op,
            collective=collective,
            small_threshold_bytes=self.policy.small_threshold_bytes,
            pipeline_chunks=pin,
        )

    def _engine_for(
        self, decision: Decision, op: str, collective: str
    ) -> EngineSpec:
        spec = get_engine(decision.engine, collective)
        if spec.ops is not None and op not in spec.ops:
            supporting = sorted(
                s.name
                for s in _REGISTRY[collective].values()
                if s.ops is None or op in s.ops
            )
            raise NotImplementedError(
                f"{collective} engine {spec.name!r} supports "
                f"{sorted(spec.ops)}, got op={op!r}; engines supporting "
                f"it: {supporting}"
            )
        return spec

    # -- collectives (inside shard_map) -----------------------------------

    def allreduce(
        self,
        x,
        op: str = "sum",
        *,
        algorithm: str | None = None,
        pipeline_chunks: int | None = None,
    ):
        """Allreduce over the topology's joint grid (model dispatched)."""
        self.topology.require_axes()
        nbytes = int(np.prod(x.shape)) * x.dtype.itemsize
        d = self.dispatch(
            nbytes, op, algorithm=algorithm, pipeline_chunks=pipeline_chunks
        )
        spec = self._engine_for(d, op, "allreduce")
        return spec.execute(
            x, topology=self.topology, op=op, pipeline_chunks=d.chunks
        )

    def reduce_scatter(
        self, x, op: str = "sum", *, algorithm: str | None = None
    ):
        """Striped reduce-scatter: chip ``(node j, lane r)`` returns the
        fully reduced block ``(r, j)`` of the MLA stripe layout (padded
        to uniform per-chip shape ``ceil(ceil(s/ppn)/n)``).

        The ZeRO building block: each chip keeps only its optimizer
        shard's gradient slice; per-chip inter-node bytes are half the
        allreduce round trip (:func:`napalg.rs_internode_lower_bound` at
        the accounting layer).
        """
        self.topology.require_axes()
        nbytes = int(np.prod(x.shape)) * x.dtype.itemsize
        d = self.dispatch(
            nbytes, op, collective="reduce_scatter", algorithm=algorithm
        )
        spec = self._engine_for(d, op, "reduce_scatter")
        return spec.execute(x, topology=self.topology, op=op)

    def allgather(
        self, x, *, elems: int | None = None, algorithm: str | None = None
    ):
        """Inverse of :meth:`reduce_scatter`: rebuild the full payload
        from per-chip blocks.  ``elems`` is the original payload size
        (needed to strip the uniform-shape padding; defaults to
        ``x.size * group``, i.e. no padding)."""
        self.topology.require_axes()
        total = int(elems if elems is not None else x.size * self.topology.group)
        nbytes = total * x.dtype.itemsize
        d = self.dispatch(
            nbytes, "sum", collective="allgather", algorithm=algorithm
        )
        spec = self._engine_for(d, "sum", "allgather")
        return spec.execute(x, topology=self.topology, elems=total)

    # -- gradient sync (inside shard_map) ---------------------------------

    def sync_grads(self, grads, *, plan=None, ef_state=None):
        """Bucket-scheduled gradient allreduce of a pytree (the grad-sync
        executor under this context's policy; see
        :mod:`repro.core.grad_sync`).

        ``ef_state`` (optional, compressed transport only) is the
        per-chip error-feedback residual tree
        (:func:`repro.optim.error_feedback.ef_init`); when given, the
        call syncs ``grads + ef_state`` and returns ``(synced, new_ef)``.
        """
        from . import grad_sync

        return grad_sync.sync_with_context(
            grads, self, plan=plan, ef_state=ef_state
        )

    def sync_grads_sharded(self, grads):
        """ZeRO-style sharded sync: reduce-scatter each leaf, return the
        pytree of per-chip 1-D shards (see
        :func:`repro.core.grad_sync.sync_grads_sharded`)."""
        from . import grad_sync

        return grad_sync.sync_grads_sharded(grads, ctx=self)

    def plan(self, tree):
        """Host-side bucket plan for a gradient pytree under this
        context (:func:`repro.core.grad_sync.plan_for_tree`)."""
        from . import grad_sync

        return grad_sync.plan_for_tree(
            tree, cfg=self.policy, topology=self.topology
        )


# ---------------------------------------------------------------------------
# deprecation bookkeeping (shared by the shim entry points)
# ---------------------------------------------------------------------------

_DEPRECATION_WARNED: set[str] = set()


def warn_deprecated_once(key: str, replacement: str) -> None:
    """Emit one DeprecationWarning per shim per process (the shims stay
    silent after first use so hot loops don't spam)."""
    if key in _DEPRECATION_WARNED:
        return
    _DEPRECATION_WARNED.add(key)
    warnings.warn(
        f"{key} is deprecated; use {replacement} "
        f"(repro.core.comm: Topology + CommContext)",
        DeprecationWarning,
        stacklevel=3,
    )
