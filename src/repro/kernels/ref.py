"""Pure-jnp oracles for every Pallas kernel (the correctness references).

Signatures mirror the kernels exactly; tests assert allclose across
shape/dtype sweeps with the kernels in interpret mode.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["flash_attention_ref", "rwkv6_scan_ref", "mamba_scan_ref"]


def flash_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
) -> jax.Array:
    """q/k/v: (BH, S, hd) -> (BH, S, hd), plain softmax attention."""
    hd = q.shape[-1]
    s = jnp.einsum(
        "bqh,bkh->bqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) / math.sqrt(hd)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qi = jnp.arange(q.shape[1])[:, None]
    ki = jnp.arange(k.shape[1])[None, :]
    rel = qi - ki
    mask = jnp.ones_like(rel, dtype=bool)
    if causal:
        mask &= rel >= 0
    if window is not None:
        mask &= rel < window
    s = jnp.where(mask[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows -> 0
    return jnp.einsum("bqk,bkh->bqh", p, v.astype(jnp.float32)).astype(q.dtype)


def rwkv6_scan_ref(
    r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array, u: jax.Array
) -> jax.Array:
    """Sequential RWKV6 recurrence. r/k/v/w (BH,S,hd), u (BH,hd) -> f32."""
    r, k, v, w = (t.astype(jnp.float32) for t in (r, k, v, w))
    u = u.astype(jnp.float32)
    BH, S, hd = r.shape

    def step(state, inp):
        rt, kt, vt, wt = inp  # (BH, hd)
        kv = kt[:, :, None] * vt[:, None, :]          # (BH, hd, hd)
        out = jnp.einsum(
            "bi,bij->bj", rt, state + u[:, :, None] * kv
        )
        state = wt[:, :, None] * state + kv
        return state, out

    state0 = jnp.zeros((BH, hd, hd), jnp.float32)
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    _, outs = lax.scan(step, state0, xs)
    return jnp.moveaxis(outs, 0, 1)


def mamba_scan_ref(
    x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array, C: jax.Array
) -> jax.Array:
    """Sequential selective scan. x/dt (B,S,d), A (d,N), B/C (B,S,N)."""
    x, dt, A, B, C = (t.astype(jnp.float32) for t in (x, dt, A, B, C))
    Bsz, S, d = x.shape
    N = A.shape[1]

    def step(state, inp):
        xt, dtt, Bt, Ct = inp
        dA = jnp.exp(dtt[..., None] * A[None])
        state = state * dA + (dtt * xt)[..., None] * Bt[:, None, :]
        yt = jnp.einsum("bdn,bn->bd", state, Ct)
        return state, yt

    state0 = jnp.zeros((Bsz, d, N), jnp.float32)
    xs = tuple(
        jnp.moveaxis(t, 1, 0) for t in (x, dt, B, C)
    )
    _, ys = lax.scan(step, state0, xs)
    return jnp.moveaxis(ys, 0, 1)
