"""Pure-jnp oracles for every Pallas kernel (the correctness references).

Signatures mirror the kernels exactly; tests assert allclose across
shape/dtype sweeps with the kernels in interpret mode.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "flash_attention_ref",
    "rwkv6_scan_ref",
    "mamba_scan_ref",
    "quantize_pack_ref",
    "unpack_dequantize_ref",
]


def flash_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
) -> jax.Array:
    """q/k/v: (BH, S, hd) -> (BH, S, hd), plain softmax attention."""
    hd = q.shape[-1]
    s = jnp.einsum(
        "bqh,bkh->bqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) / math.sqrt(hd)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qi = jnp.arange(q.shape[1])[:, None]
    ki = jnp.arange(k.shape[1])[None, :]
    rel = qi - ki
    mask = jnp.ones_like(rel, dtype=bool)
    if causal:
        mask &= rel >= 0
    if window is not None:
        mask &= rel < window
    s = jnp.where(mask[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows -> 0
    return jnp.einsum("bqk,bkh->bqh", p, v.astype(jnp.float32)).astype(q.dtype)


def rwkv6_scan_ref(
    r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array, u: jax.Array
) -> jax.Array:
    """Sequential RWKV6 recurrence. r/k/v/w (BH,S,hd), u (BH,hd) -> f32."""
    r, k, v, w = (t.astype(jnp.float32) for t in (r, k, v, w))
    u = u.astype(jnp.float32)
    BH, S, hd = r.shape

    def step(state, inp):
        rt, kt, vt, wt = inp  # (BH, hd)
        kv = kt[:, :, None] * vt[:, None, :]          # (BH, hd, hd)
        out = jnp.einsum(
            "bi,bij->bj", rt, state + u[:, :, None] * kv
        )
        state = wt[:, :, None] * state + kv
        return state, out

    state0 = jnp.zeros((BH, hd, hd), jnp.float32)
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    _, outs = lax.scan(step, state0, xs)
    return jnp.moveaxis(outs, 0, 1)


def _transport_scale(rows, cols, scales, offsets, base, row_stride):
    """(R, C) per-element scale grid from the global flat-bucket index
    ``base + i*row_stride + c`` and the static per-leaf start offsets."""
    idx = (
        jnp.asarray(base, jnp.int32)
        + jnp.arange(rows, dtype=jnp.int32)[:, None] * int(row_stride)
        + jnp.arange(cols, dtype=jnp.int32)[None, :]
    )
    scales = jnp.asarray(scales, jnp.float32).reshape(-1)
    scale = jnp.full((rows, cols), scales[0], jnp.float32)
    for l in range(1, len(offsets)):
        scale = jnp.where(idx >= int(offsets[l]), scales[l], scale)
    return scale


def quantize_pack_ref(
    x: jax.Array,
    scales: jax.Array,
    *,
    offsets,
    bits: int,
    base=0,
    row_stride: int = 0,
    block: int = 256,
) -> jax.Array:
    """Oracle for :func:`repro.kernels.transport.quantize_pack` on an
    already column-padded (R, C) input (C a multiple of ``block``).
    Bit-identical wire bytes, including the split-half int4 nibble
    layout (low nibble = element k of a block, high = k + block/2)."""
    R, C = x.shape
    scale = _transport_scale(R, C, scales, offsets, base, row_stride)
    qmax = float(2 ** (bits - 1) - 1)
    q = jnp.clip(
        jnp.round(x.astype(jnp.float32) / scale), -qmax, qmax
    ).astype(jnp.int32)
    if bits != 4:
        return q.astype(jnp.int8)
    half = block // 2
    t = q.reshape(R, C // block, block)
    lo, hi = t[:, :, :half], t[:, :, half:]
    packed = (lo & 0xF) | ((hi & 0xF) << 4)
    return packed.reshape(R, C // 2).astype(jnp.uint8)


def unpack_dequantize_ref(
    wire: jax.Array,
    scales: jax.Array,
    *,
    offsets,
    bits: int,
    base=0,
    row_stride: int = 0,
    block: int = 256,
) -> jax.Array:
    """Oracle inverse: wire (R, Cw) -> (R, C) f32 ``q * scale`` (padded
    width; the public wrapper slices to the caller's ``cols``)."""
    R, Cw = wire.shape
    if bits == 4:
        half = block // 2
        b = wire.reshape(R, Cw // half, half).astype(jnp.int32)
        lo = b & 0xF
        hi = (b >> 4) & 0xF
        lo = jnp.where(lo > 7, lo - 16, lo)
        hi = jnp.where(hi > 7, hi - 16, hi)
        q = jnp.concatenate([lo, hi], axis=2).reshape(R, Cw * 2)
    else:
        q = wire.astype(jnp.int32)
    C = q.shape[1]
    scale = _transport_scale(R, C, scales, offsets, base, row_stride)
    return q.astype(jnp.float32) * scale


def mamba_scan_ref(
    x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array, C: jax.Array
) -> jax.Array:
    """Sequential selective scan. x/dt (B,S,d), A (d,N), B/C (B,S,N)."""
    x, dt, A, B, C = (t.astype(jnp.float32) for t in (x, dt, A, B, C))
    Bsz, S, d = x.shape
    N = A.shape[1]

    def step(state, inp):
        xt, dtt, Bt, Ct = inp
        dA = jnp.exp(dtt[..., None] * A[None])
        state = state * dA + (dtt * xt)[..., None] * Bt[:, None, :]
        yt = jnp.einsum("bdn,bn->bd", state, Ct)
        return state, yt

    state0 = jnp.zeros((Bsz, d, N), jnp.float32)
    xs = tuple(
        jnp.moveaxis(t, 1, 0) for t in (x, dt, B, C)
    )
    _, ys = lax.scan(step, state0, xs)
    return jnp.moveaxis(ys, 0, 1)
