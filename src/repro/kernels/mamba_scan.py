"""Pallas TPU kernel for the Mamba (S6) selective state-space scan.

    state_t = exp(dt_t * A) * state_{t-1} + (dt_t * x_t) B_t
    y_t     = state_t . C_t  + D * x_t            (per channel block)

Grid: (batch, channel_blocks, n_chunks); the chunk axis is minor
(sequential on TPU) so the (d_block x d_state) f32 state sits in VMEM
scratch across chunks.  The channel dimension is tiled at ``block_d`` so
arbitrary d_inner (e.g. jamba's 16384) streams through a fixed VMEM
budget: tiles x(T_c x d_blk), dt(T_c x d_blk), B/C(T_c x N),
state(d_blk x N) ≈ 0.6 MiB at T_c=64, d_blk=256, N=16.

Like the RWKV6 kernel, the inner chunk is an exact ``fori_loop``
recurrence (VPU work; the op is HBM-bandwidth-bound) — the win over the
XLA scan is state residency + chunked HBM streaming, not MXU math.
Oracle: ``repro.kernels.ref.mamba_scan_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["mamba_scan_pallas"]


def _kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, y_ref, state_ref, *, chunk):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)    # (T_c, d_blk)
    dt = dt_ref[0].astype(jnp.float32)  # (T_c, d_blk)
    A = A_ref[...].astype(jnp.float32)  # (d_blk, N)
    B = B_ref[0].astype(jnp.float32)    # (T_c, N)
    C = C_ref[0].astype(jnp.float32)    # (T_c, N)

    def step(t, carry):
        state, out = carry
        xt = jax.lax.dynamic_slice_in_dim(x, t, 1, 0)[0]    # (d_blk,)
        dtt = jax.lax.dynamic_slice_in_dim(dt, t, 1, 0)[0]
        Bt = jax.lax.dynamic_slice_in_dim(B, t, 1, 0)[0]    # (N,)
        Ct = jax.lax.dynamic_slice_in_dim(C, t, 1, 0)[0]
        dA = jnp.exp(dtt[:, None] * A)                      # (d_blk, N)
        state = state * dA + (dtt * xt)[:, None] * Bt[None, :]
        yt = (state * Ct[None, :]).sum(axis=1)              # (d_blk,)
        out = jax.lax.dynamic_update_slice_in_dim(
            out, yt[None], t, 0
        )
        return state, out

    state, out = lax.fori_loop(
        0, chunk, step, (state_ref[...], jnp.zeros_like(x))
    )
    state_ref[...] = state
    y_ref[0] = out.astype(y_ref.dtype)


def mamba_scan_pallas(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    B: jax.Array,
    C: jax.Array,
    *,
    chunk: int = 64,
    block_d: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """x/dt: (Bsz, S, d_inner); A: (d_inner, N); B/C: (Bsz, S, N).

    Returns y (Bsz, S, d_inner) f32 (caller adds the D-skip and gating).
    """
    Bsz, S, d_inner = x.shape
    N = A.shape[1]
    chunk = min(chunk, S)
    block_d = min(block_d, d_inner)
    pad_t = (-S) % chunk
    pad_d = (-d_inner) % block_d
    if pad_t or pad_d:
        x = jnp.pad(x, ((0, 0), (0, pad_t), (0, pad_d)))
        dt = jnp.pad(dt, ((0, 0), (0, pad_t), (0, pad_d)))
        B = jnp.pad(B, ((0, 0), (0, pad_t), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad_t), (0, 0)))
        A = jnp.pad(A, ((0, pad_d), (0, 0)))
    Sp, Dp = S + pad_t, d_inner + pad_d
    n_chunks, n_blk = Sp // chunk, Dp // block_d
    y = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=(Bsz, n_blk, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((block_d, N), lambda b, d, c: (d, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
        out_shape=jax.ShapeDtypeStruct((Bsz, Sp, Dp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_d, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C)
    return y[:, :S, :d_inner]
