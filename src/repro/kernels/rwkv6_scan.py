"""Pallas TPU kernel for the RWKV6 time-mix recurrence.

    S_t = diag(w_t) S_{t-1} + k_t^T v_t        (per head, S in hd x hd)
    o_t = r_t (S_{t-1}-with-decay + u-bonus k_t^T v_t)

The sequence is tiled into chunks along time; the grid is
(batch*heads, n_chunks) with the chunk axis minor (sequential on TPU), so
the (hd x hd) f32 state lives in VMEM scratch across chunk steps.  Inside
a chunk the recurrence is a ``fori_loop`` of rank-1 VPU updates — RWKV6's
per-channel data-dependent decay makes the matmul-form chunking
numerically treacherous (1/decay cumulative products overflow), and the
op is memory-bound anyway, so the honest kernel keeps the exact
recurrence and wins by keeping state resident in VMEM instead of
round-tripping HBM every step (the XLA scan's behaviour).

VMEM per cell: chunk tiles 4*(T_c x hd) f32 + state (hd x hd) f32
= 4*64*64*4 + 64*64*4 ≈ 80 KiB for hd=64, T_c=64.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["rwkv6_scan_pallas"]


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, state_ref, *, chunk):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0].astype(jnp.float32)  # (T_c, hd)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)  # (1, hd) bonus

    def step(t, carry):
        state, out = carry
        rt = jax.lax.dynamic_slice_in_dim(r, t, 1, 0)  # (1, hd)
        kt = jax.lax.dynamic_slice_in_dim(k, t, 1, 0)
        vt = jax.lax.dynamic_slice_in_dim(v, t, 1, 0)
        wt = jax.lax.dynamic_slice_in_dim(w, t, 1, 0)
        kv = kt.T @ vt                                   # (hd, hd)
        ot = rt @ (state + u.T * kv)                     # (1, hd)
        state = wt.T * state + kv
        out = jax.lax.dynamic_update_slice_in_dim(out, ot, t, 0)
        return state, out

    state, out = lax.fori_loop(
        0, chunk, step, (state_ref[...], jnp.zeros_like(r))
    )
    state_ref[...] = state
    o_ref[0] = out.astype(o_ref.dtype)


def rwkv6_scan_pallas(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> jax.Array:
    """r/k/v/w: (BH, S, hd); u: (BH, hd) bonus. Returns (BH, S, hd) f32."""
    BH, S, hd = r.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        zero = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0)))
        r, k, v = zero(r), zero(k), zero(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
    Sp = S + pad
    n_chunks = Sp // chunk
    out = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=(BH, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, hd), lambda b, c: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sp, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u[:, None, :])
    return out[:, :S, :]
