from .ops import flash_attention, mamba_scan, rwkv6_scan
from .transport import quantize_pack, unpack_dequantize

__all__ = [
    "flash_attention",
    "mamba_scan",
    "rwkv6_scan",
    "quantize_pack",
    "unpack_dequantize",
]
