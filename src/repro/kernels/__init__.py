from .ops import flash_attention, mamba_scan, rwkv6_scan

__all__ = ["flash_attention", "mamba_scan", "rwkv6_scan"]
