"""Public jit'd wrappers for the Pallas kernels.

``impl`` selection: "pallas" compiles the kernel for TPU (interpret=True
on CPU backends so the same call validates everywhere); "xla" routes to
the pure-jnp reference (the dry-run default — the 512-device compile must
not depend on Mosaic).  GQA head expansion and head flattening live here
so model code passes (B, S, H, hd) tensors straight in.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention_pallas
from .mamba_scan import mamba_scan_pallas
from .rwkv6_scan import rwkv6_scan_pallas

__all__ = ["flash_attention", "rwkv6_scan", "mamba_scan"]


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    impl: str = "pallas",
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """q: (B, S, H, hd); k/v: (B, S, KV, hd) (GQA: H % KV == 0)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, -1, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, -1, hd)
    if impl == "xla":
        of = ref.flash_attention_ref(
            qf, kf, vf, causal=causal, window=window, softcap=softcap
        )
    else:
        of = flash_attention_pallas(
            qf, kf, vf, causal=causal, window=window, softcap=softcap,
            block_q=block_q, block_k=block_k, interpret=_on_cpu(),
        )
    return of.reshape(B, H, S, hd).transpose(0, 2, 1, 3)


def rwkv6_scan(
    r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array, u: jax.Array,
    *, impl: str = "pallas", chunk: int = 64,
) -> jax.Array:
    """r/k/v/w: (B, S, H, hd); u: (H, hd). Returns (B, S, H, hd) f32."""
    B, S, H, hd = r.shape

    def flat(t):
        return t.transpose(0, 2, 1, 3).reshape(B * H, S, hd)

    uf = jnp.tile(u, (B, 1))
    if impl == "xla":
        of = ref.rwkv6_scan_ref(flat(r), flat(k), flat(v), flat(w), uf)
    else:
        of = rwkv6_scan_pallas(
            flat(r), flat(k), flat(v), flat(w), uf,
            chunk=chunk, interpret=_on_cpu(),
        )
    return of.reshape(B, H, S, hd).transpose(0, 2, 1, 3)


def mamba_scan(
    x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array, C: jax.Array,
    *, impl: str = "pallas", chunk: int = 64, block_d: int = 256,
) -> jax.Array:
    """x/dt: (Bsz, S, d); A: (d, N); B/C: (Bsz, S, N) -> (Bsz, S, d) f32."""
    if impl == "xla":
        return ref.mamba_scan_ref(x, dt, A, B, C)
    return mamba_scan_pallas(
        x, dt, A, B, C, chunk=chunk, block_d=block_d, interpret=_on_cpu()
    )
