"""Pallas TPU flash attention: blockwise online-softmax, VMEM-tiled.

The compute hot-spot of every attention arch at prefill_32k.  One grid
cell processes a (block_q x head_dim) query tile against the KV sequence
in (block_k) tiles, carrying the online-softmax statistics (m, l) and the
f32 accumulator in VMEM scratch; the K dimension is the minor grid axis,
which TPU executes sequentially, so the scratch carries across k-steps.

Supports causal masking, sliding windows (gemma2 local layers) and
attention-logit soft-capping.  ``repro.kernels.ref.flash_attention_ref``
is the pure-jnp oracle; ``repro.kernels.ops`` is the public jit wrapper
(interpret=True on CPU, compiled on TPU).

TPU sizing notes: block_q = block_k = 128 keeps the MXU matmuls
(128 x hd) x (hd x 128) hardware-aligned for hd in {64, 128}; VMEM use
per cell is q(128*hd) + k/v(2*128*hd) + acc(128*hd) f32 + p(128*128)
< 1 MiB — far under the ~16 MiB VMEM budget, leaving headroom for
double-buffered pipelines.  Causal cells fully above the diagonal are
masked (a production variant would clamp the k-grid per q-block; kept
uniform here so the same kernel serves the windowed variants).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

NEG_INF = -1.0e30


def _kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale, causal, window, softcap_val, block_q, block_k, n_k, kv_len,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # (bq, hd)
    k = k_ref[0].astype(jnp.float32)  # (bk, hd)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (bq, bk)
    if softcap_val is not None:
        s = softcap_val * jnp.tanh(s / softcap_val)

    q_idx = qi * block_q + lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_idx = ki * block_k + lax.broadcasted_iota(jnp.int32, s.shape, 1)
    rel = q_idx - k_idx
    mask = k_idx < kv_len  # padded keys never attended
    if causal:
        mask &= rel >= 0
    if window is not None:
        mask &= rel < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    # rows with no valid key yet keep m = NEG_INF; exp underflows to 0.
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalise():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """q, k, v: (BH, S, hd) with heads pre-flattened (GQA expanded).

    Returns (BH, S, hd) in q.dtype.
    """
    BH, S, hd = q.shape
    Sk = k.shape[1]
    block_q = min(block_q, S)
    block_k = min(block_k, Sk)
    pad_q = (-S) % block_q
    pad_k = (-Sk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
    Sq_p, Sk_p = S + pad_q, Sk + pad_k
    n_q, n_k = Sq_p // block_q, Sk_p // block_k
    # padded keys masked out via the window/causal logic: give them k_idx
    # beyond every query (mask=False rows handled by NEG_INF + l clamp)
    scale = 1.0 / math.sqrt(hd)
    kernel = functools.partial(
        _kernel,
        scale=scale,
        causal=causal,
        window=window,
        softcap_val=softcap,
        block_q=block_q,
        block_k=block_k,
        n_k=n_k,
        kv_len=Sk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq_p, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),   # m: running max
            pltpu.VMEM((block_q,), jnp.float32),   # l: running denom
            pltpu.VMEM((block_q, hd), jnp.float32),  # f32 accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :S, :]
