"""Fused quantize-pack-stripe transport kernels (Pallas).

The compressed grad-sync path used to quantize, cast, concat and stripe
as separate XLA ops over the gradient before a single byte moved.  These
kernels collapse that chain into **one pass per transport hop**: a grid
cell reads a (1, block) tile of the fused f32 bucket, looks up the tile's
per-leaf scale from its *global flat index* (the leaf offsets of the
bucket — the same offsets :func:`repro.core.napalg.mla_stripe_geometry`
charges for stripe bytes — baked in as static index maps), rounds/clips
to the wire width and writes the wire bytes directly in stripe layout:

* ``bits == 8`` (or any width 2..8 except 4): one ``int8`` byte per
  element (``s8`` on the wire — 1/4 of f32);
* ``bits == 4``: two int4 nibbles packed per ``uint8`` byte with a
  split-half layout per block — wire byte ``k`` of a block carries
  element ``k`` in its low nibble and element ``k + block/2`` in its
  high nibble (``u8`` on the wire — 1/8 of f32).

:func:`unpack_dequantize` is the exact inverse on receive.  Both follow
the :mod:`repro.kernels.ops` convention: ``impl="pallas"`` compiles the
kernel (``interpret=True`` on CPU so tier-1 validates everywhere) and
``impl="xla"`` routes to the pure-jnp oracle in :mod:`repro.kernels.ref`,
which is bit-identical on the wire bytes.

Index plumbing: a wire array is (R, C) — R rows that are *blocks of a
stripe* (or per-rank copies of one block).  Element (i, c) of the padded
input corresponds to global flat-bucket index ``base + i*row_stride + c``
(``base`` is traced — it depends on ``lax.axis_index`` — and
``row_stride`` is static: the padded block length for sequential blocks,
0 for all-to-all-received per-rank copies of the same block).  Scales are
an (L,) traced vector (one per leaf, NAP-max agreed across the group);
leaf start offsets are static Python ints.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from . import ref

__all__ = [
    "quantize_pack",
    "unpack_dequantize",
    "wire_dtype",
    "wire_itemsize",
    "DEFAULT_BLOCK",
]

DEFAULT_BLOCK = 256


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def wire_dtype(bits: int) -> jnp.dtype:
    """Dtype of the on-wire array: packed ``uint8`` for int4, ``int8``
    for every other supported width (2..8)."""
    return jnp.dtype(jnp.uint8) if bits == 4 else jnp.dtype(jnp.int8)


def wire_itemsize(bits: int) -> float:
    """Bytes per *element* on the wire (0.5 for packed int4, 1 else)."""
    return 0.5 if bits == 4 else 1.0


def _check_args(bits: int, block: int, scales_len: int, offsets) -> None:
    if not (2 <= bits <= 8):
        raise ValueError(f"transport bits must be in 2..8, got {bits}")
    if block % 2 or block < 2:
        raise ValueError(f"block must be even and >= 2, got {block}")
    if len(offsets) != scales_len:
        raise ValueError(
            f"{scales_len} scales but {len(offsets)} leaf offsets"
        )
    if list(offsets) != sorted(int(o) for o in offsets) or offsets[0] != 0:
        raise ValueError(f"offsets must be sorted and start at 0: {offsets}")


def _pad_cols(x: jax.Array, block: int) -> jax.Array:
    pad = (-x.shape[1]) % block
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    return x


def _tile_index(base, i, j, *, block: int, row_stride: int):
    """Global flat-bucket index of every element in grid cell (i, j)."""
    return (
        base
        + i * row_stride
        + j * block
        + lax.broadcasted_iota(jnp.int32, (1, block), 1)
    )


def _tile_scale(scales_ref, idx, *, offsets):
    """Per-element scale for a tile: leaf ``l`` spans global indices
    ``[offsets[l], offsets[l+1])`` (static loop — L is a trace-time
    constant, so this lowers to L-1 selects, not a gather)."""
    scale = jnp.full(idx.shape, scales_ref[0, 0], dtype=jnp.float32)
    for l in range(1, len(offsets)):
        scale = jnp.where(idx >= offsets[l], scales_ref[0, l], scale)
    return scale


def _quant_kernel(
    base_ref, scales_ref, x_ref, o_ref, *, offsets, bits, block, row_stride
):
    i = pl.program_id(0)
    j = pl.program_id(1)
    idx = _tile_index(
        base_ref[0, 0], i, j, block=block, row_stride=row_stride
    )
    scale = _tile_scale(scales_ref, idx, offsets=offsets)
    qmax = float(2 ** (bits - 1) - 1)
    q = jnp.clip(
        jnp.round(x_ref[...].astype(jnp.float32) / scale), -qmax, qmax
    ).astype(jnp.int32)
    if bits == 4:
        half = block // 2
        lo, hi = q[:, :half], q[:, half:]
        o_ref[...] = ((lo & 0xF) | ((hi & 0xF) << 4)).astype(jnp.uint8)
    else:
        o_ref[...] = q.astype(jnp.int8)


def _dequant_kernel(
    base_ref, scales_ref, w_ref, o_ref, *, offsets, bits, block, row_stride
):
    i = pl.program_id(0)
    j = pl.program_id(1)
    idx = _tile_index(
        base_ref[0, 0], i, j, block=block, row_stride=row_stride
    )
    scale = _tile_scale(scales_ref, idx, offsets=offsets)
    if bits == 4:
        b = w_ref[...].astype(jnp.int32)
        lo = b & 0xF
        hi = (b >> 4) & 0xF
        lo = jnp.where(lo > 7, lo - 16, lo)
        hi = jnp.where(hi > 7, hi - 16, hi)
        q = jnp.concatenate([lo, hi], axis=1)
    else:
        q = w_ref[...].astype(jnp.int32)
    o_ref[...] = q.astype(jnp.float32) * scale


def _scalar_2d(v) -> jax.Array:
    return jnp.asarray(v, jnp.int32).reshape(1, 1)


def quantize_pack(
    x: jax.Array,
    scales: jax.Array,
    *,
    offsets,
    bits: int,
    base=0,
    row_stride: int = 0,
    impl: str = "pallas",
    block: int = DEFAULT_BLOCK,
    interpret: bool | None = None,
    donate_input: bool = False,
) -> jax.Array:
    """Quantize-and-pack ``x`` (R, C) f32 into wire bytes in one pass.

    Returns (R, ceil(C/block)*block * wire_itemsize(bits)) wire bytes
    (columns zero-padded up to a ``block`` multiple; the pad quantizes
    to 0 and is sliced off by :func:`unpack_dequantize`).  ``scales`` is
    the (L,) per-leaf scale vector, ``offsets`` the static leaf start
    indices, ``base``/``row_stride`` the global-index plumbing (module
    docstring).

    ``donate_input=True`` declares that the caller is done with ``x``:
    its buffer may be reused for the wire output.  A true
    ``input_output_aliases`` is impossible here (the output dtype and
    width differ from the input), so the declaration is carried in the
    kernel *name* (``__donate<argnum>`` suffix) where the spmd lint's
    alias-donation rule statically proves the donated operand is never
    read again after the call.  Do **not** set it when the caller still
    needs ``x`` (e.g. the error-feedback path re-reads the stripe).
    """
    offsets = tuple(int(o) for o in offsets)
    scales = jnp.asarray(scales, jnp.float32).reshape(-1)
    _check_args(bits, block, scales.shape[0], offsets)
    xp = _pad_cols(jnp.asarray(x, jnp.float32), block)
    R, Cp = xp.shape
    if impl == "xla":
        return ref.quantize_pack_ref(
            xp, scales, offsets=offsets, bits=bits, base=base,
            row_stride=row_stride, block=block,
        )
    L = scales.shape[0]
    wblock = block // 2 if bits == 4 else block
    out_cols = (Cp // block) * wblock
    kern = functools.partial(
        _quant_kernel,
        offsets=offsets, bits=bits, block=block, row_stride=int(row_stride),
    )
    name = f"quantize_pack_{bits}b" + ("__donate2" if donate_input else "")
    return pl.pallas_call(
        kern,
        name=name,
        grid=(R, Cp // block),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, L), lambda i, j: (0, 0)),
            pl.BlockSpec((1, block), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, wblock), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, out_cols), wire_dtype(bits)),
        interpret=_on_cpu() if interpret is None else interpret,
    )(_scalar_2d(base), scales.reshape(1, L), xp)


def unpack_dequantize(
    wire: jax.Array,
    scales: jax.Array,
    *,
    offsets,
    bits: int,
    cols: int,
    base=0,
    row_stride: int = 0,
    impl: str = "pallas",
    block: int = DEFAULT_BLOCK,
    interpret: bool | None = None,
    donate_input: bool = False,
) -> jax.Array:
    """Inverse of :func:`quantize_pack`: wire bytes (R, Cw) back to
    (R, cols) f32 values (``q * scale``), slicing off the block padding.

    ``base``/``row_stride``/``scales``/``offsets`` must describe the
    global indices of the *received* rows — for all-to-all-received
    per-rank copies of one block that is ``row_stride=0`` (every row
    dequantizes with the same index window).

    ``donate_input=True`` declares the received wire buffer dead after
    this call (see :func:`quantize_pack` — the declaration rides in the
    kernel name and is enforced by the spmd lint's alias-donation rule).
    """
    offsets = tuple(int(o) for o in offsets)
    scales = jnp.asarray(scales, jnp.float32).reshape(-1)
    _check_args(bits, block, scales.shape[0], offsets)
    R, Cw = wire.shape
    wblock = block // 2 if bits == 4 else block
    if Cw % wblock:
        raise ValueError(
            f"wire width {Cw} is not a multiple of the {wblock}-byte "
            f"wire block (bits={bits}, block={block})"
        )
    if impl == "xla":
        out = ref.unpack_dequantize_ref(
            wire, scales, offsets=offsets, bits=bits, base=base,
            row_stride=row_stride, block=block,
        )
        return out[:, :cols]
    L = scales.shape[0]
    kern = functools.partial(
        _dequant_kernel,
        offsets=offsets, bits=bits, block=block, row_stride=int(row_stride),
    )
    name = (
        f"unpack_dequantize_{bits}b" + ("__donate2" if donate_input else "")
    )
    out = pl.pallas_call(
        kern,
        name=name,
        grid=(R, Cw // wblock),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, L), lambda i, j: (0, 0)),
            pl.BlockSpec((1, wblock), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(
            (R, (Cw // wblock) * block), jnp.float32
        ),
        interpret=_on_cpu() if interpret is None else interpret,
    )(_scalar_2d(base), scales.reshape(1, L), wire)
    return out[:, :cols]
