"""Mixture-of-Experts FFN with explicit expert parallelism.

Routed experts are sharded over the ``model`` mesh axis (EP).  Dispatch is
the production-style two-hop:

  1. tokens are bucketed by *destination EP rank* (capacity-bounded,
     deterministic cumsum positions) and exchanged with one
     ``lax.all_to_all`` over the model axis;
  2. received tokens are bucketed per *local expert*, run through a
     batched (E_local, C, D) x (E_local, D, F) GLU, and returned by the
     reverse ``all_to_all``; gathers (never scatters) restore token order.

The EP hop runs inside ``jax.shard_map`` so the collective schedule is
explicit — the same design decision as the paper's NAP collective (static
schedules beat compiler guessing); everything else stays in auto-sharded
jit.  Without a mesh (CPU smoke tests) the same local routine handles all
experts directly.

DeepSeek-style shared experts ride the dense path; a load-balance aux
loss (Switch-style) is returned for the trainer.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from .. import compat
from .layers import _ACTS, dense, init_dense, init_glu_mlp, glu_mlp

__all__ = ["init_moe", "moe_apply"]


def init_moe(key, cfg, dtype):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    params = {
        "w_router": init_dense(ks[0], d, m.num_experts, jnp.float32),
        "we_gate": _init_experts(ks[1], m.num_experts, d, m.d_expert, dtype),
        "we_up": _init_experts(ks[2], m.num_experts, d, m.d_expert, dtype),
        "we_down": _init_experts(ks[3], m.num_experts, m.d_expert, d, dtype),
    }
    if m.num_shared_experts:
        params["shared"] = init_glu_mlp(
            ks[4], d, m.num_shared_experts * m.d_expert, dtype
        )
    return params


def _init_experts(key, e, d_in, d_out, dtype):
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (e, d_in, d_out)) * scale).astype(dtype)


def _capacity(tokens: int, k: int, buckets: int, factor: float) -> int:
    cap = int(math.ceil(tokens * k / buckets * factor))
    return max(8, ((cap + 7) // 8) * 8)  # pad to 8 for TPU-friendly tiles


def _bucket_positions(dest: jax.Array, n_buckets: int, cap: int):
    """Deterministic position of each item inside its destination bucket.

    dest: (N,) int32 bucket ids. Returns (pos (N,), keep (N,) bool).
    """
    onehot = jax.nn.one_hot(dest, n_buckets, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1  # (N, buckets)
    pos = jnp.take_along_axis(pos, dest[:, None], axis=1)[:, 0]
    return pos, pos < cap


def _expert_ffn(we_gate, we_up, we_down, x, act: str):
    """Batched per-expert GLU: x (E, C, D) -> (E, C, D)."""
    h = _ACTS[act](jnp.einsum("ecd,edf->ecf", x, we_gate))
    h = h * jnp.einsum("ecd,edf->ecf", x, we_up)
    return jnp.einsum("ecf,efd->ecd", h, we_down)


def _route_local(
    x_flat, top_idx, top_gate, we_gate, we_up, we_down, *, cap_factor, act
):
    """All experts resident locally: bucket per expert, batched GLU, gather.

    x_flat: (T, D); top_idx/top_gate: (T, K).
    """
    T, D = x_flat.shape
    E = we_gate.shape[0]
    K = top_idx.shape[1]
    flat_dest = top_idx.reshape(-1)  # (T*K,)
    cap = _capacity(T, K, E, cap_factor)
    pos, keep = _bucket_positions(flat_dest, E, cap)
    slot = jnp.where(keep, flat_dest * cap + pos, E * cap)  # overflow row
    buf = jnp.zeros((E * cap + 1, D), x_flat.dtype)
    src = jnp.repeat(x_flat, K, axis=0)
    buf = buf.at[slot].set(src)  # unique slots: set, not add
    out = _expert_ffn(
        we_gate, we_up, we_down, buf[:-1].reshape(E, cap, D), act
    )
    y = out.reshape(E * cap, D)
    y = jnp.concatenate([y, jnp.zeros((1, D), y.dtype)])  # dropped -> 0
    gathered = y[slot] * top_gate.reshape(-1)[:, None].astype(y.dtype)
    return gathered.reshape(T, K, D).sum(axis=1)


def _route_ep(
    x_flat,
    top_idx,
    top_gate,
    we_gate,
    we_up,
    we_down,
    *,
    tp_axis,
    fsdp_axes,
    partial_axes=(),
    cap_factor,
    act,
):
    """Two-hop EP dispatch inside shard_map. x_flat: (T_local, D).

    ``fsdp_axes``: training layout — expert reduce dims FSDP-sharded,
    gathered here before the batched GLU.  ``partial_axes``: serving
    layout — the expert F dim is sharded over the data axes instead, so
    the down-projection yields partial sums reduced with one activation-
    sized psum (no weight gathers; the 2D-serve optimization).
    """
    ranks = compat.axis_size(tp_axis)
    if fsdp_axes:
        # FSDP shards the *reduce* dim: axis 1 (D) for gate/up, axis 2 (D)
        # for down (its layout is (E, F, D)).
        we_gate = lax.all_gather(we_gate, fsdp_axes, axis=1, tiled=True)
        we_up = lax.all_gather(we_up, fsdp_axes, axis=1, tiled=True)
        we_down = lax.all_gather(we_down, fsdp_axes, axis=2, tiled=True)
    e_local = we_gate.shape[0]
    T, D = x_flat.shape
    K = top_idx.shape[1]

    # hop 1: bucket by destination rank
    flat_dest_rank = (top_idx // e_local).reshape(-1)
    cap_s = _capacity(T, K, ranks, cap_factor)
    pos1, keep1 = _bucket_positions(flat_dest_rank, ranks, cap_s)
    slot1 = jnp.where(keep1, flat_dest_rank * cap_s + pos1, ranks * cap_s)
    send = jnp.zeros((ranks * cap_s + 1, D), x_flat.dtype)
    send = send.at[slot1].set(jnp.repeat(x_flat, K, axis=0))
    send_eid = jnp.full((ranks * cap_s + 1,), -1, jnp.int32)
    send_eid = send_eid.at[slot1].set(
        (top_idx % e_local).reshape(-1).astype(jnp.int32)
    )
    recv = lax.all_to_all(
        send[:-1].reshape(ranks, cap_s, D), tp_axis, 0, 0, tiled=False
    ).reshape(ranks * cap_s, D)
    recv_eid = lax.all_to_all(
        send_eid[:-1].reshape(ranks, cap_s, 1), tp_axis, 0, 0, tiled=False
    ).reshape(ranks * cap_s)

    # hop 2: bucket received tokens per local expert.  With a single
    # local expert every received token lands on it by construction, so
    # no second capacity factor applies (a 1.25x waste of expert flops
    # otherwise — measured on jamba: ~20% of total train compute).
    N = ranks * cap_s
    cap_e = _capacity(N, 1, e_local, cap_factor if e_local > 1 else 1.0)
    valid = recv_eid >= 0
    dest2 = jnp.where(valid, recv_eid, 0)
    pos2, keep2 = _bucket_positions(dest2, e_local, cap_e)
    keep2 &= valid
    slot2 = jnp.where(keep2, dest2 * cap_e + pos2, e_local * cap_e)
    buf = jnp.zeros((e_local * cap_e + 1, D), x_flat.dtype)
    buf = buf.at[slot2].set(recv)
    out = _expert_ffn(
        we_gate, we_up, we_down, buf[:-1].reshape(e_local, cap_e, D), act
    )
    if partial_axes:  # serve2d: F was sharded -> partial sums over data
        out = lax.psum(out, partial_axes)
    y = jnp.concatenate(
        [out.reshape(e_local * cap_e, D), jnp.zeros((1, D), out.dtype)]
    )
    back = y[slot2]  # (N, D): dropped -> 0, restored to recv order

    # reverse hop 1
    ret = lax.all_to_all(
        back.reshape(ranks, cap_s, D), tp_axis, 0, 0, tiled=False
    ).reshape(ranks * cap_s, D)
    ret = jnp.concatenate([ret, jnp.zeros((1, D), ret.dtype)])
    gathered = ret[slot1] * top_gate.reshape(-1)[:, None].astype(ret.dtype)
    return gathered.reshape(T, K, D).sum(axis=1)


def moe_apply(params, x: jax.Array, *, cfg, policy):
    """MoE FFN: x (B, S, D) -> (y (B, S, D), aux_loss scalar)."""
    m = cfg.moe
    B, S, D = x.shape
    logits = dense(x, params["w_router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_gate, top_idx = lax.top_k(probs, m.top_k)
    top_gate = top_gate / jnp.clip(
        top_gate.sum(-1, keepdims=True), 1e-9
    )  # renormalise over selected

    # Switch-style load-balance loss
    density = jnp.mean(
        jax.nn.one_hot(top_idx, m.num_experts, dtype=jnp.float32), axis=(0, 1, 2)
    )
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = m.router_aux_weight * m.num_experts * jnp.sum(density * mean_prob)

    use_ep = (
        policy.mesh is not None
        and policy.tp_axis is not None
        and m.num_experts % policy.tp_size == 0
        and policy.tp_size > 1
        # shard_map needs the token dim divisible by the dp axes (decode
        # with batch < dp falls back to the local route — cheap there)
        and (B * S) % max(policy.dp_size, 1) == 0
    )
    if use_ep:
        gate_spec = policy.spec_for("we_gate", params["we_gate"].shape)
        specs_in = (
            P(policy.dp, None),                    # x_flat
            P(policy.dp, None),                    # top_idx
            P(policy.dp, None),                    # top_gate
            gate_spec,
            policy.spec_for("we_up", params["we_up"].shape),
            policy.spec_for("we_down", params["we_down"].shape),
        )
        def _axes_of(entry):
            if entry is None:
                return ()
            return entry if isinstance(entry, tuple) else (entry,)

        fsdp_axes = _axes_of(gate_spec[1] if len(gate_spec) > 1 else None)
        down_spec = policy.spec_for("we_down", params["we_down"].shape)
        partial_axes = (
            _axes_of(down_spec[1] if len(down_spec) > 1 else None)
            if policy.mode == "serve2d"
            else ()
        )
        routed = compat.shard_map(
            partial(
                _route_ep,
                tp_axis=policy.tp_axis,
                fsdp_axes=fsdp_axes,
                partial_axes=partial_axes,
                cap_factor=m.capacity_factor,
                act=cfg.act,
            ),
            mesh=policy.mesh,
            in_specs=specs_in,
            out_specs=P(policy.dp, None),
            check_vma=False,
        )(
            x.reshape(B * S, D),
            top_idx.reshape(B * S, m.top_k),
            top_gate.reshape(B * S, m.top_k),
            params["we_gate"],
            params["we_up"],
            params["we_down"],
        )
    else:
        routed = _route_local(
            x.reshape(B * S, D),
            top_idx.reshape(B * S, m.top_k),
            top_gate.reshape(B * S, m.top_k),
            params["we_gate"],
            params["we_up"],
            params["we_down"],
            cap_factor=m.capacity_factor,
            act=cfg.act,
        )
    y = routed.reshape(B, S, D)
    if "shared" in params:
        y = y + glu_mlp(params["shared"], x, cfg.act)
    return y.astype(x.dtype), aux
