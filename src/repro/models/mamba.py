"""Mamba (S6) selective state-space mixer — the jamba hybrid's workhorse.

Full-sequence mode runs the selective scan with ``lax.scan`` over time
(memory-light, compile-friendly for the 512-device dry-run); single-token
decode is an O(1) state update.  The VMEM-tiled chunked formulation lives
in ``repro.kernels.mamba_scan`` (TPU target; this module is its oracle).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .layers import dense, init_dense

__all__ = ["init_mamba", "mamba_full", "mamba_decode", "init_mamba_cache"]


def _dims(cfg):
    m = cfg.mamba
    d_inner = m.expand * cfg.d_model
    dt_rank = m.dt_rank or math.ceil(cfg.d_model / 16)
    return m, d_inner, dt_rank


def init_mamba(key, cfg, dtype):
    m, d_inner, dt_rank = _dims(cfg)
    ks = jax.random.split(key, 6)
    # S4D-real initialisation of A
    A = jnp.tile(
        jnp.arange(1, m.d_state + 1, dtype=jnp.float32)[None, :],
        (d_inner, 1),
    )
    return {
        "w_in": init_dense(ks[0], cfg.d_model, 2 * d_inner, dtype),
        "conv_w": (
            jax.random.normal(ks[1], (d_inner, m.d_conv)) / math.sqrt(m.d_conv)
        ).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "x_proj": init_dense(ks[2], d_inner, dt_rank + 2 * m.d_state, dtype),
        "w_dt": init_dense(ks[3], dt_rank, d_inner, dtype),
        "dt_bias": jnp.log(
            jnp.exp(
                jax.random.uniform(ks[4], (d_inner,), minval=1e-3, maxval=0.1)
            )
            - 1.0
        ).astype(jnp.float32),
        "A_log": jnp.log(A),
        "D": jnp.ones((d_inner,), jnp.float32),
        "w_out": init_dense(ks[5], d_inner, cfg.d_model, dtype),
    }


def _ssm_inputs(params, xz, cfg):
    """Shared projections: returns (x_conv_in, z, dt, B, C)."""
    m, d_inner, dt_rank = _dims(cfg)
    x, z = jnp.split(xz, 2, axis=-1)
    return x, z


def _dt_B_C(params, x, cfg):
    m, d_inner, dt_rank = _dims(cfg)
    proj = dense(x, params["x_proj"])
    dt, B, C = jnp.split(proj, [dt_rank, dt_rank + m.d_state], axis=-1)
    dt = jax.nn.softplus(
        dense(dt, params["w_dt"]).astype(jnp.float32)
        + params["dt_bias"]
    )
    return dt, B.astype(jnp.float32), C.astype(jnp.float32)


def mamba_full(params, u: jax.Array, *, cfg, policy) -> jax.Array:
    """Full-sequence mamba: u (B, S, D) -> (B, S, D)."""
    m, d_inner, _ = _dims(cfg)
    Bsz, S, _ = u.shape
    xz = dense(u, params["w_in"])
    x, z = jnp.split(xz, 2, axis=-1)

    # causal depthwise conv over time
    w = params["conv_w"].astype(x.dtype)  # (d_inner, k)
    pad = jnp.zeros((Bsz, m.d_conv - 1, d_inner), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    x = sum(
        xp[:, i : i + S, :] * w[:, i][None, None, :]
        for i in range(m.d_conv)
    )
    x = jax.nn.silu(x + params["conv_b"].astype(x.dtype))

    dt, Bmat, Cmat = _dt_B_C(params, x, cfg)  # (B,S,d_in),(B,S,N),(B,S,N)
    if getattr(cfg, "mamba_bf16_io", False):
        # stream the selective-scan inputs at bf16 (state math stays f32;
        # halves the dominant dt/B/C HBM traffic of the jamba train cell)
        dt = dt.astype(jnp.bfloat16)
        Bmat = Bmat.astype(jnp.bfloat16)
        Cmat = Cmat.astype(jnp.bfloat16)
    A = -jnp.exp(params["A_log"])  # (d_in, N)

    def step(state, inp):
        xt, dtt, Bt, Ct = inp  # (B,d_in),(B,d_in),(B,N),(B,N)
        dtt = dtt.astype(jnp.float32)
        Bt, Ct = Bt.astype(jnp.float32), Ct.astype(jnp.float32)
        dA = jnp.exp(dtt[..., None] * A[None])          # (B,d_in,N)
        dBx = (dtt * xt.astype(jnp.float32))[..., None] * Bt[:, None, :]
        state = state * dA + dBx                         # (B,d_in,N)
        y = jnp.einsum("bdn,bn->bd", state, Ct)
        return state, y

    state0 = jnp.zeros((Bsz, d_inner, m.d_state), jnp.float32)
    xs = (
        jnp.moveaxis(x, 1, 0),
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(Bmat, 1, 0),
        jnp.moveaxis(Cmat, 1, 0),
    )
    _, ys = lax.scan(
        step, state0, xs, unroll=getattr(cfg, "scan_unroll", 1)
    )
    y = jnp.moveaxis(ys, 0, 1)  # (B,S,d_in)
    y = y + x.astype(jnp.float32) * params["D"][None, None, :]
    y = y.astype(u.dtype) * jax.nn.silu(z)
    return dense(y, params["w_out"])


def init_mamba_cache(cfg, batch: int, dtype):
    m, d_inner, _ = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, m.d_conv - 1, d_inner), dtype),
        "state": jnp.zeros((batch, d_inner, m.d_state), jnp.float32),
    }


def mamba_decode(params, u, cache, *, cfg, policy):
    """One-token update: u (B, 1, D) -> ((B, 1, D), new cache)."""
    m, d_inner, _ = _dims(cfg)
    xz = dense(u[:, 0], params["w_in"])
    x, z = jnp.split(xz, 2, axis=-1)
    hist = jnp.concatenate([cache["conv"], x[:, None]], axis=1)  # (B,k,d)
    w = params["conv_w"].astype(x.dtype)
    x = jnp.einsum("bkd,dk->bd", hist, w) + params["conv_b"].astype(x.dtype)
    x = jax.nn.silu(x)
    dt, Bt, Ct = _dt_B_C(params, x, cfg)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt[..., None] * A[None])
    dBx = (dt * x.astype(jnp.float32))[..., None] * Bt[:, None, :]
    state = cache["state"] * dA + dBx
    y = jnp.einsum("bdn,bn->bd", state, Ct)
    y = y + x.astype(jnp.float32) * params["D"][None]
    y = y.astype(u.dtype) * jax.nn.silu(z)
    out = dense(y, params["w_out"])[:, None]
    return out, {"conv": hist[:, 1:], "state": state}
