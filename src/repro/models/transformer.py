"""Decoder stack: super-layer pattern, scan-over-layers, remat, caches.

A model is ``num_super_layers`` repetitions of the config's sublayer
*pattern* (DESIGN.md §3).  Per-sublayer parameters are stacked along a
leading ``n_super`` dim and the super-layer body is ``lax.scan``-ned
(keeps the HLO one-body-deep — essential for 512-device compiles of
80-layer models) with a configurable remat policy.

Mixer kinds: "attn" (global), "attn_local" (sliding window), "mamba",
"rwkv6".  FFN kinds: "dense" GLU, "moe" (EP), and the implicit RWKV
channel-mix when the mixer is rwkv6.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from . import attention as attn_mod
from . import mamba as mamba_mod
from . import moe as moe_mod
from . import rwkv as rwkv_mod
from .layers import glu_mlp, init_glu_mlp, rms_norm

__all__ = [
    "init_stack",
    "stack_apply",
    "stack_decode",
    "init_stack_cache",
]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_sublayer(key, sub, cfg, dtype, *, cross: bool):
    ks = jax.random.split(key, 6)
    p: dict[str, Any] = {"norm1": jnp.zeros((cfg.d_model,), jnp.float32)}
    if sub.mixer in ("attn", "attn_local"):
        p["mixer"] = attn_mod.init_attention(ks[0], cfg, dtype)
    elif sub.mixer == "mamba":
        p["mixer"] = mamba_mod.init_mamba(ks[0], cfg, dtype)
    elif sub.mixer == "rwkv6":
        p["mixer"] = rwkv_mod.init_rwkv(ks[0], cfg, dtype)
    if cross:
        p["norm_cross"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["cross"] = attn_mod.init_attention(ks[1], cfg, dtype, cross=True)
    if sub.mixer == "rwkv6":
        p["ffn"] = rwkv_mod.init_rwkv_cm(ks[2], cfg, dtype)
    elif sub.ffn == "dense":
        p["ffn"] = init_glu_mlp(ks[2], cfg.d_model, cfg.d_ff, dtype)
    elif sub.ffn == "moe":
        p["ffn"] = moe_mod.init_moe(ks[2], cfg, dtype)
    if sub.ffn != "none" or sub.mixer == "rwkv6":
        p["norm2"] = jnp.zeros((cfg.d_model,), jnp.float32)
    if cfg.sandwich_norm:
        p["norm1_post"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["norm2_post"] = jnp.zeros((cfg.d_model,), jnp.float32)
    return p


def init_stack(
    key, cfg, dtype, *, n_layers: int | None = None, pattern=None,
    cross: bool = False,
):
    """Stacked params: {"sub<i>": pytree with leading n_super dim}."""
    pattern = pattern if pattern is not None else cfg.pattern
    n_super = (n_layers or cfg.num_layers) // len(pattern)
    keys = jax.random.split(key, n_super)

    def init_one(k):
        sks = jax.random.split(k, len(pattern))
        return {
            f"sub{i}": _init_sublayer(sks[i], sub, cfg, dtype, cross=cross)
            for i, sub in enumerate(pattern)
        }

    return jax.vmap(init_one)(keys)


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _sublayer_full(p, x, sub, *, cfg, policy, positions, causal, enc_out):
    def maybe_post(h, name):
        if cfg.sandwich_norm:
            return rms_norm(h, p[name], cfg.norm_eps)
        return h

    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if sub.mixer in ("attn", "attn_local"):
        window = cfg.sliding_window if sub.mixer == "attn_local" else None
        h = attn_mod.attention_full(
            p["mixer"], h, cfg=cfg, policy=policy, positions=positions,
            causal=causal, window=window,
        )
    elif sub.mixer == "mamba":
        h = mamba_mod.mamba_full(p["mixer"], h, cfg=cfg, policy=policy)
    elif sub.mixer == "rwkv6":
        h = rwkv_mod.rwkv_full(p["mixer"], h, cfg=cfg, policy=policy)
    else:
        h = jnp.zeros_like(h)
    x = x + maybe_post(h, "norm1_post")

    if "cross" in p:
        h = rms_norm(x, p["norm_cross"], cfg.norm_eps)
        h = attn_mod.attention_full(
            p["cross"], h, cfg=cfg, policy=policy, positions=positions,
            causal=False, kv_src=enc_out,
        )
        x = x + h

    aux = jnp.zeros((), jnp.float32)
    if "ffn" in p:
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        if sub.mixer == "rwkv6":
            h = rwkv_mod.rwkv_cm_full(p["ffn"], h, cfg=cfg)
        elif sub.ffn == "moe":
            h, aux = moe_mod.moe_apply(p["ffn"], h, cfg=cfg, policy=policy)
        else:
            h = glu_mlp(p["ffn"], h, cfg.act)
        x = x + maybe_post(h, "norm2_post")
    return x, aux


_REMAT_POLICIES = {
    "full": None,
    "dots": "dots_saveable",
    "none": "none",
}


def _remat_wrap(body, remat: str):
    if remat == "none":
        return body
    if remat == "dots":
        return jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_saveable
        )
    return jax.checkpoint(body)


def stack_apply(
    stack_params,
    x: jax.Array,
    *,
    cfg,
    policy,
    positions,
    pattern=None,
    causal: bool = True,
    enc_out: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Run the scanned stack. Returns (hidden, summed aux loss)."""
    pattern = pattern if pattern is not None else cfg.pattern

    def body(carry, layer_params):
        h, aux = carry
        h = policy.act(h, kind="hidden")
        for i, sub in enumerate(pattern):
            h, a = _sublayer_full(
                layer_params[f"sub{i}"], h, sub,
                cfg=cfg, policy=policy, positions=positions,
                causal=causal, enc_out=enc_out,
            )
            aux = aux + a
        return (h, aux), None

    body = _remat_wrap(body, cfg.remat)
    (x, aux), _ = lax.scan(body, (x, jnp.zeros((), jnp.float32)), stack_params)
    return x, aux


# ---------------------------------------------------------------------------
# decode (single token, cached)
# ---------------------------------------------------------------------------


def init_stack_cache(
    cfg, batch: int, max_len: int, dtype, *, pattern=None,
    n_layers: int | None = None,
):
    """Cache pytree mirroring the stack: leaves (n_super, ...)."""
    pattern = pattern if pattern is not None else cfg.pattern
    n_super = (n_layers or cfg.num_layers) // len(pattern)

    def one(sub):
        if sub.mixer in ("attn", "attn_local"):
            window = cfg.sliding_window if sub.mixer == "attn_local" else None
            return attn_mod.init_cache(
                cfg, batch, max_len, window=window, dtype=dtype
            )
        if sub.mixer == "mamba":
            return mamba_mod.init_mamba_cache(cfg, batch, dtype)
        if sub.mixer == "rwkv6":
            c = rwkv_mod.init_rwkv_cache(cfg, batch, dtype)
            c["cm_x_prev"] = jnp.zeros((batch, cfg.d_model), dtype)
            return c
        return {}

    return {
        f"sub{i}": jax.tree.map(
            lambda l: jnp.broadcast_to(l, (n_super,) + l.shape).copy(),
            one(sub),
        )
        for i, sub in enumerate(pattern)
    }


def _sublayer_decode(p, x, cache, sub, *, cfg, policy, index, enc_out):
    def maybe_post(h, name):
        if cfg.sandwich_norm:
            return rms_norm(h, p[name], cfg.norm_eps)
        return h

    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if sub.mixer in ("attn", "attn_local"):
        window = cfg.sliding_window if sub.mixer == "attn_local" else None
        h, cache = attn_mod.attention_decode(
            p["mixer"], h, cache, index, cfg=cfg, policy=policy, window=window
        )
    elif sub.mixer == "mamba":
        h, cache = mamba_mod.mamba_decode(
            p["mixer"], h, cache, cfg=cfg, policy=policy
        )
    elif sub.mixer == "rwkv6":
        cache = dict(cache)
        cm_prev = cache.pop("cm_x_prev")
        h, cache = rwkv_mod.rwkv_decode(
            p["mixer"], h, cache, cfg=cfg, policy=policy
        )
        cache["cm_x_prev"] = cm_prev
    x = x + maybe_post(h, "norm1_post")

    if "cross" in p:
        h = rms_norm(x, p["norm_cross"], cfg.norm_eps)
        h, _ = attn_mod.attention_decode(
            p["cross"], h, {}, index, cfg=cfg, policy=policy, kv_src=enc_out
        )
        x = x + h

    if "ffn" in p:
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        if sub.mixer == "rwkv6":
            h, new_prev = rwkv_mod.rwkv_cm_decode(
                p["ffn"], h, cache["cm_x_prev"], cfg=cfg
            )
            cache = dict(cache, cm_x_prev=new_prev)
        elif sub.ffn == "moe":
            h, _ = moe_mod.moe_apply(p["ffn"], h, cfg=cfg, policy=policy)
        else:
            h = glu_mlp(p["ffn"], h, cfg.act)
        x = x + maybe_post(h, "norm2_post")
    return x, cache


def stack_decode(
    stack_params,
    x: jax.Array,
    cache,
    index,
    *,
    cfg,
    policy,
    pattern=None,
    enc_out: jax.Array | None = None,
):
    """One-token decode through the scanned stack: returns (x, new cache)."""
    pattern = pattern if pattern is not None else cfg.pattern

    def body(h, xs):
        layer_params, layer_cache = xs
        h = policy.act(h, kind="hidden")
        new_cache = {}
        for i, sub in enumerate(pattern):
            h, new_cache[f"sub{i}"] = _sublayer_decode(
                layer_params[f"sub{i}"], h, layer_cache[f"sub{i}"], sub,
                cfg=cfg, policy=policy, index=index, enc_out=enc_out,
            )
        return h, new_cache

    x, new_cache = lax.scan(body, x, (stack_params, cache))
    return x, new_cache
