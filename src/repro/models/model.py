"""Public model API: build_model(config) -> Model (pure-function bundle).

Covers all assigned families:
  * decoder-only LMs (dense / MoE / hybrid / SSM),
  * whisper-style encoder-decoder (frames stub -> encoder -> cross-attn),
  * VLM backbone (precomputed patch/frame embeddings + M-RoPE positions).

Training loss is a seq-chunked cross-entropy that never materialises the
full (B, S, V) logits (essential for 256k vocabs at 4k seq).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from . import transformer as tfm
from .layers import head_dot, mixed_bwd, rms_norm, softcap
from .sharding import ShardingPolicy

__all__ = ["Model", "build_model"]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: Any
    policy: ShardingPolicy
    init: Callable            # (key) -> params
    apply: Callable           # (params, batch) -> (hidden, aux)
    loss: Callable            # (params, batch) -> (loss, metrics)
    logits: Callable          # (params, batch) -> full logits (small use!)
    init_decode: Callable     # (params, batch, max_len[, batch_data]) -> cache
    decode_step: Callable     # (params, cache, tokens) -> (logits, cache)
    # the head-split decode pair used by the serving spine's tensor-
    # parallel logits path (repro.serve): ``decode_hidden`` is
    # ``decode_step`` up to (and including) the final norm, without the
    # LM head; ``head_weights`` exposes the (D, V) head matrix so a
    # contraction-sharded head can be computed outside the model.
    decode_hidden: Callable = None  # (params, cache, tokens) -> (hidden, cache)
    head_weights: Callable = None   # (params) -> (D, V)


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _embed_tokens(params, tokens, cfg):
    emb = params["embedding"]
    x = emb[tokens].astype(_dtype(cfg))
    if cfg.scale_embeddings:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    return x


def _head_weights(params, cfg):
    if cfg.tie_embeddings:
        return params["embedding"].T  # (D, V)
    return params["lm_head"]


def _final_hidden(params, batch, cfg, policy, *, causal=True):
    """Embed -> stack -> final norm. Returns (hidden, aux, enc_out)."""
    enc_out = None
    if cfg.encoder_layers:
        frames = batch["frames"].astype(_dtype(cfg))  # stub frontend output
        pos_e = jnp.arange(frames.shape[1])[None]
        enc, _ = tfm.stack_apply(
            params["encoder"], frames, cfg=cfg, policy=policy,
            positions=pos_e, pattern=cfg.encoder_pattern, causal=False,
        )
        enc_out = rms_norm(enc, params["encoder_norm"], cfg.norm_eps)

    if "embeds" in batch:  # VLM stub frontend: precomputed embeddings
        x = batch["embeds"].astype(_dtype(cfg))
        B, S = x.shape[:2]
    else:
        tokens = batch["tokens"]
        x = _embed_tokens(params, tokens, cfg)
        B, S = tokens.shape
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x = policy.act(x, kind="hidden")
    x, aux = tfm.stack_apply(
        params["stack"], x, cfg=cfg, policy=policy,
        positions=positions, causal=causal, enc_out=enc_out,
    )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux, enc_out


def _chunked_loss(hidden, head_w, labels, mask, cfg, policy, chunk=512):
    """CE over seq chunks; logits (B, chunk, V) only, never (B, S, V)."""
    B, S, D = hidden.shape
    chunk = min(chunk, S)
    if S % chunk:
        chunk = S
    n = S // chunk

    def one(i):
        h = lax.dynamic_slice_in_dim(hidden, i * chunk, chunk, axis=1)
        y = lax.dynamic_slice_in_dim(labels, i * chunk, chunk, axis=1)
        m = lax.dynamic_slice_in_dim(mask, i * chunk, chunk, axis=1)
        logits = head_dot(h, head_w.astype(h.dtype))
        logits = softcap(logits, cfg.final_logit_softcap)
        logits = policy.act(logits, kind="logits")
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, y[..., None], axis=-1
        )[..., 0]
        nll = (logz - gold) * m
        return nll.sum(), m.sum()

    nll, cnt = 0.0, 0.0
    if n == 1:
        nll, cnt = one(0)
    else:
        (nlls, cnts) = lax.map(one, jnp.arange(n))
        nll, cnt = nlls.sum(), cnts.sum()
    return nll / jnp.maximum(cnt, 1.0)


def build_model(cfg, policy: ShardingPolicy | None = None) -> Model:
    policy = policy or ShardingPolicy()
    dtype = _dtype(cfg)

    # ---- init --------------------------------------------------------------

    def init(key):
        ks = jax.random.split(key, 5)
        params = {
            "embedding": (
                jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model)) * 0.02
            ).astype(dtype),
            "stack": tfm.init_stack(
                ks[1], cfg, dtype, cross=cfg.cross_attention
            ),
            "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = (
                jax.random.normal(ks[2], (cfg.d_model, cfg.vocab_size)) * 0.02
            ).astype(dtype)
        if cfg.encoder_layers:
            params["encoder"] = tfm.init_stack(
                ks[3], cfg, dtype,
                n_layers=cfg.encoder_layers,
                pattern=cfg.encoder_pattern, cross=False,
            )
            params["encoder_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
        return params

    # ---- forward / loss ----------------------------------------------------

    def apply(params, batch):
        with mixed_bwd(getattr(cfg, "bf16_bwd", False)):
            return _final_hidden(params, batch, cfg, policy)[:2]

    def loss(params, batch):
        with mixed_bwd(getattr(cfg, "bf16_bwd", False)):
            return _loss_inner(params, batch)

    def _loss_inner(params, batch):
        hidden, aux, _ = _final_hidden(params, batch, cfg, policy)
        labels = batch.get("labels")
        if labels is None:
            labels = jnp.pad(batch["tokens"][:, 1:], ((0, 0), (0, 1)))
        mask = batch.get(
            "loss_mask", jnp.ones(labels.shape, jnp.float32)
        )
        ce = _chunked_loss(
            hidden, _head_weights(params, cfg), labels, mask, cfg, policy
        )
        total = ce + aux
        return total, {"loss": total, "ce": ce, "aux": aux}

    def logits_fn(params, batch):
        hidden, _, _ = _final_hidden(params, batch, cfg, policy)
        logits = jnp.einsum(
            "bsd,dv->bsv", hidden, _head_weights(params, cfg).astype(hidden.dtype),
            preferred_element_type=jnp.float32,
        )
        return softcap(logits, cfg.final_logit_softcap)

    # ---- decode ------------------------------------------------------------

    def init_decode(params, batch_size, max_len, batch=None):
        cache = {
            "index": jnp.zeros((), jnp.int32),
            "stack": tfm.init_stack_cache(cfg, batch_size, max_len, dtype),
        }
        if cfg.encoder_layers:
            assert batch is not None and "frames" in batch, (
                "enc-dec decode needs encoder frames at cache init"
            )
            frames = batch["frames"].astype(dtype)
            pos_e = jnp.arange(frames.shape[1])[None]
            enc, _ = tfm.stack_apply(
                params["encoder"], frames, cfg=cfg, policy=policy,
                positions=pos_e, pattern=cfg.encoder_pattern, causal=False,
            )
            cache["enc_out"] = rms_norm(
                enc, params["encoder_norm"], cfg.norm_eps
            )
        return cache

    def decode_hidden(params, cache, tokens):
        """tokens: (B, 1) int32 (or (B, 1, D) embeds for VLM stubs).

        One cached decode step up to (and including) the final norm —
        everything but the LM head.  ``decode_step`` is exactly this
        plus the head einsum, so a caller that computes the head itself
        (the serving spine's contraction-sharded tensor-parallel logits
        path) advances the cache identically to the plain step.
        """
        index = cache["index"]
        if tokens.ndim == 3:
            x = tokens.astype(dtype)
        else:
            x = _embed_tokens(params, tokens, cfg)
        x = policy.act(x, kind="hidden")
        x, new_stack = tfm.stack_decode(
            params["stack"], x, cache["stack"], index,
            cfg=cfg, policy=policy, enc_out=cache.get("enc_out"),
        )
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        new_cache = dict(cache, index=index + 1, stack=new_stack)
        return x, new_cache

    def decode_step(params, cache, tokens):
        """tokens: (B, 1) int32 (or (B, 1, D) embeds for VLM stubs)."""
        x, new_cache = decode_hidden(params, cache, tokens)
        logits = jnp.einsum(
            "bsd,dv->bsv", x, _head_weights(params, cfg).astype(x.dtype),
            preferred_element_type=jnp.float32,
        )
        logits = softcap(logits, cfg.final_logit_softcap)
        logits = policy.act(logits, kind="logits")
        return logits, new_cache

    return Model(
        cfg=cfg,
        policy=policy,
        init=init,
        apply=apply,
        loss=loss,
        logits=logits_fn,
        init_decode=init_decode,
        decode_step=decode_step,
        decode_hidden=decode_hidden,
        head_weights=lambda params: _head_weights(params, cfg),
    )
