"""Shared layer primitives: norms, MLPs, embeddings, RoPE / M-RoPE."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "rms_norm",
    "layer_norm",
    "softcap",
    "glu_mlp",
    "init_glu_mlp",
    "rope_angles",
    "apply_rope",
    "init_dense",
    "dense",
]


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    """gemma2 logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dtype)


def layer_norm(
    x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5
) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(dtype)


def init_dense(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


# --- mixed-precision backward for projections --------------------------------
#
# The cross-entropy produces f32 cotangents; without intervention XLA
# converts bf16 weights to f32 *before* the FSDP all-gather in the
# transposed dots, doubling per-layer collective and HBM bytes (measured
# on qwen2-72b train: f32[8192,9504] lm-head gathers).  ``mixed_bwd``
# casts incoming cotangents to the weight dtype so backward dots (and
# the weight gathers feeding them) run in bf16, with f32 accumulation
# preserved via preferred_element_type.  Enabled per-model by the
# ``bf16_bwd`` config lever (hillclimb; default off = naive baseline).

_MIXED_BWD: list[bool] = [False]


class mixed_bwd:
    """Context manager enabling bf16-backward projections (trace-time)."""

    def __init__(self, enabled: bool):
        self.enabled = bool(enabled)

    def __enter__(self):
        self.prev = _MIXED_BWD[0]
        _MIXED_BWD[0] = self.enabled
        return self

    def __exit__(self, *exc):
        _MIXED_BWD[0] = self.prev
        return False


@jax.custom_vjp
def _mdot(x, w):
    return jnp.einsum(
        "...d,df->...f", x, w, preferred_element_type=jnp.float32
    ).astype(x.dtype)


def _mdot_fwd(x, w):
    return _mdot(x, w), (x, w)


def _mdot_bwd(res, g):
    x, w = res
    g16 = g.astype(w.dtype)
    dx = jnp.einsum(
        "...f,df->...d", g16, w, preferred_element_type=jnp.float32
    ).astype(x.dtype)
    dw = jnp.einsum(
        "...d,...f->df", x, g16, preferred_element_type=jnp.float32
    ).astype(w.dtype)
    return dx, dw


_mdot.defvjp(_mdot_fwd, _mdot_bwd)


@jax.custom_vjp
def _mdot_f32out(x, w):
    return jnp.einsum(
        "...d,df->...f", x, w, preferred_element_type=jnp.float32
    )


def _mdot_f32out_fwd(x, w):
    return _mdot_f32out(x, w), (x, w)


_mdot_f32out.defvjp(_mdot_f32out_fwd, _mdot_bwd)


def head_dot(x: jax.Array, w: jax.Array) -> jax.Array:
    """Projection with f32 output (logits) and optional bf16 backward."""
    if _MIXED_BWD[0] and x.dtype == w.dtype:
        return _mdot_f32out(x, w)
    return jnp.einsum(
        "...d,df->...f", x, w, preferred_element_type=jnp.float32
    )


def dense(x: jax.Array, w: jax.Array, b: jax.Array | None = None) -> jax.Array:
    if _MIXED_BWD[0] and x.dtype == w.dtype:
        y = _mdot(x, w)
    else:
        y = jnp.einsum("...d,df->...f", x, w)
    if b is not None:
        y = y + b
    return y


_ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}


def init_glu_mlp(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": init_dense(k1, d_model, d_ff, dtype),
        "w_up": init_dense(k2, d_model, d_ff, dtype),
        "w_down": init_dense(k3, d_ff, d_model, dtype),
    }


def glu_mlp(params, x: jax.Array, act: str = "silu") -> jax.Array:
    h = _ACTS[act](dense(x, params["w_gate"])) * dense(x, params["w_up"])
    return dense(h, params["w_down"])


# ---------------------------------------------------------------------------
# rotary embeddings (+ qwen2-vl M-RoPE)
# ---------------------------------------------------------------------------


def rope_angles(
    positions: jax.Array, head_dim: int, theta: float
) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables for positions (..., S) -> (..., S, head_dim/2)."""
    half = head_dim // 2
    freq = 1.0 / (
        theta ** (jnp.arange(0, half, dtype=jnp.float32) / half)
    )
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(
    x: jax.Array,
    positions: jax.Array,
    theta: float,
    mrope_sections: tuple[int, int, int] | None = None,
) -> jax.Array:
    """Rotate q/k: x (B, S, H, hd); positions (B, S) or (3, B, S) M-RoPE.

    M-RoPE (qwen2-vl): the head_dim/2 frequency slots are split into
    (temporal, height, width) sections, each rotated by its own position
    stream.  For text tokens all three streams coincide.
    """
    hd = x.shape[-1]
    half = hd // 2
    if mrope_sections is not None:
        if positions.ndim == 2:  # text-only: same positions for t/h/w
            positions = jnp.broadcast_to(positions, (3,) + positions.shape)
        cos_parts, sin_parts = [], []
        start = 0
        for sec, pos in zip(mrope_sections, positions):
            freq = 1.0 / (
                theta ** (jnp.arange(start, start + sec, dtype=jnp.float32) / half)
            )
            ang = pos.astype(jnp.float32)[..., None] * freq
            cos_parts.append(jnp.cos(ang))
            sin_parts.append(jnp.sin(ang))
            start += sec
        cos = jnp.concatenate(cos_parts, axis=-1)[..., None, :]
        sin = jnp.concatenate(sin_parts, axis=-1)[..., None, :]
    else:
        cos, sin = rope_angles(positions, hd, theta)
        cos, sin = cos[..., None, :], sin[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)
