from .model import Model, build_model
from .sharding import ShardingPolicy

__all__ = ["Model", "build_model", "ShardingPolicy"]
