"""GQA attention: full-sequence (train/prefill), decode-with-cache, cross.

Features required by the assigned archs: grouped KV heads (GQA/MQA),
sliding-window masks (gemma2 local layers), attention-logit soft-capping
(gemma2), QKV bias (qwen2), M-RoPE (qwen2-vl), cross-attention (whisper).

Full-sequence attention is computed in *query chunks* (lax.map over chunk
index) so the S x S score matrix never materialises — the pure-XLA
equivalent of the Pallas flash kernel in ``repro.kernels`` (which is the
TPU-target implementation; this path is its oracle-compatible fallback
and is what the 512-device dry-run lowers).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .layers import apply_rope, dense, init_dense, softcap

__all__ = [
    "init_attention",
    "attention_full",
    "attention_decode",
    "init_cache",
]

NEG_INF = -2.0e38


def init_attention(key, cfg, dtype, *, cross: bool = False):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    q_dim, kv_dim = cfg.num_heads * hd, cfg.num_kv_heads * hd
    ks = jax.random.split(key, 4)
    params = {
        "w_q": init_dense(ks[0], d, q_dim, dtype),
        "w_k": init_dense(ks[1], d, kv_dim, dtype),
        "w_v": init_dense(ks[2], d, kv_dim, dtype),
        "w_o": init_dense(ks[3], q_dim, d, dtype, scale=1.0 / math.sqrt(q_dim)),
    }
    if cfg.qkv_bias and not cross:
        params["b_q"] = jnp.zeros((q_dim,), dtype)
        params["b_k"] = jnp.zeros((kv_dim,), dtype)
        params["b_v"] = jnp.zeros((kv_dim,), dtype)
    return params


def _project_qkv(params, x, kv_src, cfg, positions, kv_positions, rope=True):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = dense(x, params["w_q"], params.get("b_q"))
    k = dense(kv_src, params["w_k"], params.get("b_k"))
    v = dense(kv_src, params["w_v"], params.get("b_v"))
    q = q.reshape(B, S, cfg.num_heads, hd)
    k = k.reshape(B, kv_src.shape[1], cfg.num_kv_heads, hd)
    v = v.reshape(B, kv_src.shape[1], cfg.num_kv_heads, hd)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, kv_positions, cfg.rope_theta, cfg.mrope_sections)
    return q, k, v


def _sdpa_chunk(q, k, v, cfg, q_pos, k_pos, *, causal, window):
    """Scores for one query chunk against full K/V. q:(B,Q,K,G,h)."""
    scale = 1.0 / math.sqrt(cfg.resolved_head_dim)
    scores = jnp.einsum(
        "bqkgh,bskh->bkgqs", q, k, preferred_element_type=jnp.float32
    ) * scale
    scores = softcap(scores, cfg.attn_logit_softcap)
    mask = jnp.ones((q.shape[1], k.shape[1]), bool)
    rel = q_pos[:, None] - k_pos[None, :]
    if causal:
        mask &= rel >= 0
    if window is not None:
        mask &= rel < window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bkgqs,bskh->bqkgh", probs.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return out.astype(q.dtype)


def attention_full(
    params,
    x: jax.Array,
    *,
    cfg,
    policy,
    positions: jax.Array,
    causal: bool = True,
    window: int | None = None,
    kv_src: jax.Array | None = None,
    kv_positions: jax.Array | None = None,
    q_chunk: int = 1024,
) -> jax.Array:
    """Full-sequence attention; kv_src!=None -> cross attention (no rope)."""
    B, S, _ = x.shape
    cross = kv_src is not None
    src = kv_src if cross else x
    if kv_positions is None:
        kv_positions = (
            jnp.arange(src.shape[1])[None] if cross else positions
        )
    q, k, v = _project_qkv(
        params, x, src, cfg, positions, kv_positions, rope=not cross
    )
    q = policy.act(q, kind="heads")
    k = policy.act(k, kind="kv")
    v = policy.act(v, kind="kv")
    G = cfg.num_heads // cfg.num_kv_heads
    q = q.reshape(B, S, cfg.num_kv_heads, G, cfg.resolved_head_dim)

    q_pos_flat = jnp.arange(S)
    k_pos_flat = jnp.arange(src.shape[1])
    chunk = min(q_chunk, S)
    if S % chunk != 0:
        chunk = S
    n_chunks = S // chunk
    # sliding-window layers never need keys older than `window`: score
    # each q chunk against a static (window + chunk) KV slice instead of
    # the full sequence — an 8x flop/byte saving for gemma2 local layers
    # at 32k context.
    kv_span = None
    if (
        getattr(cfg, "window_kv_slice", False)
        and window is not None
        and causal
        and n_chunks > 1
        and src.shape[1] == S
        and window + chunk < S
    ):
        kv_span = window + chunk
    if n_chunks == 1:
        out = _sdpa_chunk(
            q, k, v, cfg, q_pos_flat, k_pos_flat, causal=causal, window=window
        )
    else:
        def one(i):
            qc = lax.dynamic_slice_in_dim(q, i * chunk, chunk, axis=1)
            qp = lax.dynamic_slice_in_dim(q_pos_flat, i * chunk, chunk)
            if kv_span is not None:
                start = jnp.maximum(0, (i + 1) * chunk - kv_span)
                kc = lax.dynamic_slice_in_dim(k, start, kv_span, axis=1)
                vc = lax.dynamic_slice_in_dim(v, start, kv_span, axis=1)
                kp = lax.dynamic_slice_in_dim(k_pos_flat, start, kv_span)
            else:
                kc, vc, kp = k, v, k_pos_flat
            return _sdpa_chunk(
                qc, kc, vc, cfg, qp, kp, causal=causal, window=window
            )
        out = lax.map(one, jnp.arange(n_chunks))  # (n, B, chunk, K, G, h)
        out = jnp.moveaxis(out, 0, 1).reshape(
            B, S, cfg.num_kv_heads, G, cfg.resolved_head_dim
        )
    out = out.reshape(B, S, cfg.num_heads * cfg.resolved_head_dim)
    return dense(out, params["w_o"])


# ---------------------------------------------------------------------------
# decode with KV cache (ring buffer for sliding-window layers)
# ---------------------------------------------------------------------------


def init_cache(cfg, batch: int, max_len: int, *, window: int | None, dtype):
    """Cache pytree for one attention sublayer."""
    size = min(max_len, window) if window else max_len
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, cfg.num_kv_heads, size, hd), dtype),
        "v": jnp.zeros((batch, cfg.num_kv_heads, size, hd), dtype),
        "pos": jnp.full((size,), -1, jnp.int32),
    }


def attention_decode(
    params,
    x: jax.Array,
    cache: dict,
    index: jax.Array,
    *,
    cfg,
    policy,
    window: int | None = None,
    kv_src: jax.Array | None = None,
) -> tuple[jax.Array, dict]:
    """One-token decode. x: (B, 1, D); cache as from init_cache.

    Cross-attention (kv_src != None) attends the full encoder output and
    leaves the cache untouched.
    """
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    positions = jnp.broadcast_to(index, (B, 1))
    if kv_src is not None:
        return (
            attention_full(
                params,
                x,
                cfg=cfg,
                policy=policy,
                positions=positions,
                causal=False,
                kv_src=kv_src,
            ),
            cache,
        )
    q, k_new, v_new = _project_qkv(
        params, x, x, cfg, positions, positions, rope=True
    )
    size = cache["k"].shape[2]
    slot = index % size
    k = lax.dynamic_update_slice_in_dim(cache["k"], jnp.swapaxes(k_new, 1, 2), slot, axis=2)
    v = lax.dynamic_update_slice_in_dim(cache["v"], jnp.swapaxes(v_new, 1, 2), slot, axis=2)
    pos = lax.dynamic_update_slice_in_dim(
        cache["pos"], index[None].astype(jnp.int32), slot, axis=0
    )
    k = policy.act(k, kind="cache")
    v = policy.act(v, kind="cache")

    G = cfg.num_heads // cfg.num_kv_heads
    q = q.reshape(B, 1, cfg.num_kv_heads, G, hd)
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum(
        "bqkgh,bksh->bkgqs", q, k, preferred_element_type=jnp.float32
    ) * scale
    scores = softcap(scores, cfg.attn_logit_softcap)
    valid = (pos >= 0) & (pos <= index)
    if window is not None:
        valid &= pos > index - window
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bkgqs,bksh->bqkgh", probs.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)
    out = out.reshape(B, 1, cfg.num_heads * hd)
    y = dense(out, params["w_o"])
    return y, {"k": k, "v": v, "pos": pos}
