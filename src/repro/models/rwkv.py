"""RWKV6 "Finch" mixer: linear attention with data-dependent decay.

Time-mix recurrence per head (state S in R^{hd x hd}):

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (S_t + diag(u) k_t^T v_t-correction)  [bonus u on current]

with w_t = exp(-exp(decay_t)) produced by a low-rank "lora" from the
token-shifted input (the data-dependent decay that distinguishes v6).
Full-seq mode scans over time; decode is O(1).  Channel-mix is the
squared-relu FFN of the RWKV family.  The chunked VMEM-tiled kernel lives
in ``repro.kernels.rwkv6_scan``; this module is its oracle.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .layers import dense, init_dense

__all__ = ["init_rwkv", "rwkv_full", "rwkv_decode", "init_rwkv_cache"]

LORA_DIM = 32


def _heads(cfg):
    hd = cfg.rwkv_head_size
    assert cfg.d_model % hd == 0
    return cfg.d_model // hd, hd


def init_rwkv(key, cfg, dtype):
    d = cfg.d_model
    H, hd = _heads(cfg)
    ks = jax.random.split(key, 10)
    return {
        # time-mix interpolation coefficients (token shift)
        "mu_r": jnp.full((d,), 0.5, dtype),
        "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_v": jnp.full((d,), 0.5, dtype),
        "mu_w": jnp.full((d,), 0.5, dtype),
        "w_r": init_dense(ks[0], d, d, dtype),
        "w_k": init_dense(ks[1], d, d, dtype),
        "w_v": init_dense(ks[2], d, d, dtype),
        "w_o": init_dense(ks[3], d, d, dtype),
        # data-dependent decay lora: d -> LORA -> d
        "decay_a": init_dense(ks[4], d, LORA_DIM, dtype),
        "decay_b": init_dense(ks[5], LORA_DIM, d, dtype),
        "decay_bias": jnp.full((d,), -6.0, jnp.float32),
        "bonus": (jax.random.normal(ks[6], (H, hd)) * 0.1).astype(jnp.float32),
        "ln_x_scale": jnp.ones((d,), jnp.float32),
    }


def _mix(x, prev, mu):
    """Token shift: lerp between current and previous token."""
    return x * mu + prev * (1.0 - mu)


def _rwkv_inputs(params, x, x_prev, cfg):
    H, hd = _heads(cfg)
    r = dense(_mix(x, x_prev, params["mu_r"]), params["w_r"])
    k = dense(_mix(x, x_prev, params["mu_k"]), params["w_k"])
    v = dense(_mix(x, x_prev, params["mu_v"]), params["w_v"])
    wx = _mix(x, x_prev, params["mu_w"])
    decay = dense(
        jnp.tanh(dense(wx, params["decay_a"])), params["decay_b"]
    ).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(decay + params["decay_bias"]))  # (…, d) in (0,1)
    return r, k, v, w


def _group_norm(x, scale, H, hd, eps=1e-5):
    """Per-head layer norm of the attention output (RWKV's ln_x)."""
    shape = x.shape
    x = x.reshape(*shape[:-1], H, hd).astype(jnp.float32)
    mu = x.mean(-1, keepdims=True)
    var = x.var(-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x.reshape(shape) * scale).astype(jnp.bfloat16).astype(jnp.float32)


def rwkv_full(params, x: jax.Array, *, cfg, policy) -> jax.Array:
    """Full-sequence time-mix: x (B, S, D) -> (B, S, D)."""
    B, S, D = x.shape
    H, hd = _heads(cfg)
    x_prev = jnp.concatenate([jnp.zeros_like(x[:, :1]), x[:, :-1]], axis=1)
    r, k, v, w = _rwkv_inputs(params, x, x_prev, cfg)

    def split_heads(t):
        return t.reshape(B, S, H, hd).astype(jnp.float32)

    r, k, v, w = map(split_heads, (r, k, v, w))
    u = params["bonus"]  # (H, hd)

    def step(state, inp):
        rt, kt, vt, wt = inp  # each (B, H, hd)
        kv = kt[..., :, None] * vt[..., None, :]      # (B,H,hd,hd)
        out = jnp.einsum("bhi,bhij->bhj", rt, state + u[None, :, :, None] * kv)
        state = wt[..., :, None] * state + kv
        return state, out

    state0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    _, outs = lax.scan(
        step, state0, xs, unroll=getattr(cfg, "scan_unroll", 1)
    )
    y = jnp.moveaxis(outs, 0, 1).reshape(B, S, D)
    y = _group_norm(y, params["ln_x_scale"], H, hd)
    return dense(y.astype(x.dtype), params["w_o"])


def init_rwkv_cache(cfg, batch: int, dtype):
    H, hd = _heads(cfg)
    return {
        "x_prev": jnp.zeros((batch, cfg.d_model), dtype),
        "state": jnp.zeros((batch, H, hd, hd), jnp.float32),
    }


def init_rwkv_cm(key, cfg, dtype):
    """Channel-mix (RWKV FFN): squared-relu with receptance gate."""
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_r": jnp.full((d,), 0.5, dtype),
        "w_up": init_dense(ks[0], d, cfg.d_ff, dtype),
        "w_down": init_dense(ks[1], cfg.d_ff, d, dtype),
        "w_r": init_dense(ks[2], d, d, dtype),
    }


def rwkv_cm_full(params, x, *, cfg):
    x_prev = jnp.concatenate([jnp.zeros_like(x[:, :1]), x[:, :-1]], axis=1)
    k = dense(_mix(x, x_prev, params["mu_k"]), params["w_up"])
    kv = dense(jnp.square(jax.nn.relu(k)), params["w_down"])
    r = jax.nn.sigmoid(dense(_mix(x, x_prev, params["mu_r"]), params["w_r"]))
    return r * kv


def rwkv_cm_decode(params, x, x_prev, *, cfg):
    """x (B, 1, D); x_prev (B, D) -> (out, new x_prev)."""
    xt = x[:, 0]
    k = dense(_mix(xt, x_prev, params["mu_k"]), params["w_up"])
    kv = dense(jnp.square(jax.nn.relu(k)), params["w_down"])
    r = jax.nn.sigmoid(dense(_mix(xt, x_prev, params["mu_r"]), params["w_r"]))
    return (r * kv)[:, None], xt


def rwkv_decode(params, x, cache, *, cfg, policy):
    """One-token time-mix: x (B, 1, D) -> ((B, 1, D), cache)."""
    B = x.shape[0]
    H, hd = _heads(cfg)
    xt = x[:, 0]
    r, k, v, w = _rwkv_inputs(params, xt, cache["x_prev"], cfg)
    r, k, v, w = (
        t.reshape(B, H, hd).astype(jnp.float32) for t in (r, k, v, w)
    )
    kv = k[..., :, None] * v[..., None, :]
    out = jnp.einsum(
        "bhi,bhij->bhj", r, cache["state"] + params["bonus"][None, :, :, None] * kv
    )
    state = w[..., :, None] * cache["state"] + kv
    y = _group_norm(out.reshape(B, -1), params["ln_x_scale"], H, hd)
    y = dense(y.astype(x.dtype), params["w_o"])[:, None]
    return y, {"x_prev": xt, "state": state}
