"""Sharding policy: parameter specs and activation constraints.

One mesh axis can mean different things per layer (Megatron TP for
attention/MLP, expert parallelism for MoE, sequence sharding for long
decode) — the policy owns those decisions so model code stays declarative.

Param specs are derived from the *leaf path names* of the param pytree
(single source of truth; no parallel spec tree to drift).  Axes that do
not divide a dimension are dropped (GSPMD could pad, but dropping keeps
memory analysis exact).
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ShardingPolicy", "REPLICATED"]

REPLICATED = P()


def _axis_size(mesh: Mesh | None, axes) -> int:
    if mesh is None or axes is None:
        return 1
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return int(np.prod([sizes[a] for a in axes])) if axes else 1


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """How to lay out params/activations on the mesh.

    mesh=None disables all constraints (single-device smoke tests).
    """

    mesh: Mesh | None = None
    dp_axes: tuple[str, ...] = ()       # batch axes ("pod","data")
    tp_axis: str | None = None          # tensor/expert-parallel axis
    fsdp_axes: tuple[str, ...] = ()     # parameter sharding axes (ZeRO-3)
    seq_parallel: bool = False          # shard activations' seq dim on tp
    # "train": FSDP x TP (batch over dp).  "serve2d": inference layout —
    # weights/experts/KV sharded over (model x data) jointly, batch
    # replicated; contractions over sharded dims produce *activation*-
    # sized all-reduces instead of per-layer weight all-gathers.
    mode: str = "train"

    # ---- helpers ----------------------------------------------------------

    def _fit(self, shape: tuple[int, ...], spec: P) -> P:
        """Drop axes that don't divide their dim; trim to rank."""
        entries = list(spec) + [None] * (len(shape) - len(spec))
        out = []
        for dim, ax in zip(shape, entries):
            if ax is None:
                out.append(None)
                continue
            if dim % _axis_size(self.mesh, ax) == 0:
                out.append(ax)
            else:
                out.append(None)
        return P(*out)

    def constrain(self, x, spec: P):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self._fit(x.shape, spec))
        )

    @property
    def dp(self):
        return self.dp_axes if self.dp_axes else None

    @property
    def tp_size(self) -> int:
        return _axis_size(self.mesh, self.tp_axis)

    @property
    def dp_size(self) -> int:
        return _axis_size(self.mesh, self.dp_axes)

    # ---- parameter specs by leaf path -------------------------------------

    def spec_for(self, path: str, shape: tuple[int, ...]) -> P:
        """PartitionSpec for a parameter leaf, from its pytree path.

        Leading stacked (scan) dims are auto-detected: rules match on the
        trailing dims; leading extra dims get None.
        """
        if self.mesh is None:
            return REPLICATED
        tp, fs = self.tp_axis, self.fsdp_axes or None
        name = path.split("/")[-1]

        def tail(spec_tail: tuple) -> P:
            lead = len(shape) - len(spec_tail)
            return self._fit(shape, P(*([None] * lead), *spec_tail))

        def best(dim: int, *candidates):
            """First candidate axis-set that divides ``dim``."""
            for cand in candidates:
                if cand is None:
                    continue
                axes = cand if isinstance(cand, tuple) else (cand,)
                if dim % _axis_size(self.mesh, axes) == 0:
                    return cand
            return None

        if self.mode == "serve2d":
            joint = ((tp,) if tp else ()) + tuple(self.fsdp_axes or ())
            joint = joint if len(joint) > 1 else (tp or None)
            d_out = shape[-1]
            d_in = shape[-2] if len(shape) >= 2 else shape[-1]
            # experts: EP on E, F over the data axes (contraction for
            # down-proj -> activation-sized partial sums)
            if name in ("we_gate", "we_up"):
                return tail((tp, None, best(d_out, fs)))
            if name == "we_down":
                return tail((tp, best(d_in, fs), None))
            # attention stays TP-only (head math); MLP/mamba go 2D
            if name in ("w_q", "w_k", "w_v"):
                return tail((None, tp))
            if name in ("b_q", "b_k", "b_v"):
                return tail((tp,))
            if name == "w_o":
                return tail((tp, None))
            if name in ("w_gate", "w_up", "w_in", "w_dt"):
                return tail((None, best(d_out, joint, tp, fs)))
            if name in ("w_down", "w_out"):
                return tail((best(d_in, joint, tp, fs), None))
            if name == "embedding":
                return tail((tp, best(d_out, fs)))
            if name == "lm_head":
                return tail((best(d_in, fs), tp))
            if name in ("conv_w", "A_log", "x_proj"):
                lead_dim = shape[-2] if len(shape) > 1 else shape[-1]
                ax = best(lead_dim, joint, tp)
                return tail((ax, None)) if len(shape) > 1 else tail((ax,))
            if name in ("conv_b", "D", "dt_bias"):
                return tail((best(shape[-1], joint, tp),))
            if name == "w_router":
                return tail((None, None))
            return REPLICATED

        # experts stacked (E, D, F)/(E, F, D): EP on E, FSDP on the reduce dim
        if name in ("we_gate", "we_up"):
            return tail((tp, fs, None))
        if name == "we_down":
            return tail((tp, None, fs))
        # column-parallel (out-features on tp)
        if name in ("w_q", "w_k", "w_v", "w_gate", "w_up", "w_in", "w_dt"):
            return tail((fs, tp))
        if name in ("b_q", "b_k", "b_v"):
            return tail((tp,))
        # row-parallel (in-features on tp)
        if name in ("w_o", "w_down", "w_out"):
            return tail((tp, fs))
        # embeddings / lm head: vocab on tp (Megatron vocab-parallel)
        if name in ("embedding",):
            return tail((tp, fs))
        if name == "lm_head":
            return tail((fs, tp))
        # router: small, replicate out-features
        if name == "w_router":
            return tail((fs, None))
        # mamba internals: channel dim on tp
        if name in ("conv_w", "A_log", "x_proj"):
            return tail((tp, None)) if len(shape) > 1 else tail((tp,))
        if name in ("conv_b", "D", "dt_bias"):
            return tail((tp,))
        # rwkv time-mix / decay loras and norms: replicated (small)
        return REPLICATED

    def param_specs(self, params) -> dict:
        """Mirror pytree of PartitionSpecs for a param tree."""

        def walk(node, prefix):
            if isinstance(node, dict):
                return {
                    k: walk(v, f"{prefix}/{k}" if prefix else k)
                    for k, v in node.items()
                }
            return self.spec_for(prefix, node.shape)

        return walk(params, "")

    def shard_params(self, params):
        """Apply NamedShardings to a concrete param tree (post-init)."""
        if self.mesh is None:
            return params
        specs = self.param_specs(params)
        return jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(self.mesh, s)),
            params,
            specs,
        )

    # ---- activation constraints -------------------------------------------

    def act(self, x, *, kind: str):
        """Constrain an activation tensor. kinds:
        hidden   (B, S, D)   — batch on dp (+ seq on tp if seq_parallel)
        logits   (B, S, V)   — vocab on tp
        heads    (B, S, H, hd) — heads on tp
        kv       (B, S, K, hd) — kv heads on tp if divisible else seq on tp
        cache    (B, K, S, hd) — same rule, decode layout
        tokens   (B, S)
        """
        if self.mesh is None:
            return x
        dp, tp = self.dp, self.tp_axis
        if self.mode == "serve2d":
            joint = ((tp,) if tp else ()) + tuple(self.fsdp_axes or ())
            if kind == "cache":  # (B, K, S, hd): sequence over the grid
                if x.shape[2] % _axis_size(self.mesh, joint) == 0:
                    return self.constrain(x, P(None, None, joint, None))
                return self.constrain(x, P(None, None, tp, None))
            if kind == "logits":
                return self.constrain(x, P(None, None, tp))
            return x  # activations replicated (tiny at decode)
        if kind == "hidden":
            seq = tp if self.seq_parallel else None
            return self.constrain(x, P(dp, seq, None))
        if kind == "tokens":
            return self.constrain(x, P(dp, None))
        if kind == "logits":
            return self.constrain(x, P(dp, None, tp))
        if kind == "heads":
            return self.constrain(x, P(dp, None, tp, None))
        if kind == "kv":
            k_heads = x.shape[2]
            if tp and k_heads % self.tp_size == 0:
                return self.constrain(x, P(dp, None, tp, None))
            # kv heads not divisible by TP: *replicate* over model.  K/V
            # are G = H/KV times smaller than Q; seq-sharding them against
            # head-sharded Q forces per-layer K/V all-gathers inside the
            # score einsums (measured 1.4 TB/chip on qwen2-72b train).
            return self.constrain(x, P(dp, None, None, None))
        if kind == "cache":
            k_heads = x.shape[1]
            if tp and k_heads % self.tp_size == 0:
                return self.constrain(x, P(dp, tp, None, None))
            return self.constrain(x, P(dp, None, tp, None))
        raise ValueError(kind)
