"""Checkpointing: atomic, async, keep-k, cross-mesh resharding restore.

Layout: ``<dir>/step_<N>/state.npz`` (flat path-keyed arrays) +
``meta.json``.  Writes go to ``step_<N>.tmp`` and are renamed only after
fsync — a crashed save can never shadow a good checkpoint (the restart
path of runtime/fault.py relies on this invariant).

Restore takes a *template* pytree (shapes/dtypes/shardings of the live
state): arrays are loaded host-side and ``device_put`` with the
template's sharding — so a checkpoint written on a 16x16 mesh restores
onto 2x16x16 (or a shrunken elastic mesh) without a resharding tool.

On a real multi-host pod each host would write its addressable shards
(same layout, one npz per host); single-process here, the gather is a
no-op.  Async mode runs save() on a worker thread with a copy-on-write
snapshot (jax arrays are immutable — the snapshot is free).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path

import jax
import numpy as np

__all__ = ["CheckpointManager"]

_SEP = "//"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        items = tree.items()
    elif isinstance(tree, (list, tuple)):
        items = ((str(i), v) for i, v in enumerate(tree))
    elif hasattr(tree, "_asdict"):  # NamedTuple
        items = tree._asdict().items()
    else:
        return {prefix: tree}
    for k, v in items:
        key = f"{prefix}{_SEP}{k}" if prefix else str(k)
        out.update(_flatten(v, key))
    return out


def _unflatten_into(template, flat):
    """Rebuild arrays in the *structure and sharding* of ``template``."""
    leaves, treedef = jax.tree.flatten(template)
    paths = list(_flatten(jax.tree.unflatten(treedef, range(len(leaves)))).items())
    # paths maps key -> leaf index
    new_leaves = list(leaves)
    for key, idx in paths:
        arr = flat[key]
        tmpl = leaves[idx]
        arr = np.asarray(arr).astype(tmpl.dtype)
        if arr.shape != tmpl.shape:
            raise ValueError(
                f"checkpoint leaf {key}: shape {arr.shape} != {tmpl.shape}"
            )
        sharding = getattr(tmpl, "sharding", None)
        new_leaves[idx] = (
            jax.device_put(arr, sharding) if sharding else jax.numpy.asarray(arr)
        )
    return jax.tree.unflatten(treedef, new_leaves)


class CheckpointManager:
    def __init__(self, directory: str | os.PathLike, *, keep: int = 3,
                 async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._worker: threading.Thread | None = None
        self._error: Exception | None = None

    # ---- save ---------------------------------------------------------

    def save(self, step: int, state, *, meta: dict | None = None,
             block: bool = False):
        flat = {
            k: np.asarray(v) for k, v in _flatten(state).items()
        }  # gather to host (snapshot; jax arrays immutable)
        if self.async_save and not block:
            self.wait()
            self._worker = threading.Thread(
                target=self._write, args=(step, flat, meta or {}), daemon=True
            )
            self._worker.start()
        else:
            self._write(step, flat, meta or {})

    def _write(self, step: int, flat: dict, meta: dict):
        try:
            tmp = self.dir / f"step_{step:08d}.tmp"
            final = self.dir / f"step_{step:08d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            np.savez(tmp / "state.npz", **flat)
            (tmp / "meta.json").write_text(
                json.dumps({"step": step, "time": time.time(), **meta})
            )
            os.replace(tmp, final)  # atomic publish
            self._gc()
        except Exception as e:  # surfaced on next wait()
            self._error = e

    def wait(self):
        if self._worker is not None:
            self._worker.join()
            self._worker = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ---- restore ------------------------------------------------------

    def all_steps(self) -> list[int]:
        return sorted(
            int(p.name.split("_")[1])
            for p in self.dir.glob("step_*")
            if p.is_dir() and not p.name.endswith(".tmp")
        )

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template):
        """Load step into the structure+sharding of ``template``."""
        path = self.dir / f"step_{step:08d}"
        with np.load(path / "state.npz") as z:
            flat = {k: z[k] for k in z.files}
        return _unflatten_into(template, flat)

    def restore_latest(self, template):
        step = self.latest_step()
        if step is None:
            return None, None
        meta = json.loads((self.dir / f"step_{step:08d}" / "meta.json").read_text())
        return self.restore(step, template), meta
