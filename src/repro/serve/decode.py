"""`CommContext`-routed tensor-parallel decode path for the serving spine.

Traced building blocks shared by :class:`repro.serve.engine.ServeEngine`,
the refactored :mod:`repro.launch.serve` wrappers, and the
``python -m repro.analysis --spmd`` sweep.  Three decode-time
collectives, each routed where the cost model says it belongs:

* **per-token logits allreduce** — the latency-regime workload the paper
  optimises: the partial head products are ``group * slots * V`` floats
  (tens of KB at serving vocab shards), far below
  ``Topology.crossover_bytes()`` on multi-node grids, so auto dispatch
  lands on NAP (``log_ppn(n)`` inter-node steps) per token;
* **hidden-state gather** — the slot-sharded final hidden states are
  rebuilt on every chip through ``ctx.allgather`` pinned to ``mla_ag``
  on multi-node grids (the striped KV-cache/activation gather), whose
  lane-major payload layout this module's block indexing mirrors;
* **EOS early-exit min-reduce** — pinned to the native ``psum`` engine:
  a value steering a ``while_loop`` predicate must be *provably*
  rank-uniform, and only a whole-group reduction primitive clears rank
  variance in the spmd lint's dataflow lattice (the PR-8 lint-clean
  form).

The tensor-parallel head splits the ``D`` contraction, not the vocab:
every chip sees the full gathered hidden block, contracts its own
``D/group`` column slice against the same slice of the head matrix, and
the sum over chips is recovered by the logits allreduce.  Contraction
(not vocab) sharding keeps the ``argmax`` local — no second collective
to find the winning token — and makes the allreduce payload exactly the
per-token logits, the paper's canonical small-message workload.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .. import compat
from ..core import comm
from ..models.layers import softcap

__all__ = [
    "payload_block_index",
    "group_all_min",
    "make_tp_head",
    "make_decode_slice",
    "make_decode_loop",
    "greedy_step",
]


def _flat_axis_index(axes: tuple[str, ...]) -> jax.Array:
    """Row-major flattened ``lax.axis_index`` over named ``axes`` (0 if
    none)."""
    idx = jnp.zeros((), jnp.int32)
    for ax in axes:
        idx = idx * compat.axis_size(ax) + lax.axis_index(ax)
    return idx


def payload_block_index(topology: comm.Topology) -> jax.Array:
    """This chip's block index in the striped allgather payload.

    ``mla_allgather`` rebuilds the flat payload lane-major: the intra
    all_gather stacks per-lane stripes, each stripe the inter all_gather
    of that lane's node shards — so chip ``(node j, lane r)`` owns block
    ``r * n_nodes + j``.  Degenerate grids (``n == 1`` or ``ppn == 1``)
    collapse to the chip-order layout of the flat fallback engine, so
    this single formula is layout-correct for whichever allgather engine
    :meth:`CommContext.dispatch` selects on those grids.  Traced (needs
    bound axes).
    """
    j = _flat_axis_index(topology.inter_axes)
    r = _flat_axis_index(topology.intra_axes)
    return r * topology.n_nodes + j


def group_all_min(ctx: comm.CommContext | None, flag: jax.Array) -> jax.Array:
    """Group-agreed "everyone done" flag for while-predicate use.

    Pinned to the native ``psum`` engine, not the latency dispatch: a
    value that steers control flow must be *provably* uniform, and only
    a whole-group reduction primitive clears rank variance in the spmd
    lint's dataflow lattice.  NAP's masked-permute output is uniform
    algorithmically but not provably so — the uniformity rule
    (correctly) rejects it as a while predicate.
    """
    if ctx is None or not (
        ctx.topology.inter_axes or ctx.topology.intra_axes
    ):
        return flag
    return ctx.allreduce(flag, op="min", algorithm="psum")


def make_tp_head(model, ctx: comm.CommContext | None):
    """Build the tensor-parallel greedy head:
    ``(params, hidden (b, 1, D)) -> next tokens (b, 1) int32``.

    With a bound multi-chip ``ctx`` the input is this chip's slot shard;
    the returned tokens are the same shard's next tokens.  Without one
    (or on a 1-chip topology) it degenerates to the local head einsum —
    same contraction, ``preferred_element_type=f32``, softcap after.
    """
    cfg = model.cfg
    use_comm = ctx is not None and ctx.topology.group > 1 and bool(
        ctx.topology.inter_axes or ctx.topology.intra_axes
    )

    if not use_comm:

        def local_head(params, hidden):
            w = model.head_weights(params)
            logits = jnp.einsum(
                "bsd,dv->bsv", hidden, w.astype(hidden.dtype),
                preferred_element_type=jnp.float32,
            )
            logits = softcap(logits, cfg.final_logit_softcap)
            logits = model.policy.act(logits, kind="logits")
            return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[
                :, None
            ]

        return local_head

    topo = ctx.topology
    group = topo.group
    D = cfg.d_model
    # pad the contraction so every chip owns an equal column slice; the
    # zero columns contribute nothing to the einsum
    d_cols = -(-D // group)
    Dp = d_cols * group
    # the striped gather is the point on multi-node grids; on flat grids
    # auto dispatch resolves to the (layout-compatible) fallback
    ag_algorithm = "mla_ag" if topo.has_slow_domain else None

    def tp_head(params, hidden):
        b, s, _ = hidden.shape
        assert s == 1, "decode head expects single-position hidden states"
        h = hidden.reshape(b, D).astype(jnp.float32)
        if Dp != D:
            h = jnp.pad(h, ((0, 0), (0, Dp - D)))
        # rebuild every chip's slot rows on all chips (lane-major blocks)
        full = ctx.allgather(
            h.reshape(-1), elems=group * b * Dp, algorithm=ag_algorithm
        ).reshape(group * b, Dp)
        bi = payload_block_index(topo)
        w = model.head_weights(params).astype(jnp.float32)
        if Dp != D:
            w = jnp.pad(w, ((0, Dp - D), (0, 0)))
        h_slice = lax.dynamic_slice_in_dim(full, bi * d_cols, d_cols, axis=1)
        w_slice = lax.dynamic_slice_in_dim(w, bi * d_cols, d_cols, axis=0)
        partial = jnp.einsum(
            "bd,dv->bv", h_slice, w_slice,
            preferred_element_type=jnp.float32,
        )
        # the latency-regime allreduce: tiny per-token payload, auto
        # dispatch -> NAP on multi-node grids (below crossover_bytes)
        logits = ctx.allreduce(partial, op="sum")
        logits = softcap(logits, cfg.final_logit_softcap)
        tok_all = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        # every chip computed all rows; keep only this chip's block
        return lax.dynamic_slice_in_dim(tok_all, bi * b, b, axis=0)[:, None]

    return tp_head


def greedy_step(model, ctx: comm.CommContext | None = None):
    """One-token cached greedy decode:
    ``(params, cache, tokens) -> (next tokens (B, 1), cache)``.

    The shared decode step: :func:`repro.launch.steps.make_serve_step`
    and the slot engine both run exactly this.  With ``ctx`` the head is
    the tensor-parallel path above; without, the model's own head.
    """
    head = make_tp_head(model, ctx)

    def step(params, cache, tokens):
        hidden, new_cache = model.decode_hidden(params, cache, tokens)
        return head(params, hidden), new_cache

    return step


# ---------------------------------------------------------------------------
# slot-stacked decode slice (the engine's jitted inner loop)
# ---------------------------------------------------------------------------


def _vmapped_decode_hidden(model):
    """``decode_hidden`` over a slot-stacked cache: every leaf carries a
    leading slot axis over an inner B=1 cache; tokens are ``(slots, 1)``.
    Returns ``(hidden (slots, 1, D), new stacked cache)``."""

    def one(params, cache, tok):
        return model.decode_hidden(params, cache, tok[None])  # B=1

    return jax.vmap(one, in_axes=(None, 0, 0))


def make_decode_slice(
    model,
    ctx: comm.CommContext | None,
    *,
    slice_len: int,
    eos_id: int | None = None,
):
    """Build the jitted decode slice
    ``(params, cache, tok, active) -> (out, tok', cache', steps)``.

    ``cache`` is slot-stacked (leading slot axis, inner B=1), ``tok`` is
    ``(slots, 1)`` int32 — the next token to feed — and ``active`` is
    ``(slots,)`` bool slot occupancy.  The slice records up to
    ``slice_len`` tokens per slot into ``out (slots, slice_len)``
    (column ``t`` is the token *emitted* at step ``t``; trailing columns
    are zero after an early exit) and returns the carry token for the
    next slice plus ``steps``, the number of decode steps actually
    executed (rank-uniform: the early exit is group-agreed).  Inactive slots still compute (their rows are garbage
    the scheduler drops) but their done flags are forced so they never
    hold up the EOS early exit, which is min-reduced through the native
    ``psum`` engine so the ``while_loop`` predicate is rank-uniform.

    Membership changes (admission, eviction, slot reuse) happen *between*
    slices — the continuous-batching boundary — by scattering fresh B=1
    prefill caches into slot rows; this function never resizes.
    """
    decode_hidden = _vmapped_decode_hidden(model)
    head = make_tp_head(model, ctx)

    def slice_fn(params, cache, tok, active):
        slots = tok.shape[0]
        out0 = jnp.zeros((slots, slice_len), jnp.int32)
        done0 = ~active
        stop0 = jnp.zeros((), jnp.float32)

        def cond(carry):
            t, _tok, _cache, _out, _done, stop = carry
            return (t < slice_len) & (stop < 0.5)

        def body(carry):
            t, tok, cache, out, done, stop = carry
            out = lax.dynamic_update_slice(out, tok, (0, t))
            hidden, cache = decode_hidden(params, cache, tok)
            nxt = head(params, hidden.reshape(slots, 1, -1))
            if eos_id is not None:
                done = done | (tok[:, 0] == eos_id)
                nxt = jnp.where(done[:, None], eos_id, nxt)
            stop = group_all_min(
                ctx, jnp.all(done).astype(jnp.float32)
            )
            return t + 1, nxt, cache, out, done, stop

        carry = (jnp.zeros((), jnp.int32), tok, cache, out0, done0, stop0)
        t, tok, cache, out, _, _ = lax.while_loop(cond, body, carry)
        return out, tok, cache, t

    return slice_fn


# ---------------------------------------------------------------------------
# whole-batch greedy decode loop (the launch/serve.py wrapper's core)
# ---------------------------------------------------------------------------


def make_decode_loop(model, ctx: comm.CommContext | None = None, *,
                     gen_len: int, eos_id: int | None = None):
    """Build the jitted greedy decode loop ``(params, cache, tok) ->
    (B, gen_len) tokens`` (the fixed-batch serve path).

    ``tok`` is the (B, 1) first generated token (argmax of the last
    prefill logits).  With ``eos_id`` set the loop exits early once
    every sequence has emitted it; with a ``ctx`` whose topology has
    bound axes, "every sequence" means *across the whole serving
    group*: the local all-done flag is min-reduced through
    ``ctx.allreduce`` pinned to the native ``psum`` engine so the
    ``while_loop`` predicate is uniform on every rank — the lint-clean
    form of distributed early exit.
    """

    def decode(params, cache, tok):
        B = tok.shape[0]
        out0 = jnp.zeros((B, gen_len), jnp.int32)
        done0 = jnp.zeros((B,), bool)
        # group-agreed stop flag: starts "not done", updated from the
        # min-reduced all-done flag so every rank sees the same value
        stop0 = jnp.zeros((), jnp.float32)

        def cond(carry):
            t, _tok, _cache, _out, _done, stop = carry
            return (t < gen_len) & (stop < 0.5)

        def body(carry):
            t, tok, cache, out, done, stop = carry
            out = lax.dynamic_update_slice(out, tok, (0, t))
            logits, cache = model.decode_step(params, cache, tok)
            nxt = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
            if eos_id is not None:
                done = done | (tok[:, 0] == eos_id)
                nxt = jnp.where(done[:, None], eos_id, nxt)
                stop = group_all_min(
                    ctx, jnp.all(done).astype(jnp.float32)
                )
            return t + 1, nxt, cache, out, done, stop

        carry = (jnp.zeros((), jnp.int32), tok, cache, out0, done0, stop0)
        _, _, _, out, _, _ = lax.while_loop(cond, body, carry)
        return out

    return decode
