"""Request lifecycle + continuous-batching scheduler (host-side).

The serving spine's control plane: pure-Python state machines with no
jax dependency, so every invariant is cheaply fuzzable.  The device
side (:mod:`repro.serve.engine`) only ever asks three questions at a
decode-step boundary — *who joined*, *who is active*, *who is done* —
and this module answers them under the invariants the tests enforce:

* **slot conservation** — every slot is free or holds exactly one
  active request; ``len(free) + len(active) == num_slots`` always;
* **FIFO fairness** — admission order equals arrival order: a request
  is never admitted while an earlier admissible one still queues;
* **silence after the end** — a finished / evicted / rejected request
  never records another token.

Request lifecycle::

    submit() ──> QUEUED ──admit()──> ACTIVE ──record_token()──> FINISHED
                   │                    │
                   └── (queue full: REJECTED)   └──evict()──> EVICTED

Membership changes happen only at decode-step boundaries: the engine
calls :meth:`Scheduler.admit` between decode slices, never inside one —
exactly the continuous-batching contract (in-flight insertion into free
slots, eviction of finished requests, the rest undisturbed).

Prompt shapes ride padded buckets (:class:`PromptBuckets`, the saxml
``servable_model`` pattern): a prompt is padded up to the smallest
registered bucket length, so the number of distinct prefill traces is
bounded by the bucket count, not by the number of distinct prompt
lengths ever seen.

Ragged batch geometry reuses :func:`repro.core.napalg.ragged_splits`:
:meth:`Scheduler.shard_geometry` splits the slot range over the serving
group's chips with the same uneven-block rule the MLA stripe layout
uses, so a slot count that does not divide the chip count costs at most
one padded slot per chip in the executed lowering — never a resize.
"""

from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Iterable, Sequence

__all__ = [
    "Request",
    "PromptBuckets",
    "Scheduler",
    "QUEUED",
    "ACTIVE",
    "FINISHED",
    "EVICTED",
    "REJECTED",
]

QUEUED = "queued"
ACTIVE = "active"
FINISHED = "finished"
EVICTED = "evicted"
REJECTED = "rejected"

#: states in which a request will never emit another token
TERMINAL = (FINISHED, EVICTED, REJECTED)

#: process-global request ids: a request rerouted between replicas keeps
#: its rid, so ids must be unique across schedulers, not within one
_GLOBAL_IDS = itertools.count()


@dataclasses.dataclass
class Request:
    """One serving request: prompt in, generated tokens out.

    The scheduler owns ``state``/``slot``; callers treat them as
    read-only.  Timestamps (``arrival``/``admitted_at``/``finished_at``
    and per-token ``token_times``) are whatever clock the driver passes
    in — wall seconds in the engine, simulated seconds in the load
    benchmark — and exist for the latency percentiles.
    """

    rid: int
    prompt: tuple[int, ...]
    max_new_tokens: int
    arrival: float = 0.0
    extras: dict | None = None   # e.g. encoder frames for enc-dec archs

    state: str = QUEUED
    slot: int | None = None
    bucket_len: int | None = None
    generated: list[int] = dataclasses.field(default_factory=list)
    admitted_at: float | None = None
    finished_at: float | None = None
    token_times: list[float] = dataclasses.field(default_factory=list)

    def __post_init__(self):
        self.prompt = tuple(int(t) for t in self.prompt)
        if not self.prompt:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {self.max_new_tokens}"
            )

    @property
    def done(self) -> bool:
        return self.state in TERMINAL

    @property
    def remaining(self) -> int:
        """Token budget left (0 once terminal)."""
        if self.done:
            return 0
        return self.max_new_tokens - len(self.generated)


class PromptBuckets:
    """Padded-shape prompt buckets bounding the prefill trace count.

    ``lengths`` are the allowed padded prompt lengths (sorted,
    deduplicated).  :meth:`bucket_len` pads a prompt up to the smallest
    bucket that holds it, so the engine compiles at most
    ``len(lengths)`` prefill programs however many distinct prompt
    lengths arrive — the saxml padded-shape dispatch pattern.
    """

    def __init__(self, lengths: Iterable[int]):
        self.lengths: tuple[int, ...] = tuple(
            sorted({int(l) for l in lengths})
        )
        if not self.lengths:
            raise ValueError("need at least one bucket length")
        if self.lengths[0] < 1:
            raise ValueError(f"bucket lengths must be >= 1: {self.lengths}")

    @classmethod
    def geometric(
        cls, max_len: int, *, start: int = 8, factor: int = 2
    ) -> "PromptBuckets":
        """Geometric ladder ``start, start*factor, ... >= max_len`` —
        O(log(max_len)) traces with <= ``factor``x padding waste."""
        if factor < 2:
            raise ValueError(f"factor must be >= 2, got {factor}")
        edges = []
        l = max(1, int(start))
        while l < int(max_len):
            edges.append(l)
            l *= factor
        edges.append(int(max_len))
        return cls(edges)

    @property
    def max_len(self) -> int:
        return self.lengths[-1]

    def bucket_len(self, prompt_len: int) -> int:
        """Smallest bucket holding ``prompt_len`` (raises past the top)."""
        for l in self.lengths:
            if prompt_len <= l:
                return l
        raise ValueError(
            f"prompt length {prompt_len} exceeds the largest bucket "
            f"{self.lengths[-1]}"
        )


class Scheduler:
    """Continuous-batching slot scheduler for one serving replica.

    ``num_slots`` is the decode batch width (the device-side slot
    count); ``max_queue`` bounds the admission queue (``None`` =
    unbounded) — a submit past the bound is **rejected**, the
    backpressure signal the router spreads load on.
    """

    def __init__(
        self,
        num_slots: int,
        *,
        max_queue: int | None = None,
        buckets: PromptBuckets | None = None,
        eos_id: int | None = None,
    ):
        if num_slots < 1:
            raise ValueError(f"num_slots must be >= 1, got {num_slots}")
        if max_queue is not None and max_queue < 0:
            raise ValueError(f"max_queue must be >= 0, got {max_queue}")
        self.num_slots = int(num_slots)
        self.max_queue = max_queue
        self.buckets = buckets
        self.eos_id = eos_id
        self.queue: deque[Request] = deque()
        self.slots: list[Request | None] = [None] * self.num_slots
        # free slots kept sorted so slot assignment is deterministic
        self._free: list[int] = list(range(self.num_slots))
        self._ids = _GLOBAL_IDS
        self.requests: dict[int, Request] = {}
        self.n_rejected = 0

    # -- admission ---------------------------------------------------------

    def submit(
        self,
        prompt: Sequence[int],
        max_new_tokens: int,
        *,
        arrival: float = 0.0,
        extras: dict | None = None,
    ) -> Request:
        """Admission control: enqueue, or mark REJECTED when the queue
        is full.  Returns the request either way (check ``state``)."""
        req = Request(
            rid=next(self._ids),
            prompt=tuple(prompt),
            max_new_tokens=int(max_new_tokens),
            arrival=arrival,
            extras=extras,
        )
        if self.buckets is not None:
            # validate at admission time, not at prefill time
            req.bucket_len = self.buckets.bucket_len(len(req.prompt))
        self.requests[req.rid] = req
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            req.state = REJECTED
            self.n_rejected += 1
            return req
        self.queue.append(req)
        return req

    def enqueue(self, req: Request, *, force: bool = False) -> Request:
        """Re-queue an existing QUEUED request (router rerouting path).

        Acceptance is binding: a request that was admitted to some
        queue must never be silently dropped mid-flight, so a full
        queue **raises** here instead of rejecting — callers check
        :attr:`queue_capacity` first (or pass ``force=True``, the
        replica-loss re-plan path, where transiently overshooting the
        backpressure bound beats losing accepted work).
        """
        if req.state != QUEUED:
            raise ValueError(
                f"only QUEUED requests can be enqueued, got {req.state}"
            )
        if (
            not force
            and self.max_queue is not None
            and len(self.queue) >= self.max_queue
        ):
            raise ValueError(
                f"queue full ({len(self.queue)}/{self.max_queue}); "
                f"rejecting an already-accepted request would break "
                f"conservation — check queue_capacity before enqueue"
            )
        if self.buckets is not None:
            req.bucket_len = self.buckets.bucket_len(len(req.prompt))
        self.requests[req.rid] = req
        self.queue.append(req)
        return req

    def admit(self, *, now: float = 0.0) -> list[Request]:
        """Fill free slots from the queue head (FIFO) — called by the
        engine at a decode-step boundary, never inside a slice.

        Returns the newly admitted requests (they need a prefill +
        cache insertion before the next decode step).
        """
        admitted = []
        while self._free and self.queue:
            req = self.queue.popleft()
            slot = self._free.pop(0)
            req.slot = slot
            req.state = ACTIVE
            req.admitted_at = now
            self.slots[slot] = req
            admitted.append(req)
        return admitted

    # -- decode-step results ----------------------------------------------

    def record_token(
        self, slot: int, token: int, *, now: float = 0.0
    ) -> Request | None:
        """One generated token for ``slot``'s request.  Finishes the
        request on EOS or budget exhaustion and frees the slot; returns
        the request if it just finished, else None.

        A token for a free slot (evicted / never filled) is dropped —
        the engine decodes padded and garbage slots unconditionally and
        relies on this being a no-op.
        """
        req = self.slots[slot]
        if req is None:
            return None
        assert not req.done, "terminal request still held a slot"
        req.generated.append(int(token))
        req.token_times.append(now)
        if (
            (self.eos_id is not None and int(token) == self.eos_id)
            or len(req.generated) >= req.max_new_tokens
        ):
            self._release(req, FINISHED, now=now)
            return req
        return None

    def evict(self, rid: int, *, now: float = 0.0) -> Request:
        """Cancel a request.  ACTIVE: frees its slot (the engine masks
        it at the next boundary).  QUEUED: removed from the queue.
        Terminal: no-op.  Raises ``KeyError`` for a rid this replica
        does not own (e.g. one already rerouted away)."""
        if rid not in self.requests:
            raise KeyError(
                f"rid {rid} is not owned by this replica (rerouted away "
                f"or never submitted here)"
            )
        req = self.requests[rid]
        if req.done:
            return req
        if req.state == QUEUED:
            self.queue.remove(req)
            req.state = EVICTED
            req.finished_at = now
            return req
        self._release(req, EVICTED, now=now)
        return req

    def _release(self, req: Request, state: str, *, now: float) -> None:
        slot = req.slot
        assert slot is not None and self.slots[slot] is req
        self.slots[slot] = None
        self._free.append(slot)
        self._free.sort()
        req.slot = None
        req.state = state
        req.finished_at = now

    def drain_queue(self) -> list[Request]:
        """Remove and return every queued request (router rerouting on a
        degraded replica); they stay QUEUED for re-submission.

        Ownership transfers with the request: the drained rids leave
        this replica's registry, so exactly one scheduler ever answers
        for a live rid (a stale registry entry would let an evict race
        the reroute and corrupt the new owner's queue).
        """
        out = list(self.queue)
        self.queue.clear()
        for req in out:
            self.requests.pop(req.rid, None)
        return out

    def drain_active(self) -> list[Request]:
        """Demote every ACTIVE request back to QUEUED and free its slot
        (replica-loss re-planning: the KV state is gone, survivors
        re-prefill ``prompt + generated`` elsewhere).  Returns them in
        slot order with ownership removed, ready to ``enqueue`` on a
        surviving replica."""
        out = []
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            self.slots[slot] = None
            self._free.append(slot)
            req.slot = None
            req.state = QUEUED
            self.requests.pop(req.rid, None)
            out.append(req)
        self._free.sort()
        return out

    @property
    def queue_capacity(self) -> int | None:
        """Admission slots left in the queue (``None`` = unbounded) —
        the router's pre-reroute capacity check."""
        if self.max_queue is None:
            return None
        return max(0, self.max_queue - len(self.queue))

    # -- views -------------------------------------------------------------

    def active(self) -> list[Request]:
        return [r for r in self.slots if r is not None]

    @property
    def free_slots(self) -> tuple[int, ...]:
        return tuple(self._free)

    def active_mask(self) -> list[bool]:
        """Per-slot occupancy, index-aligned with the device batch."""
        return [r is not None for r in self.slots]

    @property
    def idle(self) -> bool:
        return not self.queue and not any(self.slots)

    def outstanding_tokens(self) -> int:
        """Token budget still owed (queued + active) — the router's
        load metric."""
        return sum(r.remaining for r in self.queue) + sum(
            r.remaining for r in self.slots if r is not None
        )

    def shard_geometry(self, group: int) -> tuple[int, ...]:
        """Per-chip slot counts over a ``group``-chip serving grid —
        the uneven-block split of :func:`repro.core.napalg.ragged_splits`
        (the executed lowering pads every chip to ``max(geometry)``)."""
        from ..core import napalg

        return napalg.ragged_splits(self.num_slots, group)

    def check_invariants(self, peers: Sequence["Scheduler"] = ()) -> None:
        """Assert the scheduler's structural invariants (test hook).

        With ``peers`` (the other replicas behind the same router) this
        becomes the cross-replica conservation check: a live rid is
        held and registered by exactly one scheduler in the group, and
        every replica's outstanding-token figure is consistent with its
        per-request token counts.
        """
        occupied = [i for i, r in enumerate(self.slots) if r is not None]
        assert len(self._free) + len(occupied) == self.num_slots, (
            self._free, occupied,
        )
        assert not (set(self._free) & set(occupied))
        assert sorted(self._free) == list(self._free)
        for i in occupied:
            req = self.slots[i]
            assert req.slot == i and req.state == ACTIVE
        for req in self.queue:
            assert req.state == QUEUED and req.slot is None
        # outstanding-token accounting consistent with per-request
        # token counts (the router's load metric must never drift)
        live = list(self.queue) + self.active()
        for req in live:
            assert req.remaining == req.max_new_tokens - len(req.generated), (
                req.rid, req.remaining, req.max_new_tokens, req.generated,
            )
            assert req.remaining >= 1, (req.rid, req.state)
        assert self.outstanding_tokens() == sum(r.remaining for r in live)
        if not peers:
            return
        # global rid uniqueness across the replica group: each live rid
        # is registered with exactly one scheduler and held in exactly
        # one container
        group = (self, *peers)
        registered: dict[int, int] = {}
        held: dict[int, int] = {}
        for gi, s in enumerate(group):
            for rid, req in s.requests.items():
                if req.done:
                    continue
                assert rid not in registered, (
                    f"live rid {rid} registered with schedulers "
                    f"{registered[rid]} and {gi}"
                )
                registered[rid] = gi
            for req in list(s.queue) + s.active():
                assert req.rid not in held, (
                    f"live rid {req.rid} held by schedulers "
                    f"{held[req.rid]} and {gi}"
                )
                held[req.rid] = gi
                assert req.rid in s.requests, (
                    f"rid {req.rid} held by scheduler {gi} but not "
                    f"registered there"
                )
