"""repro.serve — the production serving spine.

Continuous batching over topology-aware decode collectives: the
request-level system around the paper's latency-regime result.  Decode
collectives are tiny (KBs per token — exactly NAP's ``log_ppn(n)``-step
regime) and fire thousands of times per request, so the node-aware
small-message win compounds per token; this package is the machinery
that keeps those collectives saturated with real traffic.

Three layers (each its own module):

* :mod:`repro.serve.scheduler` — host-side request lifecycle: admission
  control, FIFO slot assignment, in-flight insertion/eviction at
  decode-step boundaries, saxml-style padded prompt buckets;
* :mod:`repro.serve.decode` — the traced decode path: slot-stacked
  cached decode with a ``CommContext``-routed tensor-parallel logits
  head (latency-regime allreduce → NAP on multi-node grids, ``mla_ag``
  hidden gather, psum-min EOS early exit — the lint-clean form);
* :mod:`repro.serve.router` — multi-replica data-parallel routing by
  outstanding-token load, reroute on
  :class:`repro.runtime.fault.ReplicaHealth` straggler signals.

Quickstart — one replica, continuous batching::

    from repro.configs import get_config, reduced
    from repro.models import build_model
    from repro.serve import PromptBuckets, ServeEngine

    cfg = reduced(get_config("minicpm-2b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    eng = ServeEngine(
        model, params, num_slots=4, max_len=64,
        buckets=PromptBuckets([8, 16, 32]), eos_id=7,
    )
    r0 = eng.submit([1, 2, 3], max_new_tokens=16)
    r1 = eng.submit(list(range(20)), max_new_tokens=8)   # joins in flight
    tokens = eng.run()          # {rid: [tok, ...]}, continuous batching

Multi-chip (tensor-parallel decode over a mesh)::

    from repro.launch.mesh import make_mesh

    mesh = make_mesh((2, 4), ("pod", "data"))   # 2 nodes x 4 lanes
    eng = ServeEngine(model, params, num_slots=8, max_len=64,
                      eos_id=7, mesh=mesh)
    eng.submit([1, 2, 3], 16); tokens = eng.run()
    eng.dispatch_report()   # logits allreduce -> "nap" on this grid

Multi-replica routing::

    from repro.serve import Router

    router = Router([eng_a, eng_b])
    router.submit([1, 2, 3], 16)        # least outstanding-token load
    router.observe_step(0, step, dt)    # straggler -> reroute queue
    router.fail_replica(0)              # replica death -> re-plan onto
                                        # survivors (queued + demoted
                                        # actives, never dropped)
    router.evict(rid)                   # placement-accurate cancel

The control plane holds two protocol guarantees end to end:
**acceptance is binding** (a request once QUEUED is never silently
REJECTED by a reroute into a full peer) and **single ownership** (a
live rid is registered with exactly one scheduler, so evictions can
never race a reroute through a stale registry entry).

The decode path passes the repo's four static gates — the layer-0
protocol model check (``python -m repro.analysis --protocol``
exhaustively explores this package's scheduler/router/health protocol
at small scope; see :mod:`repro.analysis.protocol_check`), then the
schedule verifier, SPMD jaxpr lint and HLO wire-lint, swept by
``python -m repro.analysis --spmd`` as the ``serve_engine`` workload.
"""

from .decode import (
    greedy_step,
    make_decode_loop,
    make_decode_slice,
    make_tp_head,
)
from .engine import ServeEngine
from .router import Router
from .scheduler import PromptBuckets, Request, Scheduler

__all__ = [
    "ServeEngine",
    "Router",
    "Scheduler",
    "PromptBuckets",
    "Request",
    "greedy_step",
    "make_decode_loop",
    "make_decode_slice",
    "make_tp_head",
]
