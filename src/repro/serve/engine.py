"""ServeEngine: continuous-batching serving replica over slot-stacked caches.

One engine owns one model replica's device state and drives it with the
host-side :class:`repro.serve.scheduler.Scheduler`:

* **slot-stacked caches** — the decode cache is a pytree whose every
  leaf carries a leading *slot* axis over an inner B=1 cache, so
  membership changes are per-row scatters (``full.at[slot].set(one)``)
  and the decode batch shape never retraces;
* **bucketed prefill** — each admitted request prefills alone (B=1) in
  a jitted program compiled per *bucket length*, not per prompt length:
  the prompt rides padded to its :class:`PromptBuckets` bucket and a
  where-snapshot keeps only the state after exactly ``len(prompt)`` real
  steps, so the padded prefill is bitwise-identical to an unpadded one;
* **sliced decode** — between membership boundaries the engine runs one
  jitted :func:`repro.serve.decode.make_decode_slice` step (a
  ``while_loop`` of up to ``slice_len`` tokens with the psum-min EOS
  early exit); with a mesh the slice runs inside ``shard_map`` over the
  serving group's joint axes with the slot axis sharded and the logits
  head tensor-parallel through ``CommContext`` routing.

The slot count is ragged over the serving group
(:meth:`Scheduler.shard_geometry`, i.e. ``napalg.ragged_splits``); the
executed lowering pads every chip to ``max(geometry)`` rows — repo
idiom: ragged at the accounting layer, padded at execution — and the
scheduler simply never fills the pad slots.
"""

from __future__ import annotations

import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from .. import compat
from ..core import comm
from . import decode as _decode
from .scheduler import PromptBuckets, Request, Scheduler

__all__ = ["ServeEngine"]


class ServeEngine:
    """Continuous-batching engine for one serving replica.

    Args:
      model: a :class:`repro.models.Model` (needs the decode pair).
      params: model parameters (replicated on the mesh if given).
      num_slots: logical decode batch width (the scheduler's slot
        count).  With a mesh this is padded up to a multiple of the
        group size for the executed lowering; the pad slots are never
        scheduled.
      max_len: KV/state cache length per slot.
      buckets: padded prompt-length buckets (default: geometric up to
        ``max_len``).
      eos_id: early-exit token (None disables EOS handling).
      slice_len: decode steps per jitted slice; membership changes only
        at slice boundaries, so this is the admission latency in tokens
        (default 1: per-token boundaries, the continuous-batching
        ideal).
      mesh / ctx: serving group.  With a mesh the slice is shard_mapped
        over the mesh's joint axes and the head is tensor-parallel.
      max_queue: admission-control bound (None = unbounded).
      extras_template: abstract pytree (shape/dtype) of per-request
        extras (e.g. encoder ``frames``) for enc-dec archs; requests
        must then carry matching ``extras``.
    """

    def __init__(
        self,
        model,
        params,
        *,
        num_slots: int,
        max_len: int,
        buckets: PromptBuckets | None = None,
        eos_id: int | None = None,
        slice_len: int = 1,
        mesh=None,
        ctx: comm.CommContext | None = None,
        max_queue: int | None = None,
        extras_template: dict | None = None,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.model = model
        self.params = params
        self.max_len = int(max_len)
        self.slice_len = int(slice_len)
        self.eos_id = eos_id
        self.mesh = mesh
        self.clock = clock
        self.extras_template = extras_template
        if buckets is None:
            buckets = PromptBuckets.geometric(self.max_len)
        self.scheduler = Scheduler(
            num_slots, max_queue=max_queue, buckets=buckets, eos_id=eos_id
        )

        if mesh is not None and ctx is None:
            ctx = comm.CommContext(comm.Topology.from_mesh(mesh))
        self.ctx = ctx
        # without a mesh there is no shard_map to bind axes, so the
        # slice must trace collective-free even if a ctx was passed
        self._slice_ctx = ctx if mesh is not None else None
        self.group = ctx.topology.group if (ctx and mesh is not None) else 1
        # ragged slot geometry over the group; executed lowering pads
        # every chip to the max block
        geometry = self.scheduler.shard_geometry(self.group)
        self.b_max = max(geometry)
        self.padded_slots = self.b_max * self.group

        # -- device state --------------------------------------------------
        self._cache = self._init_slot_cache()
        self._tok = jnp.zeros((self.padded_slots, 1), jnp.int32)
        self._active = jnp.zeros((self.padded_slots,), bool)

        # -- compiled programs ---------------------------------------------
        self._prefills: dict[Any, Callable] = {}  # bucket key -> jitted fn
        self._slice = self._build_slice()
        # stacked leaf rows have exactly the B=1 leaf's shape, so the
        # scatter is a plain per-row set on every leaf
        self._scatter = jax.jit(
            lambda full, one, row: jax.tree.map(
                lambda f, o: f.at[row].set(o), full, one
            )
        )
        self._set_tok = jax.jit(
            lambda tok, active, row, t: (
                tok.at[row, 0].set(t),
                active.at[row].set(True),
            )
        )

        # -- accounting ----------------------------------------------------
        self.step_times: list[tuple[int, float, int]] = []  # fit-shaped rows
        self.n_slices = 0
        self.n_decode_steps = 0

    # -- device-state construction -----------------------------------------

    def _b1_extras(self):
        if self.extras_template is None:
            return None
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), self.extras_template
        )

    def _init_slot_cache(self):
        """Slot-stacked cache: every leaf gets a leading slot axis over
        an inner B=1 cache (the scalar ``index`` becomes ``(slots,)``)."""
        b1 = self.model.init_decode(
            self.params, 1, max_len=self.max_len, batch=self._b1_extras()
        )
        return jax.tree.map(
            lambda x: jnp.broadcast_to(
                x[None], (self.padded_slots,) + x.shape
            ),
            b1,
        )

    # -- compiled programs ---------------------------------------------------

    def _build_slice(self):
        slice_fn = _decode.make_decode_slice(
            self.model, self._slice_ctx,
            slice_len=self.slice_len, eos_id=self.eos_id,
        )
        if self.mesh is None:
            return jax.jit(slice_fn)
        joint = self.ctx.topology.axes
        spec = P(joint)  # pytree prefix: shard the leading slot axis
        fn = compat.shard_map(
            slice_fn,
            mesh=self.mesh,
            in_specs=(P(), spec, spec, spec),
            # the step count is group-agreed (early exit is min-reduced)
            out_specs=(spec, spec, spec, P()),
            check_vma=False,
        )
        return jax.jit(fn)

    def _prefill_fn(self, bucket_len: int, extras_sds):
        """Jitted B=1 bucketed prefill: ``(params, prompt (1, L), n_real
        [, extras]) -> (cache, first token (1,))``.

        Teacher-forces the padded prompt through ``decode_step`` inside a
        ``fori_loop``; a scalar ``keep = t < n_real`` where-snapshot on
        (logits, cache) freezes the state after exactly ``n_real`` real
        steps, so the result is bitwise what an unpadded prefill of the
        true prompt produces — and there is exactly one compiled trace
        per bucket length.
        """
        model = self.model

        def prefill(params, prompt, n_real, extras):
            cache = model.init_decode(
                params, 1, max_len=self.max_len, batch=extras
            )
            logits0 = jnp.zeros((1, 1, model.cfg.vocab_size), jnp.float32)

            def body(t, carry):
                logits, cache = carry
                step_tok = jax.lax.dynamic_slice(prompt, (0, t), (1, 1))
                new_logits, new_cache = model.decode_step(
                    params, cache, step_tok
                )
                keep = t < n_real
                logits = jnp.where(keep, new_logits, logits)
                cache = jax.tree.map(
                    lambda a, b: jnp.where(keep, a, b), new_cache, cache
                )
                return logits, cache

            logits, cache = jax.lax.fori_loop(
                0, bucket_len, body, (logits0, cache)
            )
            tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
            return cache, tok

        if extras_sds is None:
            return jax.jit(lambda p, pr, n: prefill(p, pr, n, None))
        return jax.jit(prefill)

    def _prefill(self, req: Request):
        key = (req.bucket_len, req.extras is not None)
        if key not in self._prefills:
            extras_sds = (
                jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                    req.extras,
                )
                if req.extras is not None
                else None
            )
            self._prefills[key] = self._prefill_fn(req.bucket_len, extras_sds)
        prompt = np.zeros((1, req.bucket_len), np.int32)
        prompt[0, : len(req.prompt)] = req.prompt
        n_real = jnp.asarray(len(req.prompt), jnp.int32)
        if req.extras is not None:
            return self._prefills[key](
                self.params, jnp.asarray(prompt), n_real, req.extras
            )
        return self._prefills[key](self.params, jnp.asarray(prompt), n_real)

    # -- request lifecycle ---------------------------------------------------

    def submit(
        self,
        prompt,
        max_new_tokens: int,
        *,
        arrival: float | None = None,
        extras: dict | None = None,
    ) -> Request:
        if (extras is None) != (self.extras_template is None):
            raise ValueError(
                "request extras must match the engine's extras_template"
            )
        return self.scheduler.submit(
            prompt,
            max_new_tokens,
            arrival=self.clock() if arrival is None else arrival,
            extras=extras,
        )

    def evict(self, rid: int) -> Request:
        req = self.scheduler.evict(rid, now=self.clock())
        self._sync_active()
        return req

    def outstanding_tokens(self) -> int:
        return self.scheduler.outstanding_tokens()

    @property
    def idle(self) -> bool:
        return self.scheduler.idle

    def _sync_active(self):
        mask = np.zeros((self.padded_slots,), bool)
        mask[: self.scheduler.num_slots] = self.scheduler.active_mask()
        self._active = jnp.asarray(mask)

    # -- the decode-step boundary -------------------------------------------

    def step(self, *, now: float | None = None) -> list[Request]:
        """One continuous-batching boundary: admit into free slots
        (B=1 bucketed prefill, scattered into slot rows), run one decode
        slice, record the emitted tokens.  Returns requests that
        *finished* during this step.  No-op (returns ``[]``) when idle.
        """
        now = self.clock() if now is None else now
        for req in self.scheduler.admit(now=now):
            cache_b1, tok0 = self._prefill(req)
            row = jnp.asarray(req.slot, jnp.int32)
            self._cache = self._scatter(self._cache, cache_b1, row)
            self._tok, self._active = self._set_tok(
                self._tok, self._active, row, tok0[0]
            )
        self._sync_active()
        if not any(self.scheduler.active_mask()):
            return []

        t0 = self.clock()
        out, self._tok, self._cache, steps = self._slice(
            self.params, self._cache, self._tok, self._active
        )
        out = np.asarray(out)
        steps_run = int(steps)
        t1 = self.clock()

        finished: list[Request] = []
        for t in range(steps_run):
            for slot in range(self.scheduler.num_slots):
                # record_token drops tokens for freed/never-filled slots,
                # so garbage rows and post-EOS columns are no-ops
                done = self.scheduler.record_token(
                    slot, int(out[slot, t]), now=t1
                )
                if done is not None:
                    finished.append(done)
        self._sync_active()

        # MachineParams.fit-shaped measurement row for the logits
        # allreduce this slice ran: (nbytes, seconds-per-step, senders).
        # Effective single-message rows: senders=1 (whole-payload time).
        if steps_run:
            nbytes = (
                self.group * self.b_max * self.model.cfg.vocab_size * 4
            )
            self.step_times.append(
                (int(nbytes), (t1 - t0) / steps_run, 1)
            )
            self.n_slices += 1
            self.n_decode_steps += steps_run
        return finished

    def run(self, *, max_steps: int = 100_000) -> dict[int, list[int]]:
        """Drive :meth:`step` until idle; returns ``rid -> tokens`` for
        every request that reached a terminal state."""
        for _ in range(max_steps):
            if self.idle:
                break
            self.step()
        else:
            raise RuntimeError(f"not idle after {max_steps} engine steps")
        return {
            rid: list(req.generated)
            for rid, req in self.scheduler.requests.items()
            if req.done
        }

    # -- introspection -------------------------------------------------------

    def dispatch_report(self) -> dict[str, dict]:
        """The (engine, chunks) decision for each decode-step collective
        at this engine's payload sizes — the per-collective dispatch
        table BENCH_9 publishes."""
        if self.ctx is None:
            return {}
        topo = self.ctx.topology
        V = self.model.cfg.vocab_size
        D = self.model.cfg.d_model
        d_cols = -(-D // max(self.group, 1))
        rows = self.group * self.b_max
        payloads = {
            "logits_allreduce": (rows * V * 4, "sum", "allreduce", None),
            "hidden_allgather": (
                rows * d_cols * self.group * 4,
                "sum",
                "allgather",
                "mla_ag" if topo.has_slow_domain else None,
            ),
            "eos_min_reduce": (4, "min", "allreduce", "psum"),
        }
        report = {}
        for name, (nbytes, op, coll, pin) in payloads.items():
            d = self.ctx.dispatch(
                int(nbytes), op, collective=coll, algorithm=pin
            )
            report[name] = {
                "nbytes": int(nbytes),
                "op": op,
                "collective": coll,
                "engine": d.engine,
                "pipeline_chunks": d.chunks,
            }
        return report

    def fit_rows(self) -> list[tuple[int, float, int]]:
        """Per-decode-step wall-clock as ``MachineParams.fit`` rows
        ``(size_bytes, seconds, senders)`` (open item 4's serving
        data)."""
        return list(self.step_times)
