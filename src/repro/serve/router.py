"""Multi-replica data-parallel request router for the serving spine.

Spreads requests over independent serving replicas (each a
:class:`repro.serve.engine.ServeEngine`, or anything exposing the same
``submit`` / ``outstanding_tokens`` / ``scheduler`` surface) by
**outstanding-token load** — the token budget still owed by a replica's
queue plus its active slots, the quantity that actually predicts its
drain time under continuous batching (queue *length* does not: one
queued 4k-token request outweighs ten 8-token ones).

Health is driven by :class:`repro.runtime.fault.ReplicaHealth` straggler
signals: feed per-slice step times in with :meth:`observe_step`; when a
replica degrades (a straggler event), the router stops routing to it
and **reroutes its queued requests** to healthy replicas — queued only:
active requests keep their slots (their KV state lives on the degraded
replica; rerouting them would re-prefill, usually slower than riding
out the stall).  ``recovery`` consecutive clean steps readmit it.
"""

from __future__ import annotations

from ..runtime.fault import ReplicaHealth, StragglerMonitor
from .scheduler import REJECTED, Request

__all__ = ["Router"]


class Router:
    """Load-based router over serving replicas.

    Args:
      replicas: the serving engines (index order is the tiebreak order).
      health: optional per-replica :class:`ReplicaHealth`; by default
        each replica gets one with a fresh :class:`StragglerMonitor`.
    """

    def __init__(
        self,
        replicas,
        *,
        health: list[ReplicaHealth] | None = None,
        straggler_threshold: float = 2.0,
        recovery: int = 5,
    ):
        if not replicas:
            raise ValueError("need at least one replica")
        self.replicas = list(replicas)
        if health is None:
            health = [
                ReplicaHealth(
                    StragglerMonitor(threshold=straggler_threshold),
                    recovery=recovery,
                )
                for _ in self.replicas
            ]
        if len(health) != len(self.replicas):
            raise ValueError("one ReplicaHealth per replica")
        self.health = health
        self.placement: dict[int, int] = {}  # rid -> replica index
        self.n_rerouted = 0

    # -- routing -----------------------------------------------------------

    def _eligible(self) -> list[int]:
        healthy = [
            i for i, h in enumerate(self.health) if h.healthy
        ]
        # all degraded: route anyway (stalled beats dropped)
        return healthy or list(range(len(self.replicas)))

    def pick(self) -> int:
        """Least-loaded eligible replica (lowest index breaks ties)."""
        return min(
            self._eligible(),
            key=lambda i: (self.replicas[i].outstanding_tokens(), i),
        )

    def submit(self, prompt, max_new_tokens: int, **kw) -> Request:
        i = self.pick()
        req = self.replicas[i].submit(prompt, max_new_tokens, **kw)
        if req.state != REJECTED:
            self.placement[req.rid] = i
        return req

    # -- health signals ----------------------------------------------------

    def observe_step(self, replica: int, step: int, duration: float) -> bool:
        """Feed one decode-slice wall-clock for ``replica``; on a
        health transition to degraded, reroute its queued requests.
        Returns the replica's post-update health."""
        was = self.health[replica].healthy
        ok = self.health[replica].record(step, duration)
        if was and not ok:
            self.reroute(replica)
        return ok

    def reroute(self, replica: int) -> int:
        """Move ``replica``'s queued (not yet active) requests to the
        healthiest least-loaded peers.  Returns how many moved."""
        eligible = [i for i in self._eligible() if i != replica]
        if not eligible:
            return 0
        moved = 0
        for req in self.replicas[replica].scheduler.drain_queue():
            dst = min(
                eligible,
                key=lambda i: (self.replicas[i].outstanding_tokens(), i),
            )
            out = self.replicas[dst].scheduler.enqueue(req)
            if out.state != REJECTED:
                self.placement[req.rid] = dst
                moved += 1
        self.n_rerouted += moved
        return moved

    # -- views -------------------------------------------------------------

    def loads(self) -> list[int]:
        return [r.outstanding_tokens() for r in self.replicas]

    @property
    def idle(self) -> bool:
        return all(r.idle for r in self.replicas)
