"""Multi-replica data-parallel request router for the serving spine.

Spreads requests over independent serving replicas (each a
:class:`repro.serve.engine.ServeEngine`, or anything exposing the same
``submit`` / ``outstanding_tokens`` / ``scheduler`` surface) by
**outstanding-token load** — the token budget still owed by a replica's
queue plus its active slots, the quantity that actually predicts its
drain time under continuous batching (queue *length* does not: one
queued 4k-token request outweighs ten 8-token ones).

Health is driven by :class:`repro.runtime.fault.ReplicaHealth` straggler
signals: feed per-slice step times in with :meth:`observe_step`; when a
replica degrades (a straggler event), the router stops routing to it
and **reroutes its queued requests** to healthy replicas — queued only:
active requests keep their slots (their KV state lives on the degraded
replica; rerouting them would re-prefill, usually slower than riding
out the stall).  ``recovery`` consecutive clean steps readmit it.

A replica *death* is harsher than a stall: :meth:`fail_replica` re-plans
everything the dead replica held — queued requests move like a reroute,
active ones are demoted back to QUEUED (their KV state died with the
replica) and re-queued on survivors, bypassing the backpressure bound
(transiently overshooting ``max_queue`` beats dropping accepted work).

Every placement decision is **fully deterministic**: candidates are
scanned as ascending replica indices and ties break on the stable
index, never on dict/set iteration order — so an event trace recorded
by the layer-0 protocol checker (:mod:`repro.analysis.protocol_check`)
replays bit-identically.  Two protocol invariants the checker pins:

* **acceptance is binding** — once a request is QUEUED somewhere it is
  never silently REJECTED by a reroute into a full peer queue; if no
  peer has capacity the request stays (still accepted) where it is;
* **single ownership** — a live rid is registered with exactly one
  scheduler, so an evict can never race a reroute through a stale
  registry entry.
"""

from __future__ import annotations

from ..runtime.fault import ReplicaHealth, StragglerMonitor
from .scheduler import REJECTED, Request

__all__ = ["Router"]


class Router:
    """Load-based router over serving replicas.

    Args:
      replicas: the serving engines (index order is the tiebreak order).
      health: optional per-replica :class:`ReplicaHealth`; by default
        each replica gets one with a fresh :class:`StragglerMonitor`.
    """

    def __init__(
        self,
        replicas,
        *,
        health: list[ReplicaHealth] | None = None,
        straggler_threshold: float = 2.0,
        recovery: int = 5,
    ):
        if not replicas:
            raise ValueError("need at least one replica")
        self.replicas = list(replicas)
        if health is None:
            health = [
                ReplicaHealth(
                    StragglerMonitor(threshold=straggler_threshold),
                    recovery=recovery,
                )
                for _ in self.replicas
            ]
        if len(health) != len(self.replicas):
            raise ValueError("one ReplicaHealth per replica")
        self.health = health
        self.placement: dict[int, int] = {}  # rid -> replica index
        self.failed: set[int] = set()        # dead replicas (fail_replica)
        self.n_rerouted = 0

    # -- routing -----------------------------------------------------------

    def _eligible(self) -> list[int]:
        alive = [
            i for i in range(len(self.replicas)) if i not in self.failed
        ]
        if not alive:
            raise RuntimeError("all replicas have failed")
        healthy = [i for i in alive if self.health[i].healthy]
        # all degraded: route anyway (stalled beats dropped)
        return healthy or alive

    def _place(self, candidates: list[int]) -> int:
        """Deterministic placement: least outstanding tokens, ties
        broken by the stable replica index.  ``candidates`` is always
        an ascending index list — never dict/set iteration order — so
        recorded traces replay bit-identically."""
        return min(
            candidates,
            key=lambda i: (self.replicas[i].outstanding_tokens(), i),
        )

    def _with_capacity(self, candidates: list[int]) -> list[int]:
        return [
            i
            for i in candidates
            if self.replicas[i].scheduler.queue_capacity != 0
        ]

    def pick(self) -> int:
        """Least-loaded eligible replica (lowest index breaks ties),
        preferring replicas with queue capacity: a submit is only
        rejected when *no* eligible replica can accept it, not because
        the least-loaded one happens to be full."""
        eligible = self._eligible()
        roomy = self._with_capacity(eligible)
        return self._place(roomy or eligible)

    def submit(self, prompt, max_new_tokens: int, **kw) -> Request:
        i = self.pick()
        req = self.replicas[i].submit(prompt, max_new_tokens, **kw)
        if req.state != REJECTED:
            self.placement[req.rid] = i
        return req

    def evict(self, rid: int) -> Request:
        """Cancel a request wherever it currently lives — placement is
        kept reroute-accurate, so callers need not track which replica
        owns a rid."""
        return self.replicas[self.placement[rid]].scheduler.evict(rid)

    # -- health signals ----------------------------------------------------

    def observe_step(self, replica: int, step: int, duration: float) -> bool:
        """Feed one decode-slice wall-clock for ``replica``; on a
        health transition to degraded, reroute its queued requests.
        Returns the replica's post-update health."""
        was = self.health[replica].healthy
        ok = self.health[replica].record(step, duration)
        if was and not ok:
            self.reroute(replica)
        return ok

    def reroute(self, replica: int) -> int:
        """Move ``replica``'s queued (not yet active) requests to the
        healthiest least-loaded peers **with queue capacity**; a
        request no peer can hold stays (still accepted, FIFO position
        preserved) on the degraded replica — acceptance is binding, so
        a reroute never turns an accepted request REJECTED.  Returns
        how many moved."""
        src = self.replicas[replica].scheduler
        eligible = [i for i in self._eligible() if i != replica]
        if not eligible:
            return 0
        moved = 0
        for req in src.drain_queue():
            roomy = self._with_capacity(eligible)
            if roomy:
                dst = self._place(roomy)
                self.replicas[dst].scheduler.enqueue(req)
                self.placement[req.rid] = dst
                moved += 1
            else:
                src.enqueue(req, force=True)
        self.n_rerouted += moved
        return moved

    def fail_replica(self, replica: int) -> int:
        """Replica death: re-plan everything it held onto survivors.

        Queued requests move like a reroute; ACTIVE ones are demoted
        back to QUEUED (:meth:`Scheduler.drain_active` — their KV state
        died with the replica, survivors re-prefill) and re-queued
        behind them.  Placement is force-enqueued past the survivors'
        backpressure bound: transiently overshooting ``max_queue`` is
        recoverable, dropping accepted work is not.  The dead replica
        never receives traffic again.  Returns how many requests were
        re-planned; raises ``RuntimeError`` if no replica survives.
        """
        self.failed.add(replica)
        sched = self.replicas[replica].scheduler
        peers = self._eligible()  # excludes the newly failed replica
        moved = 0
        # actives first: they were admitted before anything queued, so
        # re-queuing them ahead preserves arrival-order fairness
        for req in sched.drain_active() + sched.drain_queue():
            dst = self._place(peers)
            self.replicas[dst].scheduler.enqueue(req, force=True)
            self.placement[req.rid] = dst
            moved += 1
        self.n_rerouted += moved
        return moved

    # -- views -------------------------------------------------------------

    def loads(self) -> list[int]:
        return [r.outstanding_tokens() for r in self.replicas]

    @property
    def idle(self) -> bool:
        return all(r.idle for r in self.replicas)
