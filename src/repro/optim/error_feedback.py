"""Error-feedback residual state for compressed gradient transport.

EF-SGD style compensation (Seide et al. 2014; Karimireddy et al. 2019)
adapted to the quantized transport engine in
:mod:`repro.core.grad_sync`: each chip keeps a float32 residual per
gradient leaf, adds it to the local gradient *before* the quantized
sync (``c = g + r``), and stores back its share of what the wire could
not represent.  Unlike plain EF-SGD — where each worker quantizes its
own message and ``r' = c - Q(c)`` is local by construction — the
two-level transport quantizes *sums* (the node sum on the chip's
stripe, the group sum on its block), so the executor measures the
rounding error exactly at those compression points and hands each
chip the piece it alone produced (see
:func:`repro.core.grad_sync._compressed_fused_allreduce`).  Summed
over the group the residuals equal the true quantisation error, which
re-enters the next step's gradient instead of being lost — what lets
4-bit transport track uncompressed convergence instead of stalling at
the quantization noise floor.

The residual is *per-chip local state* — it must never be averaged or
replicated across data-parallel ranks (each chip compensates its own
contribution).  :func:`repro.launch.steps.make_dp_train_step` carries it
in the train state under ``"ef"`` with a leading group axis sharded over
the mesh, and :meth:`repro.core.comm.CommContext.sync_grads` threads it
through the executor (``ef_state=``) which returns the updated tree.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["ef_init", "ef_residual"]


def ef_init(params: Any, *, group: int | None = None) -> Any:
    """Zero residual tree matching ``params`` (float32 leaves).

    With ``group=None`` the residuals mirror the per-chip leaf shapes —
    the form :func:`repro.core.grad_sync.sync_with_context` consumes
    inside ``shard_map``.  With ``group=G`` every leaf gains a leading
    ``G`` axis: the *global* form for a train state whose per-chip slices
    are laid out along the mesh (spec ``P(mesh_axes)``), since residuals
    differ per chip and must not be stored replicated.

    Integer leaves get a residual too (kept identically zero by the
    executor) so the residual tree always matches the gradient tree
    structure leaf-for-leaf.
    """

    def zeros(p):
        shape = tuple(p.shape)
        if group is not None:
            shape = (int(group),) + shape
        return jnp.zeros(shape, jnp.float32)

    return jax.tree.map(zeros, params)


def ef_residual(c: jax.Array, scale, qmax: float) -> jax.Array:
    """``c - Q(c)``: what a round-to-nearest clip quantizer at ``scale``
    drops from ``c`` — the analytic single-scale residual (tests use it
    as the reference for the executor's measured errors; kept in pure
    f32 jnp, no integer casts)."""
    c = c.astype(jnp.float32)
    q = jnp.clip(jnp.round(c / scale), -float(qmax), float(qmax))
    return c - q * scale
