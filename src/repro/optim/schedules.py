"""LR schedules: cosine, constant, and WSD (minicpm's warmup-stable-decay)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["make_schedule"]


def make_schedule(cfg):
    """cfg: OptimizerConfig -> step -> lr (traced-friendly)."""
    warm, base = cfg.warmup_steps, cfg.lr

    if cfg.schedule == "constant":
        def sched(step):
            frac = jnp.minimum(step / jnp.maximum(warm, 1), 1.0)
            return base * frac
        return sched

    if cfg.schedule == "cosine":
        decay = jnp.maximum(cfg.decay_steps, 1)

        def sched(step):
            wfrac = jnp.minimum(step / jnp.maximum(warm, 1), 1.0)
            t = jnp.clip((step - warm) / decay, 0.0, 1.0)
            cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
            return base * wfrac * (0.1 + 0.9 * cos)
        return sched

    if cfg.schedule == "wsd":
        # MiniCPM WSD: linear warmup, long stable plateau, sharp
        # exponential-ish decay tail (arXiv:2404.06395 §4).
        stable = jnp.maximum(cfg.stable_steps, 1)
        decay = jnp.maximum(cfg.decay_steps, 1)

        def sched(step):
            wfrac = jnp.minimum(step / jnp.maximum(warm, 1), 1.0)
            in_decay = step > (warm + stable)
            t = jnp.clip((step - warm - stable) / decay, 0.0, 1.0)
            tail = 0.5 ** (t * 10.0)  # ~3 decades over the decay window
            return base * wfrac * jnp.where(in_decay, tail, 1.0)
        return sched

    raise ValueError(f"unknown schedule {cfg.schedule!r}")
