from .adamw import AdamWState, adamw_init, adamw_update, global_norm
from .schedules import make_schedule

__all__ = [
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "global_norm",
    "make_schedule",
]
