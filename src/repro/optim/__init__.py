from .adamw import AdamWState, adamw_init, adamw_update, global_norm
from .error_feedback import ef_init, ef_residual
from .schedules import make_schedule

__all__ = [
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "global_norm",
    "ef_init",
    "ef_residual",
    "make_schedule",
]
