"""AdamW with global-norm clipping and configurable moment dtype.

The global-norm computation is itself a latency-bound small allreduce in
the explicit-collectives training path — one of the paper's canonical
workloads (a single scalar over all DP chips).

Moment dtype is configurable (``bfloat16`` for the 398B jamba config so
optimizer state fits a 256-chip pod: 398e9 * (4+2+2) B / 256 ≈ 12.4 GB).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWState", "adamw_init", "adamw_update", "global_norm"]


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def adamw_init(params, *, moment_dtype: str = "float32") -> AdamWState:
    md = jnp.dtype(moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, md)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def global_norm(tree) -> jax.Array:
    leaves = [
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(tree)
    ]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    grads,
    state: AdamWState,
    params,
    *,
    lr,
    betas=(0.9, 0.95),
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float | None = 1.0,
):
    """Returns (new_params, new_state, metrics)."""
    b1, b2 = betas
    gnorm = global_norm(grads)
    if grad_clip is not None:
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + g32 * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + jnp.square(g32) * (1 - b2)
        update = (m32 / c1) / (jnp.sqrt(v32 / c2) + eps)
        decay = weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr * (update + decay)
        return new_p.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm}
