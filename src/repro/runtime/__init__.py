from .fault import ResumableLoop, StragglerMonitor, elastic_remesh

__all__ = ["ResumableLoop", "StragglerMonitor", "elastic_remesh"]
