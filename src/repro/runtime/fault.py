"""Fault-tolerant training runtime: restart, stragglers, elastic re-mesh.

Pieces a 1000-node deployment needs around the pure train step:

* :class:`ResumableLoop` — drives the step function with periodic
  (async, atomic) checkpoints and auto-resume: on construction it
  restores the newest intact checkpoint, so a SIGKILL/OOM/preemption
  costs at most ``checkpoint_every`` steps.  Transient step failures
  (the CPU analogue of a flaky ICI link) are retried from the last
  checkpoint up to ``max_retries`` times.
* :class:`StragglerMonitor` — EWMA step-time tracker; steps slower than
  ``threshold`` x EWMA emit structured events.  On a real pod the event
  hook triggers hot-spare swap / re-shard; here events are recorded and
  surfaced (tested by injecting a slow step).
* :func:`elastic_remesh` — rebuilds state for a different device count:
  template shapes stay global, only shardings change, so restoring a
  16x16-pod checkpoint onto 2x16x16 (scale-up) or 8x16 (degraded pod,
  scale-down) is the same code path as restart.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable

import jax

from ..checkpoint.manager import CheckpointManager

log = logging.getLogger("repro.runtime")

__all__ = [
    "StragglerMonitor",
    "ReplicaHealth",
    "ResumableLoop",
    "elastic_remesh",
]


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration: float
    ewma: float
    ratio: float


class StragglerMonitor:
    """EWMA-based detection of slow steps (stragglers)."""

    def __init__(self, threshold: float = 2.0, alpha: float = 0.2,
                 warmup: int = 3, on_event: Callable | None = None):
        self.threshold = threshold
        self.alpha = alpha
        self.warmup = warmup
        self.on_event = on_event
        self.ewma: float | None = None
        self.count = 0
        self.events: list[StragglerEvent] = []

    def record(self, step: int, duration: float) -> StragglerEvent | None:
        self.count += 1
        if self.ewma is None:
            self.ewma = duration
            return None
        event = None
        if self.count > self.warmup and duration > self.threshold * self.ewma:
            event = StragglerEvent(
                step, duration, self.ewma, duration / self.ewma
            )
            self.events.append(event)
            log.warning(
                "straggler: step %d took %.3fs (%.1fx EWMA %.3fs)",
                step, duration, event.ratio, self.ewma,
            )
            if self.on_event:
                self.on_event(event)
            # quarantine: do not poison the EWMA with the outlier
            return event
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * duration
        return event


class ReplicaHealth:
    """Straggler-signal-driven health state for one serving replica.

    Wraps a :class:`StragglerMonitor` with the hysteresis the serving
    router needs: a straggler event marks the replica **degraded** (the
    router stops routing to it and reroutes its queue); ``recovery``
    consecutive clean steps mark it healthy again.  A plain counter
    would flap — one fast step after a stall is not a recovery.

    The boundary is exact — healthy flips back on the ``recovery``-th
    consecutive clean step, never one early or late — and is pinned at
    every reachable state by the layer-0 protocol checker
    (:mod:`repro.analysis.protocol_check`), which asserts the
    post-state of each clean step against ``recovery`` directly.
    """

    def __init__(
        self,
        monitor: StragglerMonitor | None = None,
        *,
        recovery: int = 5,
    ):
        if recovery < 1:
            raise ValueError(f"recovery must be >= 1, got {recovery}")
        self.monitor = monitor or StragglerMonitor()
        self.recovery = recovery
        self.healthy = True
        self._clean = 0
        self.n_degraded = 0  # degradation episodes (router telemetry)

    def record(self, step: int, duration: float) -> bool:
        """Feed one step time; returns the post-update health."""
        event = self.monitor.record(step, duration)
        if event is not None:
            if self.healthy:
                self.n_degraded += 1
            self.healthy = False
            self._clean = 0
        elif not self.healthy:
            self._clean += 1
            if self._clean >= self.recovery:
                self.healthy = True
                self._clean = 0
        return self.healthy


class ResumableLoop:
    """Checkpointed, auto-resuming, retrying training loop driver."""

    def __init__(
        self,
        *,
        step_fn: Callable[[Any, int], tuple[Any, dict]],
        make_state: Callable[[], Any],
        ckpt: CheckpointManager,
        checkpoint_every: int = 50,
        max_retries: int = 2,
        monitor: StragglerMonitor | None = None,
    ):
        self.step_fn = step_fn
        self.ckpt = ckpt
        self.checkpoint_every = checkpoint_every
        self.max_retries = max_retries
        self.monitor = monitor or StragglerMonitor()
        self.metrics_log: list[dict] = []

        template = make_state()
        restored, meta = ckpt.restore_latest(template)
        if restored is not None:
            self.state = restored
            self.start_step = int(meta["step"]) + 1
            log.info("resumed from checkpoint step %d", meta["step"])
        else:
            self.state = template
            self.start_step = 0

    def run(self, until_step: int) -> Any:
        step = self.start_step
        retries = 0
        while step < until_step:
            t0 = time.perf_counter()
            try:
                self.state, metrics = self.step_fn(self.state, step)
            except Exception as e:  # transient failure -> restore + retry
                retries += 1
                log.error("step %d failed (%s); retry %d", step, e, retries)
                if retries > self.max_retries:
                    raise
                restored, meta = self.ckpt.restore_latest(self.state)
                if restored is not None:
                    self.state = restored
                    step = int(meta["step"]) + 1
                continue
            retries = 0
            dt = time.perf_counter() - t0
            self.monitor.record(step, dt)
            self.metrics_log.append({"step": step, "time_s": dt, **metrics})
            if (
                self.checkpoint_every
                and (step + 1) % self.checkpoint_every == 0
            ):
                self.ckpt.save(step, self.state, meta={"loop": "resumable"})
            step += 1
        self.ckpt.wait()
        self.start_step = step
        return self.state


def elastic_remesh(ckpt: CheckpointManager, make_template: Callable[[], Any]):
    """Restore the newest checkpoint into a *new* mesh's template.

    ``make_template`` builds the state skeleton under the new mesh (e.g.
    after losing a pod or adding one); global shapes are mesh-independent,
    so restore == reshard.  Returns (state, meta) or (None, None).
    """
    return ckpt.restore_latest(make_template())
