"""Deterministic synthetic LM data pipeline.

Design goals for the 1000-node regime:

* **Stateless addressing**: batch ``i`` is a pure function of
  ``(seed, step)`` — any host can (re)produce its shard without global
  coordination, so restarts and elastic re-meshes are bitwise
  reproducible (no data-order drift after failover).
* **Sharded placement**: batches are built per-host and placed with the
  mesh's batch sharding (``jax.device_put`` with NamedSharding).
* **Prefetch**: a small background thread keeps ``depth`` batches ahead.

The token distribution is a mixture of Zipfian unigrams and short
repeated motifs — enough structure that a ~100M model's loss visibly
drops within a few hundred steps (used by examples/train_lm.py).
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["SyntheticLM", "Prefetcher"]


@dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mesh: Mesh | None = None
    batch_axes: tuple[str, ...] = ()

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step])
        )

    def batch(self, step: int) -> dict:
        """Materialise batch ``step`` (host-side numpy)."""
        rng = self._rng(step)
        B, S, V = self.global_batch, self.seq_len, self.vocab_size
        # zipfian unigrams
        ranks = rng.zipf(1.3, size=(B, S)).astype(np.int64)
        tokens = np.minimum(ranks, V - 1).astype(np.int32)
        # motif injection: repeat a short pattern somewhere in each row
        motif_len = min(16, S // 2)
        motif = rng.integers(0, V, size=(B, motif_len), dtype=np.int32)
        start = rng.integers(0, max(1, S - 2 * motif_len), size=B)
        for b in range(B):
            s0 = start[b]
            tokens[b, s0 : s0 + motif_len] = motif[b]
            tokens[b, s0 + motif_len : s0 + 2 * motif_len] = motif[b]
        labels = np.concatenate(
            [tokens[:, 1:], np.zeros((B, 1), np.int32)], axis=1
        )
        mask = np.ones((B, S), np.float32)
        mask[:, -1] = 0.0
        out = {"tokens": tokens, "labels": labels, "loss_mask": mask}
        return self._place(out)

    def _place(self, batch: dict) -> dict:
        if self.mesh is None:
            return {k: jax.numpy.asarray(v) for k, v in batch.items()}
        ax = self.batch_axes or None
        sh = NamedSharding(self.mesh, P(ax, None))
        return {k: jax.device_put(v, sh) for k, v in batch.items()}


class Prefetcher:
    """Background prefetch of ``depth`` batches (thread + queue)."""

    def __init__(self, source: SyntheticLM, start_step: int = 0, depth: int = 2):
        self._source = source
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._source.batch(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self) -> tuple[int, dict]:
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
