from .pipeline import Prefetcher, SyntheticLM

__all__ = ["Prefetcher", "SyntheticLM"]
