"""Rule-based lint over compiled-step HLO (and jaxpr text).

Promotes the structural parser of :mod:`repro.launch.hlo_analysis` into
reusable wire rules, so HLO regressions live in one place instead of
ad-hoc regexes per test:

* :func:`lint_compressed_wire` — a ``compress_bits``-configured
  gradient sync must put the compressed dtype (``s8`` at 5-8 bits,
  packed ``u8`` below) on its collectives and must never move a
  wide-integer or payload-sized float across the wire.
* :func:`lint_collective_counts` — op-count budgets (e.g. the fused
  bucket path stays exactly 4 ``pallas_call`` sites per bucket no
  matter how many leaves it fuses).
* :func:`lint_stable_lowering` — lowering the same function twice must
  produce identical text; a divergence means tracing captures varying
  state and the train loop would silently recompile every step.
* :func:`lint_replica_groups` — every collective's replica groups must
  exactly partition the device set: no device in two groups (double
  participation deadlocks or double-counts), no device missing (a rank
  that never joins hangs the group), none out of range.

Rules return a list of :class:`LintViolation` (empty = clean) so a
driver can aggregate them into a report; the ``assert_clean`` helper
turns them into one readable failure for test use.
"""

from __future__ import annotations

import dataclasses
import re

from ..launch.hlo_analysis import CollectiveOp, iter_collectives  # noqa: F401

__all__ = [
    "LintViolation",
    "collective_ops",
    "lint_compressed_wire",
    "lint_collective_counts",
    "lint_stable_lowering",
    "lint_replica_groups",
    "assert_clean",
]


@dataclasses.dataclass(frozen=True)
class LintViolation:
    """One lint rule violation on a compiled module."""

    rule: str
    message: str

    def to_row(self) -> dict:
        return {"rule": self.rule, "message": self.message}


def collective_ops(hlo_text: str) -> list[CollectiveOp]:
    """All collective instructions of a module (while bodies included)."""
    return list(iter_collectives(hlo_text))


#: integer dtypes wider than the widest compressed wire word — none of
#: these ever belongs on a compressed transport collective
_WIDE_INT = frozenset({"s16", "u16", "s32", "u32", "s64", "u64"})
_WIDE_FLOAT = frozenset({"f32", "f64"})


def expected_wire_dtype(bits: int) -> str:
    """The on-wire dtype of ``bits``-bit compressed transport: ``s8``
    holds one 5-8 bit word per byte, ``u8`` packs two <=4-bit nibbles."""
    if not 2 <= bits <= 8:
        raise ValueError(f"compressed transport is 2..8 bits, got {bits}")
    return "s8" if bits >= 5 else "u8"


def _is_intra_node(c: CollectiveOp, ppn: int | None) -> bool:
    """Whether every replica group of ``c`` stays inside one node
    (devices grouped as ``device // ppn``).  Iota-format groups (not
    parsed into explicit lists) are conservatively treated as
    inter-node."""
    if ppn is None or not c.replica_groups:
        return False
    return all(
        len({d // ppn for d in g}) <= 1 for g in c.replica_groups
    )


def lint_compressed_wire(
    hlo_text: str,
    *,
    bits: int,
    payload_elems: int | None = None,
    ppn: int | None = None,
) -> list[LintViolation]:
    """Wire-dtype rules for a ``bits``-bit compressed collective step.

    * the compressed dtype must actually appear on a collective (a
      compiled step that quantizes but ships f32 is silently paying the
      full wire cost);
    * no collective moves a wide-integer payload (``s32`` is legal for
      Pallas index math *outside* collectives, so the rule is scoped to
      collective shapes — plus a whole-text ``s16``/payload-sized
      ``s32`` screen matching the historical regression);
    * with ``payload_elems``, no *inter-node* collective moves a
      payload-sized float tensor (the uncompressed-gradient leak).
      Compression pays on the slow domain only: with ``ppn`` given,
      collectives whose replica groups stay inside one node (the intra
      RS/AG phases, which are f32 by design) are exempt.
    """
    out: list[LintViolation] = []
    want = expected_wire_dtype(bits)
    cols = collective_ops(hlo_text)

    if cols:
        if not any(want in c.dtypes for c in cols):
            out.append(
                LintViolation(
                    "wire-dtype",
                    f"no collective carries the {want} wire dtype "
                    f"expected for {bits}-bit compressed transport "
                    f"({len(cols)} collectives inspected)",
                )
            )
        for c in cols:
            for d in c.dtypes:
                if d in _WIDE_INT:
                    out.append(
                        LintViolation(
                            "wire-dtype",
                            f"collective {c.name} ({c.op}) in "
                            f"{c.computation} moves a wide-integer "
                            f"{d} payload: {c.shape}",
                        )
                    )
                elif (
                    d in _WIDE_FLOAT
                    and payload_elems is not None
                    and c.elems >= payload_elems
                    and not _is_intra_node(c, ppn)
                ):
                    out.append(
                        LintViolation(
                            "wire-dtype",
                            f"collective {c.name} ({c.op}) in "
                            f"{c.computation} moves a payload-sized "
                            f"{d} tensor ({c.elems} elems >= "
                            f"{payload_elems}): uncompressed wire",
                        )
                    )
    elif f"{want}[" not in hlo_text:
        # no parseable collectives (e.g. jaxpr text or single-device
        # lowering): fall back to the text-level dtype screen
        out.append(
            LintViolation(
                "wire-dtype",
                f"{want}[ absent from the lowering text (expected for "
                f"{bits}-bit compressed transport)",
            )
        )

    # whole-text screens, independent of collective parsing: s16 has no
    # legitimate producer anywhere in these modules, and a payload-sized
    # s32 tensor is the classic unpacked-wire regression
    if "s16[" in hlo_text:
        out.append(
            LintViolation(
                "wire-dtype",
                "s16[ appears in the lowering: some wire word was "
                "widened to 16-bit",
            )
        )
    if payload_elems is not None and f"s32[{payload_elems}]" in hlo_text:
        out.append(
            LintViolation(
                "wire-dtype",
                f"s32[{payload_elems}] appears in the lowering: a "
                "payload-sized unpacked integer tensor survived "
                "(index math is fine, payload-sized s32 is not)",
            )
        )
    return out


#: iota-format replica groups, ``replica_groups=[num_groups,group_size]``
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def lint_replica_groups(
    hlo_text: str, *, num_devices: int
) -> list[LintViolation]:
    """Every collective's replica groups must *partition* the devices.

    For explicit groups (``replica_groups={{0,1},{2,3}}``) the rule
    checks the three partition axioms directly: no device appears in
    two groups (double participation — the op double-counts or
    deadlocks), no device in ``range(num_devices)`` is missing (an
    absent rank never joins and the group hangs waiting for it), and
    no member lies outside the device range.  For the iota form
    (``replica_groups=[num_groups,group_size]``) the partition is
    structural by construction, so only the product is checked against
    ``num_devices``.  Collectives with no ``replica_groups`` attribute
    use the single implicit all-devices group, which always partitions.
    """
    out: list[LintViolation] = []
    want = set(range(num_devices))
    for c in iter_collectives(hlo_text):
        where = f"collective {c.name} ({c.op}) in {c.computation}"
        if c.replica_groups:
            seen: dict[int, int] = {}
            for g in c.replica_groups:
                for d in g:
                    seen[d] = seen.get(d, 0) + 1
            dup = sorted(d for d, n in seen.items() if n > 1)
            if dup:
                out.append(
                    LintViolation(
                        "replica-groups",
                        f"{where}: devices {dup} appear in more than "
                        f"one replica group (overlap): "
                        f"{c.replica_groups}",
                    )
                )
            bogus = sorted(set(seen) - want)
            if bogus:
                out.append(
                    LintViolation(
                        "replica-groups",
                        f"{where}: devices {bogus} are outside the "
                        f"{num_devices}-device range: "
                        f"{c.replica_groups}",
                    )
                )
            missing = sorted(want - set(seen))
            if missing:
                out.append(
                    LintViolation(
                        "replica-groups",
                        f"{where}: devices {missing} appear in no "
                        f"replica group (gap): {c.replica_groups}",
                    )
                )
        else:
            m = _IOTA_GROUPS_RE.search(c.rest)
            if m:
                n_g, g_sz = int(m.group(1)), int(m.group(2))
                if n_g * g_sz != num_devices:
                    out.append(
                        LintViolation(
                            "replica-groups",
                            f"{where}: iota replica_groups "
                            f"[{n_g},{g_sz}] cover {n_g * g_sz} "
                            f"devices, module has {num_devices}",
                        )
                    )
    return out


def lint_collective_counts(
    text: str, budgets: dict[str, int | tuple[int, int]]
) -> list[LintViolation]:
    """Op-count budgets over HLO or jaxpr text.

    ``budgets`` maps an op key to an exact expected count or an
    inclusive ``(lo, hi)`` range.  Keys naming HLO collectives
    (``all-reduce`` etc.) are counted on the parsed module (async
    ``-start`` forms folded in); any other key is a plain substring
    count, which is how ``pallas_call`` sites are counted in jaxpr
    text.
    """
    out: list[LintViolation] = []
    cols = None
    for key, budget in budgets.items():
        lo, hi = budget if isinstance(budget, tuple) else (budget, budget)
        if key in ("all-gather", "all-reduce", "reduce-scatter",
                   "all-to-all", "collective-permute"):
            if cols is None:
                cols = collective_ops(text)
            count = sum(1 for c in cols if c.kind == key)
        else:
            count = text.count(key)
        if not lo <= count <= hi:
            want = str(lo) if lo == hi else f"[{lo}, {hi}]"
            out.append(
                LintViolation(
                    "collective-count",
                    f"{count} x {key!r}, budget {want}",
                )
            )
    return out


def lint_stable_lowering(fn, *args, **kwargs) -> list[LintViolation]:
    """Lower ``fn`` twice and require byte-identical text.

    A function whose trace captures varying state (a closure counter, a
    fresh constant per call) lowers differently each time — under
    ``jax.jit`` that is a silent recompile on every train step.  jax is
    imported lazily so the rule module stays import-light.
    """
    import jax

    def _lower_once():
        # a fresh wrapper object per lowering defeats the jit trace
        # cache (keyed on function identity) so fn really traces twice
        def _w(*a, **k):
            return fn(*a, **k)

        return jax.jit(_w).lower(*args, **kwargs).as_text()

    first = _lower_once()
    second = _lower_once()
    if first == second:
        return []
    diff_at = next(
        (i for i, (a, b) in enumerate(zip(first, second)) if a != b),
        min(len(first), len(second)),
    )
    ctx = first[max(0, diff_at - 60) : diff_at + 60].strip()
    return [
        LintViolation(
            "stable-lowering",
            "lowering the same function twice produced different text "
            f"(first divergence near char {diff_at}: ...{ctx}...) — "
            "the traced function captures varying state and would "
            "silently recompile every step",
        )
    ]


def assert_clean(violations: list[LintViolation], context: str = "") -> None:
    """Raise ``AssertionError`` listing every violation (test helper)."""
    if violations:
        head = f"{context}: " if context else ""
        raise AssertionError(
            head
            + f"{len(violations)} lint violation(s):\n"
            + "\n".join(f"  [{v.rule}] {v.message}" for v in violations)
        )
