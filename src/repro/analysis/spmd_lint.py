"""SPMD jaxpr lint: prove the *executed* lowerings match the verified schedules.

The schedule verifier (:mod:`repro.analysis.schedule_verifier`) proves an
engine's *schedule object* correct and the HLO wire-lint
(:mod:`repro.analysis.hlo_lint`) checks the *compiled text* — this module
analyzes the layer in between: the traced jaxpr, the SPMD program we
actually run.  A dataflow walker recurses through ``pjit`` / ``shard_map``
/ ``scan`` / ``while`` / ``cond`` sub-jaxprs carrying, per value, the set
of mesh axes it may *vary* over, and proves four rule families:

1. **collective-uniformity** — every collective primitive (``psum``,
   ``ppermute``, ``all_to_all``, ``all_gather``, ``reduce_scatter``,
   transport ``pallas_call``) is reached uniformly across ranks: never
   under a ``cond``/``while`` predicate whose dataflow cone includes
   rank-varying values (``axis_index``, un-reduced device data).  A
   collective some group members skip deadlocks even when its schedule
   is a proven DAG — this is the static hang detector.
2. **axis-discipline** — collective axis names resolve against the
   declared topology axes, nested ``shard_map`` never shadows a bound
   axis, and the per-axis collective *sequence* is structurally
   identical on every path through branching control flow (the executed
   counterpart of the schedule verifier's deadlock invariant).
3. **numerics-flow** — no silent precision demotion on reduction paths:
   sub-f32 floats must not be sum-reduced across the slow domain
   (``psum``/``psum_scatter`` over an inter axis) or folded (``add`` /
   ``reduce_sum``) straight off an inter-node exchange without an f32
   upcast; quantize transport kernels must be dominated by a measured
   scale computation (``abs``/``max``/``pmax`` ancestry); packed wire
   words must stay within the kernel's declared width.
4. **byte-accounting** — per-collective inter-node bytes are recomputed
   from jaxpr shapes x replica groups (node-major chip enumeration,
   exactly :func:`repro.core.collectives._chip_index`'s layout) and
   compared against the schedule verifier's declared bound, closing the
   proof chain *schedule -> jaxpr -> HLO*.

Plus **alias-donation**: a transport ``pallas_call`` whose name declares
a donated operand (``...__donate<i>``, see
:mod:`repro.kernels.transport`) must never have that operand read again
after the call.

Entry points: :func:`lint_jaxpr` over a ``jax.make_jaxpr`` result, or
the :func:`lint_traced` convenience that traces for you.  Engine-level
integration lives in :func:`repro.core.comm.lint_lowering` (run at
registration for every engine — including the natives that opt out of
schedule verification, which have no schedule to verify but very much
have a jaxpr to lint).

This module imports ``jax`` only inside functions (package rule: the
registry calls *into* the analyzers, and ``__main__`` must set
``XLA_FLAGS`` before anything pulls in jax).
"""

from __future__ import annotations

import dataclasses
import re

import numpy as np

__all__ = [
    "SPMD_RULES",
    "COLLECTIVE_PRIMS",
    "SpmdViolation",
    "SpmdLintReport",
    "lint_jaxpr",
    "lint_traced",
    "assert_spmd_clean",
]

#: the four rule families (+ the donation rule) with one-line contracts
SPMD_RULES = {
    "collective-uniformity": (
        "no collective under a rank-varying cond/while predicate"
    ),
    "axis-discipline": (
        "collective axes resolve, are never shadowed, and the collective "
        "sequence is identical on every control-flow path"
    ),
    "numerics-flow": (
        "no sub-f32 accumulation across the slow domain; quantize scales "
        "are measured; wire words stay within declared width"
    ),
    "byte-accounting": (
        "jaxpr-recomputed inter-node bytes equal the declared bound"
    ),
    "alias-donation": (
        "a donated pallas operand is never read after the call"
    ),
}

#: jaxpr primitives that move data between devices
COLLECTIVE_PRIMS = frozenset(
    {"psum", "pmax", "pmin", "ppermute", "all_to_all", "all_gather",
     "reduce_scatter"}
)

# sum-semantics reductions (pmax/pmin lose nothing to low precision)
_SUM_REDUCING = frozenset({"psum", "reduce_scatter"})
# local sum-fold primitives the f32-accumulation rule watches
_FOLD_PRIMS = frozenset({"add", "add_any", "reduce_sum"})
# primitives that seed scale provenance (max-abs scale computations)
_SCALE_SEEDS = frozenset(
    {"abs", "max", "min", "reduce_max", "reduce_min", "pmax", "pmin"}
)
#: pallas transport kernel name prefixes (repro.kernels.transport)
_TRANSPORT_PREFIXES = ("quantize_pack", "unpack_dequantize")
_DONATE_RE = re.compile(r"__donate(\d+)")
_BITS_RE = re.compile(r"^(?:quantize_pack|unpack_dequantize)_(\d+)b")

_REL_TOL = 1e-6  # byte-accounting comparison tolerance (relative)


@dataclasses.dataclass(frozen=True)
class SpmdViolation:
    """One SPMD lint rule violation."""

    rule: str
    message: str

    def to_row(self) -> dict:
        return {"rule": self.rule, "message": self.message}


@dataclasses.dataclass
class SpmdLintReport:
    """Result of linting one traced program."""

    label: str
    violations: list = dataclasses.field(default_factory=list)
    collectives: int = 0
    internode_bytes_per_chip: float | None = None
    declared_bytes: tuple | None = None
    notes: list = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_row(self) -> dict:
        return {
            "label": self.label,
            "ok": self.ok,
            "collectives": self.collectives,
            "internode_bytes_per_chip": self.internode_bytes_per_chip,
            "declared_bytes": (
                list(self.declared_bytes)
                if self.declared_bytes is not None
                else None
            ),
            "notes": list(self.notes),
            "violations": [v.to_row() for v in self.violations],
        }


# ---------------------------------------------------------------------------
# per-value dataflow state
# ---------------------------------------------------------------------------


class _St:
    """Lattice state of one jaxpr value.

    var:   axis names the value may vary over (rank variance).
    scale: has max-abs/reduce ancestry (quantize scale provenance).
    wire:  packed wire bytes produced by a quantize transport kernel.
    net:   crossed the slow domain without re-accumulation (f32 upcast
           or an actual reduction clears it).
    """

    __slots__ = ("var", "scale", "wire", "net")

    def __init__(self, var=frozenset(), scale=False, wire=False, net=False):
        self.var = frozenset(var)
        self.scale = bool(scale)
        self.wire = bool(wire)
        self.net = bool(net)

    def join(self, other: "_St") -> "_St":
        return _St(
            self.var | other.var,
            self.scale or other.scale,
            self.wire or other.wire,
            self.net or other.net,
        )

    def __eq__(self, other):
        return (
            isinstance(other, _St)
            and self.var == other.var
            and self.scale == other.scale
            and self.wire == other.wire
            and self.net == other.net
        )

    def __hash__(self):
        return hash((self.var, self.scale, self.wire, self.net))


_BOTTOM = _St()


def _join_all(states) -> _St:
    out = _BOTTOM
    for s in states:
        out = out.join(s)
    return out


def _axes_of(params) -> tuple[str, ...]:
    """Named axes of a collective eqn (positional int axes ignored)."""
    raw = params.get("axes", params.get("axis_name", ()))
    if isinstance(raw, str):
        raw = (raw,)
    return tuple(a for a in raw if isinstance(a, str))


def _pallas_name(params) -> str:
    """The user-visible kernel name of a ``pallas_call`` eqn."""
    info = params.get("name_and_src_info")
    if info is None:
        return str(params.get("name", ""))
    text = str(info)
    # "myname for kernel function _k at /p.py:1" or "_k at /p.py:1"
    return text.split(" for ")[0].split(" at ")[0].strip()


def _is_transport(name: str) -> bool:
    return name.startswith(_TRANSPORT_PREFIXES)


def _sub_f32_float(dtype) -> bool:
    """A float dtype narrower than float32 (accumulation hazard)."""
    name = str(dtype)
    return name in ("bfloat16", "float16") or name.startswith("float8")


def _wide_int(dtype) -> bool:
    name = str(dtype)
    return name in ("int16", "uint16", "int32", "uint32", "int64", "uint64")


def _aval_bytes(atom) -> float:
    aval = atom.aval
    elems = 1
    for d in aval.shape:
        elems *= int(d)
    return float(elems) * np.dtype(aval.dtype).itemsize


# ---------------------------------------------------------------------------
# collective signature (branch-symmetry rule)
# ---------------------------------------------------------------------------


def _signature(jaxpr) -> tuple:
    """Structural collective sequence of an (open) jaxpr."""
    out = []
    for eqn in jaxpr.eqns:
        p = eqn.primitive.name
        if p in COLLECTIVE_PRIMS:
            out.append((p, tuple(sorted(_axes_of(eqn.params)))))
        elif p == "cond":
            out.append(
                ("cond",)
                + tuple(_signature(b.jaxpr) for b in eqn.params["branches"])
            )
        elif p == "while":
            out.append(
                (
                    "while",
                    _signature(eqn.params["cond_jaxpr"].jaxpr),
                    _signature(eqn.params["body_jaxpr"].jaxpr),
                )
            )
        elif p == "scan":
            out.append(
                (
                    "scan",
                    int(eqn.params["length"]),
                    _signature(eqn.params["jaxpr"].jaxpr),
                )
            )
        elif p in ("pjit", "closed_call", "custom_jvp_call",
                   "custom_vjp_call", "remat", "checkpoint"):
            sub = eqn.params.get("jaxpr", eqn.params.get("call_jaxpr"))
            if sub is not None:
                out.extend(_signature(getattr(sub, "jaxpr", sub)))
        elif p == "shard_map":
            out.append(("shard_map", _signature(eqn.params["jaxpr"])))
        elif p == "pallas_call":
            name = _pallas_name(eqn.params)
            if _is_transport(name):
                out.append(("pallas", name))
    return tuple(out)


# ---------------------------------------------------------------------------
# the analyzer
# ---------------------------------------------------------------------------


class _Analyzer:
    def __init__(self, axis_sizes, inter_axes, intra_axes, declared, *,
                 bind_root=True):
        # ``sizes`` is the topology universe (byte accounting); ``bound``
        # is the axes *in scope* at the current program point (shadowing
        # and axis resolution).  They differ for a mesh-level program
        # traced without an axis env: the topology axes exist, but only
        # the program's own shard_map brings them into scope.
        self.sizes = dict(axis_sizes)
        self.bound = dict(axis_sizes) if bind_root else {}
        self.inter = frozenset(inter_axes)
        self.intra = frozenset(intra_axes)
        self.violations: list[SpmdViolation] = []
        self.notes: list[str] = []
        self.collectives = 0
        self.declared = declared
        # byte accounting: node-major chip universe over the topology
        # axes (inter-major), matching collectives._chip_index
        self.track_bytes = bool(inter_axes)
        self.bytes_unknown = False
        order = tuple(inter_axes) + tuple(intra_axes)
        if self.track_bytes and not all(a in self.sizes for a in order):
            self.track_bytes = False
            self.notes.append("topology axes unbound: bytes not tracked")
        self.axis_order = order
        if self.track_bytes:
            sizes = [self.sizes[a] for a in order]
            self.n_chips = int(np.prod(sizes)) if sizes else 1
            self.ppn = int(
                np.prod([self.sizes[a] for a in intra_axes])
            ) if intra_axes else 1
            coords = []
            for c in range(self.n_chips):
                rem, cc = c, {}
                for a in reversed(order):
                    cc[a] = rem % self.sizes[a]
                    rem //= self.sizes[a]
                coords.append(cc)
            self.coords = coords
            self.sends = np.zeros(self.n_chips, dtype=np.float64)

    # -- violation helpers -------------------------------------------------

    def _flag(self, rule: str, message: str) -> None:
        self.violations.append(SpmdViolation(rule, message))

    # -- byte accounting ---------------------------------------------------

    def _account(self, prim, axes, eqn, mult) -> None:
        if not self.track_bytes:
            return
        if not set(axes) & self.inter:
            return  # intra-node traffic is free at this accounting layer
        b = sum(_aval_bytes(a) for a in eqn.invars if hasattr(a, "aval"))
        if b == 0.0:
            return
        if mult is None:
            self.bytes_unknown = True
            self.notes.append(
                f"{prim} inside a while body: inter-node bytes unbounded"
            )
            return
        if not all(a in self.axis_order for a in axes) or eqn.params.get(
            "axis_index_groups"
        ):
            self.bytes_unknown = True
            self.notes.append(
                f"{prim} over non-topology axes/index groups: not modeled"
            )
            return
        # build groups: chips agreeing on every non-collective axis
        others = [a for a in self.axis_order if a not in axes]
        groups: dict[tuple, list] = {}
        for c in range(self.n_chips):
            cc = self.coords[c]
            key = tuple(cc[o] for o in others)
            m = 0
            for a in axes:
                m = m * self.sizes[a] + cc[a]
            groups.setdefault(key, []).append((m, c))
        node = lambda c: c // self.ppn  # noqa: E731
        perm = eqn.params.get("perm", ())
        for members in groups.values():
            members.sort()
            mem = [c for _, c in members]
            g = len(mem)
            if prim == "ppermute":
                for (s, d) in perm:
                    if s != d and node(mem[s]) != node(mem[d]):
                        self.sends[mem[s]] += b * mult
                continue
            for i, c in enumerate(mem):
                cross = sum(
                    1 for j, c2 in enumerate(mem)
                    if j != i and node(c2) != node(c)
                )
                if prim in ("psum", "pmax", "pmin"):
                    self.sends[c] += 2.0 * b / g * cross * mult
                elif prim in ("reduce_scatter", "all_to_all"):
                    self.sends[c] += b / g * cross * mult
                elif prim == "all_gather":
                    self.sends[c] += b * cross * mult

    # -- walker ------------------------------------------------------------

    def run(self, closed, in_states):
        consts = {
            v: _BOTTOM for v in closed.jaxpr.constvars
        }
        env = dict(consts)
        for v, s in zip(closed.jaxpr.invars, in_states):
            env[v] = s
        return self._walk(
            closed.jaxpr, env, ctx=frozenset(), mult=1, record=True
        )

    def _state(self, env, atom) -> _St:
        if hasattr(atom, "val"):  # Literal
            return _BOTTOM
        return env.get(atom, _BOTTOM)

    def _walk(self, jaxpr, env, ctx, mult, record):
        for eqn in jaxpr.eqns:
            p = eqn.primitive.name
            ins = [self._state(env, a) for a in eqn.invars]
            if p in COLLECTIVE_PRIMS:
                outs = self._collective(eqn, p, ins, ctx, mult, record)
            elif p == "axis_index":
                ax = eqn.params.get("axis_name")
                outs = [_St(var={ax} if isinstance(ax, str) else set())]
            elif p == "cond":
                outs = self._cond(eqn, ins, ctx, mult, record)
            elif p == "while":
                outs = self._while(eqn, ins, ctx, mult, record)
            elif p == "scan":
                outs = self._scan(eqn, ins, ctx, mult, record)
            elif p in ("pjit", "closed_call", "core_call", "remat",
                       "checkpoint", "custom_jvp_call", "custom_vjp_call"):
                outs = self._call(eqn, ins, ctx, mult, record)
            elif p == "shard_map":
                outs = self._shard_map(eqn, ins, ctx, mult, record)
            elif p == "pallas_call":
                outs = self._pallas(eqn, jaxpr, ins, ctx, record)
            elif p == "convert_element_type":
                j = _join_all(ins)
                # an f32/f64 upcast legalizes downstream accumulation
                wide = str(eqn.params.get("new_dtype")) in (
                    "float32", "float64"
                )
                outs = [_St(j.var, j.scale, j.wire, j.net and not wide)]
            else:
                j = _join_all(ins)
                if p in _FOLD_PRIMS and record:
                    self._check_fold(eqn, p, ins, j)
                if p in _SCALE_SEEDS:
                    j = _St(j.var, True, j.wire, j.net)
                outs = [j] * len(eqn.outvars)
            if len(outs) != len(eqn.outvars):
                outs = [_join_all(outs)] * len(eqn.outvars)
            for v, s in zip(eqn.outvars, outs):
                env[v] = s
        return [self._state(env, a) for a in jaxpr.outvars]

    # -- rule checks at specific primitives --------------------------------

    def _check_fold(self, eqn, p, ins, joined):
        out_dtype = eqn.outvars[0].aval.dtype
        if any(s.net for s in ins) and _sub_f32_float(out_dtype):
            self._flag(
                "numerics-flow",
                f"{p} folds an inter-node exchanged value in "
                f"{out_dtype} without an f32 upcast (accumulation must "
                "be float32 across the slow domain)",
            )

    def _collective(self, eqn, p, ins, ctx, mult, record):
        axes = _axes_of(eqn.params)
        if record:
            self.collectives += 1
            unknown = [a for a in axes if a not in self.bound]
            if unknown:
                self._flag(
                    "axis-discipline",
                    f"{p} names unbound axes {unknown}; declared axes: "
                    f"{sorted(self.bound)}",
                )
            hang = set(axes) & ctx
            if hang:
                self._flag(
                    "collective-uniformity",
                    f"{p} over {axes} sits under a predicate that varies "
                    f"over {sorted(hang)}: group members may disagree on "
                    "reaching it (static hang)",
                )
            if p in _SUM_REDUCING and set(axes) & self.inter:
                for a in eqn.invars:
                    if hasattr(a, "aval") and _sub_f32_float(a.aval.dtype):
                        self._flag(
                            "numerics-flow",
                            f"{p} over inter axes {axes} sum-reduces a "
                            f"{a.aval.dtype} payload: cross-node "
                            "accumulation must be float32",
                        )
            for a, s in zip(eqn.invars, ins):
                if s.wire and hasattr(a, "aval") and _wide_int(a.aval.dtype):
                    self._flag(
                        "numerics-flow",
                        f"{p} moves a {a.aval.dtype} value carrying packed "
                        "wire words: exceeds the declared wire width",
                    )
            self._account(p, axes, eqn, mult)
        j = _join_all(ins)
        crosses = bool(set(axes) & self.inter)
        if p in ("psum", "pmax", "pmin"):
            # reduced over axes: uniform there, and the reduction itself
            # re-accumulated whatever crossed the wire
            out = _St(j.var - set(axes), j.scale, j.wire, False)
        elif p == "all_gather":
            # gathered: uniform over axes, but copies crossed un-reduced
            out = _St(j.var - set(axes), j.scale, j.wire, j.net or crosses)
        else:  # ppermute, all_to_all, reduce_scatter: position-dependent
            net = False if p == "reduce_scatter" else (j.net or crosses)
            out = _St(j.var | set(axes), j.scale, j.wire, net)
        return [out] * len(eqn.outvars)

    def _cond(self, eqn, ins, ctx, mult, record):
        branches = eqn.params["branches"]
        pred = ins[0]
        if record and len(branches) > 1:
            sigs = {_signature(b.jaxpr) for b in branches}
            if len(sigs) > 1:
                self._flag(
                    "axis-discipline",
                    "cond branches execute different collective "
                    "sequences: "
                    + " vs ".join(str(s) for s in sorted(sigs)),
                )
        sub_ctx = ctx | pred.var
        outs = None
        for b in branches:
            env = {v: _BOTTOM for v in b.jaxpr.constvars}
            for v, s in zip(b.jaxpr.invars, ins[1:]):
                env[v] = s
            res = self._walk(b.jaxpr, env, sub_ctx, mult, record)
            outs = res if outs is None else [
                a.join(bb) for a, bb in zip(outs, res)
            ]
        # branch outputs data-depend on the predicate
        return [_St(s.var | pred.var, s.scale, s.wire, s.net) for s in outs]

    def _run_closed(self, closed, in_states, ctx, mult, record):
        env = {v: _BOTTOM for v in closed.jaxpr.constvars}
        for v, s in zip(closed.jaxpr.invars, in_states):
            env[v] = s
        return self._walk(closed.jaxpr, env, ctx, mult, record)

    def _while(self, eqn, ins, ctx, mult, record):
        P = eqn.params
        cj, bj = P["cond_jaxpr"], P["body_jaxpr"]
        nc, nb = P["cond_nconsts"], P["body_nconsts"]
        cconsts, bconsts = ins[:nc], ins[nc:nc + nb]
        carry = list(ins[nc + nb:])
        for _ in range(len(self.bound) + 3):
            pred = self._run_closed(
                cj, cconsts + carry, ctx, mult, False
            )[0]
            new = self._run_closed(
                bj, bconsts + carry, ctx | pred.var, mult, False
            )
            nxt = [a.join(b) for a, b in zip(carry, new)]
            if nxt == carry:
                break
            carry = nxt
        pred = self._run_closed(cj, cconsts + carry, ctx, mult, False)[0]
        if record:
            self._run_closed(cj, cconsts + carry, ctx | pred.var, None, True)
            self._run_closed(
                bj, bconsts + carry, ctx | pred.var, None, True
            )
        return [
            _St(s.var | pred.var, s.scale, s.wire, s.net) for s in carry
        ]

    def _scan(self, eqn, ins, ctx, mult, record):
        P = eqn.params
        closed = P["jaxpr"]
        nc, ncarry = P["num_consts"], P["num_carry"]
        length = int(P["length"])
        consts = ins[:nc]
        carry = list(ins[nc:nc + ncarry])
        xs = ins[nc + ncarry:]
        ys = None
        for _ in range(len(self.bound) + 3):
            res = self._run_closed(
                closed, consts + carry + xs, ctx, mult, False
            )
            new_carry = [
                a.join(b) for a, b in zip(carry, res[:ncarry])
            ]
            ys = res[ncarry:]
            if new_carry == carry:
                break
            carry = new_carry
        if record:
            m = None if mult is None else mult * length
            self._run_closed(closed, consts + carry + xs, ctx, m, True)
        return carry + list(ys)

    def _call(self, eqn, ins, ctx, mult, record):
        sub = eqn.params.get("jaxpr", eqn.params.get("call_jaxpr"))
        if sub is None:
            j = _join_all(ins)
            return [j] * len(eqn.outvars)
        if hasattr(sub, "consts"):  # ClosedJaxpr
            return self._run_closed(sub, ins, ctx, mult, record)
        env = {v: _BOTTOM for v in getattr(sub, "constvars", ())}
        for v, s in zip(sub.invars, ins):
            env[v] = s
        return self._walk(sub, env, ctx, mult, record)

    def _shard_map(self, eqn, ins, ctx, mult, record):
        mesh = eqn.params.get("mesh")
        try:
            sizes = dict(mesh.shape)
        except Exception:
            sizes = {}
        if record:
            shadow = sorted(set(sizes) & set(self.bound))
            if shadow:
                self._flag(
                    "axis-discipline",
                    f"shard_map re-binds already-bound axes {shadow} "
                    "(axis shadowing)",
                )
        saved = dict(self.bound)
        self.bound.update(sizes)
        sub = eqn.params["jaxpr"]  # open jaxpr
        in_names = eqn.params.get("in_names", ())

        def _split_axes(entry):
            found = set()
            stack = [entry]
            while stack:
                e = stack.pop()
                if isinstance(e, str):
                    found.add(e)
                elif isinstance(e, dict):
                    stack.extend(e.values())
                elif isinstance(e, (tuple, list, frozenset, set)):
                    stack.extend(e)
            return found

        env = {v: _BOTTOM for v in getattr(sub, "constvars", ())}
        for i, (v, s) in enumerate(zip(sub.invars, ins)):
            split = (
                _split_axes(in_names[i]) if i < len(in_names) else set(sizes)
            )
            env[v] = _St(s.var | split, s.scale, s.wire, s.net)
        try:
            outs = self._walk(sub, env, ctx, mult, record)
        finally:
            self.bound = saved
        return outs

    def _pallas(self, eqn, parent, ins, ctx, record):
        name = _pallas_name(eqn.params)
        transport = _is_transport(name)
        j = _join_all(ins)
        if transport and record:
            if ctx:
                self._flag(
                    "collective-uniformity",
                    f"transport kernel {name!r} sits under a predicate "
                    f"that varies over {sorted(ctx)}: its paired "
                    "collective cannot be reached uniformly",
                )
            m = _BITS_RE.match(name)
            if name.startswith("quantize_pack"):
                # scale provenance: operand 1 is the (1, L) scale vector
                if len(ins) > 1 and not ins[1].scale:
                    self._flag(
                        "numerics-flow",
                        f"quantize kernel {name!r}: scales operand has "
                        "no max-abs ancestry (undominated scale)",
                    )
                out_dtype = eqn.outvars[0].aval.dtype
                if m is not None:
                    bits = int(m.group(1))
                    want = "uint8" if bits == 4 else "int8"
                    if str(out_dtype) != want:
                        self._flag(
                            "numerics-flow",
                            f"{name!r} emits {out_dtype} wire words; "
                            f"{bits}-bit transport declares {want}",
                        )
                elif np.dtype(out_dtype).itemsize != 1:
                    self._flag(
                        "numerics-flow",
                        f"{name!r} emits {out_dtype} wire words "
                        "(wider than one byte)",
                    )
            # donation: the declared operand must be dead after the call
            for d in _DONATE_RE.findall(name):
                idx = int(d)
                if idx >= len(eqn.invars):
                    continue
                donated = eqn.invars[idx]
                if hasattr(donated, "val"):
                    continue  # literal
                self._check_dead_after(parent, eqn, donated, name)
        if transport and name.startswith("quantize_pack"):
            out = _St(j.var, False, True, False)
        elif transport:
            out = _St(j.var, j.scale, False, False)
        else:
            out = j
        return [out] * len(eqn.outvars)

    def _check_dead_after(self, jaxpr, call_eqn, var, name):
        seen = False
        for eqn in jaxpr.eqns:
            if eqn is call_eqn:
                seen = True
                continue
            if seen and any(a is var for a in eqn.invars):
                self._flag(
                    "alias-donation",
                    f"{name!r} declares operand donation but the donated "
                    f"buffer is read again by {eqn.primitive.name} after "
                    "the call (alias hazard)",
                )
                return
        if any(a is var for a in jaxpr.outvars):
            self._flag(
                "alias-donation",
                f"{name!r} declares operand donation but the donated "
                "buffer is returned as an output (alias hazard)",
            )


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------


def lint_jaxpr(
    closed,
    *,
    axis_sizes: dict,
    inter_axes=(),
    intra_axes=(),
    declared_internode_bytes=None,
    label: str = "",
    axes_bound_at_root: bool = True,
) -> SpmdLintReport:
    """Lint one ``ClosedJaxpr`` (a ``jax.make_jaxpr`` result).

    ``axis_sizes`` declares the topology axes and their sizes;
    ``inter_axes`` names the slow domain for the numerics and byte
    rules.  ``declared_internode_bytes`` — a float or ``(lo, hi)``
    range of per-chip inter-node bytes — switches byte accounting on;
    the recomputed maximum must land inside it.

    ``axes_bound_at_root`` says whether the jaxpr was traced *under*
    that axis environment (``jax.make_jaxpr(..., axis_env=...)`` — the
    per-shard view, inputs vary over every axis, a nested ``shard_map``
    over the same names is shadowing) or is a mesh-level program whose
    own inner ``shard_map`` brings the axes into scope for the first
    time (pass ``False``: inputs start uniform — they are host-level
    values — and the first binding is not shadowing).
    """
    inter = (inter_axes,) if isinstance(inter_axes, str) else tuple(inter_axes)
    intra = (intra_axes,) if isinstance(intra_axes, str) else tuple(intra_axes)
    declared = declared_internode_bytes
    if declared is not None and not isinstance(declared, (tuple, list)):
        declared = (float(declared), float(declared))
    a = _Analyzer(axis_sizes, inter, intra, declared,
                  bind_root=axes_bound_at_root)
    jaxpr = closed.jaxpr
    in_states = [
        _St(var=set(axis_sizes) if axes_bound_at_root else frozenset())
        for _ in jaxpr.invars
    ]  # per-shard trace: device data varies over every declared axis;
    #    mesh-level trace: inputs are host values, uniform until sharded
    a.run(closed, in_states)
    report = SpmdLintReport(label=label or "jaxpr")
    report.violations = a.violations
    report.notes = a.notes
    report.collectives = a.collectives
    if a.track_bytes and not a.bytes_unknown:
        got = float(a.sends.max(initial=0.0))
        report.internode_bytes_per_chip = got
        if declared is not None:
            report.declared_bytes = declared
            lo, hi = declared
            tol = _REL_TOL * max(1.0, hi)
            if not (lo - tol <= got <= hi + tol):
                report.violations.append(
                    SpmdViolation(
                        "byte-accounting",
                        f"jaxpr-recomputed inter-node bytes/chip "
                        f"{got:.1f} outside the declared bound "
                        f"[{lo:.1f}, {hi:.1f}]",
                    )
                )
    return report


def lint_traced(
    fn,
    *example_args,
    axis_env=(),
    inter_axes=(),
    intra_axes=(),
    declared_internode_bytes=None,
    label: str = "",
) -> SpmdLintReport:
    """Trace ``fn`` under ``axis_env`` (``[(name, size), ...]``) and lint.

    ``example_args`` may be arrays or ``jax.ShapeDtypeStruct``s — the
    trace is abstract either way.  This is the convenience the tests and
    the ``--spmd`` sweep use; :func:`repro.core.comm.lint_lowering`
    wraps it per registered engine with the schedule-declared byte
    bound filled in.
    """
    import jax

    axis_env = list(axis_env)
    closed = jax.make_jaxpr(fn, axis_env=axis_env or None)(*example_args)
    return lint_jaxpr(
        closed,
        axis_sizes=dict(axis_env),
        inter_axes=inter_axes,
        intra_axes=intra_axes,
        declared_internode_bytes=declared_internode_bytes,
        label=label,
    )


def assert_spmd_clean(report: SpmdLintReport) -> None:
    """Raise ``AssertionError`` listing every violation (test helper)."""
    if not report.ok:
        raise AssertionError(
            f"{report.label}: {len(report.violations)} SPMD lint "
            "violation(s):\n"
            + "\n".join(
                f"  [{v.rule}] {v.message}" for v in report.violations
            )
        )
