"""Static analysis for the collective engine tournament.

Four layers prove the serving stack correct *before* it races — a
proof chain from the host protocol down to the compiled module:

0. **Protocol model check** (:mod:`repro.analysis.protocol_check`) —
   explicit-state bounded exhaustive exploration of the serving
   control plane: the **real** ``Scheduler``/``Router``/
   ``ReplicaHealth`` objects driven through every interleaving of
   submit/admit/token/EOS/evict/degrade/recover/reroute/replica-loss
   at small scope, with canonical-state dedup and request-id symmetry
   reduction.  Safety (conservation, single ownership, slot
   accounting, FIFO under reroute, binding acceptance, silence after
   terminal states, hysteresis boundaries) plus quiescence-style
   liveness at every reachable state; violations come out as minimal
   replayable event traces.  This proves the *protocol* that fires
   the collectives is right.
1. **Schedule verifier** (:mod:`repro.analysis.schedule_verifier`) —
   given any built ``NapSchedule``/``P2PSchedule``, statically proves
   match-completeness, deadlock-freedom, exactly-once reduction
   correctness and byte-accounting equality against the engine's
   declared inter-node bound.  This proves the *plan* is right.
2. **SPMD jaxpr lint** (:mod:`repro.analysis.spmd_lint`) — a dataflow
   analyzer over the traced program (the closed jaxpr, recursing
   through ``pjit``/``shard_map``/``scan``/``while``/``cond``) proving
   the *executed lowering matches the verified plan*: every collective
   is reached uniformly (no collective under a rank-varying predicate
   — the static form of a hang), axis discipline holds (axes resolve,
   no shadowing, branch-symmetric collective sequences), numerics flow
   is sound (sub-f32 payloads accumulate in f32 across the slow
   domain, quantization is scale-dominated, packed words fit the
   wire), byte accounting re-derived from the jaxpr equals the
   schedule-declared bound, and donated transport buffers are dead
   after the call.
3. **HLO wire-lint** (:mod:`repro.analysis.hlo_lint`) — rule-based
   linter over compiled-step HLO: wire-dtype rules for compressed
   transport (no ``f32``/wide-int payloads on a compressed wire),
   replica-group partition checks (no overlap, no gap), collective-
   count budgets, and a no-silent-recompile rule.  This proves what
   XLA actually emitted.

Layer 0 is tied to layer 2 by the decode-geometry link
(:func:`repro.analysis.protocol_check.verify_decode_geometry_link`):
the slot occupancies the protocol can reach are proved to be exactly
the ragged slot geometry the linted decode slice is swept at, so the
checked protocol and the linted lowering talk about the same shapes.

Layers 1 and 2 both run at engine registration (see
:func:`repro.core.comm.register_engine`): the schedule verifier for
``verify=True`` engines, the jaxpr lint for **every** engine — natives
included, since the lint needs only a trace, not a schedule.

Quickstart::

    from repro.core import comm
    from repro.analysis import verify_schedule, spmd_lint
    from repro.analysis import protocol_check as pc

    # layer 0: exhaustively check the serving control plane
    report = pc.check_protocol(pc.CheckConfig(replicas=2, slots=2,
                                              queue=1, requests=4))
    assert report.ok, report.violations[0].to_row()
    # a violation's trace replays as a pytest:
    #   pc.assert_trace_clean(cfg, trace)  /  pc.assert_trace_violates(...)

    # layer 1: verify one schedule directly
    sched = comm.engine_schedule("mla", n_nodes=5, ppn=4, elems=193)
    report = verify_schedule(sched, engine="mla", elems=193)
    assert report.ok, report.violations

    # layer 2a: lint a registered engine's traced lowering (what
    # register_engine does automatically under REPRO_VERIFY_ON_REGISTER)
    comm.lint_lowering("nap", n_nodes=3, ppn=2)

    # layer 2b: lint any traced function under an axis env
    rep = spmd_lint.lint_traced(
        my_step, example_arg,
        axis_env=[("pod", 2), ("data", 4)],
        inter_axes=("pod",), intra_axes=("data",),
    )
    spmd_lint.assert_spmd_clean(rep)

    # or sweep everything and emit the benchmark tables:
    #   PYTHONPATH=src python -m repro.analysis --json reports/BENCH_7.json
    #   PYTHONPATH=src python -m repro.analysis --spmd \\
    #       --json reports/BENCH_8.json
    #   PYTHONPATH=src python -m repro.analysis --protocol \\
    #       --json reports/BENCH_10.json

This package imports neither ``jax`` nor ``repro.core.comm`` at module
scope: the registry calls *into* the verifier on registration, and the
``__main__`` driver must be able to set ``XLA_FLAGS`` before anything
pulls in jax.  (:mod:`repro.analysis.spmd_lint` is likewise
jax-import-free at module scope — it walks jaxprs structurally.)
"""

from .schedule_verifier import (  # noqa: F401
    GRID_MATRIX,
    PAYLOAD_ELEMS,
    REGISTER_GRIDS,
    RULES,
    VerificationReport,
    Violation,
    build_spec_schedule,
    verify_schedule,
    verify_spec,
    verify_spec_grid,
)
from .hlo_lint import (  # noqa: F401
    LintViolation,
    collective_ops,
    lint_collective_counts,
    lint_compressed_wire,
    lint_replica_groups,
    lint_stable_lowering,
)
from .spmd_lint import (  # noqa: F401
    SPMD_RULES,
    SpmdLintReport,
    SpmdViolation,
    assert_spmd_clean,
    lint_jaxpr,
    lint_traced,
)
from .protocol_check import (  # noqa: F401
    CheckConfig,
    CheckReport,
    assert_trace_clean,
    assert_trace_violates,
    check_protocol,
    run_trace,
    verify_decode_geometry_link,
)

__all__ = [
    "CheckConfig",
    "CheckReport",
    "assert_trace_clean",
    "assert_trace_violates",
    "check_protocol",
    "run_trace",
    "verify_decode_geometry_link",
    "GRID_MATRIX",
    "PAYLOAD_ELEMS",
    "REGISTER_GRIDS",
    "RULES",
    "VerificationReport",
    "Violation",
    "build_spec_schedule",
    "verify_schedule",
    "verify_spec",
    "verify_spec_grid",
    "LintViolation",
    "collective_ops",
    "lint_collective_counts",
    "lint_compressed_wire",
    "lint_replica_groups",
    "lint_stable_lowering",
    "SPMD_RULES",
    "SpmdLintReport",
    "SpmdViolation",
    "assert_spmd_clean",
    "lint_jaxpr",
    "lint_traced",
]
