"""Static analysis for the collective engine tournament.

Two passes prove a registered engine correct *before* it races:

1. **Schedule verifier** (:mod:`repro.analysis.schedule_verifier`) —
   given any built ``NapSchedule``/``P2PSchedule``, statically proves
   match-completeness, deadlock-freedom, exactly-once reduction
   correctness and byte-accounting equality against the engine's
   declared inter-node bound.
2. **HLO wire-lint** (:mod:`repro.analysis.hlo_lint`) — rule-based
   linter over compiled-step HLO: wire-dtype rules for compressed
   transport (no ``f32``/wide-int payloads on a compressed wire),
   collective-count budgets, and a no-silent-recompile rule.

Quickstart::

    from repro.core import comm
    from repro.analysis import verify_schedule

    # verify one schedule directly
    sched = comm.engine_schedule("mla", n_nodes=5, ppn=4, elems=193)
    report = verify_schedule(sched, engine="mla", elems=193)
    assert report.ok, report.violations

    # or verify a registered engine over its grid (what
    # register_engine does automatically under REPRO_VERIFY_ON_REGISTER)
    comm.verify_engine("mla", n_nodes=5, ppn=4, elems=193)

    # or sweep everything and emit the BENCH_7 verification table:
    #   PYTHONPATH=src python -m repro.analysis --json reports/BENCH_7.json

This package imports neither ``jax`` nor ``repro.core.comm`` at module
scope: the registry calls *into* the verifier on registration, and the
``__main__`` driver must be able to set ``XLA_FLAGS`` before anything
pulls in jax.
"""

from .schedule_verifier import (  # noqa: F401
    GRID_MATRIX,
    PAYLOAD_ELEMS,
    REGISTER_GRIDS,
    RULES,
    VerificationReport,
    Violation,
    build_spec_schedule,
    verify_schedule,
    verify_spec,
    verify_spec_grid,
)
from .hlo_lint import (  # noqa: F401
    LintViolation,
    collective_ops,
    lint_collective_counts,
    lint_compressed_wire,
    lint_stable_lowering,
)

__all__ = [
    "GRID_MATRIX",
    "PAYLOAD_ELEMS",
    "REGISTER_GRIDS",
    "RULES",
    "VerificationReport",
    "Violation",
    "build_spec_schedule",
    "verify_schedule",
    "verify_spec",
    "verify_spec_grid",
    "LintViolation",
    "collective_ops",
    "lint_collective_counts",
    "lint_compressed_wire",
    "lint_stable_lowering",
]
